"""Layer-2 JAX graphs for the Moses cost model.

Four AOT entry points, all over ONE flat f32[N_PARAMS] parameter vector
(layout in :mod:`kernels.ref`; mirrored by rust/src/costmodel/layout.rs):

* :func:`predict`    — score a batch of candidate programs (Pallas MLP
  forward; THE search-loop hot path).
* :func:`train_step` — one masked Adam step of the pairwise ranking loss.
  ``mask`` selects the transferable (domain-invariant) parameters: Moses
  passes the lottery-ticket mask, vanilla fine-tuning passes all-ones.
  Gradients flow through the pure-jnp forward (pallas_call is not
  differentiable); the parameter update itself is the Pallas
  ``masked_adam_update`` kernel.
* :func:`xi_scores`  — per-parameter saliency xi = |w * grad w| (paper
  Eq. 5); Rust ranks these to draw the transferable/variant boundary.
* :func:`loss_eval`  — held-out ranking loss for the AC module.

Batch geometry is fixed at lowering time (PRED_BATCH / TRAIN_BATCH);
Rust pads partial batches with zero-weight rows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import mlp, ref, update

PRED_BATCH = 512
# Small-batch predict variant for evolutionary-population scoring (one
# population = 64 candidates); see aot.entry_points.
PRED_BATCH_SMALL = 64
TRAIN_BATCH = 256


def predict(params, x):
    """Scores for x f32[PRED_BATCH, 164] via the Pallas fused MLP."""
    return mlp.mlp_forward(params, x)


def _rank_loss(params, x, y, w):
    scores = ref.mlp_forward(params, x)
    return ref.pairwise_rank_loss(scores, y, w)


_loss_and_grad = jax.value_and_grad(_rank_loss)


def train_step(params, m, v, x, y, w, mask, hp):
    """One Moses/vanilla training step.

    Args: params/m/v/mask f32[N_PARAMS], x f32[TRAIN_BATCH,164],
    y/w f32[TRAIN_BATCH], hp = [lr, wd, step, _reserved] f32[4].
    Returns (params', m', v', loss f32[1]).
    """
    loss, grads = _loss_and_grad(params, x, y, w)
    p_new, m_new, v_new = update.masked_adam_update(params, m, v, grads, mask, hp)
    return p_new, m_new, v_new, jnp.reshape(loss, (1,))


def xi_scores(params, x, y, w):
    """Saliency xi = |w * grad w| over the ranking loss (paper Eq. 5)."""
    grads = jax.grad(_rank_loss)(params, x, y, w)
    return jnp.abs(params * grads)


def loss_eval(params, x, y, w):
    """Held-out ranking loss, f32[1]."""
    return jnp.reshape(_rank_loss(params, x, y, w), (1,))
