"""AOT lowering: JAX entry points -> HLO *text* artifacts for Rust/PJRT.

HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Everything is lowered with ``return_tuple=True``; the Rust side unwraps
with ``to_tuple1``/``to_tuple4``.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


P = ref.N_PARAMS
NF = ref.N_FEATURES


def entry_points():
    """(name, fn, example specs) for every artifact.

    Two predict batch sizes: the evolutionary search scores populations
    of ~64 candidates per generation, so a dedicated 64-row executable
    avoids padding every query 8x to the 512-row dataset-scoring shape
    (measured ~7x faster per query — EXPERIMENTS.md §Perf).
    """
    vec = _spec(P)
    xb_pred = _spec(model.PRED_BATCH, NF)
    xb_pred_small = _spec(model.PRED_BATCH_SMALL, NF)
    xb_train = _spec(model.TRAIN_BATCH, NF)
    yb = _spec(model.TRAIN_BATCH)
    hp = _spec(4)
    return [
        ("predict", model.predict, (vec, xb_pred)),
        ("predict_small", model.predict, (vec, xb_pred_small)),
        ("train_step", model.train_step, (vec, vec, vec, xb_train, yb, yb, vec, hp)),
        ("xi", model.xi_scores, (vec, xb_train, yb, yb)),
        ("loss_eval", model.loss_eval, (vec, xb_train, yb, yb)),
    ]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifact dir")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    meta = {
        "n_params": P,
        "n_features": NF,
        "hidden": ref.HIDDEN,
        "pred_batch": model.PRED_BATCH,
        "pred_batch_small": model.PRED_BATCH_SMALL,
        "train_batch": model.TRAIN_BATCH,
        "adam": {"b1": ref.ADAM_B1, "b2": ref.ADAM_B2, "eps": ref.ADAM_EPS},
        "artifacts": {},
    }
    for name, fn, specs in entry_points():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        meta["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256_16": digest,
            "num_inputs": len(specs),
        }
        print(f"wrote {path}: {len(text)} chars sha={digest}")

    with open(os.path.join(args.out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'meta.json')}")


if __name__ == "__main__":
    main()
