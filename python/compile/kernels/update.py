"""Masked Adam + weight-decay update as a Pallas kernel (Moses Eq. 6/7).

This is the lottery-ticket update rule over the flat parameter vector:
transferable parameters (mask==1) take a bias-corrected Adam step on the
masked gradient; domain-variant parameters (mask==0) decay toward zero
(``w_v <- w_v - lr*wd*w_v``, paper Eq. 7).

TPU mapping: pure elementwise over f32[N_PARAMS]; the vector is padded to
a multiple of ``CHUNK`` and gridded so each step streams one VMEM-sized
chunk of (params, m, v, grads, mask) through the VPU.  Hyper-parameters
arrive as a tiny f32[4] vector ``hp = [lr, wd, step, _reserved]`` kept
VMEM-resident (constant index_map).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

CHUNK = 8192  # elements per grid step; 5 operands * 32 KiB each << VMEM.


def _update_kernel(p_ref, m_ref, v_ref, g_ref, mask_ref, hp_ref,
                   p_out, m_out, v_out):
    lr = hp_ref[0]
    wd = hp_ref[1]
    step = hp_ref[2]
    p = p_ref[...]
    mask = mask_ref[...]
    g = g_ref[...] * mask
    m_new = ref.ADAM_B1 * m_ref[...] + (1.0 - ref.ADAM_B1) * g
    v_new = ref.ADAM_B2 * v_ref[...] + (1.0 - ref.ADAM_B2) * (g * g)
    bc1 = 1.0 - ref.ADAM_B1**step
    bc2 = 1.0 - ref.ADAM_B2**step
    adam_step = lr * (m_new / bc1) / (jnp.sqrt(v_new / bc2) + ref.ADAM_EPS)
    p_out[...] = p - mask * adam_step - (1.0 - mask) * (lr * wd * p)
    m_out[...] = m_new
    v_out[...] = v_new


@functools.partial(jax.jit, static_argnames=("interpret",))
def masked_adam_update(params, m, v, grads, mask, hp, interpret=True):
    """Pallas Moses update.

    All vector args are f32[N_PARAMS]; ``hp = [lr, wd, step, _]`` (f32[4]).
    Returns (params', m', v').
    """
    n = params.shape[0]
    pad = (-n) % CHUNK
    padded = n + pad

    def pad1(a):
        return jnp.pad(a, (0, pad))

    grid = (padded // CHUNK,)
    chunk_spec = pl.BlockSpec((CHUNK,), lambda i: (i,))
    p_new, m_new, v_new = pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[chunk_spec] * 5 + [pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=[chunk_spec] * 3,
        out_shape=[jax.ShapeDtypeStruct((padded,), jnp.float32)] * 3,
        interpret=interpret,
    )(pad1(params), pad1(m), pad1(v), pad1(grads), pad1(mask), hp)
    return p_new[:n], m_new[:n], v_new[:n]
