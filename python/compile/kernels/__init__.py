"""Layer-1 Pallas kernels for the Moses cost model.

Two kernels:
  * :mod:`mlp` — fused 3-layer MLP forward (the prediction hot path).
  * :mod:`update` — masked Adam + weight-decay parameter update
    (the Moses lottery-ticket update rule, Eq. 6/7 of the paper).

Both are lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls) and are verified against the pure-jnp oracles in
:mod:`ref` by the pytest suite.
"""

from . import mlp, ref, update  # noqa: F401
