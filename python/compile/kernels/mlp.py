"""Fused 3-layer MLP forward as a Pallas kernel — the prediction hot path.

The auto-tuner scores millions of candidate tensor programs per session,
so the cost-model forward dominates Layer-1 compute.  TPU mapping (see
DESIGN.md §Hardware-Adaptation — the paper targets CUDA GPUs; we rethink
for the MXU instead of porting threadblocks):

* the batch is tiled at ``TILE_B = 128`` rows per grid step (the MXU
  systolic dimension), expressed with a ``BlockSpec`` over the batch axis
  so Pallas pipelines the HBM->VMEM streaming of ``x`` tiles;
* ALL weights stay resident in VMEM across grid steps (their index_map is
  constant, so the pipeline loads them once): ~348k f32 = 1.39 MB, far
  under the ~16 MB VMEM budget.  The whole forward therefore runs on-chip
  with no inter-layer HBM round-trips — the TPU analogue of the
  persistent-weights trick the CUDA era used for small MLPs;
* matmul accumulation is forced to f32 via ``preferred_element_type`` so
  an eventual bf16 weight variant keeps MXU-friendly accumulation.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO and the BlockSpec
structure is what carries the TPU scheduling intent (analysed statically
in DESIGN.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE_B = 128  # MXU systolic dim; batch tile per grid step.


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    """One batch tile: x[TILE_B,164] -> scores[TILE_B], all three layers
    computed from VMEM-resident weights."""
    x = x_ref[...]
    h1 = jnp.maximum(
        jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...],
        0.0,
    )
    h2 = jnp.maximum(
        jnp.dot(h1, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...],
        0.0,
    )
    out = jnp.dot(h2, w3_ref[...], preferred_element_type=jnp.float32) + b3_ref[...]
    o_ref[...] = out[:, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def mlp_forward(params, x, interpret=True):
    """Pallas MLP forward: params f32[N_PARAMS], x f32[B,164] -> f32[B].

    ``B`` must be a multiple of ``TILE_B`` (the AOT entry points use
    B=512; Rust pads partial batches and slices the scores).
    """
    batch, nf = x.shape
    assert nf == ref.N_FEATURES, x.shape
    # Tile at the MXU dim when the batch allows it; small-batch variants
    # (e.g. the 64-row evolutionary-population entry point) use the whole
    # batch as a single tile.
    tile_b = min(TILE_B, batch)
    assert batch % tile_b == 0, f"batch {batch} not a multiple of {tile_b}"
    w1, b1, w2, b2, w3, b3 = ref.unflatten(params)

    grid = (batch // tile_b,)
    # Weights use a constant index_map: Pallas keeps them VMEM-resident
    # across grid steps instead of re-streaming per tile.
    resident = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, ref.N_FEATURES), lambda i: (i, 0)),
            resident((ref.N_FEATURES, ref.HIDDEN)),
            resident((ref.HIDDEN,)),
            resident((ref.HIDDEN, ref.HIDDEN)),
            resident((ref.HIDDEN,)),
            resident((ref.HIDDEN, 1)),
            resident((1,)),
        ],
        out_specs=pl.BlockSpec((tile_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((batch,), jnp.float32),
        interpret=interpret,
    )(x, w1, b1, w2, b2, w3, b3)
