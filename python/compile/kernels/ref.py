"""Pure-jnp oracles for the Pallas kernels.

These define the *semantics*; the Pallas kernels in :mod:`mlp` and
:mod:`update` must match them under ``interpret=True``.  The pytest suite
sweeps shapes and seeds with hypothesis and asserts ``assert_allclose``
at tight tolerances.
"""

from __future__ import annotations

import jax.numpy as jnp

# Cost-model geometry (Ansor's representative backbone, paper §4.2):
# 164-d program features -> 512 -> 512 -> 1, ReLU activations.
N_FEATURES = 164
HIDDEN = 512

# Flat-parameter layout offsets.  All cost-model parameters travel as one
# f32[N_PARAMS] vector across the Rust<->HLO boundary so the FFI stays a
# single literal; unflatten() is the canonical decoder and the Rust side
# (rust/src/costmodel/layout.rs) mirrors these offsets exactly.
_SIZES = (
    N_FEATURES * HIDDEN,  # w1
    HIDDEN,               # b1
    HIDDEN * HIDDEN,      # w2
    HIDDEN,               # b2
    HIDDEN,               # w3 (HIDDEN x 1, stored as vector)
    1,                    # b3
)
N_PARAMS = sum(_SIZES)  # 347_649

# Adam constants (fixed; not runtime inputs).
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def unflatten(params):
    """Decode the flat f32[N_PARAMS] vector into (w1, b1, w2, b2, w3, b3)."""
    assert params.shape == (N_PARAMS,), params.shape
    out = []
    off = 0
    for size in _SIZES:
        out.append(params[off : off + size])
        off += size
    w1, b1, w2, b2, w3, b3 = out
    return (
        w1.reshape(N_FEATURES, HIDDEN),
        b1,
        w2.reshape(HIDDEN, HIDDEN),
        b2,
        w3.reshape(HIDDEN, 1),
        b3,
    )


def flatten(w1, b1, w2, b2, w3, b3):
    """Inverse of :func:`unflatten`."""
    return jnp.concatenate(
        [w1.ravel(), b1.ravel(), w2.ravel(), b2.ravel(), w3.ravel(), b3.ravel()]
    )


def mlp_forward(params, x):
    """Reference MLP forward: f32[B, 164] -> f32[B] throughput scores."""
    w1, b1, w2, b2, w3, b3 = unflatten(params)
    h1 = jnp.maximum(x @ w1 + b1, 0.0)
    h2 = jnp.maximum(h1 @ w2 + b2, 0.0)
    return (h2 @ w3 + b3)[:, 0]


def masked_adam_update(params, m, v, grads, mask, lr, wd, step):
    """Reference Moses update (paper Eq. 6/7 combined with Adam).

    Transferable parameters (mask==1) take a bias-corrected Adam step on
    the masked gradient; domain-variant parameters (mask==0) are pulled
    toward zero by weight decay: ``w_v <- w_v - lr * wd * w_v`` (Eq. 7).

    ``step`` is the 1-based Adam timestep (f32 scalar for HLO uniformity).
    Returns (params', m', v').
    """
    g = grads * mask
    m_new = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v_new = ADAM_B2 * v + (1.0 - ADAM_B2) * (g * g)
    bc1 = 1.0 - ADAM_B1**step
    bc2 = 1.0 - ADAM_B2**step
    m_hat = m_new / bc1
    v_hat = v_new / bc2
    adam_step = lr * m_hat / (jnp.sqrt(v_hat) + ADAM_EPS)
    decay = lr * wd * params
    params_new = params - mask * adam_step - (1.0 - mask) * decay
    return params_new, m_new, v_new


def pairwise_rank_loss(scores, y, w):
    """Weighted pairwise logistic ranking loss (Ansor-style rank objective).

    For every ordered pair (i, j) with y_i != y_j the model should rank the
    higher-throughput program higher; the per-pair loss is
    ``softplus(-(s_i - s_j) * sign(y_i - y_j))``.  ``w`` carries validity
    weights (0 for padding rows) so Rust can pad partial batches.
    """
    s_diff = scores[:, None] - scores[None, :]
    y_diff = y[:, None] - y[None, :]
    sign = jnp.sign(y_diff)
    pair_w = w[:, None] * w[None, :] * jnp.abs(sign)
    # log(1 + exp(-x)) computed stably.
    x = s_diff * sign
    per_pair = jnp.logaddexp(0.0, -x)
    total_w = jnp.maximum(jnp.sum(pair_w), 1.0)
    return jnp.sum(per_pair * pair_w) / total_w
