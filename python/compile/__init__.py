"""Build-time compile package: JAX model (L2) + Pallas kernels (L1).

Nothing in this package is imported at tuning time; ``make artifacts``
runs :mod:`compile.aot` once and the Rust coordinator consumes the HLO
text artifacts through PJRT.
"""
