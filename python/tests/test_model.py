"""L2 graph semantics: ranking loss, train_step, xi saliency."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from .conftest import make_batch, make_params

SETTINGS = dict(deadline=None, max_examples=10)


# ---------------------------------------------------------- rank loss ----
def test_rank_loss_perfect_ranking_is_small():
    y = jnp.linspace(0.0, 10.0, 32)
    scores = y * 100.0  # same order, huge margins
    w = jnp.ones(32)
    loss = float(ref.pairwise_rank_loss(scores, y, w))
    assert loss < 1e-3


def test_rank_loss_inverted_ranking_is_large():
    y = jnp.linspace(0.0, 10.0, 32)
    w = jnp.ones(32)
    good = float(ref.pairwise_rank_loss(y, y, w))
    bad = float(ref.pairwise_rank_loss(-y, y, w))
    assert bad > good


def test_rank_loss_ignores_zero_weight_rows():
    """Padding rows (w=0) must not influence the loss."""
    x, y, _ = make_batch(1, 64)
    scores = ref.mlp_forward(make_params(1), x)
    w_full = jnp.ones(64)
    loss_32 = float(ref.pairwise_rank_loss(scores[:32], y[:32], w_full[:32]))
    # Same 32 rows + 32 garbage rows with zero weight.
    y_pad = y.at[32:].set(-999.0)
    w_pad = w_full.at[32:].set(0.0)
    loss_pad = float(ref.pairwise_rank_loss(scores, y_pad, w_pad))
    np.testing.assert_allclose(loss_pad, loss_32, rtol=1e-6)


def test_rank_loss_constant_labels_is_zero():
    scores = jnp.linspace(-1, 1, 16)
    y = jnp.full(16, 3.0)
    assert float(ref.pairwise_rank_loss(scores, y, jnp.ones(16))) == 0.0


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_rank_loss_scale_invariant_labels(seed):
    """Only label *order* matters, not magnitude."""
    x, y, w = make_batch(seed, 32)
    scores = ref.mlp_forward(make_params(seed), x)
    a = float(ref.pairwise_rank_loss(scores, y, w))
    b = float(ref.pairwise_rank_loss(scores, y * 1000.0 + 5.0, w))
    np.testing.assert_allclose(a, b, rtol=1e-5)


# ---------------------------------------------------------- train step ----
def _step(params, m, v, x, y, w, mask, lr=1e-3, wd=1e-2, step=1.0):
    hp = jnp.array([lr, wd, step, 0.0], jnp.float32)
    return model.train_step(params, m, v, x, y, w, mask, hp)


def test_train_step_reduces_loss():
    params = make_params(2)
    x, y, w = make_batch(3, model.TRAIN_BATCH)
    m = jnp.zeros(ref.N_PARAMS)
    v = jnp.zeros(ref.N_PARAMS)
    mask = jnp.ones(ref.N_PARAMS)
    losses = []
    for i in range(8):
        params, m, v, loss = _step(params, m, v, x, y, w, mask, lr=1e-2, wd=0.0,
                                   step=float(i + 1))
        losses.append(float(loss[0]))
    assert losses[-1] < losses[0], losses


def test_train_step_respects_mask():
    """Untouched (variant) params must follow exactly the decay path."""
    params = make_params(4)
    x, y, w = make_batch(5, model.TRAIN_BATCH)
    zeros = jnp.zeros(ref.N_PARAMS)
    rng = np.random.default_rng(6)
    mask = jnp.asarray((rng.random(ref.N_PARAMS) < 0.5).astype(np.float32))
    lr, wd = 1e-3, 0.1
    p_new, _, _, _ = _step(params, zeros, zeros, x, y, w, mask, lr=lr, wd=wd)
    variant = np.asarray(mask) == 0.0
    np.testing.assert_allclose(
        np.asarray(p_new)[variant],
        np.asarray(params)[variant] * (1.0 - lr * wd),
        rtol=1e-6,
    )


def test_train_step_loss_matches_loss_eval():
    params = make_params(7)
    x, y, w = make_batch(8, model.TRAIN_BATCH)
    zeros = jnp.zeros(ref.N_PARAMS)
    _, _, _, loss = _step(params, zeros, zeros, x, y, w, jnp.ones(ref.N_PARAMS))
    loss2 = model.loss_eval(params, x, y, w)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(loss2), rtol=1e-6)


# ------------------------------------------------------------------ xi ----
def test_xi_matches_finite_difference_sign():
    """xi = |w * grad|; check grad direction against finite differences on
    a handful of coordinates."""
    params = make_params(9)
    x, y, w = make_batch(10, model.TRAIN_BATCH)
    xi = np.asarray(model.xi_scores(params, x, y, w))
    grads = np.asarray(jax.grad(lambda p: ref.pairwise_rank_loss(
        ref.mlp_forward(p, x), y, w))(params))
    np.testing.assert_allclose(xi, np.abs(np.asarray(params) * grads),
                               rtol=1e-5, atol=1e-9)


def test_xi_zero_params_zero_xi():
    x, y, w = make_batch(11, model.TRAIN_BATCH)
    xi = np.asarray(model.xi_scores(jnp.zeros(ref.N_PARAMS), x, y, w))
    assert np.all(xi == 0.0)


def test_xi_nonnegative_and_finite(params):
    x, y, w = make_batch(12, model.TRAIN_BATCH)
    xi = np.asarray(model.xi_scores(params, x, y, w))
    assert np.all(xi >= 0.0) and np.all(np.isfinite(xi))
    assert xi.shape == (ref.N_PARAMS,)


def test_predict_pallas_matches_jnp(params):
    x, _, _ = make_batch(13, model.PRED_BATCH)
    got = np.asarray(model.predict(params, x))
    want = np.asarray(ref.mlp_forward(params, x))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
