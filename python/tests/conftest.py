import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref


def make_params(seed: int, scale: float = 0.05) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0.0, scale, ref.N_PARAMS).astype(np.float32))


def make_batch(seed: int, batch: int):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0.0, 1.0, (batch, ref.N_FEATURES)).astype(np.float32))
    y = jnp.asarray(rng.uniform(0.0, 10.0, batch).astype(np.float32))
    w = jnp.ones(batch, jnp.float32)
    return x, y, w


@pytest.fixture(scope="session")
def params():
    return make_params(0)
