"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps batch shapes, seeds and hyper-parameters; every case
asserts allclose against :mod:`compile.kernels.ref`.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import mlp, ref, update
from .conftest import make_batch, make_params

SETTINGS = dict(deadline=None, max_examples=12)


# ---------------------------------------------------------------- MLP ----
@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    tiles=st.integers(1, 4),
    scale=st.sampled_from([0.01, 0.05, 0.2]),
)
def test_mlp_forward_matches_ref(seed, tiles, scale):
    batch = tiles * mlp.TILE_B
    params = make_params(seed, scale)
    x, _, _ = make_batch(seed + 1, batch)
    got = np.asarray(mlp.mlp_forward(params, x))
    want = np.asarray(ref.mlp_forward(params, x))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_mlp_forward_small_batch_64():
    """The predict_small AOT entry point uses a 64-row batch (single
    sub-TILE_B tile); must match the oracle exactly like the big one."""
    params = make_params(21)
    x, _, _ = make_batch(22, 64)
    got = np.asarray(mlp.mlp_forward(params, x))
    want = np.asarray(ref.mlp_forward(params, x))
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_mlp_forward_zero_params_zero_scores():
    params = jnp.zeros(ref.N_PARAMS, jnp.float32)
    x, _, _ = make_batch(7, mlp.TILE_B)
    assert np.all(np.asarray(mlp.mlp_forward(params, x)) == 0.0)


def test_mlp_forward_row_independence():
    """Scores must not leak across batch rows (tiling correctness)."""
    params = make_params(3)
    x, _, _ = make_batch(4, 2 * mlp.TILE_B)
    full = np.asarray(mlp.mlp_forward(params, x))
    # Perturb the second tile; first tile scores must be unchanged.
    x2 = x.at[mlp.TILE_B :].set(x[mlp.TILE_B :] * 2.0 + 1.0)
    half = np.asarray(mlp.mlp_forward(params, x2))
    np.testing.assert_array_equal(full[: mlp.TILE_B], half[: mlp.TILE_B])


def test_mlp_forward_relu_saturation():
    """Strongly negative biases must zero the network output head-bias."""
    rng = np.random.default_rng(11)
    w1 = rng.normal(0, 0.05, (ref.N_FEATURES, ref.HIDDEN)).astype(np.float32)
    b1 = np.full(ref.HIDDEN, -1e6, np.float32)  # kills layer 1
    w2 = rng.normal(0, 0.05, (ref.HIDDEN, ref.HIDDEN)).astype(np.float32)
    b2 = np.full(ref.HIDDEN, -1e6, np.float32)
    w3 = rng.normal(0, 0.05, (ref.HIDDEN, 1)).astype(np.float32)
    b3 = np.array([1.5], np.float32)
    params = ref.flatten(*(jnp.asarray(a) for a in (w1, b1, w2, b2, w3, b3)))
    x, _, _ = make_batch(12, mlp.TILE_B)
    got = np.asarray(mlp.mlp_forward(params, x))
    np.testing.assert_allclose(got, np.full(mlp.TILE_B, 1.5), rtol=1e-6)


# ------------------------------------------------------------- update ----
@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    lr=st.sampled_from([1e-4, 1e-3, 1e-2]),
    wd=st.sampled_from([0.0, 1e-3, 0.1]),
    step=st.integers(1, 50),
    ratio=st.floats(0.0, 1.0),
)
def test_masked_adam_matches_ref(seed, lr, wd, step, ratio):
    rng = np.random.default_rng(seed)
    p = make_params(seed)
    m = jnp.asarray(rng.normal(0, 0.01, ref.N_PARAMS).astype(np.float32))
    v = jnp.asarray(np.abs(rng.normal(0, 1e-4, ref.N_PARAMS)).astype(np.float32))
    g = jnp.asarray(rng.normal(0, 0.1, ref.N_PARAMS).astype(np.float32))
    mask = jnp.asarray((rng.random(ref.N_PARAMS) < ratio).astype(np.float32))
    hp = jnp.array([lr, wd, float(step), 0.0], jnp.float32)
    got = update.masked_adam_update(p, m, v, g, mask, hp)
    # step as f32 so the bias-correction pow matches the kernel's f32 math.
    want = ref.masked_adam_update(p, m, v, g, mask, lr, wd, jnp.float32(step))
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=1e-7)


def test_masked_adam_variant_params_decay_only():
    """mask==0 parameters must follow pure weight decay (paper Eq. 7)."""
    p = make_params(5)
    zeros = jnp.zeros(ref.N_PARAMS, jnp.float32)
    g = jnp.asarray(np.random.default_rng(6).normal(0, 1, ref.N_PARAMS).astype(np.float32))
    lr, wd = 0.01, 0.1
    hp = jnp.array([lr, wd, 1.0, 0.0], jnp.float32)
    p_new, m_new, v_new = update.masked_adam_update(p, zeros, zeros, g, zeros, hp)
    np.testing.assert_allclose(
        np.asarray(p_new), np.asarray(p) * (1.0 - lr * wd), rtol=1e-6
    )
    # Moments never see the masked-out gradient.
    assert np.all(np.asarray(m_new) == 0.0) and np.all(np.asarray(v_new) == 0.0)


def test_masked_adam_full_mask_moves_every_param():
    p = make_params(8)
    zeros = jnp.zeros(ref.N_PARAMS, jnp.float32)
    ones = jnp.ones(ref.N_PARAMS, jnp.float32)
    g = jnp.asarray(np.random.default_rng(9).normal(0.5, 1, ref.N_PARAMS).astype(np.float32))
    hp = jnp.array([1e-3, 0.0, 1.0, 0.0], jnp.float32)
    p_new, _, _ = update.masked_adam_update(p, zeros, zeros, g, ones, hp)
    moved = np.mean(np.asarray(p_new) != np.asarray(p))
    assert moved > 0.999
