"""AOT lowering smoke: every entry point lowers to parseable HLO text."""

import json
import os

import jax

from compile import aot, model
from compile.kernels import ref


def test_entry_points_cover_all_artifacts():
    names = [name for name, _, _ in aot.entry_points()]
    assert names == ["predict", "predict_small", "train_step", "xi", "loss_eval"]


def test_predict_lowers_to_hlo_text():
    name, fn, specs = aot.entry_points()[0]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text and "f32[512,164]" in text
    # Flat parameter vector appears as an input.
    assert f"f32[{ref.N_PARAMS}]" in text


def test_train_step_lowers_with_four_outputs():
    name, fn, specs = aot.entry_points()[2]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text
    # return_tuple=True: root is a 4-tuple (params', m', v', loss).
    n = ref.N_PARAMS
    assert f"(f32[{n}]{{0}}, f32[{n}]{{0}}, f32[{n}]{{0}}, f32[1]{{0}}) tuple(" in text


def test_meta_written_by_cli(tmp_path):
    import subprocess, sys

    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    meta = json.loads((out / "meta.json").read_text())
    assert meta["n_params"] == ref.N_PARAMS
    assert meta["pred_batch"] == model.PRED_BATCH
    assert meta["pred_batch_small"] == model.PRED_BATCH_SMALL
    assert set(meta["artifacts"]) == {
        "predict", "predict_small", "train_step", "xi", "loss_eval",
    }
    for info in meta["artifacts"].values():
        assert (out / info["file"]).exists()
