//! Per-rule good/bad fixtures plus the self-check that keeps the real
//! `rust/src/` tree clean against the checked-in config and baseline.

use std::collections::BTreeMap;
use std::path::Path;

use detlint::{collect_sources, config, rules, scan_all, Config};

/// A config shaped like the real one, but inline so fixtures are
/// self-contained: deterministic planes `search/` + `coordinator/`,
/// `obs/` allowed wall-clock reads, ratchet over everything but
/// `main.rs`.
fn test_config() -> Config {
    Config::parse(
        r#"
[scan]
skip-cfg-test = true

[rules.wall-clock]
scope = ["."]
allow = ["obs/"]

[rules.unordered-collections]
scope = ["search/", "coordinator/"]

[rules.ambient]
scope = ["search/", "coordinator/"]

[rules.panic-ratchet]
scope = ["."]
allow = ["main.rs"]
"#,
        &rules::rule_names(),
    )
    .expect("test config parses")
}

fn lint_one(rel: &str, src: &str) -> Vec<rules::Finding> {
    lint_with_baseline(rel, src, &BTreeMap::new())
}

fn lint_with_baseline(
    rel: &str,
    src: &str,
    baseline: &BTreeMap<String, usize>,
) -> Vec<rules::Finding> {
    let cfg = test_config();
    let sources = vec![(rel.to_string(), src.to_string())];
    let scans = scan_all(&sources, &cfg);
    rules::check(&scans, &cfg, baseline)
}

fn active(findings: &[rules::Finding]) -> Vec<&rules::Finding> {
    findings.iter().filter(|f| !f.suppressed).collect()
}

#[test]
fn wall_clock_fires_in_deterministic_code_and_not_in_obs() {
    let bad = "fn f() { let t = std::time::Instant::now(); }\n";
    let f = lint_one("search/evolutionary.rs", bad);
    assert_eq!(active(&f).len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "wall-clock");
    assert_eq!((f[0].file.as_str(), f[0].line), ("search/evolutionary.rs", 1));

    assert!(active(&lint_one("obs/span.rs", bad)).is_empty());
    // SystemTime is the same rule.
    let f = lint_one("coordinator/tuner.rs", "let t = SystemTime::now();\n");
    assert_eq!(active(&f).len(), 1);
    // Prose and strings do not trip it.
    let good = "// Instant::now is forbidden here\nlet s = \"Instant::now\";\n";
    assert!(active(&lint_one("search/mod.rs", good)).is_empty());
}

#[test]
fn unordered_collections_fire_only_in_planes() {
    let bad = "use std::collections::HashMap;\nfn f() -> HashSet<u32> { todo!() }\n";
    let f = lint_one("coordinator/pipeline.rs", bad);
    let a = active(&f);
    assert_eq!(a.len(), 2, "{f:?}"); // one per offending line/pattern
    assert!(a.iter().all(|f| f.rule == "unordered-collections"));

    // BTreeMap is the sanctioned container.
    let good = "use std::collections::BTreeMap;\n";
    assert!(active(&lint_one("coordinator/pipeline.rs", good)).is_empty());
    // Outside the planes (e.g. the tunecache store) HashMap is fine.
    assert!(active(&lint_one("tunecache/store.rs", bad)).is_empty());
}

#[test]
fn ambient_nondeterminism_fires_in_planes() {
    for bad in [
        "let r = rand::thread_rng();\n",
        "let v = std::env::var(\"X\");\n",
        "let p = std::process::id();\n",
        "let n = std::thread::available_parallelism();\n",
    ] {
        let f = lint_one("coordinator/sched.rs", bad);
        assert_eq!(active(&f).len(), 1, "{bad}: {f:?}");
        assert_eq!(f[0].rule, "ambient");
        assert!(active(&lint_one("device/sim.rs", bad)).is_empty(), "{bad} out of scope");
    }
}

#[test]
fn panic_ratchet_fails_on_growth_and_passes_at_baseline() {
    let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\nfn g() { h().expect(\"boom\"); }\n";
    // No baseline entry → any panic surface is growth.
    let f = lint_one("transfer/moses.rs", src);
    assert_eq!(active(&f).len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "panic-ratchet");
    assert!(f[0].message.contains("2 unwrap()/expect() vs baseline 0"));

    // At (or under) the recorded baseline the ratchet is quiet.
    let mut base = BTreeMap::new();
    base.insert("transfer/moses.rs".to_string(), 2);
    assert!(active(&lint_with_baseline("transfer/moses.rs", src, &base)).is_empty());

    // Test modules do not count against the ratchet.
    let test_only = "#[cfg(test)]\nmod tests {\n fn t() { x.unwrap().expect(\"y\"); }\n}\n";
    assert!(active(&lint_one("transfer/moses.rs", test_only)).is_empty());

    // The bin driver is allowlisted.
    assert!(active(&lint_one("main.rs", src)).is_empty());
}

#[test]
fn pragmas_suppress_with_reason_and_fail_without() {
    // Trailing pragma with a reason: finding is recorded but suppressed.
    let ok = "let t = Instant::now(); // detlint: allow(wall-clock) -- driver-only timing\n";
    let f = lint_one("coordinator/tuner.rs", ok);
    assert_eq!(f.len(), 1);
    assert!(f[0].suppressed);
    assert!(active(&f).is_empty());

    // Standalone pragma suppresses the next code line.
    let standalone = "// detlint: allow(ambient) -- pid is part of the segment name\n\
                      let p = std::process::id();\n";
    let f = lint_one("coordinator/sched.rs", standalone);
    assert_eq!(f.len(), 1);
    assert!(f[0].suppressed);

    // A reasonless pragma is itself a (never-suppressible) finding,
    // and does not suppress.
    let bad = "let t = Instant::now(); // detlint: allow(wall-clock)\n";
    let f = lint_one("coordinator/tuner.rs", bad);
    let a = active(&f);
    assert_eq!(a.len(), 2, "{f:?}");
    assert!(a.iter().any(|f| f.rule == "pragma"));
    assert!(a.iter().any(|f| f.rule == "wall-clock" && !f.suppressed));

    // Unknown rule names are rejected too.
    let unk = "let x = 1; // detlint: allow(made-up) -- because\n";
    let f = lint_one("search/mod.rs", unk);
    assert_eq!(active(&f).len(), 1);
    assert_eq!(f[0].rule, "pragma");

    // A pragma'd line is excluded from the ratchet count.
    let counted = "fn f() { g().expect(\"invariant\") } // detlint: allow(panic-ratchet) -- invariant\n";
    assert!(active(&lint_one("transfer/moses.rs", counted)).is_empty());
}

#[test]
fn write_baseline_shape_roundtrips() {
    let cfg = test_config();
    let sources = vec![(
        "transfer/moses.rs".to_string(),
        "fn f() { a.unwrap(); b.unwrap(); }\n".to_string(),
    )];
    let scans = scan_all(&sources, &cfg);
    let counts = rules::ratchet_counts(&scans, &cfg);
    let text = config::render_baseline(&counts);
    let back = config::parse_baseline(&text).unwrap();
    assert_eq!(back, counts);
    assert_eq!(back.get("transfer/moses.rs"), Some(&2));
}

/// The real tree must lint clean against the checked-in `detlint.toml`
/// and `detlint-baseline.toml`: zero unsuppressed findings, and the
/// baseline exactly matches a fresh `--write-baseline` (no drift).
#[test]
fn real_tree_is_clean_and_baseline_is_current() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("..")
        .join("..");
    let cfg_text = std::fs::read_to_string(root.join("detlint.toml"))
        .expect("detlint.toml at workspace root");
    let cfg = Config::parse(&cfg_text, &rules::rule_names()).expect("config parses");
    let baseline_text = std::fs::read_to_string(root.join("detlint-baseline.toml"))
        .expect("detlint-baseline.toml at workspace root");
    let baseline = config::parse_baseline(&baseline_text).expect("baseline parses");

    let sources = collect_sources(&root.join("rust").join("src")).expect("sources readable");
    assert!(sources.len() > 40, "expected the full moses tree");
    let scans = scan_all(&sources, &cfg);

    let findings = rules::check(&scans, &cfg, &baseline);
    let bad: Vec<_> = findings.iter().filter(|f| !f.suppressed).collect();
    assert!(
        bad.is_empty(),
        "rust/src violates the determinism contract:\n{}",
        detlint::report::human(&findings, scans.len())
    );

    let counts = rules::ratchet_counts(&scans, &cfg);
    assert_eq!(
        counts, baseline,
        "detlint-baseline.toml drifted — regenerate with `cargo run -p detlint -- --write-baseline`"
    );
}
