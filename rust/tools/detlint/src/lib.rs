//! detlint — a determinism-contract static analyzer for the Moses
//! tuning engine.
//!
//! The engine's transfer guarantees (comparable cross-device records,
//! replayable export corpora, draft-then-verify equivalence) rest on
//! sessions being bitwise functions of `(seed, jobs)`.  detlint
//! enforces that contract at the source level with four rules over
//! `rust/src/`:
//!
//! * **wall-clock** — no `Instant::now` / `SystemTime::now` outside
//!   allowlisted modules; deterministic code runs on the virtual clock.
//! * **unordered-collections** — no `HashMap` / `HashSet` in the
//!   deterministic planes; iteration order must be reproducible.
//! * **ambient** — no `thread_rng`, `env::var`, `process::id`, or
//!   `available_parallelism` in the deterministic planes.
//! * **panic-ratchet** — `.unwrap()` / `.expect(` counts per library
//!   module may never grow past `detlint-baseline.toml`.
//!
//! Rules are configured in `detlint.toml` (scope + allowlist per rule)
//! and suppressible inline with
//! `// detlint: allow(<rules>) -- <reason>` pragmas; the reason is
//! mandatory.  See `rust/tools/detlint/tests/rules.rs` for each rule
//! firing and passing, and the self-check test that keeps the real
//! tree clean.

pub mod config;
pub mod report;
pub mod rules;
pub mod scan;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

pub use config::Config;
pub use rules::Finding;
pub use scan::FileScan;

/// Walk up from `start` to the first directory containing
/// `detlint.toml` — the workspace root.
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut dir = start.to_path_buf();
    loop {
        if dir.join("detlint.toml").is_file() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Collect every `.rs` file under `src_root` as `(rel_path, contents)`,
/// sorted by path for deterministic output.
pub fn collect_sources(src_root: &Path) -> Result<Vec<(String, String)>> {
    let mut files = Vec::new();
    walk(src_root, src_root, &mut files)?;
    files.sort();
    let mut out = Vec::with_capacity(files.len());
    for rel in files {
        let text = std::fs::read_to_string(src_root.join(&rel))
            .with_context(|| format!("reading {rel}"))?;
        out.push((rel, text));
    }
    Ok(out)
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    let rd = std::fs::read_dir(dir).with_context(|| format!("listing {dir:?}"))?;
    for entry in rd {
        let entry = entry.with_context(|| format!("listing {dir:?}"))?;
        let path = entry.path();
        if path.is_dir() {
            walk(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .expect("walk stays under root")
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

/// Scan a set of `(rel, contents)` sources under one config.
pub fn scan_all(sources: &[(String, String)], cfg: &Config) -> Vec<FileScan> {
    let known = rules::rule_names();
    sources
        .iter()
        .map(|(rel, text)| scan::scan_source(rel, text, &known, cfg.skip_cfg_test))
        .collect()
}
