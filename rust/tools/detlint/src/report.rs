//! Human and machine-readable finding reports.

use crate::rules::Finding;

/// Human output: one `file:line` anchored line per finding plus a
/// summary tail.  Paths are printed relative to the repo root
/// (`rust/src/<rel>`) so terminal hyperlinking works from the root.
pub fn human(findings: &[Finding], files_scanned: usize) -> String {
    let mut out = String::new();
    for f in findings {
        let tag = if f.suppressed { " (suppressed)" } else { "" };
        out.push_str(&format!(
            "rust/src/{}:{}: [{}]{} {}\n",
            f.file, f.line, f.rule, tag, f.message
        ));
    }
    let active = findings.iter().filter(|f| !f.suppressed).count();
    let suppressed = findings.len() - active;
    out.push_str(&format!(
        "detlint: {active} finding(s), {suppressed} suppressed, {files_scanned} file(s) scanned\n"
    ));
    out
}

/// JSON output (versioned, for the CI artifact).
pub fn json(findings: &[Finding], files_scanned: usize) -> String {
    let active = findings.iter().filter(|f| !f.suppressed).count();
    let mut out = String::new();
    out.push_str("{\"version\":1,\"files_scanned\":");
    out.push_str(&files_scanned.to_string());
    out.push_str(",\"findings\":");
    out.push_str(&active.to_string());
    out.push_str(",\"suppressed\":");
    out.push_str(&(findings.len() - active).to_string());
    out.push_str(",\"items\":[");
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\"suppressed\":{},\"message\":\"{}\"}}",
            esc(&f.rule),
            esc(&f.file),
            f.line,
            f.suppressed,
            esc(&f.message)
        ));
    }
    out.push_str("]}\n");
    out
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<Finding> {
        vec![
            Finding {
                rule: "wall-clock".into(),
                file: "search/mod.rs".into(),
                line: 7,
                message: "`Instant::now` — \"quoted\"".into(),
                suppressed: false,
            },
            Finding {
                rule: "ambient".into(),
                file: "coordinator/sched.rs".into(),
                line: 3,
                message: "ok".into(),
                suppressed: true,
            },
        ]
    }

    #[test]
    fn human_anchors_and_counts() {
        let h = human(&sample(), 42);
        assert!(h.contains("rust/src/search/mod.rs:7: [wall-clock]"));
        assert!(h.contains("(suppressed)"));
        assert!(h.contains("1 finding(s), 1 suppressed, 42 file(s)"));
    }

    #[test]
    fn json_is_parseable_shape() {
        let j = json(&sample(), 42);
        assert!(j.starts_with("{\"version\":1,"));
        assert!(j.contains("\"findings\":1"));
        assert!(j.contains("\\\"quoted\\\""));
        assert!(j.contains("\"suppressed\":true"));
    }
}
