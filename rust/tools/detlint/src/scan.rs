//! Lexical source scanner: comment/string-aware line model + pragmas.
//!
//! detlint is deliberately a *lexical* tool (no syn, no rustc): it
//! blanks out comments, string literals and char literals so rule
//! patterns only ever match real code, tracks `#[cfg(test)] mod`
//! blocks so test code is exempt, and extracts
//! `// detlint: allow(<rules>) -- <reason>` pragmas from line
//! comments.  Block comments are blanked but never carry pragmas.

/// One scanned source line.
#[derive(Debug, Clone)]
pub struct LineInfo {
    /// The line with comment text, string contents and char literals
    /// removed — rule patterns match against this.
    pub code: String,
    /// Inside a `#[cfg(test)] mod … { … }` block.
    pub in_test: bool,
    /// Rules suppressed on this line by a valid pragma.
    pub suppress: Vec<String>,
}

/// A malformed pragma (missing reason, unknown rule, bad syntax).
#[derive(Debug, Clone)]
pub struct PragmaIssue {
    pub line: usize,
    pub message: String,
}

/// One scanned file.
#[derive(Debug, Clone)]
pub struct FileScan {
    /// Path relative to the scan root, forward slashes.
    pub rel: String,
    /// 0-indexed; line numbers in findings are `index + 1`.
    pub lines: Vec<LineInfo>,
    pub pragma_issues: Vec<PragmaIssue>,
}

/// A line comment captured during blanking.
struct Comment {
    /// 0-indexed line the comment starts on.
    line: usize,
    /// Text after the `//` (or `///` / `//!`) marker.
    text: String,
    /// Whether code precedes the comment on its line.
    trailing: bool,
}

/// Scan one source file.  `known_rules` validates pragma rule names;
/// `skip_cfg_test` marks test-module lines so rules can exempt them.
pub fn scan_source(
    rel: &str,
    src: &str,
    known_rules: &[&str],
    skip_cfg_test: bool,
) -> FileScan {
    let (blanked, comments) = blank(src);
    let in_test = mark_cfg_test(&blanked, skip_cfg_test);
    let mut lines: Vec<LineInfo> = blanked
        .into_iter()
        .zip(in_test)
        .map(|(code, in_test)| LineInfo {
            code,
            in_test,
            suppress: Vec::new(),
        })
        .collect();

    let mut issues = Vec::new();
    for c in &comments {
        let parsed = match parse_pragma(&c.text) {
            None => continue,
            Some(Ok(rules)) => rules,
            Some(Err(msg)) => {
                issues.push(PragmaIssue {
                    line: c.line + 1,
                    message: msg,
                });
                continue;
            }
        };
        let mut ok = true;
        for r in &parsed {
            if !known_rules.contains(&r.as_str()) {
                issues.push(PragmaIssue {
                    line: c.line + 1,
                    message: format!(
                        "pragma names unknown rule `{r}` (known: {})",
                        known_rules.join(", ")
                    ),
                });
                ok = false;
            }
        }
        if !ok {
            continue;
        }
        // A trailing pragma suppresses its own line; a standalone
        // comment suppresses the next line that carries code.
        let target = if c.trailing {
            Some(c.line)
        } else {
            lines
                .iter()
                .enumerate()
                .skip(c.line + 1)
                .find(|(_, l)| !l.code.trim().is_empty())
                .map(|(i, _)| i)
        };
        match target {
            Some(i) => lines[i].suppress.extend(parsed),
            None => issues.push(PragmaIssue {
                line: c.line + 1,
                message: "standalone pragma with no following code line".to_string(),
            }),
        }
    }

    FileScan {
        rel: rel.to_string(),
        lines,
        pragma_issues: issues,
    }
}

/// Parse a comment body as a pragma.  Returns `None` when the comment
/// is not a pragma at all, `Some(Err)` when it tries to be one but is
/// malformed (most importantly: a missing `-- <reason>`).
fn parse_pragma(text: &str) -> Option<Result<Vec<String>, String>> {
    let rest = text.trim_start().strip_prefix("detlint:")?;
    let bad = |msg: &str| Some(Err(msg.to_string()));
    let Some(rest) = rest.trim_start().strip_prefix("allow") else {
        return bad("pragma must be `detlint: allow(<rules>) -- <reason>`");
    };
    let Some(rest) = rest.trim_start().strip_prefix('(') else {
        return bad("pragma missing `(` after allow");
    };
    let Some((inside, after)) = rest.split_once(')') else {
        return bad("pragma missing `)`");
    };
    let rules: Vec<String> = inside
        .split(',')
        .map(|r| r.trim().to_string())
        .filter(|r| !r.is_empty())
        .collect();
    if rules.is_empty() {
        return bad("pragma allows no rules");
    }
    let Some(reason) = after.trim_start().strip_prefix("--") else {
        return bad("pragma requires a reason: `detlint: allow(<rules>) -- <reason>`");
    };
    if reason.trim().is_empty() {
        return bad("pragma reason is empty");
    }
    Some(Ok(rules))
}

/// Blank comments, strings and char literals out of `src`, returning
/// the per-line code text plus every line comment (for pragma parsing).
fn blank(src: &str) -> (Vec<String>, Vec<Comment>) {
    let chars: Vec<char> = src.chars().collect();
    let mut lines = Vec::new();
    let mut comments = Vec::new();
    let mut cur = String::new();
    let mut i = 0;
    let mut prev_word = false; // previous code char could end an identifier
    while i < chars.len() {
        let c = chars[i];
        match c {
            '\n' => {
                lines.push(std::mem::take(&mut cur));
                prev_word = false;
                i += 1;
            }
            '/' if chars.get(i + 1) == Some(&'/') => {
                // Line comment (incl. doc comments): capture for
                // pragmas, blank from the code view.
                let start = i + 2;
                let mut end = start;
                while end < chars.len() && chars[end] != '\n' {
                    end += 1;
                }
                comments.push(Comment {
                    line: lines.len(),
                    text: chars[start..end].iter().collect(),
                    trailing: !cur.trim().is_empty(),
                });
                i = end;
            }
            '/' if chars.get(i + 1) == Some(&'*') => {
                // Block comment; Rust block comments nest.
                let mut depth = 1;
                i += 2;
                while i < chars.len() && depth > 0 {
                    if chars[i] == '\n' {
                        lines.push(std::mem::take(&mut cur));
                        i += 1;
                    } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        depth += 1;
                        i += 2;
                    } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                prev_word = false;
            }
            '"' => {
                i = skip_string(&chars, i + 1, &mut lines, &mut cur);
                prev_word = false;
            }
            'r' | 'b' if !prev_word && starts_raw_string(&chars, i) => {
                i = skip_raw_string(&chars, i, &mut lines, &mut cur);
                prev_word = false;
            }
            '\'' => {
                // Char literal vs lifetime.  `'\…'` and `'x'` are
                // literals (skipped); anything else is a lifetime
                // tick, which is ordinary code.
                if chars.get(i + 1) == Some(&'\\') {
                    // Escaped literal: skip `\` + escaped char, then
                    // scan to the closing quote (handles '\'' , '\\',
                    // '\u{…}').
                    i += 3;
                    while i < chars.len() && chars[i] != '\'' {
                        i += 1;
                    }
                    i += 1;
                } else if chars.get(i + 2) == Some(&'\'') {
                    i += 3;
                } else {
                    cur.push('\'');
                    i += 1;
                }
                prev_word = false;
            }
            _ => {
                cur.push(c);
                prev_word = c.is_alphanumeric() || c == '_';
                i += 1;
            }
        }
    }
    lines.push(cur);
    (lines, comments)
}

/// Is `chars[i]` the start of a raw (or raw byte) string literal:
/// `r"`, `r#"`, `br"`, `b"` …?  (`b"` plain byte strings go through
/// [`skip_string`]; this detects the `r`-prefixed forms and `b"`.)
fn starts_raw_string(chars: &[char], i: usize) -> bool {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        // b"…" plain byte string.
        return chars.get(i) == Some(&'b');
    }
    if chars.get(j) != Some(&'r') {
        return false;
    }
    j += 1;
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    chars.get(j) == Some(&'"')
}

/// Skip a raw/byte string starting at its prefix; returns the index
/// after the closing delimiter.
fn skip_raw_string(
    chars: &[char],
    mut i: usize,
    lines: &mut Vec<String>,
    cur: &mut String,
) -> usize {
    if chars.get(i) == Some(&'b') {
        i += 1;
    }
    if chars.get(i) == Some(&'"') {
        // Plain byte string: same escape rules as a normal string.
        return skip_string(chars, i + 1, lines, cur);
    }
    i += 1; // the `r`
    let mut hashes = 0;
    while chars.get(i) == Some(&'#') {
        hashes += 1;
        i += 1;
    }
    i += 1; // opening quote
    loop {
        match chars.get(i) {
            None => return i,
            Some('\n') => {
                lines.push(std::mem::take(cur));
                i += 1;
            }
            Some('"') => {
                let mut k = 0;
                while k < hashes && chars.get(i + 1 + k) == Some(&'#') {
                    k += 1;
                }
                i += 1 + k;
                if k == hashes {
                    return i;
                }
            }
            Some(_) => i += 1,
        }
    }
}

/// Skip a normal string body (opening quote already consumed);
/// returns the index after the closing quote.
fn skip_string(chars: &[char], mut i: usize, lines: &mut Vec<String>, cur: &mut String) -> usize {
    loop {
        match chars.get(i) {
            None => return i,
            Some('\\') => {
                // Keep line numbering intact across `\` + newline
                // string continuations.
                if chars.get(i + 1) == Some(&'\n') {
                    lines.push(std::mem::take(cur));
                }
                i += 2;
            }
            Some('\n') => {
                lines.push(std::mem::take(cur));
                i += 1;
            }
            Some('"') => return i + 1,
            Some(_) => i += 1,
        }
    }
}

/// Mark lines inside `#[cfg(test)] mod … { … }` blocks via brace
/// tracking over the blanked code.  When `enabled` is false every line
/// reads as non-test.
fn mark_cfg_test(blanked: &[String], enabled: bool) -> Vec<bool> {
    let mut out = vec![false; blanked.len()];
    if !enabled {
        return out;
    }
    let mut depth: i64 = 0;
    let mut pending = false;
    let mut in_test = false;
    let mut start_depth = 0i64;
    for (idx, code) in blanked.iter().enumerate() {
        if !in_test && code.contains("#[cfg(test)]") {
            pending = true;
        }
        let opens_mod = (code.trim_start().starts_with("mod ") || code.contains(" mod "))
            && code.contains('{');
        if pending && !in_test && opens_mod {
            in_test = true;
            pending = false;
            start_depth = depth;
        }
        if in_test {
            out[idx] = true;
        }
        depth += code.matches('{').count() as i64;
        depth -= code.matches('}').count() as i64;
        if in_test && depth <= start_depth {
            in_test = false;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const RULES: &[&str] = &["wall-clock", "ambient"];

    fn scan(src: &str) -> FileScan {
        scan_source("x.rs", src, RULES, true)
    }

    #[test]
    fn comments_and_strings_are_blanked() {
        let s = scan(
            "let a = \"Instant::now\"; // Instant::now in prose\n/* Instant::now */ let b = 1;\n",
        );
        assert!(!s.lines[0].code.contains("Instant::now"));
        assert!(!s.lines[1].code.contains("Instant::now"));
        assert!(s.lines[1].code.contains("let b"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let s = scan("fn f<'a>(x: &'a str) -> u8 { b'\"' }\nlet c = '\\'';\nlet d = 'y';\n");
        assert!(s.lines[0].code.contains("fn f<'a>(x: &'a str)"));
        assert!(s.lines[1].code.contains("let c ="));
        assert!(s.lines[2].code.contains("let d ="));
        // Nothing after the literals leaked into a string state.
        assert!(s.lines[0].code.contains('}'));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let s = scan("let a = r#\"Instant::now \"quoted\" \"#; let tail = 2;\n");
        assert!(!s.lines[0].code.contains("Instant::now"));
        assert!(s.lines[0].code.contains("let tail"));
    }

    #[test]
    fn trailing_pragma_hits_its_line_standalone_hits_next() {
        let s = scan(
            "let a = 1; // detlint: allow(ambient) -- reason here\n\
             // detlint: allow(wall-clock) -- spans need wall time\n\
             let b = 2;\n",
        );
        assert_eq!(s.lines[0].suppress, vec!["ambient".to_string()]);
        assert!(s.lines[1].suppress.is_empty());
        assert_eq!(s.lines[2].suppress, vec!["wall-clock".to_string()]);
        assert!(s.pragma_issues.is_empty());
    }

    #[test]
    fn pragma_without_reason_is_an_issue() {
        let s = scan("let a = 1; // detlint: allow(ambient)\n");
        assert_eq!(s.pragma_issues.len(), 1);
        assert!(s.pragma_issues[0].message.contains("reason"));
        assert!(s.lines[0].suppress.is_empty());
    }

    #[test]
    fn pragma_with_unknown_rule_is_an_issue() {
        let s = scan("let a = 1; // detlint: allow(no-such-rule) -- why\n");
        assert_eq!(s.pragma_issues.len(), 1);
        assert!(s.pragma_issues[0].message.contains("no-such-rule"));
    }

    #[test]
    fn cfg_test_blocks_are_marked() {
        let src = "fn lib() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                   fn t() { x.unwrap(); }\n\
                   }\n\
                   fn after() {}\n";
        let s = scan(src);
        assert!(!s.lines[0].in_test);
        assert!(s.lines[3].in_test);
        assert!(!s.lines[5].in_test);
    }
}
