//! detlint CLI.
//!
//! ```text
//! cargo run -p detlint --                    # lint rust/src, human output
//! cargo run -p detlint -- --format json      # machine-readable report
//! cargo run -p detlint -- --write-baseline   # regenerate the ratchet file
//! ```
//!
//! Exit codes: 0 clean (or suppressed-only), 1 unsuppressed findings,
//! 2 usage/config errors.

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use detlint::{collect_sources, config, find_root, report, rules, scan_all, Config};

struct Args {
    root: Option<PathBuf>,
    format: String,
    out: Option<PathBuf>,
    write_baseline: bool,
}

const USAGE: &str = "\
detlint — determinism-contract static analyzer (see detlint.toml)

USAGE:
    detlint [--root <dir>] [--format human|json] [--out <file>] [--write-baseline]

OPTIONS:
    --root <dir>       Workspace root (default: walk up from cwd to detlint.toml)
    --format <fmt>     Output format: human (default) or json
    --out <file>       Also write the report to <file>
    --write-baseline   Regenerate detlint-baseline.toml from the current tree
    -h, --help         This help
";

fn parse_args() -> Result<Args> {
    let mut args = Args {
        root: None,
        format: "human".to_string(),
        out: None,
        write_baseline: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => args.root = Some(PathBuf::from(next(&mut it, "--root")?)),
            "--format" => args.format = next(&mut it, "--format")?,
            "--out" => args.out = Some(PathBuf::from(next(&mut it, "--out")?)),
            "--write-baseline" => args.write_baseline = true,
            "-h" | "--help" => {
                print!("{USAGE}");
                std::process::exit(0);
            }
            other => bail!("unknown argument `{other}`\n{USAGE}"),
        }
    }
    if args.format != "human" && args.format != "json" {
        bail!("--format must be human or json");
    }
    Ok(args)
}

fn next(it: &mut impl Iterator<Item = String>, flag: &str) -> Result<String> {
    it.next().with_context(|| format!("{flag} needs a value"))
}

fn run() -> Result<ExitCode> {
    let args = parse_args()?;
    let root = match args.root {
        Some(r) => r,
        None => {
            let cwd = std::env::current_dir().context("getting cwd")?;
            find_root(&cwd).context(
                "no detlint.toml found between cwd and filesystem root (pass --root)",
            )?
        }
    };
    let cfg_path = root.join("detlint.toml");
    let cfg_text = std::fs::read_to_string(&cfg_path)
        .with_context(|| format!("reading {cfg_path:?}"))?;
    let cfg = Config::parse(&cfg_text, &rules::rule_names())?;

    let src_root = root.join("rust").join("src");
    let sources = collect_sources(&src_root)?;
    let scans = scan_all(&sources, &cfg);

    let baseline_path = root.join("detlint-baseline.toml");
    if args.write_baseline {
        let counts = rules::ratchet_counts(&scans, &cfg);
        let text = config::render_baseline(&counts);
        std::fs::write(&baseline_path, &text)
            .with_context(|| format!("writing {baseline_path:?}"))?;
        println!(
            "detlint: wrote {} module count(s) to {}",
            counts.len(),
            baseline_path.display()
        );
        return Ok(ExitCode::SUCCESS);
    }

    let baseline = match std::fs::read_to_string(&baseline_path) {
        Ok(text) => config::parse_baseline(&text)?,
        // A missing baseline reads as all-zero: every panic site then
        // fails until --write-baseline records the starting surface.
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Default::default(),
        Err(e) => return Err(e).with_context(|| format!("reading {baseline_path:?}")),
    };

    let findings = rules::check(&scans, &cfg, &baseline);
    let rendered = match args.format.as_str() {
        "json" => report::json(&findings, scans.len()),
        _ => report::human(&findings, scans.len()),
    };
    print!("{rendered}");
    if let Some(out) = &args.out {
        std::fs::write(out, &rendered).with_context(|| format!("writing {out:?}"))?;
    }
    let clean = findings.iter().all(|f| f.suppressed);
    Ok(if clean {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    })
}

fn main() -> ExitCode {
    match run() {
        Ok(code) => code,
        Err(e) => {
            eprintln!("detlint: error: {e:#}");
            ExitCode::from(2)
        }
    }
}
