//! The determinism-contract rules.
//!
//! Each rule is a named set of lexical patterns plus a scope/allow
//! configuration loaded from `detlint.toml`.  Three rules are
//! per-occurrence (wall-clock, unordered-collections, ambient); the
//! fourth (panic-ratchet) is a per-module counter compared against the
//! checked-in `detlint-baseline.toml`.

use std::collections::BTreeMap;

use crate::config::Config;
use crate::scan::FileScan;

pub const WALL_CLOCK: &str = "wall-clock";
pub const UNORDERED: &str = "unordered-collections";
pub const AMBIENT: &str = "ambient";
pub const PANIC_RATCHET: &str = "panic-ratchet";
/// Pseudo-rule for malformed pragmas; never suppressible.
pub const PRAGMA: &str = "pragma";

/// A pattern-based rule.
pub struct RuleSpec {
    pub name: &'static str,
    pub patterns: &'static [&'static str],
    pub hint: &'static str,
}

/// The three per-occurrence rules.  The panic ratchet shares their
/// scope/allow machinery but its own counting pass.
pub const PATTERN_RULES: &[RuleSpec] = &[
    RuleSpec {
        name: WALL_CLOCK,
        patterns: &["Instant::now", "SystemTime::now"],
        hint: "deterministic modules run on the virtual clock; wall time belongs to \
               the obs diag payload or the drivers",
    },
    RuleSpec {
        name: UNORDERED,
        patterns: &["HashMap", "HashSet"],
        hint: "iteration order is nondeterministic in the deterministic planes; use \
               BTreeMap/BTreeSet or sort before draining",
    },
    RuleSpec {
        name: AMBIENT,
        patterns: &[
            "thread_rng",
            "env::var",
            "process::id",
            "available_parallelism",
        ],
        hint: "sessions must be pure functions of (seed, jobs); ambient process state \
               may not leak into the deterministic planes",
    },
];

/// Patterns counted by the panic ratchet.
pub const PANIC_PATTERNS: &[&str] = &[".unwrap()", ".expect("];

/// Every rule name a pragma may reference.
pub fn rule_names() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = PATTERN_RULES.iter().map(|r| r.name).collect();
    names.push(PANIC_RATCHET);
    names
}

/// One reported violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: String,
    /// Path relative to the scan root.
    pub file: String,
    /// 1-indexed.
    pub line: usize,
    pub message: String,
    /// Covered by a valid pragma: reported, but does not fail the run.
    pub suppressed: bool,
}

/// Run every rule over the scanned files.  Findings are sorted by
/// `(file, line, rule)`.
pub fn check(
    scans: &[FileScan],
    cfg: &Config,
    baseline: &BTreeMap<String, usize>,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    for scan in scans {
        for issue in &scan.pragma_issues {
            findings.push(Finding {
                rule: PRAGMA.to_string(),
                file: scan.rel.clone(),
                line: issue.line,
                message: issue.message.clone(),
                suppressed: false,
            });
        }
        for rule in PATTERN_RULES {
            if !cfg.rule(rule.name).applies(&scan.rel) {
                continue;
            }
            for (idx, line) in scan.lines.iter().enumerate() {
                if cfg.skip_cfg_test && line.in_test {
                    continue;
                }
                for pat in rule.patterns {
                    if !line.code.contains(pat) {
                        continue;
                    }
                    findings.push(Finding {
                        rule: rule.name.to_string(),
                        file: scan.rel.clone(),
                        line: idx + 1,
                        message: format!("`{pat}` — {}", rule.hint),
                        suppressed: line.suppress.iter().any(|s| s == rule.name),
                    });
                }
            }
        }
    }
    findings.extend(ratchet(scans, cfg, baseline));
    findings.sort_by(|a, b| {
        (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule))
    });
    findings
}

/// Count `.unwrap()` / `.expect(` occurrences per in-scope module
/// (non-test, non-suppressed lines).  Modules with zero occurrences
/// are omitted — the baseline lists only modules with panic surface.
pub fn ratchet_counts(scans: &[FileScan], cfg: &Config) -> BTreeMap<String, usize> {
    let rule = cfg.rule(PANIC_RATCHET);
    let mut counts = BTreeMap::new();
    for scan in scans {
        if !rule.applies(&scan.rel) {
            continue;
        }
        let mut n = 0;
        for line in &scan.lines {
            if cfg.skip_cfg_test && line.in_test {
                continue;
            }
            if line.suppress.iter().any(|s| s == PANIC_RATCHET) {
                continue;
            }
            for pat in PANIC_PATTERNS {
                n += line.code.matches(pat).count();
            }
        }
        if n > 0 {
            counts.insert(scan.rel.clone(), n);
        }
    }
    counts
}

/// Compare current counts against the baseline: growth in any module
/// is a finding, anchored at the module's first counted site.
fn ratchet(
    scans: &[FileScan],
    cfg: &Config,
    baseline: &BTreeMap<String, usize>,
) -> Vec<Finding> {
    let counts = ratchet_counts(scans, cfg);
    let mut findings = Vec::new();
    for (rel, &n) in &counts {
        let base = baseline.get(rel).copied().unwrap_or(0);
        if n <= base {
            continue;
        }
        let line = scans
            .iter()
            .find(|s| &s.rel == rel)
            .map(|s| first_panic_line(s, cfg))
            .unwrap_or(1);
        findings.push(Finding {
            rule: PANIC_RATCHET.to_string(),
            file: rel.clone(),
            line,
            message: format!(
                "panic surface grew: {n} unwrap()/expect() vs baseline {base} — return \
                 a Result instead, or regenerate detlint-baseline.toml with \
                 --write-baseline if the growth is deliberate"
            ),
            suppressed: false,
        });
    }
    findings
}

fn first_panic_line(scan: &FileScan, cfg: &Config) -> usize {
    for (idx, line) in scan.lines.iter().enumerate() {
        if cfg.skip_cfg_test && line.in_test {
            continue;
        }
        if line.suppress.iter().any(|s| s == PANIC_RATCHET) {
            continue;
        }
        if PANIC_PATTERNS.iter().any(|p| line.code.contains(p)) {
            return idx + 1;
        }
    }
    1
}
