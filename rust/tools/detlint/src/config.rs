//! Configuration loading for `detlint.toml` and `detlint-baseline.toml`.
//!
//! A minimal TOML-subset parser keeps the tool dependency-free: it
//! supports `[dotted.section]` headers, `#` comments, and `key = value`
//! lines whose value is a bool, an integer, a `"string"`, or a
//! single-line `["array", "of", "strings"]`.  That is all the two files
//! use; anything else is a hard error, so a typo can never silently
//! relax a rule.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Bool(bool),
    Int(i64),
    Str(String),
    StrList(Vec<String>),
}

/// section name → key → value.  Keys before any header land in `""`.
pub type Doc = BTreeMap<String, BTreeMap<String, Value>>;

/// Parse a TOML-subset document.
pub fn parse_doc(text: &str) -> Result<Doc> {
    let mut doc: Doc = BTreeMap::new();
    let mut section = String::new();
    doc.entry(section.clone()).or_default();
    for (idx, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let at = |msg: &str| anyhow!("line {}: {msg}: `{}`", idx + 1, raw.trim());
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| at("unterminated section header"))?;
            section = name.trim().to_string();
            doc.entry(section.clone()).or_default();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| at("expected `key = value`"))?;
        let key = parse_key(key.trim()).ok_or_else(|| at("bad key"))?;
        let value = parse_value(value.trim()).ok_or_else(|| at("bad value"))?;
        let table = doc.get_mut(&section).expect("section entry exists");
        if table.insert(key, value).is_some() {
            return Err(at("duplicate key"));
        }
    }
    Ok(doc)
}

/// Strip a `#` comment, ignoring `#` inside double-quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_key(s: &str) -> Option<String> {
    if let Some(q) = parse_quoted(s) {
        return Some(q);
    }
    let ok = !s.is_empty()
        && s.chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'));
    ok.then(|| s.to_string())
}

fn parse_quoted(s: &str) -> Option<String> {
    let inner = s.strip_prefix('"')?.strip_suffix('"')?;
    if inner.contains('"') {
        return None;
    }
    Some(inner.to_string())
}

fn parse_value(s: &str) -> Option<Value> {
    match s {
        "true" => return Some(Value::Bool(true)),
        "false" => return Some(Value::Bool(false)),
        _ => {}
    }
    if let Some(q) = parse_quoted(s) {
        return Some(Value::Str(q));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']')?;
        let mut items = Vec::new();
        for item in inner.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue; // trailing comma
            }
            items.push(parse_quoted(item)?);
        }
        return Some(Value::StrList(items));
    }
    s.parse::<i64>().ok().map(Value::Int)
}

/// Per-rule configuration: which files the rule scans and which it
/// exempts.  Entries ending in `/` are directory prefixes, `"."`
/// matches everything, anything else is an exact file path — all
/// relative to the scan root (`rust/src/`), forward slashes.
#[derive(Debug, Clone, Default)]
pub struct RuleConfig {
    pub scope: Vec<String>,
    pub allow: Vec<String>,
}

impl RuleConfig {
    /// Does this rule apply to the file at `rel`?
    pub fn applies(&self, rel: &str) -> bool {
        Self::matches(&self.scope, rel) && !Self::matches(&self.allow, rel)
    }

    fn matches(entries: &[String], rel: &str) -> bool {
        entries.iter().any(|e| {
            e == "." || (e.ends_with('/') && rel.starts_with(e.as_str())) || e == rel
        })
    }
}

/// The loaded `detlint.toml`.
#[derive(Debug, Clone)]
pub struct Config {
    /// Skip `#[cfg(test)] mod … { … }` blocks (default true): the
    /// determinism contract governs library behavior, tests assert it.
    pub skip_cfg_test: bool,
    pub rules: BTreeMap<String, RuleConfig>,
}

impl Config {
    /// Parse a config and require a `[rules.<name>]` section for every
    /// rule in `required` — a silently missing section must not read as
    /// "rule disabled".
    pub fn parse(text: &str, required: &[&str]) -> Result<Config> {
        let doc = parse_doc(text)?;
        let skip_cfg_test = match doc.get("scan").and_then(|t| t.get("skip-cfg-test")) {
            Some(Value::Bool(b)) => *b,
            Some(_) => bail!("[scan] skip-cfg-test must be a bool"),
            None => true,
        };
        let mut rules = BTreeMap::new();
        for name in required {
            let section = format!("rules.{name}");
            let table = doc
                .get(&section)
                .ok_or_else(|| anyhow!("missing [{section}] in detlint.toml"))?;
            let list = |key: &str| -> Result<Vec<String>> {
                match table.get(key) {
                    Some(Value::StrList(v)) => Ok(v.clone()),
                    Some(_) => bail!("[{section}] {key} must be a string array"),
                    None => Ok(Vec::new()),
                }
            };
            let rule = RuleConfig {
                scope: list("scope")?,
                allow: list("allow")?,
            };
            if rule.scope.is_empty() {
                bail!("[{section}] needs a non-empty scope");
            }
            rules.insert(name.to_string(), rule);
        }
        Ok(Config {
            skip_cfg_test,
            rules,
        })
    }

    pub fn rule(&self, name: &str) -> &RuleConfig {
        self.rules
            .get(name)
            .expect("rule sections are validated at parse time")
    }
}

/// Parse `detlint-baseline.toml`: a single `[counts]` table mapping
/// `"module path" = count`.
pub fn parse_baseline(text: &str) -> Result<BTreeMap<String, usize>> {
    let doc = parse_doc(text)?;
    let table = doc
        .get("counts")
        .ok_or_else(|| anyhow!("missing [counts] in baseline"))?;
    let mut counts = BTreeMap::new();
    for (k, v) in table {
        match v {
            Value::Int(n) if *n >= 0 => counts.insert(k.clone(), *n as usize),
            _ => bail!("baseline count for {k} must be a non-negative integer"),
        };
    }
    Ok(counts)
}

/// Render a baseline file deterministically (sorted by module path).
pub fn render_baseline(counts: &BTreeMap<String, usize>) -> String {
    let mut out = String::new();
    out.push_str(
        "# Panic-surface ratchet baseline: `.unwrap()` / `.expect(` occurrences per\n\
         # library module under rust/src/ (tests excluded).  Generated by\n\
         # `cargo run -p detlint -- --write-baseline`; do not edit by hand.\n\
         # detlint fails when any module's count GROWS past its entry here;\n\
         # CI fails when this file drifts from the regenerated output.\n\n\
         [counts]\n",
    );
    for (k, v) in counts {
        out.push_str(&format!("\"{k}\" = {v}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_subset() {
        let doc = parse_doc(
            "top = 3\n# comment\n[a.b]\nflag = true\nlist = [\"x\", \"y/\",]\nname = \"s#t\" # tail\n",
        )
        .unwrap();
        assert_eq!(doc[""]["top"], Value::Int(3));
        assert_eq!(doc["a.b"]["flag"], Value::Bool(true));
        assert_eq!(
            doc["a.b"]["list"],
            Value::StrList(vec!["x".into(), "y/".into()])
        );
        assert_eq!(doc["a.b"]["name"], Value::Str("s#t".into()));
    }

    #[test]
    fn rejects_junk() {
        assert!(parse_doc("[unterminated\n").is_err());
        assert!(parse_doc("key value\n").is_err());
        assert!(parse_doc("k = [1, 2]\n").is_err());
        assert!(parse_doc("k = 1\nk = 2\n").is_err());
    }

    #[test]
    fn scope_matching() {
        let rule = RuleConfig {
            scope: vec!["coordinator/".into(), "main.rs".into()],
            allow: vec!["coordinator/sched.rs".into()],
        };
        assert!(rule.applies("coordinator/pipeline.rs"));
        assert!(rule.applies("main.rs"));
        assert!(!rule.applies("coordinator/sched.rs"));
        assert!(!rule.applies("obs/span.rs"));
        let all = RuleConfig {
            scope: vec![".".into()],
            allow: vec![],
        };
        assert!(all.applies("anything/at/all.rs"));
    }

    #[test]
    fn baseline_roundtrip() {
        let mut counts = BTreeMap::new();
        counts.insert("coordinator/sched.rs".to_string(), 13);
        counts.insert("util/json.rs".to_string(), 0);
        let text = render_baseline(&counts);
        assert_eq!(parse_baseline(&text).unwrap(), counts);
    }
}
