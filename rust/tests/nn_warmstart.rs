//! Nearest-neighbor warm start, end to end: a never-cached conv shape
//! starts its search from a *similar* cached workload's schedules
//! (remapped and validated against the new geometry), the seed probe
//! grounds round 0 on the best neighbor, and the fallback degrades to
//! zero seeds when the index is empty, disabled, or every record
//! carries a stale featurizer/simulator version stamp.

use std::sync::Arc;

use moses::coordinator::{AutoTuner, BackendKind, TuneConfig};
use moses::device::{presets, DeviceSim};
use moses::program::{Subgraph, SubgraphKind, TensorProgram};
use moses::transfer::Strategy;
use moses::tunecache::{persist, warmstart, TuneCache, WarmStartOptions, RECORD_VERSION};

fn conv(name: &str, cout: usize) -> Subgraph {
    Subgraph::new(
        name,
        SubgraphKind::Conv2d {
            n: 1, h: 28, w: 28, cin: 64, cout, kh: 3, kw: 3, stride: 1, pad: 1,
        },
    )
}

fn cfg(seed: u64) -> TuneConfig {
    TuneConfig {
        trials_per_task: 16,
        measure_batch: 4,
        strategy: Strategy::AnsorRandom,
        population: 24,
        generations: 2,
        backend: BackendKind::Rust,
        seed,
        ..TuneConfig::default()
    }
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("moses_nn_warmstart_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn never_cached_shape_starts_from_neighbor_schedules() {
    let cache = Arc::new(TuneCache::in_memory(8));

    // Tune a 48-channel conv: its records populate store AND index.
    let similar = conv("nn.similar", 48);
    let mut src = AutoTuner::builder(presets::rtx_2060())
        .config(&cfg(1))
        .cache(cache.clone())
        .build()
        .unwrap();
    src.tune(std::slice::from_ref(&similar)).unwrap();
    assert!(cache.total_records() > 0);

    // A 64-channel conv was never cached: no exact hit, no same-workload
    // cross-device records — but the neighbor tier finds the 48-channel
    // records and remaps their schedules onto the new geometry.
    let novel = conv("nn.novel", 64);
    let plan = warmstart::plan(
        &cache,
        &novel,
        &presets::rtx_2060(),
        &WarmStartOptions::new(8, 16),
    );
    assert!(plan.exact.is_none());
    assert!(plan.seeds.is_empty(), "no same-workload records can exist");
    assert!(!plan.neighbor_seeds.is_empty(), "similar conv should seed the novel one");
    let g = novel.geometry();
    for s in &plan.neighbor_seeds {
        assert!(s.schedule.is_valid(&g), "neighbor seed invalid for new geometry");
        assert!(s.distance > 0.0, "a different workload cannot be at distance 0");
    }
    assert!(cache.stats().neighbor_seeds >= plan.neighbor_seeds.len());

    // End to end: the tuner reports the neighbor seeding, and the seed
    // probe grounds round 0 at (or below) the best probed neighbor.
    let mut warm = AutoTuner::builder(presets::rtx_2060())
        .config(&cfg(2))
        .cache(cache.clone())
        .build()
        .unwrap();
    let sw = warm.tune(std::slice::from_ref(&novel)).unwrap();
    assert!(!sw.tasks[0].cache_hit);
    assert_eq!(sw.tasks[0].warm_seeds, 0);
    assert!(sw.tasks[0].neighbor_seeds >= 1, "session must report neighbor seeds");
    assert_eq!(sw.neighbor_seeded_tasks(), 1);

    let sim = DeviceSim::new(presets::rtx_2060());
    let probe_best = plan
        .neighbor_seeds
        .iter()
        .take(cfg(2).seed_probe)
        .map(|s| sim.true_latency(&TensorProgram::new(novel.clone(), s.schedule)))
        .fold(f64::INFINITY, f64::min);
    if probe_best.is_finite() {
        assert!(
            sw.tasks[0].history[0] <= probe_best * (1.0 + 1e-9),
            "round-0 best {} should already match the probed neighbor {}",
            sw.tasks[0].history[0],
            probe_best
        );
    }
}

#[test]
fn empty_index_and_disabled_nn_yield_zero_neighbor_seeds() {
    // Empty cache: nothing to retrieve.
    let cache = Arc::new(TuneCache::in_memory(8));
    let novel = conv("nn.empty", 64);
    let plan = warmstart::plan(
        &cache,
        &novel,
        &presets::rtx_2060(),
        &WarmStartOptions::new(8, 16),
    );
    assert!(plan.neighbor_seeds.is_empty());
    assert_eq!(cache.stats().neighbor_seeds, 0);

    // Populated cache but NN disabled (the --no-nn path).
    let similar = conv("nn.similar", 48);
    let mut src = AutoTuner::builder(presets::rtx_2060())
        .config(&cfg(3))
        .cache(cache.clone())
        .build()
        .unwrap();
    src.tune(std::slice::from_ref(&similar)).unwrap();

    let mut off = cfg(4);
    off.nn_radius = None;
    let mut tuner = AutoTuner::builder(presets::rtx_2060())
        .config(&off)
        .cache(cache.clone())
        .build()
        .unwrap();
    let s = tuner.tune(std::slice::from_ref(&novel)).unwrap();
    assert_eq!(s.tasks[0].neighbor_seeds, 0);
    assert_eq!(s.neighbor_seeded_tasks(), 0);
    assert_eq!(cache.stats().neighbor_seeds, 0);
}

#[test]
fn stale_version_stamps_are_dropped_on_load_and_never_seed() {
    let path = tmp("stale.jsonl");
    let _ = std::fs::remove_file(&path);

    // Write a single-file log of records produced under a *different*
    // featurizer/simulator version.
    let similar = conv("nn.similar", 48);
    let src_cache = Arc::new(TuneCache::in_memory(8));
    {
        let mut src = AutoTuner::builder(presets::rtx_2060())
            .config(&cfg(5))
            .cache(src_cache.clone())
            .build()
            .unwrap();
        src.tune(std::slice::from_ref(&similar)).unwrap();
    }
    let mut records = src_cache.snapshot();
    assert!(!records.is_empty());
    for r in &mut records {
        r.version = RECORD_VERSION + 1;
    }
    persist::rewrite(&path, &records).unwrap();

    // Reopen: the single-file log imports via the legacy read-only
    // path, and every record is stale — dropped from store and index.
    let cache = Arc::new(TuneCache::open(&path, 8).unwrap());
    assert!(path.is_file(), "legacy import must leave the file a file");
    assert_eq!(cache.total_records(), 0);
    assert_eq!(cache.stats().stale_dropped, records.len());

    // Neither the exact tier nor the neighbor tier may serve them: even
    // the *same* workload is a cold start now, and the similar novel
    // shape gets zero neighbor seeds.
    for task in [similar, conv("nn.novel", 64)] {
        let plan = warmstart::plan(
            &cache,
            &task,
            &presets::rtx_2060(),
            &WarmStartOptions::new(8, 16),
        );
        assert!(plan.exact.is_none());
        assert!(plan.seeds.is_empty());
        assert!(plan.neighbor_seeds.is_empty(), "stale records must not seed");
    }
    assert_eq!(cache.stats().neighbor_seeds, 0);
}
