//! Rust↔XLA numeric parity: the pure-Rust mirror (`rust_mlp`) must agree
//! with the AOT-compiled Pallas/JAX artifacts executed through PJRT.
//!
//! This is the cross-language analogue of the pytest kernel-vs-ref suite:
//! python tests pin Pallas == jnp-oracle, this test pins XLA artifacts ==
//! Rust mirror, so all four implementations agree transitively.
//!
//! Skips (with a loud message) if `artifacts/` is missing — run
//! `make artifacts` first; the Makefile `test` target does.  The whole
//! suite is compiled out when the `xla` feature is off (the default in
//! offline builds, where the PJRT runtime is unavailable).

#![cfg(feature = "xla")]

use std::sync::Arc;

use moses::costmodel::{layout, mask::Mask, Backend, RustBackend, XlaBackend};
use moses::runtime::Engine;
use moses::util::rng::Rng;

fn engine_or_skip() -> Option<Arc<Engine>> {
    let dir = Engine::default_dir();
    if !dir.join("meta.json").exists() {
        eprintln!("SKIP xla_parity: no artifacts at {dir:?} — run `make artifacts`");
        return None;
    }
    Some(Arc::new(Engine::load(&dir).expect("engine load")))
}

fn rand_rows(rng: &mut Rng, n: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let x: Vec<f32> = (0..n * layout::N_FEATURES).map(|_| rng.normal() as f32).collect();
    let y: Vec<f32> = (0..n).map(|_| rng.uniform_in(0.0, 10.0) as f32).collect();
    let w: Vec<f32> = vec![1.0; n];
    (x, y, w)
}

fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        let diff = (a[i] - b[i]).abs();
        let tol = atol + rtol * b[i].abs();
        assert!(
            diff <= tol,
            "{what}[{i}]: xla={} rust={} diff={diff} tol={tol}",
            a[i],
            b[i]
        );
    }
}

#[test]
fn predict_parity() {
    let Some(engine) = engine_or_skip() else { return };
    let xla = XlaBackend { engine };
    let rust = RustBackend::default();
    assert_eq!(xla.pred_batch(), rust.pred_batch);

    let mut rng = Rng::new(100);
    let params = layout::init_params(&mut rng);
    let (x, _, _) = rand_rows(&mut rng, xla.pred_batch());
    let a = xla.predict_fixed(&params, &x).unwrap();
    let b = rust.predict_fixed(&params, &x).unwrap();
    assert_close(&a, &b, 2e-4, 2e-4, "predict");
}

#[test]
fn loss_parity() {
    let Some(engine) = engine_or_skip() else { return };
    let xla = XlaBackend { engine };
    let rust = RustBackend::default();
    let mut rng = Rng::new(101);
    let params = layout::init_params(&mut rng);
    let (x, y, w) = rand_rows(&mut rng, xla.train_batch());
    let a = xla.loss_fixed(&params, &x, &y, &w).unwrap();
    let b = rust.loss_fixed(&params, &x, &y, &w).unwrap();
    assert!((a - b).abs() <= 1e-4 + 1e-3 * b.abs(), "loss: xla={a} rust={b}");
}

#[test]
fn train_step_parity_vanilla_and_masked() {
    let Some(engine) = engine_or_skip() else { return };
    let xla = XlaBackend { engine };
    let rust = RustBackend::default();
    let mut rng = Rng::new(102);
    let params = layout::init_params(&mut rng);
    let m = vec![0.0f32; layout::N_PARAMS];
    let v = vec![0.0f32; layout::N_PARAMS];
    let (x, y, w) = rand_rows(&mut rng, xla.train_batch());

    // Coordinates whose analytic gradient is ~0 (e.g. the head bias b3 —
    // a pairwise-difference loss is invariant to constant score shifts)
    // get an Adam step of lr·g/(|g|+eps) where g is pure summation noise,
    // so XLA and Rust legitimately disagree there.  Compare only where
    // the gradient carries signal.
    let (_, grads) = moses::costmodel::rust_mlp::backward(&params, &x, y.len(), &y, &w);
    let signal: Vec<bool> = grads.iter().map(|g| g.abs() >= 1e-6).collect();
    let n_signal = signal.iter().filter(|&&s| s).count();
    assert!(
        n_signal as f64 > 0.5 * layout::N_PARAMS as f64,
        "degenerate test batch: only {n_signal} signal coords"
    );
    let close_where = |a: &[f32], b: &[f32], rtol: f32, atol: f32, what: &str| {
        for i in 0..a.len() {
            if !signal[i] {
                continue;
            }
            let diff = (a[i] - b[i]).abs();
            let tol = atol + rtol * b[i].abs();
            assert!(diff <= tol, "{what}[{i}]: xla={} rust={} diff={diff}", a[i], b[i]);
        }
    };

    for (label, mask) in [
        ("vanilla", Mask::all_ones(layout::N_PARAMS)),
        ("half", {
            let xi: Vec<f32> = (0..layout::N_PARAMS).map(|_| rng.uniform() as f32).collect();
            Mask::from_xi_ratio(&xi, 0.5)
        }),
    ] {
        let hp = [1e-3, 1e-2, 1.0, 0.0];
        let (pa, ma, va, la) = xla
            .train_step_fixed(&params, &m, &v, &x, &y, &w, &mask.values, hp)
            .unwrap();
        let (pb, mb, vb, lb) = rust
            .train_step_fixed(&params, &m, &v, &x, &y, &w, &mask.values, hp)
            .unwrap();
        assert!((la - lb).abs() <= 1e-4 + 1e-3 * lb.abs(), "{label} loss: {la} vs {lb}");
        close_where(&pa, &pb, 1e-3, 2e-5, &format!("{label} params"));
        close_where(&ma, &mb, 2e-2, 1e-7, &format!("{label} m"));
        close_where(&va, &vb, 2e-2, 1e-10, &format!("{label} v"));
    }
}

#[test]
fn xi_parity() {
    let Some(engine) = engine_or_skip() else { return };
    let xla = XlaBackend { engine };
    let rust = RustBackend::default();
    let mut rng = Rng::new(103);
    let params = layout::init_params(&mut rng);
    let (x, y, w) = rand_rows(&mut rng, xla.train_batch());
    let a = xla.xi_fixed(&params, &x, &y, &w).unwrap();
    let b = rust.xi_fixed(&params, &x, &y, &w).unwrap();
    // ξ magnitudes are tiny; compare with a mixed tolerance and also the
    // *induced masks*, which is what the algorithm actually consumes.
    assert_close(&a, &b, 5e-3, 1e-7, "xi");
    let ma = Mask::from_xi_ratio(&a, 0.5);
    let mb = Mask::from_xi_ratio(&b, 0.5);
    let agree = ma
        .values
        .iter()
        .zip(mb.values.iter())
        .filter(|(x, y)| x == y)
        .count() as f64
        / layout::N_PARAMS as f64;
    assert!(agree > 0.99, "mask agreement {agree}");
}

#[test]
fn padded_predict_ignores_padding() {
    let Some(engine) = engine_or_skip() else { return };
    let backend: Arc<dyn Backend> = Arc::new(XlaBackend { engine });
    let mut rng = Rng::new(104);
    let model = moses::costmodel::CostModel::new(backend, &mut rng);
    let (x, _, _) = rand_rows(&mut rng, 13);
    let scores = model.predict(&x, 13).unwrap();
    assert_eq!(scores.len(), 13);
    // Re-scoring the same rows in a different-sized call gives the same
    // answers (padding did not bleed in).
    let again = model.predict(&x[..5 * layout::N_FEATURES], 5).unwrap();
    for i in 0..5 {
        assert!((scores[i] - again[i]).abs() < 1e-6);
    }
}
