//! Crash-safety and multi-writer integration for the segmented
//! tunecache: torn temp files and dead writers' segments are recovered,
//! an interrupted compaction (temp written, rename never happened,
//! advisory lock leaked) loses nothing, two cache instances appending
//! to one directory merge without record loss, and a legacy single-file
//! log imports read-only.

use std::path::{Path, PathBuf};

use moses::device::presets;
use moses::program::{SpaceGenerator, Subgraph, SubgraphKind};
use moses::tunecache::{persist, TuneCache, TuneRecord, WorkloadKey};
use moses::util::rng::Rng;

fn conv(name: &str, cout: usize) -> Subgraph {
    Subgraph::new(
        name,
        SubgraphKind::Conv2d {
            n: 1, h: 28, w: 28, cin: 64, cout, kh: 3, kw: 3, stride: 1, pad: 1,
        },
    )
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("moses_tunecache_crash_it").join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// `n` distinct-schedule records for `(task, arch)` with latencies
/// `base, 2*base, ...` — so `base` is always the per-key best.
fn records_for(
    task: &Subgraph,
    arch: &moses::device::DeviceArch,
    n: usize,
    seed: u64,
    base_latency: f64,
) -> Vec<TuneRecord> {
    let gen = SpaceGenerator::new(task.geometry());
    let mut rng = Rng::new(seed);
    let scheds = gen.sample_distinct(&mut rng, n);
    assert_eq!(scheds.len(), n, "schedule space too small for this test");
    scheds
        .iter()
        .enumerate()
        .map(|(i, s)| {
            TuneRecord::new(
                WorkloadKey::new(task, arch),
                task.descriptor(),
                &arch.name,
                s,
                base_latency * (i + 1) as f64,
                2.0,
                64,
            )
        })
        .collect()
}

/// A pid no process on this box can hold (pid_max caps far below).
const DEAD_PID: u32 = u32::MAX;

fn write_file(path: &Path, contents: &str) {
    std::fs::write(path, contents).unwrap();
}

#[test]
fn torn_temp_and_dead_writer_segments_are_recovered() {
    let dir = tmp_dir("torn");
    let task = conv("crash.conv", 64);
    let arch = presets::rtx_2060();
    let recs = records_for(&task, &arch, 4, 1, 1e-3);
    {
        let cache = TuneCache::open(&dir, 8).unwrap();
        for r in &recs {
            assert!(cache.commit(r.clone()));
        }
    } // clean close seals the segment

    // A compactor crashed mid-rewrite: a torn temp sits beside the log.
    let torn_tmp = dir.join(format!("checkpoint.jsonl.tmp-{DEAD_PID}-0"));
    write_file(&torn_tmp, "{\"workload\": trunc");
    // A writer crashed before sealing: its dead-pid segment carries one
    // good record and a torn tail.
    let other = records_for(&conv("crash.other", 96), &arch, 1, 2, 5e-4);
    let dead_seg = dir.join(format!("seg-{DEAD_PID}-1.jsonl"));
    write_file(
        &dead_seg,
        &format!("{}\n{{\"workload\": trunc", persist::encode_line(&other[0])),
    );

    // Merge-on-open admits every record; the torn temp matches no log
    // pattern and is never read as one.
    let cache = TuneCache::open(&dir, 8).unwrap();
    assert_eq!(cache.total_records(), recs.len() + 1);
    let key = WorkloadKey::new(&task, &arch);
    assert!((cache.best(&key).unwrap().latency_s - 1e-3).abs() < 1e-15);

    if !cfg!(target_os = "linux") {
        return; // dead-pid detection (and thus GC) needs /proc
    }
    // The torn line triggered the open-time purge: the crashed writer's
    // segment folded into the checkpoint, the orphan temp was swept.
    assert!(!torn_tmp.exists(), "orphaned temp must be swept");
    assert!(!dead_seg.exists(), "dead writer's segment must be folded away");
    drop(cache);
    let (records, skipped) = persist::load_log(&dir).unwrap();
    assert_eq!(records.len(), recs.len() + 1, "no admitted record may be lost");
    assert_eq!(skipped, 0, "junk lines must be purged from disk");
}

#[test]
fn interrupted_compaction_and_stale_lock_lose_nothing() {
    let dir = tmp_dir("interrupted");
    let task = conv("crash.rn", 64);
    let arch = presets::jetson_tx2();
    let recs = records_for(&task, &arch, 5, 3, 1e-3);
    {
        let cache = TuneCache::open(&dir, 8).unwrap();
        for r in &recs {
            assert!(cache.commit(r.clone()));
        }
    }
    // A compactor died after writing its temp checkpoint but before the
    // rename.  The temp holds a strict subset — trusting it would lose
    // records; the unique `.tmp-*` name keeps it invisible to readers.
    let stranded = dir.join(format!("checkpoint.jsonl.tmp-{DEAD_PID}-7"));
    write_file(&stranded, &format!("{}\n", persist::encode_line(&recs[0])));
    // ...and it leaked its advisory lock.
    write_file(&dir.join("compact.lock"), &format!("{DEAD_PID}\n"));

    // Reopen: the abandoned temp is ignored, nothing is lost.
    let cache = TuneCache::open(&dir, 8).unwrap();
    assert_eq!(cache.total_records(), recs.len());

    if !cfg!(target_os = "linux") {
        return; // stealing the dead holder's lock needs /proc liveness
    }
    // Compaction steals the stale lock, folds the sealed segment into a
    // durable checkpoint, sweeps the orphan temp, releases the lock.
    cache.compact().unwrap();
    assert!(dir.join("checkpoint.jsonl").is_file());
    assert!(!stranded.exists(), "orphaned temp must be swept");
    assert!(!dir.join("compact.lock").exists(), "lock must be released");
    let (records, skipped) = persist::load_log(&dir).unwrap();
    assert_eq!(records.len(), recs.len());
    assert_eq!(skipped, 0);
    let best = records.iter().map(|r| r.latency_s).fold(f64::INFINITY, f64::min);
    assert!((best - 1e-3).abs() < 1e-15);
}

#[test]
fn two_writers_share_one_directory_without_record_loss() {
    let dir = tmp_dir("two-writers");
    let arch_a = presets::rtx_2060();
    let arch_b = presets::jetson_tx2();
    let task_a = conv("tw.a", 64);
    let task_b = conv("tw.b", 96);
    let task_c = conv("tw.c", 128);
    let recs_a = records_for(&task_a, &arch_a, 5, 4, 1e-3);
    let recs_b = records_for(&task_b, &arch_b, 5, 5, 2e-3);
    let recs_c = records_for(&task_c, &arch_b, 3, 6, 3e-3);

    // Two instances (stand-ins for two processes) on one directory,
    // each appending to its own exclusively-owned segment.
    let a = TuneCache::open(&dir, 8).unwrap();
    let b = TuneCache::open(&dir, 8).unwrap();
    for (ra, rb) in recs_a.iter().zip(&recs_b) {
        assert!(a.commit(ra.clone()));
        assert!(b.commit(rb.clone()));
    }
    // One writer compacts mid-flight: it may fold only its own rotated
    // segment (covered by its in-memory frontier) — the other's live
    // segment must survive untouched.
    a.compact().unwrap();
    for r in &recs_c {
        assert!(b.commit(r.clone()));
    }
    drop(a);
    drop(b);

    // A third open merges checkpoint + both writers' output: zero
    // admitted records lost across append + compaction + reopen.
    let merged = TuneCache::open(&dir, 8).unwrap();
    assert_eq!(
        merged.total_records(),
        recs_a.len() + recs_b.len() + recs_c.len(),
        "merge-on-open lost records"
    );
    let ka = WorkloadKey::new(&task_a, &arch_a);
    let kb = WorkloadKey::new(&task_b, &arch_b);
    let kc = WorkloadKey::new(&task_c, &arch_b);
    assert!((merged.best(&ka).unwrap().latency_s - 1e-3).abs() < 1e-15);
    assert!((merged.best(&kb).unwrap().latency_s - 2e-3).abs() < 1e-15);
    assert_eq!(merged.records(&kc).len(), recs_c.len());
}

#[test]
fn legacy_single_file_log_imports_read_only() {
    let parent = std::env::temp_dir().join("moses_tunecache_crash_it");
    std::fs::create_dir_all(&parent).unwrap();
    let path = parent.join("legacy.jsonl");
    let _ = std::fs::remove_file(&path);
    let task = conv("legacy.conv", 64);
    let arch = presets::rtx_2060();
    let recs = records_for(&task, &arch, 4, 7, 1e-3);
    persist::rewrite(&path, &recs).unwrap();
    let before = std::fs::read(&path).unwrap();

    let cache = TuneCache::open(&path, 8).unwrap();
    assert!(path.is_file(), "legacy import must leave the file a file");
    assert_eq!(cache.total_records(), recs.len());
    // Commits are admitted in memory but never written back...
    let extra = records_for(&conv("legacy.other", 96), &arch, 1, 8, 5e-4);
    assert!(cache.commit(extra[0].clone()));
    assert_eq!(cache.total_records(), recs.len() + 1);
    // ...and compaction is a no-op: the log is never mutated.
    cache.compact().unwrap();
    drop(cache);
    assert_eq!(std::fs::read(&path).unwrap(), before, "legacy log must stay untouched");

    // A reopen sees the original records only — by design: one shared
    // file cannot host concurrent appends safely, so it is frozen.
    let reopened = TuneCache::open(&path, 8).unwrap();
    assert_eq!(reopened.total_records(), recs.len());
}

#[test]
fn append_debt_triggers_directory_compaction() {
    let dir = tmp_dir("debt");
    let task = conv("debt.conv", 64);
    let arch = presets::rtx_2060();
    let gen = SpaceGenerator::new(task.geometry());
    let mut rng = Rng::new(9);
    let sched = gen.sample_distinct(&mut rng, 1)[0];
    let key = WorkloadKey::new(&task, &arch);
    let cache = TuneCache::builder(&dir).topk(1).open().unwrap();
    // 80 successive improvements of one schedule: every commit is
    // admitted (strictly better) and appended, but the live frontier
    // stays at ONE record — classic append debt.
    for i in 0..80u32 {
        let lat = 1e-3 / f64::from(i + 1);
        assert!(cache.commit(TuneRecord::new(
            key,
            task.descriptor(),
            &arch.name,
            &sched,
            lat,
            2.0,
            64,
        )));
    }
    assert_eq!(cache.total_records(), 1);
    assert!(cache.stats().compactions >= 1, "append debt must trigger compaction");
    assert!(dir.join("checkpoint.jsonl").is_file());
    // Disk holds far fewer lines than the 80 appends...
    let (records, skipped) = persist::load_log(&dir).unwrap();
    assert_eq!(skipped, 0);
    assert!(records.len() < 40, "log was not folded: {} lines", records.len());
    drop(cache);
    // ...and the surviving record is the true best.
    let reopened = TuneCache::open(&dir, 1).unwrap();
    assert_eq!(reopened.best(&key).unwrap().latency_s, 1e-3 / 80.0);
}
