//! The redesigned model/session API surface: snapshot immutability on
//! the zero-copy prediction plane (a pinned `Predictor` must be immune
//! to later learner updates) and builder-time validation (invalid knob
//! combinations return errors before a session exists — never a panic
//! mid-session).

use std::sync::Arc;

use moses::coordinator::{AutoTuner, BackendKind, ModelSnapshot, SnapshotCell, TuneConfig};
use moses::costmodel::{layout, CostModel, Mask, ModelState, Predictor, RustBackend};
use moses::program::{Subgraph, SubgraphKind};
use moses::transfer::Strategy;
use moses::util::rng::Rng;

fn backend() -> Arc<RustBackend> {
    Arc::new(RustBackend { pred_batch: 16, train_batch: 16 })
}

fn labeled_rows(rng: &mut Rng, n: usize) -> (Vec<f32>, Vec<f32>) {
    let x: Vec<f32> = (0..n * layout::N_FEATURES).map(|_| rng.normal() as f32).collect();
    let y: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
    (x, y)
}

#[test]
fn pinned_predictor_survives_learner_updates_unchanged() {
    let mut rng = Rng::new(1);
    let mut model = CostModel::new(backend(), &mut rng);
    let (x, y) = labeled_rows(&mut rng, 16);

    let pinned = model.predictor();
    let before = pinned.predict(&x, 16).unwrap();
    let pinned_version = pinned.version();

    // Several "learner" updates after the pin.
    let mask = Mask::all_ones(layout::N_PARAMS);
    for _ in 0..5 {
        model.train_step(&x, &y, &mask, 1e-2, 0.0).unwrap();
    }

    // Bitwise-identical predictions from the pin; the live model moved.
    assert_eq!(pinned.predict(&x, 16).unwrap(), before);
    assert_eq!(pinned.version(), pinned_version);
    let live = model.predictor();
    assert!(live.version() > pinned_version);
    assert_ne!(live.predict(&x, 16).unwrap(), before);
    // Copy-on-write means the storages are distinct objects now.
    assert!(!Arc::ptr_eq(pinned.state(), live.state()));
}

#[test]
fn snapshot_publish_and_pin_share_storage() {
    let mut rng = Rng::new(2);
    let model = CostModel::new(backend(), &mut rng);

    // Publish through the cell exactly as the parallel learner actor
    // does, pin twice as two workers would: every handle aliases the
    // same storage — the publish→pin round trip never copies params.
    let cell = SnapshotCell::new(ModelSnapshot::from_model(model.shared_state()));
    let worker_a = cell.wait_for(0).unwrap();
    let worker_b = cell.wait_for(0).unwrap();
    assert!(Arc::ptr_eq(&worker_a.model, &worker_b.model));
    assert!(Arc::ptr_eq(&worker_a.model, &model.shared_state()));
    // No draft tier configured: snapshots carry no draft scorer.
    assert!(worker_a.draft.is_none());

    // A pinned view built from the snapshot predicts identically to the
    // source model.
    let (x, _) = labeled_rows(&mut rng, 8);
    let view = Predictor::new(backend(), worker_a.model);
    assert_eq!(view.predict(&x, 8).unwrap(), model.predict(&x, 8).unwrap());
}

#[test]
fn publishing_a_new_state_leaves_old_pins_untouched() {
    let mut rng = Rng::new(3);
    let mut model = CostModel::new(backend(), &mut rng);
    let (x, y) = labeled_rows(&mut rng, 16);

    let cell = SnapshotCell::new(ModelSnapshot::from_model(model.shared_state()));
    let pin_v0 = cell.wait_for(0).unwrap();
    let before = Predictor::new(backend(), pin_v0.model.clone()).predict(&x, 16).unwrap();

    let mask = Mask::all_ones(layout::N_PARAMS);
    model.train_step(&x, &y, &mask, 1e-2, 0.0).unwrap();
    cell.publish(1, ModelSnapshot::from_model(model.shared_state()));

    let pin_v1 = cell.wait_for(1).unwrap();
    assert!(!Arc::ptr_eq(&pin_v0.model, &pin_v1.model));
    assert_eq!(Predictor::new(backend(), pin_v0.model).predict(&x, 16).unwrap(), before);
    assert_ne!(Predictor::new(backend(), pin_v1.model).predict(&x, 16).unwrap(), before);
}

#[test]
fn model_state_clone_is_shallow_and_send() {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<ModelState>();

    let state = ModelState::from_params(vec![0.25; layout::N_PARAMS]);
    let cloned = state.clone();
    // Shared storage: the clone's parameter slice is the same allocation.
    assert!(std::ptr::eq(state.params().as_ptr(), cloned.params().as_ptr()));
}

// ------------------------------------------------------------ builder ----

#[test]
fn builder_rejects_jobs_on_the_xla_backend() {
    let err = AutoTuner::builder(moses::device::presets::rtx_2060())
        .strategy(Strategy::AnsorRandom)
        .backend(BackendKind::Xla)
        .jobs(2)
        .build()
        .unwrap_err();
    assert!(err.to_string().contains("rust cost-model backend"), "{err}");
}

#[test]
fn builder_rejects_pretrain_strategy_without_a_checkpoint() {
    // Previously this panicked (`expect`) deep inside model init; the
    // builder must return an error instead.
    let err = AutoTuner::builder(moses::device::presets::jetson_tx2())
        .strategy(Strategy::TensetFinetune)
        .build()
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("pre-trained checkpoint"), "{msg}");
}

#[test]
fn builder_rejects_degenerate_budgets_and_radii() {
    let tx2 = moses::device::presets::jetson_tx2;
    assert!(AutoTuner::builder(tx2())
        .strategy(Strategy::AnsorRandom)
        .trials(0)
        .build()
        .is_err());
    assert!(AutoTuner::builder(tx2())
        .strategy(Strategy::AnsorRandom)
        .measure_batch(0)
        .build()
        .is_err());
    assert!(AutoTuner::builder(tx2())
        .strategy(Strategy::AnsorRandom)
        .search_params(1, 2)
        .build()
        .is_err());
    assert!(AutoTuner::builder(tx2())
        .strategy(Strategy::AnsorRandom)
        .jobs(0)
        .build()
        .is_err());
    assert!(AutoTuner::builder(tx2())
        .strategy(Strategy::AnsorRandom)
        .nn(Some(f64::NAN))
        .build()
        .is_err());
    assert!(AutoTuner::builder(tx2())
        .strategy(Strategy::AnsorRandom)
        .nn(Some(-0.5))
        .build()
        .is_err());
}

#[test]
fn builder_produces_the_serialized_config_and_tunes() {
    let mut tuner = AutoTuner::builder(moses::device::presets::rtx_2060())
        .trials(8)
        .measure_batch(4)
        .strategy(Strategy::AnsorRandom)
        .seed(5)
        .backend(BackendKind::Rust)
        .search_params(16, 2)
        .nn(None)
        .build()
        .unwrap();
    // The builder's output IS the serialized TuneConfig form.
    assert_eq!(tuner.config.trials_per_task, 8);
    assert_eq!(tuner.config.measure_batch, 4);
    assert_eq!(tuner.config.seed, 5);
    assert!(tuner.config.nn_radius.is_none());

    let task = Subgraph::new("api.dense", SubgraphKind::Dense { m: 32, n: 128, k: 128 });
    let session = tuner.tune(&[task]).unwrap();
    assert_eq!(session.tasks.len(), 1);
    assert!(session.tasks[0].best_latency_s.is_finite());
}

#[test]
fn builder_config_roundtrip_reproduces_flag_built_sessions() {
    // `.config(&cfg)` (the mechanical migration path) and the typed
    // setters build identical tuners: same session bit-for-bit.
    let cfg = TuneConfig {
        trials_per_task: 12,
        measure_batch: 4,
        strategy: Strategy::AnsorRandom,
        population: 16,
        generations: 2,
        backend: BackendKind::Rust,
        seed: 9,
        ..TuneConfig::default()
    };
    let task = || Subgraph::new("api.conv", SubgraphKind::Dense { m: 64, n: 128, k: 256 });
    let a = AutoTuner::builder(moses::device::presets::rtx_2060())
        .config(&cfg)
        .build()
        .unwrap()
        .tune(&[task()])
        .unwrap();
    let b = AutoTuner::builder(moses::device::presets::rtx_2060())
        .trials(12)
        .measure_batch(4)
        .strategy(Strategy::AnsorRandom)
        .search_params(16, 2)
        .backend(BackendKind::Rust)
        .seed(9)
        .build()
        .unwrap()
        .tune(&[task()])
        .unwrap();
    assert_eq!(a.tasks[0].best_latency_s.to_bits(), b.tasks[0].best_latency_s.to_bits());
    assert_eq!(a.total_measurements(), b.total_measurements());
}
