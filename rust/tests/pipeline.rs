//! End-to-end pipeline integration tests on the pure-Rust backend
//! (fast, artifact-free): pretraining → transfer → tuning → metrics,
//! plus the cross-device mechanism tests that pin the paper's core
//! claims at the system level.

use std::sync::Arc;

use moses::coordinator::{AutoTuner, BackendKind, TuneConfig};
use moses::costmodel::{layout, CostModel, Mask, RustBackend};
use moses::dataset::gen::{generate, GenConfig, TaskSource};
use moses::device::presets;
use moses::metrics;
use moses::models::zoo;
use moses::program::{Subgraph, SubgraphKind};
use moses::transfer::{MosesConfig, Strategy};
use moses::util::rng::Rng;

fn backend() -> Arc<RustBackend> {
    Arc::new(RustBackend { pred_batch: 64, train_batch: 64 })
}

fn small_tasks() -> Vec<Subgraph> {
    vec![
        Subgraph::new(
            "pl.conv",
            SubgraphKind::Conv2d {
                n: 1, h: 28, w: 28, cin: 96, cout: 96, kh: 3, kw: 3, stride: 1, pad: 1,
            },
        ),
        Subgraph::new("pl.dense", SubgraphKind::Dense { m: 128, n: 512, k: 768 }),
        Subgraph::new(
            "pl.dw",
            SubgraphKind::DepthwiseConv2d {
                n: 1, h: 28, w: 28, c: 192, kh: 3, kw: 3, stride: 1, pad: 1,
            },
        ),
    ]
}

/// Pre-train a small model on a K80 corpus over the same tasks.
fn pretrain(seed: u64, epochs: usize) -> Vec<f32> {
    let ds = generate(
        &presets::tesla_k80(),
        TaskSource::Tasks(small_tasks()),
        &GenConfig { records_per_task: 48, seed },
    );
    let (x, y) = ds.training_arrays();
    let mut rng = Rng::new(seed);
    let mut model = CostModel::new(backend(), &mut rng);
    let mask = Mask::all_ones(layout::N_PARAMS);
    for _ in 0..epochs {
        model.train_epoch(&x, &y, &mask, 1e-3, 0.0, &mut rng).unwrap();
    }
    model.params().to_vec()
}

fn cfg(strategy: Strategy, trials: usize) -> TuneConfig {
    TuneConfig {
        trials_per_task: trials,
        measure_batch: 4,
        strategy,
        population: 32,
        generations: 2,
        backend: BackendKind::Rust,
        seed: 7,
        ..TuneConfig::default()
    }
}

#[test]
fn full_pipeline_pretrain_transfer_tune() {
    let pre = pretrain(1, 4);
    let target = presets::jetson_tx2();

    let run = |strategy: Strategy| {
        let model = CostModel::with_params(backend(), pre.clone());
        let mut tuner = AutoTuner::builder(target.clone())
            .config(&cfg(strategy, 24))
            .model(model)
            .build()
            .unwrap();
        tuner.tune(&small_tasks()).unwrap()
    };

    let finetune = run(Strategy::TensetFinetune);
    let moses_s = run(Strategy::Moses(MosesConfig::default()));
    let pretrain_only = run(Strategy::TensetPretrain);

    // All improve on the default schedule.
    assert!(finetune.speedup() > 1.0);
    assert!(moses_s.speedup() > 1.0);

    // The paper's qualitative shape:
    // 1. Moses searches faster than vanilla fine-tuning (AC + masked
    //    updates ⇒ fewer measurements).
    assert!(
        moses_s.search_time_s() < finetune.search_time_s(),
        "moses {} vs finetune {}",
        moses_s.search_time_s(),
        finetune.search_time_s()
    );
    // 2. Pretrain-only is the fastest searcher (no online learning).
    assert!(pretrain_only.search_time_s() < moses_s.search_time_s());
    // 3. Moses' tuned latency is competitive with fine-tuning (within
    //    20% on this tiny budget) and better than pretrain-only.
    assert!(
        moses_s.total_best_latency_ms() < 1.2 * finetune.total_best_latency_ms(),
        "moses latency {} vs finetune {}",
        moses_s.total_best_latency_ms(),
        finetune.total_best_latency_ms()
    );
    // 4. CMAT vs finetune is positive (the paper's headline claim).
    let cmat = metrics::cmat(
        metrics::search_gain(finetune.search_time_s(), moses_s.search_time_s()),
        metrics::latency_reduction(
            finetune.total_best_latency_ms(),
            moses_s.total_best_latency_ms(),
        ),
    );
    assert!(cmat > 0.0, "CMAT {cmat}");
}

#[test]
fn transfer_beats_cold_start_on_quality_per_measurement() {
    // With the same small measurement budget, starting from the source
    // checkpoint should not be worse than a random-init model (the whole
    // premise of cross-device transfer).
    let pre = pretrain(3, 4);
    let target = presets::rtx_2060();

    let model_pre = CostModel::with_params(backend(), pre);
    let mut tuner_pre = AutoTuner::builder(target.clone())
        .config(&cfg(Strategy::TensetFinetune, 16))
        .model(model_pre)
        .build()
        .unwrap();
    let s_pre = tuner_pre.tune(&small_tasks()).unwrap();

    let mut tuner_cold =
        AutoTuner::builder(target).config(&cfg(Strategy::AnsorRandom, 16)).build().unwrap();
    let s_cold = tuner_cold.tune(&small_tasks()).unwrap();

    assert!(
        s_pre.total_best_latency_ms() < 1.25 * s_cold.total_best_latency_ms(),
        "transfer {} vs cold {}",
        s_pre.total_best_latency_ms(),
        s_cold.total_best_latency_ms()
    );
}

#[test]
fn moses_masked_training_changes_fewer_parameters() {
    // Mechanism check at system level: after a Moses session, the
    // fraction of parameters that moved from the checkpoint should be
    // well below a vanilla fine-tune session's.
    let pre = pretrain(5, 2);
    let target = presets::jetson_tx2();

    let moved_frac = |params: &[f32]| {
        params
            .iter()
            .zip(&pre)
            .filter(|(a, b)| (**a - **b).abs() > 1e-7)
            .count() as f64
            / params.len() as f64
    };

    let model_mo = CostModel::with_params(backend(), pre.clone());
    let mo_cfg = cfg(
        Strategy::Moses(MosesConfig { ratio: Some(0.3), ..MosesConfig::default() }),
        16,
    );
    let mut tuner_mo = AutoTuner::builder(target.clone())
        .config(&mo_cfg)
        .model(model_mo)
        .build()
        .unwrap();
    tuner_mo.tune(&small_tasks()[..1]).unwrap();
    let moses_moved = moved_frac(tuner_mo.model().params());

    let model_ft = CostModel::with_params(backend(), pre.clone());
    let mut tuner_ft = AutoTuner::builder(target)
        .config(&cfg(Strategy::TensetFinetune, 16))
        .model(model_ft)
        .build()
        .unwrap();
    tuner_ft.tune(&small_tasks()[..1]).unwrap();
    let ft_moved = moved_frac(tuner_ft.model().params());

    // Variant params under Moses move only by weight decay (tiny but
    // non-zero), so compare Adam-scale movements instead.
    let big_moved = |params: &[f32]| {
        params
            .iter()
            .zip(&pre)
            .filter(|(a, b)| (**a - **b).abs() > 1e-4)
            .count() as f64
            / params.len() as f64
    };
    let moses_big = big_moved(tuner_mo.model().params());
    let ft_big = big_moved(tuner_ft.model().params());
    assert!(
        moses_big < ft_big,
        "moses moved {moses_big} (any: {moses_moved}) vs finetune {ft_big} (any: {ft_moved})"
    );
}

#[test]
fn tuning_a_full_zoo_model_terminates() {
    // Whole SqueezeNet (23 tasks) through the rust backend at tiny
    // budget: exercises every subgraph kind end to end.
    let mut tuner = AutoTuner::builder(presets::rtx_2080())
        .config(&cfg(Strategy::RandomSearch, 8))
        .build()
        .unwrap();
    let session = tuner.tune(&zoo::squeezenet().tasks()).unwrap();
    assert_eq!(session.tasks.len(), 23);
    assert!(session.total_best_latency_ms() > 0.0);
    assert!(session.speedup() >= 1.0);
}

#[test]
fn virtual_clock_reflects_device_economics() {
    // The same tuning work must cost far more virtual time on TX2 than
    // on RTX 2060 (embedded measurement overhead — why the paper's
    // efficiency gains are larger there).
    let run_on = |arch: moses::device::DeviceArch| {
        let mut tuner =
            AutoTuner::builder(arch).config(&cfg(Strategy::RandomSearch, 8)).build().unwrap();
        tuner.tune(&small_tasks()[..1]).unwrap().search_time_s()
    };
    let t_2060 = run_on(presets::rtx_2060());
    let t_tx2 = run_on(presets::jetson_tx2());
    assert!(t_tx2 > 5.0 * t_2060, "tx2 {t_tx2} vs 2060 {t_2060}");
}

#[test]
fn prop_session_invariants_hold_for_random_configs() {
    // Randomized coordinator invariants (proptest-style, seeded runner):
    // whatever the strategy/budget, a session must produce a finite best
    // latency no worse than ~the default, a measurement count bounded by
    // the trial budget, and a monotone convergence history.
    moses::util::prop::check_with(0xC0DE, 12, |rng| {
        let strategies = [
            Strategy::RandomSearch,
            Strategy::AnsorRandom,
            Strategy::TensetFinetune,
            Strategy::TensetPretrain,
            Strategy::Moses(MosesConfig::default()),
        ];
        let strategy = strategies[rng.below(strategies.len())].clone();
        let trials = 4 + rng.below(16);
        let batch = 2 + rng.below(4);
        let mut config = cfg(strategy.clone(), trials);
        config.measure_batch = batch;
        config.seed = rng.next_u64();

        let model = if strategy.uses_pretrained() {
            CostModel::with_params(backend(), layout::init_params(&mut Rng::new(1)))
        } else {
            CostModel::new(backend(), &mut Rng::new(2))
        };
        let target = match rng.below(3) {
            0 => presets::rtx_2060(),
            1 => presets::jetson_tx2(),
            _ => presets::tesla_k80(),
        };
        let mut tuner = AutoTuner::builder(target).config(&config).model(model).build().unwrap();
        let session = tuner.tune(&small_tasks()[..1]).unwrap();
        let r = &session.tasks[0];

        assert!(r.best_latency_s.is_finite() && r.best_latency_s > 0.0);
        assert!(
            r.best_latency_s <= r.default_latency_s * 1.0001,
            "worse than default: {} vs {}",
            r.best_latency_s,
            r.default_latency_s
        );
        let rounds = (trials / batch).max(1);
        // Measurements: at most one batch per round plus final verify.
        assert!(r.measured <= rounds * batch + 1, "{} > {}", r.measured, rounds * batch + 1);
        assert_eq!(r.history.len(), rounds);
        for w in r.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
        // Clock consistency: session time positive iff anything ran.
        assert!(session.search_time_s() > 0.0);
    });
}
