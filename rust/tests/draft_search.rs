//! Draft-then-verify speculative search: session-level guarantees of
//! the draft tier. A distilled linear draft scorer prunes the
//! evolutionary population before the full `Predictor` ranks the
//! survivors, so (a) search quality at an equal trial budget must not
//! regress, (b) the `(seed, jobs)` determinism contract must survive
//! verbatim with the tier on — including worker-count independence —
//! and (c) `draft_keep = 1.0` must be bitwise indistinguishable from
//! running with the tier off.

use moses::coordinator::{AutoTuner, BackendKind, Session, TuneConfig};
use moses::device::presets;
use moses::program::{Subgraph, SubgraphKind};
use moses::transfer::Strategy;

fn tasks(n: usize) -> Vec<Subgraph> {
    (0..n)
        .map(|i| {
            if i % 2 == 0 {
                Subgraph::new(
                    &format!("ds.conv{i}"),
                    SubgraphKind::Conv2d {
                        n: 1,
                        h: 14,
                        w: 14,
                        cin: 32,
                        cout: 32 + 16 * i,
                        kh: 3,
                        kw: 3,
                        stride: 1,
                        pad: 1,
                    },
                )
            } else {
                Subgraph::new(
                    &format!("ds.dense{i}"),
                    SubgraphKind::Dense { m: 64, n: 128 + 64 * i, k: 256 },
                )
            }
        })
        .collect()
}

fn cfg(jobs: usize, seed: u64, draft: bool, draft_keep: f64) -> TuneConfig {
    TuneConfig {
        trials_per_task: 24,
        measure_batch: 4,
        strategy: Strategy::AnsorRandom,
        population: 24,
        generations: 2,
        backend: BackendKind::Rust,
        seed,
        jobs,
        draft,
        draft_keep,
        ..TuneConfig::default()
    }
}

fn run(jobs: usize, seed: u64, n_tasks: usize, draft: bool, keep: f64) -> Session {
    AutoTuner::builder(presets::rtx_2060())
        .config(&cfg(jobs, seed, draft, keep))
        .build()
        .unwrap()
        .tune(&tasks(n_tasks))
        .unwrap()
}

/// Bitwise session fingerprint: per-task outcomes + aggregate clocks.
fn fingerprint(s: &Session) -> Vec<u64> {
    let mut out = Vec::new();
    for t in &s.tasks {
        out.push(t.best_latency_s.to_bits());
        out.push(t.measured as u64);
        out.push(t.predicted_only as u64);
        out.push(t.history.len() as u64);
        for h in &t.history {
            out.push(h.to_bits());
        }
    }
    out.push(s.search_time_s().to_bits());
    out.push(s.wall_time_s().to_bits());
    out
}

#[test]
fn draft_on_matches_or_beats_draft_off_at_equal_trial_budget() {
    // Equal trial budget on both sides: the draft tier only changes
    // which candidates the full model ranks, never how many schedules
    // are measured. A draft distilled from the live predictor keeps the
    // full model's own top picks, so aggregate best-found latency must
    // not regress; the small slack absorbs residual reorder noise among
    // near-tied candidates in the simulated measurements.
    let mut on_total = 0.0;
    let mut off_total = 0.0;
    for seed in [13u64, 17, 29] {
        let on = run(1, seed, 2, true, 0.5);
        let off = run(1, seed, 2, false, 0.2);
        for (a, b) in on.tasks.iter().zip(off.tasks.iter()) {
            assert!(a.best_latency_s.is_finite());
            assert!(a.best_latency_s <= a.default_latency_s * 1.0001);
            assert_eq!(a.measured + a.predicted_only, b.measured + b.predicted_only);
        }
        assert!(on.speedup() >= 1.0);
        on_total += on.total_best_latency_ms();
        off_total += off.total_best_latency_ms();
    }
    assert!(
        on_total <= off_total * 1.05 + 1e-9,
        "draft-on best-found {on_total} ms must not regress vs draft-off {off_total} ms"
    );
}

#[test]
fn draft_sessions_reproduce_bitwise_for_a_fixed_seed_and_jobs() {
    for jobs in [1, 2] {
        let a = run(jobs, 47, 4, true, 0.25);
        let b = run(jobs, 47, 4, true, 0.25);
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "--draft --jobs {jobs} must be deterministic for a fixed seed"
        );
    }
}

#[test]
fn draft_sessions_are_independent_of_the_worker_count() {
    // Batches apply in (seq, ord) order and every task pins its
    // (ModelState, DraftState) pair together, so the worker count must
    // not leak into results even with the speculative tier pruning.
    let two = run(2, 53, 6, true, 0.25);
    let four = run(4, 53, 6, true, 0.25);
    assert_eq!(
        fingerprint(&two),
        fingerprint(&four),
        "--jobs 2 and --jobs 4 must agree bitwise with the draft tier on"
    );
}

#[test]
fn keep_everything_is_bitwise_identical_to_draft_off() {
    // draft_keep = 1.0 shortlists the entire population, so the full
    // model scores exactly the rows it would have scored anyway, in the
    // same order, with the same query charging — the sessions must be
    // indistinguishable bit for bit, sequentially and scheduled.
    for jobs in [1, 2] {
        let keep_all = run(jobs, 61, 4, true, 1.0);
        let off = run(jobs, 61, 4, false, 0.2);
        assert_eq!(
            fingerprint(&keep_all),
            fingerprint(&off),
            "--draft-keep 1.0 at --jobs {jobs} must match draft-off bitwise"
        );
    }
}
