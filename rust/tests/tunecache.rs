//! tunecache integration: key stability, top-k eviction, segmented-log
//! persistence across cache generations, and end-to-end warm start
//! through the AutoTuner — repeats are measurement-free, cross-device
//! records seed the target device's evolutionary search.  (Crash and
//! multi-writer scenarios live in `tunecache_crash.rs`.)

use std::sync::Arc;

use moses::coordinator::{AutoTuner, BackendKind, TuneConfig};
use moses::device::{presets, DeviceSim};
use moses::program::{SpaceGenerator, Subgraph, SubgraphKind, TensorProgram};
use moses::transfer::Strategy;
use moses::tunecache::{persist, warmstart, TuneCache, TuneRecord, WorkloadKey};
use moses::util::rng::Rng;

fn conv_task(name: &str) -> Subgraph {
    Subgraph::new(
        name,
        SubgraphKind::Conv2d {
            n: 1, h: 28, w: 28, cin: 64, cout: 64, kh: 3, kw: 3, stride: 1, pad: 1,
        },
    )
}

fn cfg(seed: u64) -> TuneConfig {
    TuneConfig {
        trials_per_task: 16,
        measure_batch: 4,
        strategy: Strategy::AnsorRandom,
        population: 24,
        generations: 2,
        backend: BackendKind::Rust,
        seed,
        ..TuneConfig::default()
    }
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("moses_tunecache_it");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn workload_key_is_name_invariant_and_device_aware() {
    let a = conv_task("resnet18.conv2_1");
    let b = conv_task("mobilenet.pw3").with_repeats(4);
    assert_eq!(a.workload_fingerprint(), b.workload_fingerprint());
    let arch = presets::rtx_2060();
    assert_eq!(WorkloadKey::new(&a, &arch), WorkloadKey::new(&b, &arch));
    // Shape changes move the key; device changes move the key.
    let c = Subgraph::new(
        "x",
        SubgraphKind::Conv2d {
            n: 1, h: 28, w: 28, cin: 64, cout: 128, kh: 3, kw: 3, stride: 1, pad: 1,
        },
    );
    assert_ne!(a.workload_fingerprint(), c.workload_fingerprint());
    assert_ne!(
        WorkloadKey::new(&a, &presets::rtx_2060()),
        WorkloadKey::new(&a, &presets::jetson_tx2())
    );
}

#[test]
fn persist_roundtrip_tolerance_and_compaction() {
    let dir = tmp("roundtrip-cache");
    let _ = std::fs::remove_dir_all(&dir);
    let task = conv_task("p.conv");
    let gen = SpaceGenerator::new(task.geometry());
    let mut rng = Rng::new(2);
    let scheds = gen.sample_distinct(&mut rng, 6);
    {
        let cache = TuneCache::open(&dir, 8).unwrap();
        for (i, s) in scheds.iter().enumerate() {
            for arch in [presets::rtx_2060(), presets::jetson_tx2()] {
                let key = WorkloadKey::new(&task, &arch);
                cache.commit(TuneRecord::new(
                    key,
                    task.descriptor(),
                    &arch.name,
                    s,
                    (i + 1) as f64 * 1e-3,
                    2.0,
                    64,
                ));
            }
        }
        assert_eq!(cache.total_records(), 12);
    } // clean close seals this generation's segment

    // A new cache generation merges the sealed segment and sees the
    // identical frontier.
    let reopened = TuneCache::open(&dir, 8).unwrap();
    assert_eq!(reopened.total_records(), 12);
    let key = WorkloadKey::new(&task, &presets::rtx_2060());
    assert_eq!(reopened.records(&key).len(), 6);
    assert!((reopened.best(&key).unwrap().latency_s - 1e-3).abs() < 1e-15);
    drop(reopened);

    // A torn append (crash mid-write) must not poison the store: plant
    // garbage at the tail of the surviving segment.
    {
        use std::io::Write;
        let seg = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .find(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("seg-"))
            })
            .expect("a sealed segment should survive the clean closes");
        let mut f = std::fs::OpenOptions::new().append(true).open(&seg).unwrap();
        writeln!(f, "{{\"workload\": trunca").unwrap();
    }
    let tolerant = TuneCache::open(&dir, 8).unwrap();
    assert_eq!(tolerant.total_records(), 12);

    // The open-time purge (and explicit compaction) fold everything
    // into the checkpoint, dropping the junk line from disk for good.
    tolerant.compact().unwrap();
    let (records, skipped) = persist::load_log(&dir).unwrap();
    assert_eq!(records.len(), 12);
    assert_eq!(skipped, 0);
    // And the cache still appends fine after compaction.
    let extra = gen.sample_distinct(&mut rng, 7)[6];
    assert!(tolerant.commit(TuneRecord::new(
        key,
        task.descriptor(),
        "rtx2060",
        &extra,
        0.1e-3,
        3.0,
        64
    )));
    let (records2, _) = persist::load_log(&dir).unwrap();
    assert_eq!(records2.len(), 13);
}

#[test]
fn repeat_run_is_measurement_free() {
    let tasks = vec![
        conv_task("rr.conv"),
        Subgraph::new("rr.dense", SubgraphKind::Dense { m: 64, n: 256, k: 256 }),
    ];
    let cache = Arc::new(TuneCache::in_memory(8));

    let mut first = AutoTuner::builder(presets::rtx_2060())
        .config(&cfg(1))
        .cache(cache.clone())
        .build()
        .unwrap();
    let s1 = first.tune(&tasks).unwrap();
    assert!(s1.total_measurements() > 0);
    assert_eq!(s1.cache_hits(), 0);

    let mut second = AutoTuner::builder(presets::rtx_2060())
        .config(&cfg(2))
        .cache(cache.clone())
        .build()
        .unwrap();
    let s2 = second.tune(&tasks).unwrap();
    assert_eq!(s2.total_measurements(), 0, "repeat run must be served from cache");
    assert_eq!(s2.cache_hits(), 2);
    // The cached choice is exactly as good as what the first session
    // found (both report noise-free true latencies).
    assert!(s2.total_best_latency_ms() <= s1.total_best_latency_ms() * (1.0 + 1e-9));

    let stats = cache.stats();
    assert_eq!(stats.hits, 2);
    assert_eq!(stats.misses, 2);
    // Session embeds the snapshot.
    assert_eq!(s2.cache.unwrap().hits, 2);
}

#[test]
fn cross_device_records_seed_target_search() {
    let task = conv_task("xd.conv");
    let cache = Arc::new(TuneCache::in_memory(8));

    // A session on the source device populates the cache.
    let mut src = AutoTuner::builder(presets::rtx_2060())
        .config(&cfg(5))
        .cache(cache.clone())
        .build()
        .unwrap();
    src.tune(std::slice::from_ref(&task)).unwrap();
    assert!(cache.total_records() > 0);

    // The target device misses exactly but receives cross-device seeds.
    let plan = warmstart::plan(
        &cache,
        &task,
        &presets::jetson_tx2(),
        &warmstart::WarmStartOptions::new(8, 16),
    );
    assert!(plan.exact.is_none());
    assert!(!plan.seeds.is_empty(), "cross-device seeds expected");
    assert!(plan.seeds.iter().all(|s| s.source_device == "rtx2060"));

    // Seeded tuning on the target injects the seeds into the search.
    let mut warm = AutoTuner::builder(presets::jetson_tx2())
        .config(&cfg(6))
        .cache(cache.clone())
        .build()
        .unwrap();
    let sw = warm.tune(std::slice::from_ref(&task)).unwrap();
    assert!(!sw.tasks[0].cache_hit);
    assert!(sw.tasks[0].warm_seeds > 0, "search population must be seeded");

    // The probed seeds ground the session immediately: by the end of the
    // FIRST round the seeded session is already at least as good as the
    // best probed cross-device schedule — a cold session needs however
    // many trials its search takes to get there.
    let sim = DeviceSim::new(presets::jetson_tx2());
    let probe_best = plan
        .seeds
        .iter()
        .take(cfg(6).seed_probe)
        .map(|s| sim.true_latency(&TensorProgram::new(task.clone(), s.schedule)))
        .fold(f64::INFINITY, f64::min);
    if probe_best.is_finite() {
        assert!(
            sw.tasks[0].history[0] <= probe_best * (1.0 + 1e-9),
            "round-0 best {} should already match the probed seed {}",
            sw.tasks[0].history[0],
            probe_best
        );
        // Fewer-trials claim: the warm session reaches that quality at
        // round 0; the cold session may or may not, but never earlier.
        let mut cold = AutoTuner::builder(presets::jetson_tx2()).config(&cfg(6)).build().unwrap();
        let sc = cold.tune(std::slice::from_ref(&task)).unwrap();
        let reach = |h: &[f64]| {
            h.iter()
                .position(|&v| v <= probe_best * (1.0 + 1e-9))
                .unwrap_or(h.len())
        };
        assert!(
            reach(&sw.tasks[0].history) <= reach(&sc.tasks[0].history),
            "warm start took longer to reach the cached quality: {:?} vs {:?}",
            sw.tasks[0].history,
            sc.tasks[0].history
        );
    }

    // Commit-after-measure: the target device's results are now cached
    // too, so a repeat on the target is measurement-free.
    let mut again = AutoTuner::builder(presets::jetson_tx2())
        .config(&cfg(7))
        .cache(cache.clone())
        .build()
        .unwrap();
    let sa = again.tune(std::slice::from_ref(&task)).unwrap();
    assert_eq!(sa.total_measurements(), 0);
    assert_eq!(sa.cache_hits(), 1);
}

#[test]
fn larger_budget_overrides_exact_hit_and_reuses_local_records() {
    // A cheap run must not permanently satisfy (or poison) the
    // workload: requesting more trials re-searches, grounded on the
    // device's own cached records at zero measurement cost.
    let task = conv_task("lb.conv");
    let cache = Arc::new(TuneCache::in_memory(8));

    let mut small = AutoTuner::builder(presets::rtx_2060())
        .config(&cfg(9))
        .cache(cache.clone())
        .build()
        .unwrap();
    small.tune(std::slice::from_ref(&task)).unwrap();
    let key = WorkloadKey::new(&task, &presets::rtx_2060());
    let cached_best = cache.best(&key).unwrap().latency_s;

    // Equal budget: exact hit, zero measurements.
    let mut same = AutoTuner::builder(presets::rtx_2060())
        .config(&cfg(10))
        .cache(cache.clone())
        .build()
        .unwrap();
    let ss = same.tune(std::slice::from_ref(&task)).unwrap();
    assert_eq!(ss.total_measurements(), 0);

    // Double the budget: the hit is refused, search runs again...
    let mut big_cfg = cfg(11);
    big_cfg.trials_per_task = 32;
    let mut big = AutoTuner::builder(presets::rtx_2060())
        .config(&big_cfg)
        .cache(cache.clone())
        .build()
        .unwrap();
    let sb = big.tune(std::slice::from_ref(&task)).unwrap();
    assert!(!sb.tasks[0].cache_hit);
    assert!(sb.total_measurements() > 0);
    // ...but never regresses below the cached best (local re-seeding).
    assert!(
        sb.tasks[0].best_latency_s <= cached_best * (1.0 + 1e-9),
        "big-budget run regressed: {} vs cached {}",
        sb.tasks[0].best_latency_s,
        cached_best
    );

    // The workload now counts as searched at 32 trials: repeating at 32
    // is measurement-free again.
    let mut big2_cfg = cfg(12);
    big2_cfg.trials_per_task = 32;
    let mut big2 = AutoTuner::builder(presets::rtx_2060())
        .config(&big2_cfg)
        .cache(cache.clone())
        .build()
        .unwrap();
    let sb2 = big2.tune(std::slice::from_ref(&task)).unwrap();
    assert_eq!(sb2.total_measurements(), 0);
}
