//! Work-stealing concurrency: `--jobs N` sessions must be exactly
//! reproducible for a fixed `(seed, N)` (and in fact independent of
//! `N` for `N >= 2`, since batches apply in `(seq, ord)` order and
//! each task pins its own snapshot), `--jobs 1` must behave as the
//! sequential loop (wall == cost, the classic invariants), concurrent
//! `TuneCache` commits from parallel tasks must all land, exact cache
//! hits must report a truthful single-point history, and skewed task
//! budgets must show the stealing schedule beating wave accounting on
//! the virtual clock.

use std::sync::Arc;

use moses::coordinator::{AutoTuner, BackendKind, Session, TuneConfig};
use moses::device::presets;
use moses::program::{Subgraph, SubgraphKind};
use moses::transfer::Strategy;
use moses::tunecache::{TuneCache, WorkloadKey};

fn tasks(n: usize) -> Vec<Subgraph> {
    // Distinct shapes so every task is its own workload in the cache.
    (0..n)
        .map(|i| {
            if i % 2 == 0 {
                Subgraph::new(
                    &format!("pt.conv{i}"),
                    SubgraphKind::Conv2d {
                        n: 1,
                        h: 14,
                        w: 14,
                        cin: 32,
                        cout: 32 + 16 * i,
                        kh: 3,
                        kw: 3,
                        stride: 1,
                        pad: 1,
                    },
                )
            } else {
                Subgraph::new(
                    &format!("pt.dense{i}"),
                    SubgraphKind::Dense { m: 64, n: 128 + 64 * i, k: 256 },
                )
            }
        })
        .collect()
}

fn cfg(jobs: usize, seed: u64) -> TuneConfig {
    TuneConfig {
        trials_per_task: 16,
        measure_batch: 4,
        strategy: Strategy::AnsorRandom,
        population: 16,
        generations: 2,
        backend: BackendKind::Rust,
        seed,
        jobs,
        ..TuneConfig::default()
    }
}

fn run(jobs: usize, seed: u64, n_tasks: usize, cache: Option<Arc<TuneCache>>) -> Session {
    let mut b = AutoTuner::builder(presets::rtx_2060()).config(&cfg(jobs, seed));
    if let Some(c) = cache {
        b = b.cache(c);
    }
    b.build().unwrap().tune(&tasks(n_tasks)).unwrap()
}

/// Bitwise session fingerprint: per-task outcomes + aggregate clocks.
fn fingerprint(s: &Session) -> Vec<u64> {
    let mut out = Vec::new();
    for t in &s.tasks {
        out.push(t.best_latency_s.to_bits());
        out.push(t.measured as u64);
        out.push(t.predicted_only as u64);
        out.push(t.history.len() as u64);
        for h in &t.history {
            out.push(h.to_bits());
        }
    }
    out.push(s.search_time_s().to_bits());
    out.push(s.wall_time_s().to_bits());
    out
}

#[test]
fn fixed_jobs_and_seed_reproduce_bit_identical_sessions() {
    for jobs in [2, 3] {
        let a = run(jobs, 11, 6, None);
        let b = run(jobs, 11, 6, None);
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "--jobs {jobs} must be deterministic for a fixed seed"
        );
    }
}

#[test]
fn jobs_one_is_the_sequential_path() {
    // Classic sequential invariants: wall time equals summed cost, and
    // repeated runs are bit-identical.
    let a = run(1, 5, 4, None);
    let b = run(1, 5, 4, None);
    assert_eq!(fingerprint(&a), fingerprint(&b));
    assert!((a.wall_time_s() - a.search_time_s()).abs() < 1e-9);
    assert_eq!(a.tasks.len(), 4);
    for t in &a.tasks {
        assert!(t.best_latency_s.is_finite());
        assert!(t.best_latency_s <= t.default_latency_s * 1.0001);
        for w in t.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "history not monotone: {:?}", t.history);
        }
    }
}

#[test]
fn parallel_session_matches_task_set_and_overlaps_execution() {
    // 8 tasks over 4 stealing workers: results stay per-task sane, the
    // critical path is strictly shorter than the device bill, and no
    // result slot is lost to thread scheduling.
    let s = run(4, 23, 8, None);
    assert_eq!(s.tasks.len(), 8);
    let expected = tasks(8);
    for (i, t) in s.tasks.iter().enumerate() {
        assert_eq!(t.task.name, expected[i].name, "results must keep task order");
        assert!(t.best_latency_s <= t.default_latency_s * 1.0001);
    }
    assert!(s.speedup() >= 1.0);
    assert!(
        s.wall_time_s() < s.search_time_s(),
        "concurrent tasks must overlap: wall {} vs cost {}",
        s.wall_time_s(),
        s.search_time_s()
    );
}

#[test]
fn concurrent_cache_commits_all_land() {
    let cache = Arc::new(TuneCache::in_memory(8));
    let s = run(4, 31, 8, Some(cache.clone()));
    assert_eq!(s.cache_hits(), 0);
    let arch = presets::rtx_2060();
    // Every task's final best must be present in the store, committed
    // concurrently from 4 worker threads without loss.
    for t in &s.tasks {
        let key = WorkloadKey::new(&t.task, &arch);
        let best = cache.best(&key).unwrap_or_else(|| panic!("no record for {}", t.task.name));
        assert!(
            best.latency_s <= t.best_latency_s * (1.0 + 1e-9),
            "{}: cached {} vs session best {}",
            t.task.name,
            best.latency_s,
            t.best_latency_s
        );
        assert_eq!(best.task.as_ref().map(|x| x.name.as_str()), Some(t.task.name.as_str()));
    }
    assert!(cache.stats().commits >= 8);

    // A repeat parallel session is served entirely from the cache.
    let s2 = run(4, 32, 8, Some(cache.clone()));
    assert_eq!(s2.total_measurements(), 0);
    assert_eq!(s2.cache_hits(), 8);
}

#[test]
fn exact_cache_hits_report_truthful_single_point_history() {
    let cache = Arc::new(TuneCache::in_memory(8));
    let first = run(1, 41, 2, Some(cache.clone()));
    let rounds = 16 / 4;
    for t in &first.tasks {
        assert_eq!(t.history.len(), rounds, "a searched task records every round");
    }
    let second = run(1, 42, 2, Some(cache));
    for t in &second.tasks {
        assert!(t.cache_hit);
        assert_eq!(
            t.history.len(),
            1,
            "an exact hit ran zero rounds and must not fabricate {rounds} of them"
        );
        assert!((t.history[0] - t.best_latency_s).abs() < 1e-15);
    }
    // Downstream aggregates handle the short history.
    assert!(second.speedup() >= 1.0);
}

#[test]
fn parallel_determinism_holds_with_a_shared_cache() {
    // Warm-started parallel sessions stay deterministic: scheduled
    // sessions defer cache commits to the driver, so warm-start lookups
    // never observe a commit whose timing depends on thread scheduling.
    let seed_cache = Arc::new(TuneCache::in_memory(8));
    let _ = run(1, 51, 6, Some(seed_cache.clone()));
    // Two identical parallel runs against identical cache contents
    // (fresh clones so the first doesn't poison the second).
    let reload = |src: &TuneCache| {
        let c = TuneCache::in_memory(8);
        for r in src.snapshot() {
            c.commit(r);
        }
        Arc::new(c)
    };
    let mut big = cfg(3, 52);
    big.trials_per_task = 32; // bigger budget: hits downgrade to re-search
    let run_warm = |cache: Arc<TuneCache>| {
        let mut tuner = AutoTuner::builder(presets::rtx_2060())
            .config(&big)
            .cache(cache)
            .build()
            .unwrap();
        tuner.tune(&tasks(6)).unwrap()
    };
    let a = run_warm(reload(&seed_cache));
    let b = run_warm(reload(&seed_cache));
    assert_eq!(fingerprint(&a), fingerprint(&b));
    assert!(a.tasks.iter().any(|t| t.warm_seeds > 0 || !t.cache_hit));
}

/// Seed the cache with every odd task so a later mixed session sees a
/// straggler pattern: odd ordinals are exact hits (near-zero virtual
/// cost), even ordinals search a full budget.
fn skewed_cache(seed: u64) -> Arc<TuneCache> {
    let cache = Arc::new(TuneCache::in_memory(8));
    let shorts: Vec<_> = tasks(8).into_iter().skip(1).step_by(2).collect();
    AutoTuner::builder(presets::rtx_2060())
        .config(&cfg(1, seed))
        .cache(cache.clone())
        .build()
        .unwrap()
        .tune(&shorts)
        .unwrap();
    cache
}

#[test]
fn stealing_beats_wave_accounting_on_skewed_budgets() {
    // In task order the session alternates full-budget searchers with
    // near-free cache hits. Wave accounting charges every chunk its
    // slowest member, so the hits buy nothing; the stealing schedule
    // lets a worker that drains a hit immediately pull the next
    // searcher, roughly halving the critical path.
    let s = run(2, 61, 8, Some(skewed_cache(61)));
    assert_eq!(s.tasks.len(), 8);
    assert_eq!(s.cache_hits(), 4);
    assert!(
        s.wall_time_s() < s.wave_wall_time_s() - 1e-9,
        "stealing wall {} s must beat wave wall {} s on a straggler mix",
        s.wall_time_s(),
        s.wave_wall_time_s()
    );
    // Sanity: the schedule can never beat perfect overlap or exceed
    // the full sequential bill.
    assert!(s.wall_time_s() >= s.search_time_s() / 2.0 - 1e-9);
    assert!(s.wave_wall_time_s() <= s.search_time_s() + 1e-9);
}

#[test]
fn skewed_schedules_stay_bit_reproducible() {
    // Stragglers maximize steal/park traffic; the (seq, ord) apply
    // order and per-task snapshot pins must still make the session a
    // pure function of (seed, tasks).
    let seed_cache = skewed_cache(71);
    let reload = || {
        let c = TuneCache::in_memory(8);
        for r in seed_cache.snapshot() {
            c.commit(r);
        }
        Arc::new(c)
    };
    let a = run(2, 71, 8, Some(reload()));
    let b = run(2, 71, 8, Some(reload()));
    assert_eq!(fingerprint(&a), fingerprint(&b), "skewed sessions must reproduce bitwise");
}

#[test]
fn fast_nondeterministic_mode_yields_valid_sessions() {
    // --fast-nondeterministic drops the per-task snapshot pin, so no
    // bitwise assertion is made by design — the session must merely be
    // structurally valid and keep the parallel accounting invariants.
    let s = AutoTuner::builder(presets::rtx_2060())
        .config(&cfg(2, 81))
        .fast_nondeterministic(true)
        .build()
        .unwrap()
        .tune(&tasks(4))
        .unwrap();
    assert_eq!(s.tasks.len(), 4);
    for t in &s.tasks {
        assert!(t.best_latency_s.is_finite());
        assert!(t.best_latency_s <= t.default_latency_s * 1.0001);
        assert!(t.measured > 0);
    }
    assert!(s.speedup() >= 1.0);
    assert!(s.total_measurements() > 0);
    assert!(s.wall_time_s() > 0.0);
    assert!(s.wall_time_s() <= s.search_time_s() + 1e-9);
}
