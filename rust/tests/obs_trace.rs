//! Observability-plane integration: a traced session round-trips
//! through the JSONL trace format, span nesting matches the pipeline's
//! stage order, event content is deterministic per `(seed, jobs)`
//! (scheduling-dependent readings live in `diag`, and the work-stealing
//! `sched:{worker}` lanes are exempt wholesale), a disabled recorder
//! emits nothing and perturbs nothing, and the stage spans' virtual
//! time reconciles with `Session::search_time_s()`.

use std::sync::Arc;

use moses::coordinator::{AutoTuner, BackendKind, Session, TuneConfig};
use moses::device::presets;
use moses::obs::{Lane, Recorder, Trace, TraceEvent, TraceHeader, TRACE_VERSION};
use moses::program::{Subgraph, SubgraphKind};
use moses::transfer::Strategy;
use moses::tunecache::TuneCache;

fn tasks(n: usize) -> Vec<Subgraph> {
    (0..n)
        .map(|i| {
            if i % 2 == 0 {
                Subgraph::new(
                    &format!("ot.conv{i}"),
                    SubgraphKind::Conv2d {
                        n: 1,
                        h: 14,
                        w: 14,
                        cin: 32,
                        cout: 32 + 16 * i,
                        kh: 3,
                        kw: 3,
                        stride: 1,
                        pad: 1,
                    },
                )
            } else {
                Subgraph::new(
                    &format!("ot.dense{i}"),
                    SubgraphKind::Dense { m: 64, n: 128 + 64 * i, k: 256 },
                )
            }
        })
        .collect()
}

fn cfg(jobs: usize, seed: u64) -> TuneConfig {
    TuneConfig {
        trials_per_task: 24,
        measure_batch: 4,
        strategy: Strategy::AnsorRandom,
        population: 24,
        generations: 2,
        backend: BackendKind::Rust,
        seed,
        jobs,
        ..TuneConfig::default()
    }
}

fn traced_session(
    jobs: usize,
    seed: u64,
    n_tasks: usize,
    rec: &Recorder,
    cache: Option<Arc<TuneCache>>,
) -> Session {
    let mut b = AutoTuner::builder(presets::rtx_2060())
        .config(&cfg(jobs, seed))
        .trace(rec.clone());
    if let Some(c) = cache {
        b = b.cache(c);
    }
    b.build().unwrap().tune(&tasks(n_tasks)).unwrap()
}

fn trace_from(rec: &Recorder, jobs: usize, seed: u64) -> Trace {
    Trace {
        header: TraceHeader {
            version: TRACE_VERSION,
            device: "rtx-2060".to_string(),
            strategy: "ansor-random".to_string(),
            model: "obs-test".to_string(),
            jobs,
            seed,
        },
        events: rec.drain(),
        metrics: rec.metrics_snapshot(),
    }
}

/// Session outcome fingerprint (same shape as the parallel_tune one):
/// tracing must never change what the tuner computes.
fn fingerprint(s: &Session) -> Vec<u64> {
    let mut out = Vec::new();
    for t in &s.tasks {
        out.push(t.best_latency_s.to_bits());
        out.push(t.measured as u64);
        out.push(t.predicted_only as u64);
        for h in &t.history {
            out.push(h.to_bits());
        }
    }
    out.push(s.search_time_s().to_bits());
    out
}

/// Strip the scheduling-dependent payload; everything left must be a
/// pure function of `(seed, jobs, tasks)`. Two pieces are exempt from
/// the contract: per-event `diag` readings (wall-clock timings), and
/// the `sched:{worker}` lanes as a whole — which unit a worker steals
/// or when it parks is real thread scheduling, so those lanes are
/// diagnostic by definition.
fn deterministic_view(events: &[TraceEvent]) -> Vec<TraceEvent> {
    events
        .iter()
        .filter(|e| !matches!(e.lane, Lane::Sched(_)))
        .map(|e| TraceEvent { diag: Vec::new(), ..e.clone() })
        .collect()
}

#[test]
fn trace_roundtrips_through_the_report_parser() {
    let rec = Recorder::enabled();
    let cache = {
        let mut tc = TuneCache::in_memory(8);
        tc.attach_recorder(&rec);
        Arc::new(tc)
    };
    traced_session(2, 9, 4, &rec, Some(cache));
    let trace = trace_from(&rec, 2, 9);
    assert!(!trace.events.is_empty());

    let back = Trace::parse(&trace.to_jsonl()).expect("written trace must parse");
    assert_eq!(back, trace);

    // The attached cache surfaces its lane and its counters.
    assert!(trace.events.iter().any(|e| e.lane == Lane::Cache && e.name == "open"));
    assert!(trace.metrics.keys().any(|k| k.starts_with("cache.")));

    // Reports render from the parsed trace, labelled with task names.
    let task_md = trace.per_task_table().to_markdown();
    let stage_md = trace.per_stage_table().to_markdown();
    assert!(task_md.contains("ot.conv0") && task_md.contains("ot.dense1"));
    assert!(stage_md.contains("measure") && stage_md.contains("total"));
    assert!(trace.vt_total_s() > 0.0);
}

#[test]
fn span_nesting_matches_pipeline_stage_order() {
    let rec = Recorder::enabled();
    traced_session(1, 13, 2, &rec, None);
    let events = rec.drain();

    for ord in 0..2usize {
        let lane: Vec<&TraceEvent> =
            events.iter().filter(|e| e.lane == Lane::Task(ord)).collect();
        assert!(!lane.is_empty(), "task {ord} must have a lane");

        // Per-lane seqs are contiguous from 0 in drain order.
        for (i, e) in lane.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
        }

        // Stage-level order: warm_start, round*, finalize.
        let stages: Vec<&str> =
            lane.iter().filter(|e| e.depth == 0).map(|e| e.name.as_str()).collect();
        assert_eq!(stages.first(), Some(&"warm_start"));
        assert_eq!(stages.last(), Some(&"finalize"));
        assert!(stages[1..stages.len() - 1].iter().all(|n| *n == "round"));

        // Depth-1 detail nests inside a round's virtual interval.
        let rounds: Vec<(f64, f64)> = lane
            .iter()
            .filter(|e| e.depth == 0 && e.name == "round")
            .map(|e| (e.vt_start_s, e.vt_start_s + e.vt_dur_s))
            .collect();
        for e in lane.iter().filter(|e| e.depth == 1) {
            assert!(
                matches!(e.name.as_str(), "propose" | "measure" | "pin"),
                "unexpected depth-1 event '{}'",
                e.name
            );
            let (s, t) = (e.vt_start_s, e.vt_start_s + e.vt_dur_s);
            assert!(
                rounds.iter().any(|(rs, rt)| *rs - 1e-9 <= s && t <= *rt + 1e-9),
                "depth-1 '{}' [{s}, {t}] outside every round {rounds:?}",
                e.name
            );
        }
    }

    // The learner lane recorded one learn span per absorbed batch, each
    // tagged with its task ordinal.
    let learns: Vec<&TraceEvent> = events
        .iter()
        .filter(|e| e.lane == Lane::Learner && e.name == "learn")
        .collect();
    assert!(!learns.is_empty());
    for e in &learns {
        assert!(e.args.iter().any(|(k, v)| k == "task" && (*v == 0.0 || *v == 1.0)));
    }
}

#[test]
fn event_content_is_deterministic_per_seed_and_jobs() {
    let run = || {
        let rec = Recorder::enabled();
        let session = traced_session(2, 21, 4, &rec, None);
        (deterministic_view(&rec.drain()), rec.metrics_snapshot(), fingerprint(&session))
    };
    let (ev_a, m_a, fp_a) = run();
    let (ev_b, m_b, fp_b) = run();
    assert_eq!(fp_a, fp_b, "session itself must be reproducible");
    assert_eq!(m_a, m_b, "metrics must be reproducible");
    assert_eq!(ev_a.len(), ev_b.len());
    for (a, b) in ev_a.iter().zip(&ev_b) {
        assert_eq!(a, b, "event content must not depend on thread scheduling");
    }
}

#[test]
fn disabled_recorder_emits_nothing_and_changes_nothing() {
    let off = Recorder::disabled();
    let s_off = traced_session(2, 33, 4, &off, None);
    assert!(off.drain().is_empty());
    assert!(off.metrics_snapshot().is_empty());

    let on = Recorder::enabled();
    let s_on = traced_session(2, 33, 4, &on, None);
    assert!(!on.drain().is_empty());
    assert_eq!(
        fingerprint(&s_off),
        fingerprint(&s_on),
        "recording must not perturb tuning results"
    );
}

#[test]
fn stage_spans_reconcile_with_session_search_time() {
    let rec = Recorder::enabled();
    let session = traced_session(4, 7, 8, &rec, None);
    let trace = trace_from(&rec, 4, 7);
    let vt = trace.vt_total_s();
    let engine = session.search_time_s();
    assert!(engine > 0.0);
    let rel = (vt - engine).abs() / engine;
    assert!(
        rel < 0.01,
        "stage spans must account for the virtual search time: \
         spans {vt} vs session {engine} (rel err {rel})"
    );
}
