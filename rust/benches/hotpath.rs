//! Hot-path microbenchmarks (perf-pass instrumentation, EXPERIMENTS.md
//! §Perf): feature extraction, simulator, search, mask derivation, and
//! the XLA cost-model predict/train calls.
//!
//! Run: `cargo bench --bench hotpath`

use std::sync::Arc;

use moses::coordinator::{AutoTuner, BackendKind, ModelSnapshot, SnapshotCell, TuneConfig};
use moses::costmodel::{layout, CostModel, Mask, RustBackend, XlaBackend};
use moses::device::{presets, DeviceSim};
use moses::obs::{Lane, Recorder, TraceScope};
use moses::program::{featurize, SpaceGenerator, Subgraph, SubgraphKind, TensorProgram};
use moses::runtime::Engine;
use moses::search::{DraftGate, DraftState, EvolutionarySearch, SearchPolicy};
use moses::transfer::Strategy;
use moses::tunecache::{TuneCache, TuneRecord, TuneStore, WorkloadIndex, WorkloadKey, RECORD_VERSION};
use moses::util::bench::Bencher;
use moses::util::rng::Rng;

fn task() -> Subgraph {
    Subgraph::new(
        "bench.conv",
        SubgraphKind::Conv2d {
            n: 1, h: 56, w: 56, cin: 64, cout: 128, kh: 3, kw: 3, stride: 1, pad: 1,
        },
    )
}

fn main() {
    moses::util::log::init_from_env(false);
    let b = Bencher::default();
    let sub = task();
    let gen = SpaceGenerator::new(sub.geometry());
    let mut rng = Rng::new(1);
    let sched = gen.sample(&mut rng);
    let prog = TensorProgram::new(sub.clone(), sched);
    let sim = DeviceSim::new(presets::rtx_2060());

    // --- L3 scalar hot paths -------------------------------------------
    b.run("featurize_164d", || featurize(&sub, &sched));
    b.run("sim_true_latency", || sim.true_latency(&prog));
    b.run("sim_measure", || sim.measure(&prog, &mut rng));
    b.run("schedule_sample", || gen.sample(&mut rng));
    b.run("schedule_mutate", || gen.mutate(&sched, &mut rng));

    let xi: Vec<f32> = (0..layout::N_PARAMS).map(|_| rng.uniform() as f32).collect();
    b.run("mask_from_xi_ratio", || Mask::from_xi_ratio(&xi, 0.5));

    // --- trace recording (the obs plane) ----------------------------------
    // Disabled is what every un-traced session pays per pipeline stage
    // (budget: < 2% regression with tracing off — EXPERIMENTS.md §Perf);
    // enabled is the marginal cost of recording one stage span.
    let mut off_scope = TraceScope::disabled();
    let mut off_vt = 0.0f64;
    b.run("obs_span_disabled", || {
        off_vt += 1e-3;
        let t = off_scope.begin(off_vt);
        off_scope.end(t, 0, "round", off_vt + 5e-4, &[("round", 1.0)], &[]);
    });
    let on_rec = Recorder::enabled();
    let mut on_scope = on_rec.scope(Lane::Task(0), "bench");
    let mut on_vt = 0.0f64;
    let mut on_i = 0usize;
    b.run("obs_span_enabled", || {
        // Drain periodically so warmup iterations don't accumulate an
        // unbounded sink (amortized cost ~0).
        on_i += 1;
        if on_i % 1024 == 0 {
            std::hint::black_box(on_rec.drain());
        }
        on_vt += 1e-3;
        let t = on_scope.begin(on_vt);
        on_scope.end(t, 0, "round", on_vt + 5e-4, &[("round", 1.0)], &[]);
    });
    std::hint::black_box(on_rec.drain());

    // --- batched scoring (the inner search loop) ------------------------
    let pop: Vec<_> = gen.sample_distinct(&mut rng, 64);
    b.run("featurize_batch64", || {
        let mut buf = Vec::with_capacity(64 * 164);
        for s in &pop {
            buf.extend_from_slice(&featurize(&sub, s));
        }
        buf
    });

    // --- Rust backend ----------------------------------------------------
    let rust_model =
        CostModel::new(Arc::new(RustBackend { pred_batch: 64, train_batch: 64 }), &mut rng);
    let mut feats = Vec::with_capacity(64 * 164);
    for s in &pop {
        feats.extend_from_slice(&featurize(&sub, s));
    }
    b.run("rust_predict_64", || rust_model.predict(&feats, 64).unwrap());

    // --- evolutionary round (rust backend) -------------------------------
    let mut evo = EvolutionarySearch::new(sub.clone());
    evo.population = 64;
    evo.generations = 3;
    let rust_view = rust_model.predictor();
    b.run("evolutionary_propose_8of64x3", || {
        evo.propose(8, &rust_view, &|_| false, &mut rng, None, &mut || {})
    });

    // --- draft-then-verify propose (the speculative search tier) ----------
    // Equal population/generations, draft off vs on (keep = 0.2): the
    // draft ranks every fresh schedule with one 164-d dot product and
    // the full model verifies only the top fraction.  Hard gate: the
    // draft must cut full-model rows per propose round by >= 3x.
    let mut draft_evo = EvolutionarySearch::with_params(sub.clone(), 128, 3);
    let draft_pool = gen.sample_distinct(&mut rng, 128);
    let mut dx = Vec::with_capacity(draft_pool.len() * 164);
    for s in &draft_pool {
        dx.extend_from_slice(&featurize(&sub, s));
    }
    let dy = rust_model.predict(&dx, draft_pool.len()).expect("draft labels");
    let prior = rust_view.feature_projection();
    let draft = DraftState::fit(&dx, &dy, draft_pool.len(), Some(&prior), 1);
    assert!(!draft.is_passthrough(), "bench draft distillation must fit");
    b.run("propose_draft_off", || {
        draft_evo.propose(8, &rust_view, &|_| false, &mut rng, None, &mut || {})
    });
    let off_rows = draft_evo.last_draft_stats().full_rows;
    let draft_gate = DraftGate { state: &draft, keep: 0.2 };
    b.run("propose_draft_on", || {
        draft_evo.propose(8, &rust_view, &|_| false, &mut rng, Some(&draft_gate), &mut || {})
    });
    let on_stats = draft_evo.last_draft_stats();
    assert!(
        on_stats.full_rows * 3 <= off_rows,
        "gate: draft must cut full-model rows >= 3x per round (draft {} vs full {})",
        on_stats.full_rows,
        off_rows
    );
    println!(
        "bench propose_draft                  {} full-model rows/round with draft vs {} \
         without ({:.1}x fewer; {} drafted, {} pruned)",
        on_stats.full_rows,
        off_rows,
        off_rows as f64 / on_stats.full_rows.max(1) as f64,
        on_stats.draft_scored,
        on_stats.pruned
    );

    // --- snapshot publish/pin (the zero-copy prediction plane) ------------
    // One learner publish followed by 4 worker pins + view construction,
    // the per-round round trip of a `--jobs 4` session.  The cost
    // is pointer swaps under a mutex — independent of the ~350k-float
    // parameter count (contrast with the per-round deep copy this
    // replaced, which scaled with N_PARAMS).
    let publish_state = rust_model.shared_state();
    let snap_cell = SnapshotCell::new(ModelSnapshot::from_model(publish_state.clone()));
    let snap_backend = Arc::new(RustBackend { pred_batch: 64, train_batch: 64 });
    let mut snap_version = 0u64;
    b.run("snapshot_publish_pin_jobs4", || {
        snap_version += 1;
        snap_cell.publish(snap_version, ModelSnapshot::from_model(publish_state.clone()));
        for _ in 0..4 {
            let pinned = snap_cell.wait_for(snap_version).expect("live cell");
            std::hint::black_box(moses::costmodel::Predictor::new(
                snap_backend.clone(),
                pinned.model,
            ));
        }
    });

    // --- tunecache (the check-before-search hot path) ---------------------
    // A populated store: 128 workloads × 2 devices × topk records each.
    let store = TuneStore::new(8);
    let index = WorkloadIndex::new();
    let arch_a = presets::rtx_2060();
    let arch_b = presets::jetson_tx2();
    let mut workload_keys = Vec::new();
    let mut descs = Vec::new();
    for i in 0..128usize {
        let t = Subgraph::new(
            "cache.dense",
            SubgraphKind::Dense { m: 32 + i, n: 256, k: 256 },
        );
        let desc = t.descriptor();
        for arch in [&arch_a, &arch_b] {
            let key = WorkloadKey::new(&t, arch);
            for j in 0..8usize {
                let sched = gen.sample(&mut rng);
                store.commit(&TuneRecord::new(
                    key,
                    desc,
                    &arch.name,
                    &sched,
                    1e-3 * (j + 1) as f64,
                    100.0,
                    64,
                ));
            }
        }
        let key = WorkloadKey::new(&t, &arch_a);
        index.insert(key.workload, desc, RECORD_VERSION);
        workload_keys.push(key);
        descs.push(desc);
    }
    let hit_key = workload_keys[64];
    let miss_key = WorkloadKey { workload: 0xDEAD_BEEF, device: hit_key.device };
    b.run("cache_lookup_hit", || store.best(&hit_key));
    b.run("cache_lookup_miss", || store.best(&miss_key));
    b.run("cache_cross_device_seeds", || {
        store.cross_device(hit_key.workload, hit_key.device)
    });
    // Rotate schedules and latencies so commits exercise the real
    // admission path (insert + sort + evict), not just duplicate-reject.
    let commit_pool: Vec<_> = gen.sample_distinct(&mut rng, 16);
    let mut commit_i = 0usize;
    let hit_desc = descs[64];
    b.run("cache_commit", || {
        commit_i += 1;
        let sched = &commit_pool[commit_i % commit_pool.len()];
        let lat = 1e-3 / (1.0 + (commit_i % 7) as f64);
        store.commit(&TuneRecord::new(hit_key, hit_desc, &arch_a.name, sched, lat, 200.0, 64))
    });

    // --- nearest-neighbor index (the miss-path retrieval) ------------------
    // 128 indexed workloads, as a miss on a novel shape would scan.
    let novel = Subgraph::new(
        "nn.dense",
        SubgraphKind::Dense { m: 96, n: 320, k: 256 },
    );
    b.run("nn_descriptor", || novel.descriptor());
    let query = novel.descriptor();
    b.run("nn_query_k4_of128", || index.nearest(&query, 4, 1.0, 0));
    let mut nn_i = 0usize;
    b.run("nn_index_insert", || {
        nn_i += 1;
        index.insert(nn_i as u64, descs[nn_i % descs.len()], RECORD_VERSION)
    });
    b.run("nn_workload_records", || store.workload_records(hit_key.workload));

    // --- work-stealing sessions: multi-task throughput ---------------------
    // 8 tasks tuned end to end, sequentially vs over 4 stealing workers
    // sharing one learner actor.  Real wall time — the parallel case
    // overlaps search + measurement across cores.
    let session_tasks: Vec<Subgraph> = (0..8)
        .map(|i| {
            if i % 2 == 0 {
                Subgraph::new(
                    "sess.conv",
                    SubgraphKind::Conv2d {
                        n: 1,
                        h: 14,
                        w: 14,
                        cin: 32,
                        cout: 32 + 16 * i,
                        kh: 3,
                        kw: 3,
                        stride: 1,
                        pad: 1,
                    },
                )
            } else {
                Subgraph::new(
                    "sess.dense",
                    SubgraphKind::Dense { m: 64, n: 128 + 64 * i, k: 256 },
                )
            }
        })
        .collect();
    let session_cfg = |jobs: usize| TuneConfig {
        trials_per_task: 24,
        measure_batch: 4,
        strategy: Strategy::AnsorRandom,
        population: 32,
        generations: 2,
        backend: BackendKind::Rust,
        seed: 7,
        jobs,
        ..TuneConfig::default()
    };
    let tune_session = |jobs: usize, cache: Option<Arc<TuneCache>>| {
        let mut builder = AutoTuner::builder(presets::rtx_2060()).config(&session_cfg(jobs));
        if let Some(c) = cache {
            builder = builder.cache(c);
        }
        builder.build().expect("tuner").tune(&session_tasks).expect("session")
    };
    let (r1, _) =
        b.run_once("tune_session_8tasks_jobs1", || tune_session(1, None).total_measurements());
    let (r4, _) =
        b.run_once("tune_session_8tasks_jobs4", || tune_session(4, None).total_measurements());
    println!(
        "bench tune_session_8tasks            jobs4 speedup {:.2}x over jobs1",
        r1.median_ns() / r4.median_ns().max(1.0)
    );

    // --- work-stealing gate: skewed budgets --------------------------------
    // Odd tasks are seeded into a tune cache so the mixed session sees a
    // straggler pattern: exact hits finish in near-zero virtual time
    // while even tasks search the full budget.  Two gates: the stealing
    // schedule must beat wave accounting on the virtual clock, and the
    // default (deterministic) mode must reproduce bitwise across runs.
    let shorts: Vec<Subgraph> = session_tasks.iter().skip(1).step_by(2).cloned().collect();
    let seeded_cache = || {
        let cache = Arc::new(TuneCache::in_memory(8));
        AutoTuner::builder(presets::rtx_2060())
            .config(&session_cfg(1))
            .cache(cache.clone())
            .build()
            .expect("tuner")
            .tune(&shorts)
            .expect("seed session");
        cache
    };
    let session_bits = |s: &moses::coordinator::Session| {
        let mut out: Vec<u64> = s.tasks.iter().map(|t| t.best_latency_s.to_bits()).collect();
        out.push(s.search_time_s().to_bits());
        out.push(s.wall_time_s().to_bits());
        out
    };
    let (_, skew_a) =
        b.run_once("tune_session_8tasks_jobs4_skewed", || tune_session(4, Some(seeded_cache())));
    let (_, skew_b) = b.run_once("tune_session_8tasks_jobs4_skewed_rerun", || {
        tune_session(4, Some(seeded_cache()))
    });
    assert!(
        skew_a.wall_time_s() < skew_a.wave_wall_time_s() - 1e-9,
        "gate: stealing wall {} s must beat wave wall {} s on skewed budgets",
        skew_a.wall_time_s(),
        skew_a.wave_wall_time_s()
    );
    assert_eq!(
        session_bits(&skew_a),
        session_bits(&skew_b),
        "gate: the skewed --jobs 4 session must be bit-reproducible in default mode"
    );
    println!(
        "bench tune_session_8tasks_jobs4_skewed  virtual wall {:.1} s vs wave {:.1} s \
         ({:.2}x), bit-reproducible",
        skew_a.wall_time_s(),
        skew_a.wave_wall_time_s(),
        skew_a.wave_wall_time_s() / skew_a.wall_time_s().max(1e-12)
    );

    // --- XLA backend (skipped when unavailable) ---------------------------
    let dir = Engine::default_dir();
    if Engine::xla_available() {
        let engine = Arc::new(Engine::load(&dir).expect("engine"));
        let xla_model = CostModel::new(Arc::new(XlaBackend { engine }), &mut rng);
        let mut feats512 = Vec::with_capacity(512 * 164);
        let pop512 = gen.sample_distinct(&mut rng, 512);
        for s in &pop512 {
            feats512.extend_from_slice(&featurize(&sub, s));
        }
        b.run("xla_predict_512", || xla_model.predict(&feats512, 512).unwrap());
        // Population-sized scoring through the small-batch artifact
        // (the evolutionary hot path; compare against xla_predict_512
        // to see the padding win — EXPERIMENTS.md §Perf).
        b.run("xla_predict_64_small", || xla_model.predict(&feats, 64).unwrap());

        let mut xla_train = CostModel::new(
            Arc::new(XlaBackend { engine: Arc::new(Engine::load(&dir).unwrap()) }),
            &mut rng,
        );
        let x: Vec<f32> = (0..256 * 164).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..256).map(|_| rng.uniform() as f32).collect();
        let mask = Mask::all_ones(layout::N_PARAMS);
        b.run("xla_train_step_256", || {
            xla_train.train_step(&x, &y, &mask, 1e-3, 0.0).unwrap()
        });
        b.run("xla_xi_256", || xla_train.xi(&x, &y).unwrap());
    } else {
        println!(
            "bench xla_*: SKIPPED ({})",
            Engine::xla_skip_reason().unwrap_or("unknown")
        );
    }

    // Perf-pass artifact: `MOSES_BENCH_DIR=out cargo bench --bench
    // hotpath` drops a dated BENCH_<date>.json for EXPERIMENTS.md §Perf
    // and the CI upload.
    if let Ok(dir) = std::env::var("MOSES_BENCH_DIR") {
        match b.write_json(std::path::Path::new(&dir)) {
            Ok(p) => println!("bench results written to {}", p.display()),
            Err(e) => moses::warn!("bench: writing results to {dir:?} failed: {e}"),
        }
    }
}
