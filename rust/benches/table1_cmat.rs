//! Regenerates **paper Table 1** — CMAT (%) of Moses vs Tenset-Finetune
//! under small and large trial budgets across the 2060-S/R/M/B and
//! TX2-S/R/M settings.
//!
//! Run: `make artifacts && cargo bench --bench table1_cmat`
//! (bench tier 16/64 trials; `moses tables --exp table1` for full tier).

use moses::coordinator::BackendKind;
use moses::metrics::experiments::{self, ExpConfig};
use moses::runtime::Engine;
use moses::util::bench::Bencher;

fn main() {
    moses::util::log::init_from_env(false);
    if let Some(reason) = Engine::xla_skip_reason() {
        println!("table1: SKIPPED ({reason})");
        return;
    }
    let cfg = ExpConfig {
        backend: BackendKind::Xla,
        trials_small: std::env::var("MOSES_BENCH_TRIALS_SMALL")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(12),
        trials_large: std::env::var("MOSES_BENCH_TRIALS_LARGE")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(32),
        ..ExpConfig::default()
    };
    let b = Bencher::default();
    let (_, table) = b.run_once("table1_end_to_end", || {
        experiments::table1(&cfg).expect("table1")
    });
    table.print();
}
