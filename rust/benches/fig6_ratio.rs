//! Regenerates **paper Fig. 6** — the transferable-parameter-ratio
//! ablation {0.01, 0.3, 0.5, 0.7} (mean ± std across seeds), showing
//! the optimum around 0.5 and low sensitivity in 0.3–0.7.
//!
//! Run: `make artifacts && cargo bench --bench fig6_ratio`
//! (bench tier: 2 seeds; `moses tables --exp fig6` for 3+).

use moses::coordinator::BackendKind;
use moses::metrics::experiments::{self, ExpConfig};
use moses::runtime::Engine;
use moses::util::bench::Bencher;

fn main() {
    moses::util::log::init_from_env(false);
    if let Some(reason) = Engine::xla_skip_reason() {
        println!("fig6: SKIPPED ({reason})");
        return;
    }
    let cfg = ExpConfig {
        backend: BackendKind::Xla,
        trials_small: std::env::var("MOSES_BENCH_TRIALS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(24),
        ..ExpConfig::default()
    };
    let b = Bencher::default();
    let (_, table) = b.run_once("fig6_ratio_ablation", || {
        experiments::fig6_table(&cfg, "mobilenet", &[0, 1]).expect("fig6")
    });
    table.print();
}
