//! Regenerates **paper Fig. 5** — auto-tuning search-efficiency gains of
//! Moses over the baselines (virtual search seconds, dominated by
//! simulated on-device measurement cost, paper §2.3).
//!
//! Run: `make artifacts && cargo bench --bench fig5_search`
//! (bench-tier trials; `moses tables --exp fig5` for the full tier).

use moses::coordinator::BackendKind;
use moses::device::presets;
use moses::metrics::experiments::{self, ExpConfig};
use moses::runtime::Engine;
use moses::util::bench::Bencher;

fn main() {
    moses::util::log::init_from_env(false);
    if let Some(reason) = Engine::xla_skip_reason() {
        println!("fig5: SKIPPED ({reason})");
        return;
    }
    let trials: usize = std::env::var("MOSES_BENCH_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    let cfg = ExpConfig { backend: BackendKind::Xla, ..ExpConfig::default() };
    let b = Bencher::default();
    let targets = [presets::rtx_2060(), presets::jetson_tx2()];

    let (_, outs) = b.run_once("fig5_grid_end_to_end", || {
        experiments::run_grid(&cfg, trials, &targets).expect("grid")
    });
    let names: Vec<&str> = targets.iter().map(|t| t.name.as_str()).collect();
    experiments::fig5_table(&outs, &names).print();
}
