//! Component ablation bench (design-choice study, DESIGN.md §4): the
//! contribution of each Moses component (lottery mask, variant weight
//! decay, AC early termination) vs Tenset-Finetune on MobileNet,
//! K80→TX2.
//!
//! Run: `make artifacts && cargo bench --bench ablation`

use moses::coordinator::BackendKind;
use moses::metrics::experiments::{self, ExpConfig};
use moses::runtime::Engine;
use moses::util::bench::Bencher;

fn main() {
    moses::util::log::init_from_env(false);
    if let Some(reason) = Engine::xla_skip_reason() {
        println!("ablation: SKIPPED ({reason})");
        return;
    }
    let cfg = ExpConfig {
        backend: BackendKind::Xla,
        trials_small: std::env::var("MOSES_BENCH_TRIALS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(24),
        ..ExpConfig::default()
    };
    let b = Bencher::default();
    let (_, table) = b.run_once("ablation_components", || {
        experiments::ablation_table(&cfg, "mobilenet").expect("ablation")
    });
    table.print();
}
