//! Regenerates **paper Fig. 4** — end-to-end DNN inference-latency gains
//! of Moses over the domain-adaptation baselines, on K80→2060 and
//! K80→TX2 for MobileNet / ResNet-18 / BERT-base / SqueezeNet.
//!
//! Scale note: bench-tier trials (default 32/task vs the paper's 200+)
//! keep `cargo bench` minutes-scale; `moses tables --exp fig4` runs the
//! full tier.  Override with MOSES_BENCH_TRIALS.
//!
//! Run: `make artifacts && cargo bench --bench fig4_inference`

use moses::coordinator::BackendKind;
use moses::device::presets;
use moses::metrics::experiments::{self, ExpConfig};
use moses::runtime::Engine;
use moses::util::bench::Bencher;

fn main() {
    moses::util::log::init_from_env(false);
    if let Some(reason) = Engine::xla_skip_reason() {
        println!("fig4: SKIPPED ({reason})");
        return;
    }
    let trials: usize = std::env::var("MOSES_BENCH_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    let cfg = ExpConfig { backend: BackendKind::Xla, ..ExpConfig::default() };
    let b = Bencher::default();
    let targets = [presets::rtx_2060(), presets::jetson_tx2()];

    let (_, outs) = b.run_once("fig4_grid_end_to_end", || {
        experiments::run_grid(&cfg, trials, &targets).expect("grid")
    });
    let names: Vec<&str> = targets.iter().map(|t| t.name.as_str()).collect();
    experiments::fig4_table(&outs, &names).print();
}
