//! Span and event primitives of the observability plane.
//!
//! A [`TraceEvent`] is one record in a session trace: a span (stage with
//! a duration) or an instant (point event), pinned to a [`Lane`] (one
//! per actor: each task pipeline, the learner, the tune cache, the
//! session driver) and ordered inside that lane by a `seq` counter the
//! emitting [`TraceScope`] owns.  Per-lane counters — instead of one
//! global atomic — are what keep event *content* deterministic under
//! `--jobs N`: cross-thread interleaving can reorder the shared buffer,
//! but `(lane, seq)` reconstructs the schedule-independent total order
//! (see [`crate::obs::recorder::Recorder::drain`]).
//!
//! Determinism contract: every field except `diag` is a pure function
//! of `(seed, jobs, tasks)`.  Wall-clock readings, queue depths and
//! other scheduling-dependent measurements go in `diag` and nowhere
//! else.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::obs::recorder::Recorder;
use crate::util::json::Json;

/// The actor a trace event belongs to.  Lanes order `Session < Learner
/// < Cache < Task(0) < Task(1) < … < Sched(0) < Sched(1) < …` — the
/// stable sort key of a drained trace.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Lane {
    /// The session driver (CLI / tuner).
    Session,
    /// The learning plane (inline learner or the actor thread).
    Learner,
    /// The tune cache (open / compaction events).
    Cache,
    /// One task pipeline, by its stable task ordinal.
    Task(usize),
    /// One work-stealing scheduler worker, by worker index.  EXEMPT from
    /// the determinism contract: which worker runs, steals, or parks a
    /// task is thread-timing, so steal/park/resume event counts and
    /// payloads vary across reruns (they are diagnostics, like `diag`).
    Sched(usize),
}

impl Lane {
    /// Stable string form used in trace files (`"task:3"`, `"learner"`).
    pub fn encode(&self) -> String {
        match self {
            Lane::Session => "session".to_string(),
            Lane::Learner => "learner".to_string(),
            Lane::Cache => "cache".to_string(),
            Lane::Task(ord) => format!("task:{ord}"),
            Lane::Sched(w) => format!("sched:{w}"),
        }
    }

    /// Inverse of [`Lane::encode`].
    pub fn decode(s: &str) -> Option<Lane> {
        match s {
            "session" => Some(Lane::Session),
            "learner" => Some(Lane::Learner),
            "cache" => Some(Lane::Cache),
            _ => {
                if let Some(w) = s.strip_prefix("sched:") {
                    return Some(Lane::Sched(w.parse().ok()?));
                }
                let ord = s.strip_prefix("task:")?.parse().ok()?;
                Some(Lane::Task(ord))
            }
        }
    }
}

/// One span or instant in a session trace.
///
/// Spans carry *both* clocks of the tuning engine: `vt_start_s` /
/// `vt_dur_s` read the session's deterministic virtual clock (the
/// device bill [`crate::device::VirtualClock`] accounts), while the
/// harness wall clock lands in `diag` as `wall_start_us` /
/// `wall_dur_us` (microseconds since the recorder's epoch).  Instants
/// are spans with zero duration.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub lane: Lane,
    /// Position in the lane: contiguous from 0, assigned by the
    /// emitter's [`TraceScope`].
    pub seq: u64,
    /// 0 = stage-level (these sum to the session's virtual search
    /// time), 1 = nested detail (propose/measure inside a round, pins),
    /// 2 = the draft/verify split inside a propose (draft-tier
    /// sessions only).
    pub depth: u8,
    pub name: String,
    /// Human label for the lane (task name), repeated per event so a
    /// trace line is self-describing.
    pub label: String,
    /// Virtual-clock seconds at span start.
    pub vt_start_s: f64,
    /// Virtual-clock seconds elapsed inside the span.
    pub vt_dur_s: f64,
    /// Deterministic payload (counts, versions), sorted by key.
    pub args: Vec<(String, f64)>,
    /// Nondeterministic payload (wall times, queue depths), sorted by
    /// key.  Ignored by reproducibility comparisons.
    pub diag: Vec<(String, f64)>,
}

fn pairs_to_json(pairs: &[(String, f64)]) -> Json {
    Json::Obj(pairs.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
}

fn pairs_from_json(v: &Json) -> Result<Vec<(String, f64)>, String> {
    match v {
        Json::Obj(m) => m
            .iter()
            .map(|(k, v)| match v {
                Json::Num(x) => Ok((k.clone(), *x)),
                _ => Err(format!("non-numeric value under '{k}'")),
            })
            .collect(),
        _ => Err("expected an object".to_string()),
    }
}

impl TraceEvent {
    /// Compact one-line JSON form (one trace-file line).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("lane".to_string(), Json::Str(self.lane.encode()));
        m.insert("seq".to_string(), Json::Num(self.seq as f64));
        m.insert("depth".to_string(), Json::Num(self.depth as f64));
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("label".to_string(), Json::Str(self.label.clone()));
        m.insert(
            "vt".to_string(),
            Json::Arr(vec![Json::Num(self.vt_start_s), Json::Num(self.vt_dur_s)]),
        );
        if !self.args.is_empty() {
            m.insert("args".to_string(), pairs_to_json(&self.args));
        }
        if !self.diag.is_empty() {
            m.insert("diag".to_string(), pairs_to_json(&self.diag));
        }
        Json::Obj(m)
    }

    /// Inverse of [`TraceEvent::to_json`].
    pub fn from_json(v: &Json) -> Result<TraceEvent, String> {
        let get = |k: &str| v.get(k).ok_or_else(|| format!("missing '{k}'"));
        let lane_s = get("lane")?.as_str().ok_or("lane must be a string")?;
        let lane = Lane::decode(lane_s).ok_or_else(|| format!("bad lane '{lane_s}'"))?;
        let vt = get("vt")?.as_arr().ok_or("vt must be an array")?;
        if vt.len() != 2 {
            return Err("vt must hold [start, dur]".to_string());
        }
        Ok(TraceEvent {
            lane,
            seq: get("seq")?.as_f64().ok_or("seq must be a number")? as u64,
            depth: get("depth")?.as_f64().ok_or("depth must be a number")? as u8,
            name: get("name")?.as_str().ok_or("name must be a string")?.to_string(),
            label: get("label")?.as_str().ok_or("label must be a string")?.to_string(),
            vt_start_s: vt[0].as_f64().ok_or("vt[0] must be a number")?,
            vt_dur_s: vt[1].as_f64().ok_or("vt[1] must be a number")?,
            args: v.get("args").map(pairs_from_json).transpose()?.unwrap_or_default(),
            diag: v.get("diag").map(pairs_from_json).transpose()?.unwrap_or_default(),
        })
    }
}

/// An open span handle: wall-clock start (captured only when recording
/// is enabled — the disabled path never reads `Instant::now()`) plus
/// the virtual-clock reading at [`TraceScope::begin`].
#[derive(Debug)]
pub struct SpanTimer {
    wall: Option<Instant>,
    vt_start_s: f64,
}

/// One lane's event emitter: a cheap handle every instrumented actor
/// owns, carrying the lane identity, its label, and the lane's `seq`
/// counter.  Exactly one scope may emit into a lane per session —
/// ownership of the counter is what makes `(lane, seq)` collision-free
/// without cross-thread coordination.
#[derive(Debug)]
pub struct TraceScope {
    rec: Recorder,
    lane: Lane,
    label: String,
    seq: u64,
}

fn sorted_pairs(pairs: &[(&str, f64)]) -> Vec<(String, f64)> {
    let mut v: Vec<(String, f64)> =
        pairs.iter().map(|(k, x)| (k.to_string(), *x)).collect();
    v.sort_by(|a, b| a.0.cmp(&b.0));
    v
}

impl TraceScope {
    pub(crate) fn new(rec: Recorder, lane: Lane, label: &str) -> TraceScope {
        TraceScope { rec, lane, label: label.to_string(), seq: 0 }
    }

    /// A scope that records nothing (the default for un-traced
    /// sessions).
    pub fn disabled() -> TraceScope {
        TraceScope::new(Recorder::disabled(), Lane::Session, "")
    }

    pub fn enabled(&self) -> bool {
        self.rec.is_enabled()
    }

    /// Open a span at virtual time `vt_now_s`.  Disabled scopes return
    /// a dummy timer without touching the wall clock — the no-op cost
    /// is one branch.
    // obs/ is allowlisted for detlint's wall-clock rule: span wall
    // times are quarantined in the diag payload.
    #[allow(clippy::disallowed_methods)]
    pub fn begin(&self, vt_now_s: f64) -> SpanTimer {
        if self.rec.is_enabled() {
            SpanTimer { wall: Some(Instant::now()), vt_start_s: vt_now_s }
        } else {
            SpanTimer { wall: None, vt_start_s: 0.0 }
        }
    }

    /// Close a span opened with [`TraceScope::begin`] and record it.
    /// `args` must be deterministic content; anything
    /// scheduling-dependent belongs in `diag`.
    pub fn end(
        &mut self,
        timer: SpanTimer,
        depth: u8,
        name: &str,
        vt_now_s: f64,
        args: &[(&str, f64)],
        diag: &[(&str, f64)],
    ) {
        let Some(wall_start) = timer.wall else {
            return;
        };
        let wall_dur = wall_start.elapsed();
        let mut d = sorted_pairs(diag);
        if let Some(epoch) = self.rec.epoch() {
            let start_us = wall_start.duration_since(epoch).as_secs_f64() * 1e6;
            d.push(("wall_dur_us".to_string(), wall_dur.as_secs_f64() * 1e6));
            d.push(("wall_start_us".to_string(), start_us));
            d.sort_by(|a, b| a.0.cmp(&b.0));
        }
        let ev = TraceEvent {
            lane: self.lane.clone(),
            seq: self.seq,
            depth,
            name: name.to_string(),
            label: self.label.clone(),
            vt_start_s: timer.vt_start_s,
            vt_dur_s: vt_now_s - timer.vt_start_s,
            args: sorted_pairs(args),
            diag: d,
        };
        self.seq += 1;
        self.rec.push(ev);
    }

    /// Record a zero-duration event at virtual time `vt_now_s`.
    pub fn instant(
        &mut self,
        depth: u8,
        name: &str,
        vt_now_s: f64,
        args: &[(&str, f64)],
        diag: &[(&str, f64)],
    ) {
        if !self.rec.is_enabled() {
            return;
        }
        let timer = self.begin(vt_now_s);
        self.end(timer, depth, name, vt_now_s, args, diag);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_encoding_roundtrips() {
        for lane in [
            Lane::Session,
            Lane::Learner,
            Lane::Cache,
            Lane::Task(0),
            Lane::Task(17),
            Lane::Sched(0),
            Lane::Sched(3),
        ] {
            assert_eq!(Lane::decode(&lane.encode()), Some(lane));
        }
        assert_eq!(Lane::decode("task:x"), None);
        assert_eq!(Lane::decode("sched:x"), None);
        assert_eq!(Lane::decode("nope"), None);
    }

    #[test]
    fn lanes_order_session_learner_cache_tasks() {
        let mut lanes = vec![
            Lane::Sched(0),
            Lane::Task(1),
            Lane::Cache,
            Lane::Task(0),
            Lane::Session,
            Lane::Learner,
        ];
        lanes.sort();
        assert_eq!(
            lanes,
            vec![
                Lane::Session,
                Lane::Learner,
                Lane::Cache,
                Lane::Task(0),
                Lane::Task(1),
                Lane::Sched(0)
            ]
        );
    }

    #[test]
    fn event_json_roundtrips() {
        let ev = TraceEvent {
            lane: Lane::Task(2),
            seq: 5,
            depth: 1,
            name: "measure".to_string(),
            label: "conv1".to_string(),
            vt_start_s: 1.25,
            vt_dur_s: 0.5,
            args: vec![("candidates".to_string(), 8.0), ("round".to_string(), 3.0)],
            diag: vec![("wall_dur_us".to_string(), 42.5)],
        };
        let back = TraceEvent::from_json(&ev.to_json()).unwrap();
        assert_eq!(back, ev);
        // Empty payloads are omitted from the line entirely.
        let bare = TraceEvent { args: Vec::new(), diag: Vec::new(), ..ev };
        let line = bare.to_json().to_string();
        assert!(!line.contains("args") && !line.contains("diag"));
        assert_eq!(TraceEvent::from_json(&Json::parse(&line).unwrap()).unwrap(), bare);
    }

    #[test]
    fn disabled_scope_records_nothing_and_counts_nothing() {
        let mut scope = TraceScope::disabled();
        assert!(!scope.enabled());
        let t = scope.begin(1.0);
        assert!(t.wall.is_none());
        scope.end(t, 0, "x", 2.0, &[("a", 1.0)], &[]);
        scope.instant(0, "y", 2.0, &[], &[]);
        assert_eq!(scope.seq, 0);
    }

    #[test]
    fn scope_payloads_are_key_sorted() {
        let rec = Recorder::enabled();
        let mut scope = rec.scope(Lane::Task(0), "t");
        let t = scope.begin(0.0);
        scope.end(t, 0, "s", 1.0, &[("z", 1.0), ("a", 2.0)], &[("q", 3.0)]);
        let evs = rec.drain();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].args[0].0, "a");
        assert_eq!(evs[0].args[1].0, "z");
        let keys: Vec<&str> = evs[0].diag.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["q", "wall_dur_us", "wall_start_us"]);
        assert!((evs[0].vt_dur_s - 1.0).abs() < 1e-12);
    }
}
