//! Versioned trace container (JSONL write/parse) and the per-task /
//! per-stage breakdown tables behind `moses trace report`.
//!
//! File layout: a header line identifying the session
//! (`{"moses_trace":1,...}`), one line per [`TraceEvent`], and a footer
//! line with the final metrics snapshot (`{"metrics":{...}}`).  Parsing
//! validates the version and the per-lane `seq` contiguity invariant,
//! so a truncated or shuffled file is rejected instead of silently
//! producing a wrong breakdown.

use std::collections::BTreeMap;

use crate::obs::span::{Lane, TraceEvent};
use crate::obs::TRACE_VERSION;
use crate::util::json::Json;
use crate::util::table::{pct, Table};

/// Session identity recorded on the first trace line.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceHeader {
    pub version: u32,
    pub device: String,
    pub strategy: String,
    pub model: String,
    pub jobs: usize,
    pub seed: u64,
}

impl TraceHeader {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("moses_trace", Json::Num(self.version as f64)),
            ("device", Json::Str(self.device.clone())),
            ("strategy", Json::Str(self.strategy.clone())),
            ("model", Json::Str(self.model.clone())),
            ("jobs", Json::Num(self.jobs as f64)),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    fn from_json(v: &Json) -> Result<TraceHeader, String> {
        let num = |k: &str| {
            v.get(k).and_then(Json::as_f64).ok_or_else(|| format!("header missing '{k}'"))
        };
        let s = |k: &str| {
            v.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("header missing '{k}'"))
        };
        Ok(TraceHeader {
            version: num("moses_trace")? as u32,
            device: s("device")?,
            strategy: s("strategy")?,
            model: s("model")?,
            jobs: num("jobs")? as usize,
            seed: num("seed")? as u64,
        })
    }
}

/// A complete session trace: header, events, and the final metrics
/// snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub header: TraceHeader,
    pub events: Vec<TraceEvent>,
    pub metrics: BTreeMap<String, u64>,
}

impl Trace {
    /// Serialize to the versioned JSONL trace format.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.header.to_json().to_string());
        out.push('\n');
        for ev in &self.events {
            out.push_str(&ev.to_json().to_string());
            out.push('\n');
        }
        let metrics = Json::Obj(
            self.metrics
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                .collect(),
        );
        out.push_str(&Json::obj(vec![("metrics", metrics)]).to_string());
        out.push('\n');
        out
    }

    /// Parse a trace file, validating the format version and that each
    /// lane's `seq` values are contiguous from 0 (i.e. no events were
    /// lost or reordered).
    pub fn parse(text: &str) -> Result<Trace, String> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let first = lines.next().ok_or("empty trace file")?;
        let hv = Json::parse(first).map_err(|e| format!("header: {e}"))?;
        if hv.get("moses_trace").is_none() {
            return Err("not a moses trace (missing 'moses_trace' header)".to_string());
        }
        let header = TraceHeader::from_json(&hv)?;
        if header.version != TRACE_VERSION {
            return Err(format!(
                "trace version {} unsupported (expected {TRACE_VERSION})",
                header.version
            ));
        }
        let mut events = Vec::new();
        let mut metrics = BTreeMap::new();
        for (i, line) in lines.enumerate() {
            let v = Json::parse(line).map_err(|e| format!("line {}: {e}", i + 2))?;
            if let Some(m) = v.get("metrics") {
                match m {
                    Json::Obj(map) => {
                        for (k, val) in map {
                            let x = val
                                .as_f64()
                                .ok_or_else(|| format!("line {}: bad metric '{k}'", i + 2))?;
                            metrics.insert(k.clone(), x as u64);
                        }
                    }
                    _ => return Err(format!("line {}: 'metrics' must be an object", i + 2)),
                }
                continue;
            }
            events.push(
                TraceEvent::from_json(&v).map_err(|e| format!("line {}: {e}", i + 2))?,
            );
        }
        let mut next_seq: BTreeMap<Lane, u64> = BTreeMap::new();
        for ev in &events {
            let expect = next_seq.entry(ev.lane.clone()).or_insert(0);
            if ev.seq != *expect {
                return Err(format!(
                    "lane {} seq gap: got {}, expected {}",
                    ev.lane.encode(),
                    ev.seq,
                    expect
                ));
            }
            *expect += 1;
        }
        Ok(Trace { header, events, metrics })
    }

    /// Total virtual time inside stage-level (depth-0) spans across the
    /// working lanes.  By construction every virtual-clock charge in a
    /// session happens inside such a span, so this reconciles with
    /// `Session::search_time_s()`.
    pub fn vt_total_s(&self) -> f64 {
        self.events
            .iter()
            .filter(|e| e.depth == 0 && e.lane != Lane::Session)
            .map(|e| e.vt_dur_s)
            .sum()
    }

    fn task_lanes(&self) -> Vec<usize> {
        let mut ords: Vec<usize> = self
            .events
            .iter()
            .filter_map(|e| match e.lane {
                Lane::Task(ord) => Some(ord),
                _ => None,
            })
            .collect();
        ords.sort_unstable();
        ords.dedup();
        ords
    }

    fn learn_vt_for(&self, ord: usize) -> f64 {
        self.events
            .iter()
            .filter(|e| {
                e.lane == Lane::Learner
                    && e.name == "learn"
                    && e.args.iter().any(|(k, v)| k == "task" && *v == ord as f64)
            })
            .map(|e| e.vt_dur_s)
            .sum()
    }

    /// Per-task breakdown: where each task's virtual search time went.
    pub fn per_task_table(&self) -> Table {
        let mut t = Table::new(
            "Per-task virtual time (s)",
            &["task", "label", "warm", "rounds", "propose", "measure", "learn", "final", "total"],
        );
        for ord in self.task_lanes() {
            let lane = Lane::Task(ord);
            let sum = |depth: u8, name: &str| -> f64 {
                self.events
                    .iter()
                    .filter(|e| e.lane == lane && e.depth == depth && e.name == name)
                    .map(|e| e.vt_dur_s)
                    .sum()
            };
            let rounds = self
                .events
                .iter()
                .filter(|e| e.lane == lane && e.depth == 0 && e.name == "round")
                .count();
            let label = self
                .events
                .iter()
                .find(|e| e.lane == lane)
                .map(|e| e.label.clone())
                .unwrap_or_default();
            let learn = self.learn_vt_for(ord);
            let total = sum(0, "warm_start") + sum(0, "round") + sum(0, "finalize") + learn;
            t.row(vec![
                ord.to_string(),
                label,
                format!("{:.3}", sum(0, "warm_start")),
                rounds.to_string(),
                format!("{:.3}", sum(1, "propose")),
                format!("{:.3}", sum(1, "measure")),
                format!("{learn:.3}"),
                format!("{:.3}", sum(0, "finalize")),
                format!("{total:.3}"),
            ]);
        }
        t
    }

    /// Per-stage breakdown across all tasks: which pipeline stage the
    /// session's virtual time went to.
    pub fn per_stage_table(&self) -> Table {
        let sum_named = |depth: u8, name: &str| -> f64 {
            self.events
                .iter()
                .filter(|e| {
                    matches!(e.lane, Lane::Task(_)) && e.depth == depth && e.name == name
                })
                .map(|e| e.vt_dur_s)
                .sum()
        };
        let warm = sum_named(0, "warm_start");
        let round = sum_named(0, "round");
        let propose = sum_named(1, "propose");
        let measure = sum_named(1, "measure");
        let finalize = sum_named(0, "finalize");
        let learn: f64 = self
            .events
            .iter()
            .filter(|e| e.lane == Lane::Learner && e.depth == 0 && e.name == "learn")
            .map(|e| e.vt_dur_s)
            .sum();
        let round_other = (round - propose - measure).max(0.0);
        let total = warm + round + finalize + learn;
        let mut t = Table::new("Per-stage virtual time (s)", &["stage", "vt_s", "share_%"]);
        let share = |x: f64| if total > 0.0 { pct(x / total) } else { pct(0.0) };
        for (name, vt) in [
            ("warm_start", warm),
            ("propose", propose),
            ("measure", measure),
            ("round (other)", round_other),
            ("finalize", finalize),
            ("learn", learn),
        ] {
            t.row(vec![name.to_string(), format!("{vt:.3}"), share(vt)]);
        }
        t.row(vec!["total".to_string(), format!("{total:.3}"), share(total)]);
        t
    }

    /// Draft-tier split per task: how many schedules the draft scorer
    /// ranked, how many it kept/pruned, and how many rows the full
    /// predictor actually verified (summed from the depth-2
    /// `draft`/`verify` events nested inside propose spans).  Returns
    /// `None` for traces without draft events — draft-off sessions —
    /// so `moses trace report` stays unchanged for them.
    pub fn draft_table(&self) -> Option<Table> {
        let mut tasks: BTreeMap<usize, (f64, f64, f64, f64)> = BTreeMap::new();
        for e in &self.events {
            let Lane::Task(ord) = &e.lane else { continue };
            if e.depth != 2 {
                continue;
            }
            let arg = |k: &str| {
                e.args.iter().find(|(n, _)| n == k).map(|(_, v)| *v).unwrap_or(0.0)
            };
            let c = tasks.entry(*ord).or_insert((0.0, 0.0, 0.0, 0.0));
            match e.name.as_str() {
                "draft" => {
                    c.0 += arg("scored");
                    c.1 += arg("kept");
                    c.2 += arg("pruned");
                }
                "verify" => c.3 += arg("rows"),
                _ => {}
            }
        }
        if tasks.is_empty() {
            return None;
        }
        let mut t = Table::new(
            "Draft-then-verify split (schedules per task)",
            &["task", "draft_scored", "kept", "pruned", "full_rows"],
        );
        for (ord, (scored, kept, pruned, rows)) in &tasks {
            t.row(vec![
                ord.to_string(),
                format!("{scored:.0}"),
                format!("{kept:.0}"),
                format!("{pruned:.0}"),
                format!("{rows:.0}"),
            ]);
        }
        Some(t)
    }

    /// Scheduler decisions per work-stealing worker (steal / park /
    /// resume instants on the `sched:{worker}` lanes).  Returns `None`
    /// for traces without scheduler traffic — sequential sessions, or
    /// parallel ones where every worker stayed busy on its own deque —
    /// so `moses trace report` stays unchanged for them.
    pub fn sched_table(&self) -> Option<Table> {
        let mut workers: BTreeMap<usize, (u64, u64, u64)> = BTreeMap::new();
        for e in &self.events {
            if let Lane::Sched(w) = e.lane {
                let c = workers.entry(w).or_insert((0, 0, 0));
                match e.name.as_str() {
                    "steal" => c.0 += 1,
                    "park" => c.1 += 1,
                    "resume" => c.2 += 1,
                    _ => {}
                }
            }
        }
        if workers.is_empty() {
            return None;
        }
        let mut t = Table::new(
            "Work-stealing scheduler (events per worker)",
            &["worker", "steals", "parks", "resumes"],
        );
        for (w, (steals, parks, resumes)) in &workers {
            t.row(vec![
                w.to_string(),
                steals.to_string(),
                parks.to_string(),
                resumes.to_string(),
            ]);
        }
        Some(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(lane: Lane, seq: u64, depth: u8, name: &str, vt: (f64, f64)) -> TraceEvent {
        TraceEvent {
            lane,
            seq,
            depth,
            name: name.to_string(),
            label: "t".to_string(),
            vt_start_s: vt.0,
            vt_dur_s: vt.1,
            args: Vec::new(),
            diag: Vec::new(),
        }
    }

    fn sample() -> Trace {
        Trace {
            header: TraceHeader {
                version: TRACE_VERSION,
                device: "rtx-2060".to_string(),
                strategy: "ansor-random".to_string(),
                model: "squeezenet".to_string(),
                jobs: 2,
                seed: 42,
            },
            events: vec![
                ev(Lane::Learner, 0, 0, "learn", (0.0, 0.5)),
                ev(Lane::Task(0), 0, 0, "warm_start", (0.0, 1.0)),
                ev(Lane::Task(0), 1, 1, "propose", (1.0, 0.25)),
                ev(Lane::Task(0), 2, 1, "measure", (1.25, 0.5)),
                ev(Lane::Task(0), 3, 0, "round", (1.0, 1.0)),
                ev(Lane::Task(0), 4, 0, "finalize", (2.0, 0.5)),
            ],
            metrics: BTreeMap::from([("cache.hits".to_string(), 3u64)]),
        }
    }

    #[test]
    fn jsonl_roundtrips() {
        let trace = sample();
        let text = trace.to_jsonl();
        let back = Trace::parse(&text).unwrap();
        assert_eq!(back, trace);
    }

    #[test]
    fn rejects_bad_version_and_seq_gaps() {
        let mut trace = sample();
        trace.header.version = 99;
        assert!(Trace::parse(&trace.to_jsonl()).unwrap_err().contains("version"));

        let mut gap = sample();
        gap.events.remove(1); // drop Task(0) seq 0 -> gap
        assert!(Trace::parse(&gap.to_jsonl()).unwrap_err().contains("seq gap"));

        assert!(Trace::parse("{\"x\":1}\n").unwrap_err().contains("moses_trace"));
        assert!(Trace::parse("").is_err());
    }

    #[test]
    fn vt_total_counts_stage_spans_only() {
        // warm 1.0 + round 1.0 + finalize 0.5 + learn 0.5; depth-1
        // propose/measure are inside the round and must not be
        // double-counted.
        assert!((sample().vt_total_s() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn tables_render() {
        let trace = sample();
        let task_md = trace.per_task_table().to_markdown();
        assert!(task_md.contains("warm") && task_md.contains("1.000"));
        let stage_md = trace.per_stage_table().to_markdown();
        assert!(stage_md.contains("round (other)") && stage_md.contains("total"));
    }

    #[test]
    fn draft_table_sums_the_split_or_stays_absent() {
        // Draft-off traces carry no depth-2 events: the report is
        // unchanged.
        assert!(sample().draft_table().is_none());

        let mut trace = sample();
        let with_args = |mut e: TraceEvent, args: Vec<(&str, f64)>| {
            e.args = args.into_iter().map(|(k, v)| (k.to_string(), v)).collect();
            e
        };
        trace.events = vec![
            ev(Lane::Task(0), 0, 0, "warm_start", (0.0, 1.0)),
            with_args(
                ev(Lane::Task(0), 1, 2, "draft", (1.0, 0.0)),
                vec![("kept", 7.0), ("pruned", 25.0), ("round", 0.0), ("scored", 32.0)],
            ),
            with_args(
                ev(Lane::Task(0), 2, 2, "verify", (1.0, 0.25)),
                vec![("round", 0.0), ("rows", 39.0)],
            ),
            ev(Lane::Task(0), 3, 1, "propose", (1.0, 0.25)),
            with_args(
                ev(Lane::Task(0), 4, 2, "draft", (1.25, 0.0)),
                vec![("kept", 7.0), ("pruned", 25.0), ("round", 1.0), ("scored", 32.0)],
            ),
            with_args(
                ev(Lane::Task(0), 5, 2, "verify", (1.25, 0.25)),
                vec![("round", 1.0), ("rows", 7.0)],
            ),
            ev(Lane::Task(0), 6, 1, "propose", (1.25, 0.25)),
        ];
        let md = trace.draft_table().expect("draft events present").to_markdown();
        let squeezed: String = md.split_whitespace().collect::<Vec<_>>().join(" ");
        // Task 0: 64 draft-scored, 14 kept, 50 pruned, 46 verified.
        assert!(squeezed.contains("| 0 | 64 | 14 | 50 | 46 |"), "unexpected table: {md}");
        // Depth-2 detail never perturbs the vt reconciliation total.
        assert!((trace.vt_total_s() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sched_table_counts_worker_decisions_or_stays_absent() {
        // Without sched lanes the report is unchanged.
        assert!(sample().sched_table().is_none());

        let mut trace = sample();
        trace.events.extend([
            ev(Lane::Sched(0), 0, 0, "steal", (0.0, 0.0)),
            ev(Lane::Sched(0), 1, 0, "resume", (0.0, 0.0)),
            ev(Lane::Sched(1), 0, 0, "park", (0.0, 0.0)),
            ev(Lane::Sched(1), 1, 0, "park", (0.0, 0.0)),
        ]);
        let md = trace.sched_table().expect("sched lanes present").to_markdown();
        assert!(md.contains("steals"));
        // Worker 0: 1 steal, 0 parks, 1 resume; worker 1: 0/2/0.
        let squeezed: String = md.split_whitespace().collect::<Vec<_>>().join(" ");
        assert!(squeezed.contains("| 0 | 1 | 0 | 1 |"), "unexpected table: {md}");
        assert!(squeezed.contains("| 1 | 0 | 2 | 0 |"), "unexpected table: {md}");
        // Zero-vt instants never perturb the reconciliation total.
        assert!((trace.vt_total_s() - 3.0).abs() < 1e-12);
    }
}
