//! The trace recorder: a cheaply cloneable handle instrumented code
//! holds, plus the session-wide metrics registry.
//!
//! A [`Recorder`] is either *disabled* (the default — `sink: None`, so
//! every hot-path check is one `Option` branch on an `Arc` clone) or
//! *enabled*, in which case all clones share one sink: an event buffer,
//! a wall-clock epoch, and a [`MetricsRegistry`] of named counters.
//! Enablement is decided once per session; there is no runtime toggle,
//! which is what keeps the disabled cost near zero.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs::span::{Lane, TraceEvent, TraceScope};

/// A monotonically increasing named counter.  Clones share storage, so
/// a counter handed out by [`MetricsRegistry::counter`] can be bumped
/// lock-free from any thread.
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn incr(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A registry of named counters.  Subsystems register their counters
/// here (or keep a private registry and let a recorder [`adopt`] it),
/// and the session snapshot folds everything into the trace footer.
///
/// [`adopt`]: MetricsRegistry::adopt
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    inner: Arc<Mutex<BTreeMap<String, Counter>>>,
}

impl MetricsRegistry {
    /// Get or create the counter registered under `name`.
    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.inner.lock().expect("metrics registry poisoned");
        m.entry(name.to_string()).or_default().clone()
    }

    /// Share every counter of `other` into this registry (by handle,
    /// not by value): future bumps through either registry are visible
    /// in both.  Lets a subsystem with its own registry (the tunecache
    /// counters) fold into the session-wide one.
    pub fn adopt(&self, other: &MetricsRegistry) {
        let theirs = other.inner.lock().expect("metrics registry poisoned").clone();
        let mut m = self.inner.lock().expect("metrics registry poisoned");
        for (name, c) in theirs {
            m.insert(name, c);
        }
    }

    /// Current value of every registered counter.
    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        let m = self.inner.lock().expect("metrics registry poisoned");
        m.iter().map(|(k, c)| (k.clone(), c.get())).collect()
    }
}

struct Sink {
    epoch: Instant,
    events: Mutex<Vec<TraceEvent>>,
    metrics: MetricsRegistry,
}

/// Handle to the (possibly absent) trace sink.  `Recorder::default()`
/// is disabled; [`Recorder::enabled`] allocates a shared sink.
#[derive(Clone, Default)]
pub struct Recorder {
    sink: Option<Arc<Sink>>,
}

impl Recorder {
    /// A recorder that drops everything (the no-op default).
    pub fn disabled() -> Recorder {
        Recorder { sink: None }
    }

    /// A live recorder; all clones feed one event buffer.
    // obs/ is allowlisted for detlint's wall-clock rule: the wall
    // epoch exists so spans can carry diag wall times alongside the
    // virtual clock.
    #[allow(clippy::disallowed_methods)]
    pub fn enabled() -> Recorder {
        Recorder {
            sink: Some(Arc::new(Sink {
                epoch: Instant::now(),
                events: Mutex::new(Vec::new()),
                metrics: MetricsRegistry::default(),
            })),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// Create the event emitter for one lane.  Each lane must have
    /// exactly one scope per session (the scope owns the lane's `seq`
    /// counter).
    pub fn scope(&self, lane: Lane, label: &str) -> TraceScope {
        TraceScope::new(self.clone(), lane, label)
    }

    pub(crate) fn push(&self, ev: TraceEvent) {
        if let Some(sink) = &self.sink {
            sink.events.lock().expect("trace sink poisoned").push(ev);
        }
    }

    /// Wall-clock zero of this recording, if enabled.
    pub(crate) fn epoch(&self) -> Option<Instant> {
        self.sink.as_ref().map(|s| s.epoch)
    }

    /// The session metrics registry, if enabled.
    pub fn metrics(&self) -> Option<&MetricsRegistry> {
        self.sink.as_ref().map(|s| &s.metrics)
    }

    /// Counter values at this moment (empty when disabled).
    pub fn metrics_snapshot(&self) -> BTreeMap<String, u64> {
        self.metrics().map(|m| m.snapshot()).unwrap_or_default()
    }

    /// Take all recorded events, sorted into the deterministic
    /// `(lane, seq)` order.  Buffer insertion order depends on thread
    /// scheduling under `--jobs N`; the sort restores the
    /// schedule-independent total order the determinism contract
    /// promises.
    pub fn drain(&self) -> Vec<TraceEvent> {
        let Some(sink) = &self.sink else {
            return Vec::new();
        };
        let mut events =
            std::mem::take(&mut *sink.events.lock().expect("trace sink poisoned"));
        events.sort_by(|a, b| (&a.lane, a.seq).cmp(&(&b.lane, b.seq)));
        events
    }
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Recorder").field("enabled", &self.is_enabled()).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_swallows_everything() {
        let rec = Recorder::disabled();
        assert!(!rec.is_enabled());
        let mut scope = rec.scope(Lane::Task(0), "t0");
        let t = scope.begin(0.0);
        scope.end(t, 0, "warm_start", 1.0, &[], &[]);
        assert!(rec.drain().is_empty());
        assert!(rec.metrics_snapshot().is_empty());
        assert!(rec.metrics().is_none());
    }

    #[test]
    fn drain_sorts_by_lane_then_seq() {
        let rec = Recorder::enabled();
        let mut t1 = rec.scope(Lane::Task(1), "b");
        let mut t0 = rec.scope(Lane::Task(0), "a");
        let mut lrn = rec.scope(Lane::Learner, "learner");
        // Interleave emissions across lanes.
        t1.instant(0, "x", 0.0, &[], &[]);
        t0.instant(0, "x", 0.0, &[], &[]);
        lrn.instant(0, "x", 0.0, &[], &[]);
        t0.instant(0, "y", 0.0, &[], &[]);
        let evs = rec.drain();
        let order: Vec<(Lane, u64)> = evs.iter().map(|e| (e.lane.clone(), e.seq)).collect();
        assert_eq!(
            order,
            vec![(Lane::Learner, 0), (Lane::Task(0), 0), (Lane::Task(0), 1), (Lane::Task(1), 0)]
        );
        // Drain empties the buffer.
        assert!(rec.drain().is_empty());
    }

    #[test]
    fn clones_share_one_sink() {
        let rec = Recorder::enabled();
        let clone = rec.clone();
        clone.scope(Lane::Cache, "tc").instant(0, "open", 0.0, &[], &[]);
        assert_eq!(rec.drain().len(), 1);
    }

    #[test]
    fn registry_counters_shared_and_adopted() {
        let local = MetricsRegistry::default();
        let hits = local.counter("cache.hits");
        hits.add(3);
        // Same name returns the same storage.
        local.counter("cache.hits").incr();
        assert_eq!(hits.get(), 4);

        let rec = Recorder::enabled();
        rec.metrics().unwrap().adopt(&local);
        hits.incr();
        assert_eq!(rec.metrics_snapshot().get("cache.hits"), Some(&5));
    }
}
