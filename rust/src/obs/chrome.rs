//! Chrome trace-event export (`moses trace chrome`): converts a parsed
//! [`Trace`] into the JSON array format `chrome://tracing` / Perfetto
//! load for flame views.
//!
//! The export uses the *wall* clock (`diag.wall_start_us` /
//! `diag.wall_dur_us`) — a flame view shows what actually overlapped on
//! the machine, while the virtual-clock numbers ride along in each
//! event's `args` for inspection.  Lanes map to threads of one process;
//! events with no wall-clock reading (a trace stripped of `diag`) are
//! skipped.

use crate::obs::report::Trace;
use crate::obs::span::TraceEvent;
use crate::util::json::Json;

fn diag(ev: &TraceEvent, key: &str) -> Option<f64> {
    ev.diag.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
}

fn event_args(ev: &TraceEvent) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![
        ("label", Json::Str(ev.label.clone())),
        ("vt_start_s", Json::Num(ev.vt_start_s)),
        ("vt_dur_s", Json::Num(ev.vt_dur_s)),
    ];
    for (k, v) in &ev.args {
        pairs.push((k.as_str(), Json::Num(*v)));
    }
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convert a trace to a Chrome trace-event document.
pub fn to_chrome(trace: &Trace) -> Json {
    let mut lanes: Vec<_> = trace.events.iter().map(|e| e.lane.clone()).collect();
    lanes.sort();
    lanes.dedup();
    let tid_of = |ev: &TraceEvent| -> f64 {
        lanes.iter().position(|l| *l == ev.lane).unwrap_or(0) as f64
    };

    let mut out = Vec::new();
    for (tid, lane) in lanes.iter().enumerate() {
        out.push(Json::obj(vec![
            ("ph", Json::Str("M".to_string())),
            ("name", Json::Str("thread_name".to_string())),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid as f64)),
            (
                "args",
                Json::obj(vec![("name", Json::Str(lane.encode()))]),
            ),
        ]));
    }
    for ev in &trace.events {
        let Some(ts) = diag(ev, "wall_start_us") else {
            continue;
        };
        let dur = diag(ev, "wall_dur_us").unwrap_or(0.0);
        let instant = dur == 0.0 && ev.vt_dur_s == 0.0;
        let mut pairs = vec![
            ("ph", Json::Str(if instant { "i" } else { "X" }.to_string())),
            ("name", Json::Str(ev.name.clone())),
            ("cat", Json::Str(format!("depth{}", ev.depth))),
            ("pid", Json::Num(1.0)),
            ("tid", Json::Num(tid_of(ev))),
            ("ts", Json::Num(ts)),
            ("args", event_args(ev)),
        ];
        if instant {
            pairs.push(("s", Json::Str("t".to_string())));
        } else {
            pairs.push(("dur", Json::Num(dur)));
        }
        out.push(Json::obj(pairs));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::report::TraceHeader;
    use crate::obs::span::Lane;
    use crate::obs::TRACE_VERSION;
    use std::collections::BTreeMap;

    fn ev(lane: Lane, seq: u64, name: &str, wall: Option<(f64, f64)>, vt_dur: f64) -> TraceEvent {
        let diag = wall
            .map(|(s, d)| {
                vec![("wall_dur_us".to_string(), d), ("wall_start_us".to_string(), s)]
            })
            .unwrap_or_default();
        TraceEvent {
            lane,
            seq,
            depth: 0,
            name: name.to_string(),
            label: "t".to_string(),
            vt_start_s: 0.0,
            vt_dur_s: vt_dur,
            args: vec![("round".to_string(), 1.0)],
            diag,
        }
    }

    #[test]
    fn exports_durations_instants_and_thread_names() {
        let trace = Trace {
            header: TraceHeader {
                version: TRACE_VERSION,
                device: "d".to_string(),
                strategy: "s".to_string(),
                model: "m".to_string(),
                jobs: 1,
                seed: 0,
            },
            events: vec![
                ev(Lane::Learner, 0, "publish", Some((5.0, 0.0)), 0.0),
                ev(Lane::Task(0), 0, "round", Some((10.0, 250.0)), 1.5),
                ev(Lane::Task(0), 1, "stripped", None, 1.0),
            ],
            metrics: BTreeMap::new(),
        };
        let doc = to_chrome(&trace);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 thread_name metadata + 1 instant + 1 duration; the
        // diag-stripped event is skipped.
        assert_eq!(evs.len(), 4);
        let phs: Vec<&str> =
            evs.iter().map(|e| e.get("ph").unwrap().as_str().unwrap()).collect();
        assert_eq!(phs.iter().filter(|p| **p == "M").count(), 2);
        assert!(phs.contains(&"i") && phs.contains(&"X"));
        let x = evs.iter().find(|e| e.get("ph").unwrap().as_str() == Some("X")).unwrap();
        assert_eq!(x.get("dur").unwrap().as_f64(), Some(250.0));
        assert_eq!(x.get("args").unwrap().get("vt_dur_s").unwrap().as_f64(), Some(1.5));
    }
}
