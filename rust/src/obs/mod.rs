//! Observability plane: structured tracing and metrics for the tuning
//! engine (`moses tune --trace`, `moses trace report|chrome`).
//!
//! # Two clocks
//!
//! The engine runs against a *virtual* device clock
//! ([`crate::device::VirtualClock`]): every measurement, model query
//! and update charges simulated seconds, and `(seed, jobs)` determines
//! those charges bit-exactly.  The harness also has an ordinary *wall*
//! clock, which depends on the machine and the thread schedule.  Every
//! span records both: virtual start/duration as first-class fields
//! (`vt`), wall microseconds in the `diag` payload.  Reports and the
//! reconcile property (`Σ depth-0 vt == Session::search_time_s()`) use
//! virtual time; the Chrome export uses wall time, because a flame view
//! is about what actually overlapped.
//!
//! # Determinism contract
//!
//! Everything except `diag` is a pure function of `(seed, jobs,
//! tasks)`: lane, seq, depth, name, label, virtual times, `args`.
//! Scheduling-dependent readings (wall clock, learner stash depth) go
//! in `diag` and nowhere else, so two traces of the same session are
//! identical after stripping `diag`.  Event ordering is made
//! schedule-independent by per-lane sequence counters owned by each
//! emitter plus a `(lane, seq)` sort at drain time — there is no global
//! event counter to race on.
//!
//! One exemption: the work-stealing scheduler's `sched:{worker}` lanes
//! ([`Lane::Sched`]) are diagnostic *as a whole*.  Which unit a worker
//! steals, when it parks, and when it resumes are decisions of the real
//! thread schedule, so their steal/park/resume instants vary run to run
//! by design.  They carry zero virtual duration (they can never perturb
//! the vt reconcile property) and consumers that check the determinism
//! contract must drop `sched:` lanes wholesale, as the `obs_trace`
//! integration tests do.  Everything the scheduler *computes* — task
//! results, snapshot pins, learn order — stays on the contract-bound
//! task and learner lanes.
//!
//! # Granularity
//!
//! Stages trace as spans; high-frequency cache lookups and commits are
//! *counters* in the [`MetricsRegistry`] (folded into the trace
//! footer), not spans — a per-lookup event would dominate the trace and
//! the hot path.  A disabled [`Recorder`] (the default) reduces every
//! instrumentation point to one branch; `benches/hotpath.rs` measures
//! that cost.
//!
//! One recorder covers one tuning session: lane sequence counters
//! restart with each session, so reuse a recorder only if its events
//! were drained in between.

pub mod chrome;
pub mod recorder;
pub mod report;
pub mod span;

pub use recorder::{Counter, MetricsRegistry, Recorder};
pub use report::{Trace, TraceHeader};
pub use span::{Lane, SpanTimer, TraceEvent, TraceScope};

/// Version stamp written into (and required of) trace files.
pub const TRACE_VERSION: u32 = 1;
