//! Lottery-ticket masks over the flat parameter vector (paper §3.4).
//!
//! A mask marks each parameter as *transferable* (1.0 — domain-invariant,
//! fine-tuned on the target device) or *domain-variant* (0.0 — decayed to
//! zero).  Masks are derived from the ξ = |w · ∇w| saliency either by an
//! absolute threshold ϑ or by ranking to a user-set transferable ratio
//! (the paper exposes both; the ratio form drives the Fig. 6 ablation).
//!
//! Like [`crate::costmodel::ModelState`], a mask sits on the learning
//! hot path (one per gradient round), so its storage is shared
//! `Arc<[f32]>`: cloning a mask is a pointer copy, never an
//! N_PARAMS-float copy.  Masks are immutable once built — every
//! derivation returns a fresh mask.

use std::sync::Arc;

use crate::costmodel::layout;

/// A 0/1 mask over the flat parameter vector (immutable, cheap to
/// clone — the values are `Arc`-shared).
#[derive(Debug, Clone, PartialEq)]
pub struct Mask {
    pub values: Arc<[f32]>,
}

impl Mask {
    /// All-ones mask (vanilla fine-tuning trains every parameter).
    pub fn all_ones(n: usize) -> Mask {
        Mask { values: vec![1.0; n].into() }
    }

    /// All-zeros mask (frozen model).
    pub fn all_zeros(n: usize) -> Mask {
        Mask { values: vec![0.0; n].into() }
    }

    /// Mask over explicit values (tests, custom boundaries).
    pub fn from_values(values: Vec<f32>) -> Mask {
        Mask { values: values.into() }
    }

    /// Threshold form: transferable iff ξ(i) > ϑ (paper's default
    /// criterion with ϑ = 0.5 *after per-batch normalization*; raw ξ
    /// magnitudes depend on loss scale, so we normalize ξ to [0, 1] by
    /// its max before thresholding).
    pub fn from_xi_threshold(xi: &[f32], theta: f32) -> Mask {
        let max = xi.iter().cloned().fold(0.0f32, f32::max);
        if max <= 0.0 {
            // Degenerate saliency (e.g. zero grads): keep everything
            // trainable rather than freezing the whole model.
            return Mask::all_ones(xi.len());
        }
        let values = xi.iter().map(|&s| if s / max > theta { 1.0 } else { 0.0 }).collect();
        Mask { values }
    }

    /// Ranking form: keep exactly `ceil(ratio * n)` highest-ξ parameters
    /// transferable (paper §3.4 "ranking mechanism"; Fig. 6 ablation).
    pub fn from_xi_ratio(xi: &[f32], ratio: f64) -> Mask {
        let n = xi.len();
        let keep = ((ratio * n as f64).ceil() as usize).min(n);
        if keep == 0 {
            return Mask::all_zeros(n);
        }
        if keep == n {
            return Mask::all_ones(n);
        }
        let mut idx: Vec<u32> = (0..n as u32).collect();
        // Partial selection of the top-`keep` by ξ (descending).
        idx.select_nth_unstable_by(keep - 1, |&a, &b| {
            xi[b as usize]
                .partial_cmp(&xi[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut values = vec![0.0f32; n];
        for &i in &idx[..keep] {
            values[i as usize] = 1.0;
        }
        Mask::from_values(values)
    }

    /// Number of transferable parameters.
    pub fn count_transferable(&self) -> usize {
        self.values.iter().filter(|&&v| v == 1.0).count()
    }

    /// Transferable fraction.
    pub fn ratio(&self) -> f64 {
        if self.values.is_empty() {
            0.0
        } else {
            self.count_transferable() as f64 / self.values.len() as f64
        }
    }

    /// Per-layer transferable fractions (diagnostics: the paper argues
    /// early layers carry more hardware-independent structure).
    pub fn per_segment_ratio(&self) -> [f64; 6] {
        let off = layout::offsets();
        let mut out = [0.0f64; 6];
        for (seg, item) in out.iter_mut().enumerate() {
            let start = off[seg];
            let len = layout::SIZES[seg];
            let ones = self.values[start..start + len].iter().filter(|&&v| v == 1.0).count();
            *item = ones as f64 / len as f64;
        }
        out
    }

    /// Union with another mask (parameter transferable in either).
    pub fn union(&self, other: &Mask) -> Mask {
        assert_eq!(self.values.len(), other.values.len());
        Mask {
            values: self
                .values
                .iter()
                .zip(other.values.iter())
                .map(|(&a, &b)| if a == 1.0 || b == 1.0 { 1.0 } else { 0.0 })
                .collect(),
        }
    }

    /// Exponential-moving blend of mask refreshes: a parameter stays
    /// transferable if it was recently salient — stabilizes the
    /// iterative boundary updates across tuning phases (paper §3.4
    /// "iteratively update the boundary").
    pub fn ema_refresh(history: &Mask, fresh: &Mask, keep_prob: f64) -> Mask {
        assert_eq!(history.values.len(), fresh.values.len());
        let mut values = fresh.values.to_vec();
        for i in 0..values.len() {
            if history.values[i] == 1.0 && fresh.values[i] == 0.0 {
                // Previously-transferable param: retain with probability
                // keep_prob using a deterministic hash of the index so
                // refreshes are reproducible.
                if crate::util::rng::hash_unit(i as u64 ^ 0x5EED) < keep_prob {
                    values[i] = 1.0;
                }
            }
        }
        Mask::from_values(values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_xi(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.uniform().powi(2) as f32).collect()
    }

    #[test]
    fn ratio_mask_exact_count() {
        let mut rng = Rng::new(1);
        let xi = random_xi(&mut rng, 1000);
        for ratio in [0.01, 0.3, 0.5, 0.7, 1.0] {
            let m = Mask::from_xi_ratio(&xi, ratio);
            assert_eq!(m.count_transferable(), (ratio * 1000.0).ceil() as usize);
        }
    }

    #[test]
    fn ratio_mask_keeps_highest_xi() {
        let xi = vec![0.1, 0.9, 0.5, 0.7, 0.2];
        let m = Mask::from_xi_ratio(&xi, 0.4); // keep 2
        assert_eq!(&m.values[..], &[0.0, 1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn threshold_mask_normalizes() {
        let xi = vec![0.0, 10.0, 4.0, 6.0];
        let m = Mask::from_xi_threshold(&xi, 0.5);
        assert_eq!(&m.values[..], &[0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn threshold_degenerate_keeps_all() {
        let m = Mask::from_xi_threshold(&[0.0; 8], 0.5);
        assert_eq!(m.count_transferable(), 8);
    }

    #[test]
    fn per_segment_ratio_sums() {
        let m = Mask::all_ones(layout::N_PARAMS);
        assert!(m.per_segment_ratio().iter().all(|&r| (r - 1.0).abs() < 1e-12));
    }

    #[test]
    fn union_is_or() {
        let a = Mask::from_values(vec![1.0, 0.0, 0.0]);
        let b = Mask::from_values(vec![0.0, 1.0, 0.0]);
        assert_eq!(&a.union(&b).values[..], &[1.0, 1.0, 0.0]);
    }

    #[test]
    fn ema_refresh_keeps_all_with_prob_one() {
        let hist = Mask::from_values(vec![1.0, 1.0, 0.0, 0.0]);
        let fresh = Mask::from_values(vec![0.0, 1.0, 1.0, 0.0]);
        let m = Mask::ema_refresh(&hist, &fresh, 1.0);
        assert_eq!(&m.values[..], &[1.0, 1.0, 1.0, 0.0]);
        let m0 = Mask::ema_refresh(&hist, &fresh, 0.0);
        assert_eq!(m0.values, fresh.values);
    }

    #[test]
    fn prop_ratio_mask_invariants() {
        prop::check(|rng| {
            let n = rng.below(2000) + 1;
            let xi = random_xi(rng, n);
            let ratio = rng.uniform();
            let m = Mask::from_xi_ratio(&xi, ratio);
            assert_eq!(m.values.len(), n);
            let keep = (ratio * n as f64).ceil() as usize;
            assert_eq!(m.count_transferable(), keep.min(n));
            // Every selected element's xi >= every unselected element's xi
            // (up to ties at the boundary).
            let sel_min = m
                .values
                .iter()
                .zip(&xi)
                .filter(|(v, _)| **v == 1.0)
                .map(|(_, &s)| s)
                .fold(f32::INFINITY, f32::min);
            let unsel_max = m
                .values
                .iter()
                .zip(&xi)
                .filter(|(v, _)| **v == 0.0)
                .map(|(_, &s)| s)
                .fold(f32::NEG_INFINITY, f32::max);
            if m.count_transferable() < n && m.count_transferable() > 0 {
                assert!(sel_min >= unsel_max - 1e-6, "sel_min {sel_min} unsel_max {unsel_max}");
            }
        });
    }
}
