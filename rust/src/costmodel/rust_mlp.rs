//! Pure-Rust mirror of the L1/L2 cost-model math.
//!
//! Semantics are identical to the JAX graphs (`python/compile/model.py`):
//! same MLP, same pairwise logistic ranking loss, same masked-Adam +
//! weight-decay update.  Three roles:
//!
//! 1. fast unit/property tests that don't need PJRT;
//! 2. a fallback backend (`--backend rust`) so the tuner runs even
//!    without artifacts;
//! 3. the cross-checking oracle for the Rust↔XLA parity integration test
//!    (`rust/tests/xla_parity.rs`).
//!
//! The matmuls here are written as straightforward loops with an
//! 8-wide inner accumulation; the perf pass (EXPERIMENTS.md §Perf)
//! measures them against the XLA backend.

use crate::costmodel::layout::{self, HIDDEN, N_FEATURES, N_PARAMS};

pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

/// Activations recorded by the forward pass (needed for backprop).
pub struct Activations {
    pub h1: Vec<f32>, // [batch, HIDDEN] post-ReLU
    pub h2: Vec<f32>, // [batch, HIDDEN] post-ReLU
    pub scores: Vec<f32>,
}

/// y[rows x cols] = x[rows x inner] * w[inner x cols] + b, ReLU optional.
fn dense(
    x: &[f32],
    rows: usize,
    inner: usize,
    w: &[f32],
    b: &[f32],
    cols: usize,
    relu: bool,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), rows * inner);
    debug_assert_eq!(w.len(), inner * cols);
    debug_assert_eq!(out.len(), rows * cols);
    for r in 0..rows {
        let xrow = &x[r * inner..(r + 1) * inner];
        let orow = &mut out[r * cols..(r + 1) * cols];
        orow.copy_from_slice(&b[..cols]);
        for (k, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue; // ReLU sparsity shortcut
            }
            let wrow = &w[k * cols..(k + 1) * cols];
            for c in 0..cols {
                orow[c] += xv * wrow[c];
            }
        }
        if relu {
            for v in orow.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
    }
}

/// Forward pass over a row-major batch `x[batch, N_FEATURES]`.
pub fn forward(params: &[f32], x: &[f32], batch: usize) -> Vec<f32> {
    forward_full(params, x, batch).scores
}

/// Forward pass that also returns hidden activations.
pub fn forward_full(params: &[f32], x: &[f32], batch: usize) -> Activations {
    assert_eq!(params.len(), N_PARAMS);
    assert_eq!(x.len(), batch * N_FEATURES);
    let v = layout::view(params);
    let mut h1 = vec![0.0f32; batch * HIDDEN];
    dense(x, batch, N_FEATURES, v.w1, v.b1, HIDDEN, true, &mut h1);
    let mut h2 = vec![0.0f32; batch * HIDDEN];
    dense(&h1, batch, HIDDEN, v.w2, v.b2, HIDDEN, true, &mut h2);
    let mut scores = vec![0.0f32; batch];
    for r in 0..batch {
        let mut acc = v.b3[0];
        let hrow = &h2[r * HIDDEN..(r + 1) * HIDDEN];
        for k in 0..HIDDEN {
            acc += hrow[k] * v.w3[k];
        }
        scores[r] = acc;
    }
    Activations { h1, h2, scores }
}

/// Pairwise logistic ranking loss (matches `ref.pairwise_rank_loss`).
pub fn rank_loss(scores: &[f32], y: &[f32], w: &[f32]) -> f32 {
    let (loss, _) = rank_loss_and_score_grads(scores, y, w);
    loss
}

/// Loss and dL/dscores for the weighted pairwise logistic objective.
pub fn rank_loss_and_score_grads(scores: &[f32], y: &[f32], w: &[f32]) -> (f32, Vec<f32>) {
    let n = scores.len();
    assert_eq!(y.len(), n);
    assert_eq!(w.len(), n);
    let mut total_w = 0.0f64;
    let mut loss = 0.0f64;
    let mut grad = vec![0.0f64; n];
    for i in 0..n {
        for j in 0..n {
            let sign = (y[i] - y[j]).signum();
            if sign == 0.0 || y[i] == y[j] {
                continue;
            }
            let pw = (w[i] * w[j]) as f64;
            if pw == 0.0 {
                continue;
            }
            total_w += pw;
            let x = ((scores[i] - scores[j]) * sign) as f64;
            // softplus(-x), stable.
            let sp = if x > 30.0 {
                (-x).exp()
            } else if x < -30.0 {
                -x
            } else {
                (1.0 + (-x).exp()).ln()
            };
            loss += pw * sp;
            // d softplus(-x)/dx = -sigmoid(-x)
            let sig = 1.0 / (1.0 + x.exp()); // sigmoid(-x)
            let d = -sig * sign as f64 * pw;
            grad[i] += d;
            grad[j] -= d;
        }
    }
    let denom = total_w.max(1.0);
    let loss = (loss / denom) as f32;
    let grads: Vec<f32> = grad.iter().map(|g| (g / denom) as f32).collect();
    (loss, grads)
}

/// Full backward pass: gradient of the ranking loss w.r.t. the flat
/// parameter vector.
pub fn backward(params: &[f32], x: &[f32], batch: usize, y: &[f32], w: &[f32]) -> (f32, Vec<f32>) {
    let acts = forward_full(params, x, batch);
    let (loss, dscores) = rank_loss_and_score_grads(&acts.scores, y, w);
    let v = layout::view(params);
    let off = layout::offsets();
    let mut grads = vec![0.0f32; N_PARAMS];

    // Layer 3: scores = h2 @ w3 + b3.
    {
        let (gw3, rest) = grads[off[4]..].split_at_mut(HIDDEN);
        let gb3 = &mut rest[..1];
        for r in 0..batch {
            let d = dscores[r];
            if d == 0.0 {
                continue;
            }
            let hrow = &acts.h2[r * HIDDEN..(r + 1) * HIDDEN];
            for k in 0..HIDDEN {
                gw3[k] += d * hrow[k];
            }
            gb3[0] += d;
        }
    }

    // dL/dh2 with ReLU mask.
    let mut dh2 = vec![0.0f32; batch * HIDDEN];
    for r in 0..batch {
        let d = dscores[r];
        if d == 0.0 {
            continue;
        }
        let hrow = &acts.h2[r * HIDDEN..(r + 1) * HIDDEN];
        let drow = &mut dh2[r * HIDDEN..(r + 1) * HIDDEN];
        for k in 0..HIDDEN {
            if hrow[k] > 0.0 {
                drow[k] = d * v.w3[k];
            }
        }
    }

    // Layer 2 grads: h2 = relu(h1 @ w2 + b2).
    {
        let (gw2, gb2) = {
            let seg = &mut grads[off[2]..off[4]];
            let (a, b) = seg.split_at_mut(HIDDEN * HIDDEN);
            (a, b)
        };
        for r in 0..batch {
            let h1row = &acts.h1[r * HIDDEN..(r + 1) * HIDDEN];
            let drow = &dh2[r * HIDDEN..(r + 1) * HIDDEN];
            for k in 0..HIDDEN {
                let hv = h1row[k];
                if hv == 0.0 {
                    continue;
                }
                let gw2row = &mut gw2[k * HIDDEN..(k + 1) * HIDDEN];
                for c in 0..HIDDEN {
                    gw2row[c] += hv * drow[c];
                }
            }
            for c in 0..HIDDEN {
                gb2[c] += drow[c];
            }
        }
    }

    // dL/dh1 with ReLU mask.
    let mut dh1 = vec![0.0f32; batch * HIDDEN];
    for r in 0..batch {
        let drow = &dh2[r * HIDDEN..(r + 1) * HIDDEN];
        let h1row = &acts.h1[r * HIDDEN..(r + 1) * HIDDEN];
        let out = &mut dh1[r * HIDDEN..(r + 1) * HIDDEN];
        for k in 0..HIDDEN {
            if h1row[k] > 0.0 {
                let w2row = &v.w2[k * HIDDEN..(k + 1) * HIDDEN];
                let mut acc = 0.0f32;
                for c in 0..HIDDEN {
                    acc += w2row[c] * drow[c];
                }
                out[k] = acc;
            }
        }
    }

    // Layer 1 grads: h1 = relu(x @ w1 + b1).
    {
        let (gw1, gb1) = {
            let seg = &mut grads[off[0]..off[2]];
            let (a, b) = seg.split_at_mut(N_FEATURES * HIDDEN);
            (a, b)
        };
        for r in 0..batch {
            let xrow = &x[r * N_FEATURES..(r + 1) * N_FEATURES];
            let drow = &dh1[r * HIDDEN..(r + 1) * HIDDEN];
            for k in 0..N_FEATURES {
                let xv = xrow[k];
                if xv == 0.0 {
                    continue;
                }
                let gw1row = &mut gw1[k * HIDDEN..(k + 1) * HIDDEN];
                for c in 0..HIDDEN {
                    gw1row[c] += xv * drow[c];
                }
            }
            for c in 0..HIDDEN {
                gb1[c] += drow[c];
            }
        }
    }

    (loss, grads)
}

/// Masked Adam + weight-decay update (matches `ref.masked_adam_update`).
#[allow(clippy::too_many_arguments)]
pub fn masked_adam_update(
    params: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    grads: &[f32],
    mask: &[f32],
    lr: f32,
    wd: f32,
    step: f32,
) {
    let bc1 = 1.0 - ADAM_B1.powf(step);
    let bc2 = 1.0 - ADAM_B2.powf(step);
    for i in 0..params.len() {
        let g = grads[i] * mask[i];
        m[i] = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * g;
        v[i] = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * g * g;
        let adam = lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + ADAM_EPS);
        params[i] -= mask[i] * adam + (1.0 - mask[i]) * lr * wd * params[i];
    }
}

/// ξ = |w · ∇w| saliency (paper Eq. 5).
pub fn xi_scores(params: &[f32], x: &[f32], batch: usize, y: &[f32], w: &[f32]) -> Vec<f32> {
    let (_, grads) = backward(params, x, batch, y, w);
    params.iter().zip(&grads).map(|(p, g)| (p * g).abs()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn small_batch(rng: &mut Rng, batch: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let x: Vec<f32> = (0..batch * N_FEATURES).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..batch).map(|_| rng.uniform_in(0.0, 10.0) as f32).collect();
        let w = vec![1.0f32; batch];
        (x, y, w)
    }

    #[test]
    fn forward_zero_params_is_zero() {
        let params = vec![0.0f32; N_PARAMS];
        let mut rng = Rng::new(1);
        let (x, _, _) = small_batch(&mut rng, 4);
        assert!(forward(&params, &x, 4).iter().all(|&s| s == 0.0));
    }

    #[test]
    fn rank_loss_direction() {
        let y = [1.0, 2.0, 3.0, 4.0];
        let w = [1.0; 4];
        let good = rank_loss(&[1.0, 2.0, 3.0, 4.0], &y, &w);
        let bad = rank_loss(&[4.0, 3.0, 2.0, 1.0], &y, &w);
        assert!(good < bad);
    }

    #[test]
    fn rank_loss_zero_weight_rows_ignored() {
        let y = [1.0, 2.0, -50.0];
        let s = [0.3, 0.9, 100.0];
        let full = rank_loss(&s[..2], &y[..2], &[1.0, 1.0]);
        let padded = rank_loss(&s, &y, &[1.0, 1.0, 0.0]);
        assert!((full - padded).abs() < 1e-6);
    }

    #[test]
    fn score_grads_match_finite_difference() {
        let mut rng = Rng::new(2);
        let n = 6;
        let scores: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.uniform_in(0.0, 5.0) as f32).collect();
        let w = vec![1.0f32; n];
        let (_, grads) = rank_loss_and_score_grads(&scores, &y, &w);
        let eps = 1e-3f32;
        for i in 0..n {
            let mut sp = scores.clone();
            sp[i] += eps;
            let mut sm = scores.clone();
            sm[i] -= eps;
            let fd = (rank_loss(&sp, &y, &w) - rank_loss(&sm, &y, &w)) / (2.0 * eps);
            assert!(
                (fd - grads[i]).abs() < 2e-3,
                "i={i} fd={fd} analytic={}",
                grads[i]
            );
        }
    }

    #[test]
    fn param_grads_match_finite_difference_spot_checks() {
        let mut rng = Rng::new(3);
        let batch = 5;
        let params = layout::init_params(&mut rng);
        let (x, y, w) = small_batch(&mut rng, batch);
        let (_, grads) = backward(&params, &x, batch, &y, &w);
        let off = layout::offsets();
        // One index per segment.
        let picks = [off[0] + 7, off[1] + 3, off[2] + 1001, off[3] + 20, off[4] + 100, off[5]];
        let eps = 3e-3f32;
        for &i in &picks {
            let mut pp = params.clone();
            pp[i] += eps;
            let lp = rank_loss(&forward(&pp, &x, batch), &y, &w);
            let mut pm = params.clone();
            pm[i] -= eps;
            let lm = rank_loss(&forward(&pm, &x, batch), &y, &w);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grads[i]).abs() < 5e-3,
                "idx {i}: fd={fd} analytic={}",
                grads[i]
            );
        }
    }

    #[test]
    fn training_reduces_loss() {
        let mut rng = Rng::new(4);
        let batch = 16;
        let mut params = layout::init_params(&mut rng);
        let (x, y, w) = small_batch(&mut rng, batch);
        let mut m = vec![0.0f32; N_PARAMS];
        let mut v = vec![0.0f32; N_PARAMS];
        let mask = vec![1.0f32; N_PARAMS];
        let first = rank_loss(&forward(&params, &x, batch), &y, &w);
        for step in 1..=20 {
            let (_, grads) = backward(&params, &x, batch, &y, &w);
            masked_adam_update(&mut params, &mut m, &mut v, &grads, &mask, 1e-2, 0.0, step as f32);
        }
        let last = rank_loss(&forward(&params, &x, batch), &y, &w);
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn variant_params_decay_under_zero_mask() {
        let mut rng = Rng::new(5);
        let mut params = layout::init_params(&mut rng);
        let orig = params.clone();
        let mut m = vec![0.0f32; N_PARAMS];
        let mut v = vec![0.0f32; N_PARAMS];
        let grads: Vec<f32> = (0..N_PARAMS).map(|_| rng.normal() as f32).collect();
        let mask = vec![0.0f32; N_PARAMS];
        let (lr, wd) = (0.01f32, 0.1f32);
        masked_adam_update(&mut params, &mut m, &mut v, &grads, &mask, lr, wd, 1.0);
        for i in (0..N_PARAMS).step_by(50_000) {
            let expect = orig[i] * (1.0 - lr * wd);
            assert!((params[i] - expect).abs() < 1e-7);
        }
        assert!(m.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn xi_zero_for_zero_params() {
        let mut rng = Rng::new(6);
        let (x, y, w) = small_batch(&mut rng, 4);
        let xi = xi_scores(&vec![0.0; N_PARAMS], &x, 4, &y, &w);
        assert!(xi.iter().all(|&s| s == 0.0));
    }
}
