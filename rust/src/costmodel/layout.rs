//! Flat-parameter layout of the cost-model MLP.
//!
//! Mirrors `python/compile/kernels/ref.py` exactly: one f32 vector holds
//! (w1[164×512], b1[512], w2[512×512], b2[512], w3[512×1], b3[1]) in that
//! order.  `runtime::ArtifactMeta::load` cross-checks these constants
//! against the artifacts at startup.

use crate::util::rng::Rng;

/// Ansor's 164-dimensional program feature vector (paper §2.2).
pub const N_FEATURES: usize = 164;
/// Hidden width of the representative Ansor MLP backbone (paper §4.2).
pub const HIDDEN: usize = 512;

/// Segment sizes in flat order.
pub const SIZES: [usize; 6] = [
    N_FEATURES * HIDDEN, // w1
    HIDDEN,              // b1
    HIDDEN * HIDDEN,     // w2
    HIDDEN,              // b2
    HIDDEN,              // w3 (HIDDEN x 1)
    1,                   // b3
];

/// Total flat parameter count (347,649).
pub const N_PARAMS: usize =
    N_FEATURES * HIDDEN + HIDDEN + HIDDEN * HIDDEN + HIDDEN + HIDDEN + 1;

/// Byte offsets of each segment in the flat vector.
pub const fn offsets() -> [usize; 6] {
    let mut off = [0usize; 6];
    let mut acc = 0;
    let mut i = 0;
    while i < 6 {
        off[i] = acc;
        acc += SIZES[i];
        i += 1;
    }
    off
}

/// Named views into a flat parameter vector.
#[derive(Debug)]
pub struct ParamView<'a> {
    pub w1: &'a [f32],
    pub b1: &'a [f32],
    pub w2: &'a [f32],
    pub b2: &'a [f32],
    pub w3: &'a [f32],
    pub b3: &'a [f32],
}

/// Split a flat parameter vector into named segments.
pub fn view(params: &[f32]) -> ParamView<'_> {
    assert_eq!(params.len(), N_PARAMS);
    let off = offsets();
    ParamView {
        w1: &params[off[0]..off[0] + SIZES[0]],
        b1: &params[off[1]..off[1] + SIZES[1]],
        w2: &params[off[2]..off[2] + SIZES[2]],
        b2: &params[off[3]..off[3] + SIZES[3]],
        w3: &params[off[4]..off[4] + SIZES[4]],
        b3: &params[off[5]..off[5] + SIZES[5]],
    }
}

/// Which layer a flat index belongs to (0..6 in SIZES order) — used by
/// per-layer transfer diagnostics.
pub fn segment_of(index: usize) -> usize {
    let off = offsets();
    for i in (0..6).rev() {
        if index >= off[i] {
            return i;
        }
    }
    0
}

/// Xavier/Glorot-style initialization of the flat vector (matches what a
/// PyTorch `nn.Linear` default would roughly give; exact scheme is not
/// performance-critical, determinism is).
pub fn init_params(rng: &mut Rng) -> Vec<f32> {
    let mut p = vec![0.0f32; N_PARAMS];
    let off = offsets();
    let layer_dims: [(usize, usize, usize); 3] = [
        (off[0], N_FEATURES, HIDDEN),
        (off[2], HIDDEN, HIDDEN),
        (off[4], HIDDEN, 1),
    ];
    for (start, fan_in, fan_out) in layer_dims {
        let scale = (2.0 / (fan_in + fan_out) as f64).sqrt();
        for i in 0..(fan_in * fan_out) {
            p[start + i] = rng.normal_ms(0.0, scale) as f32;
        }
    }
    // Biases start at zero (already).
    p
}

/// Serialize a f32 vector as little-endian bytes (checkpoint format).
pub fn to_bytes(params: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(params.len() * 4);
    for &x in params {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Deserialize a little-endian f32 vector.
pub fn from_bytes(bytes: &[u8]) -> anyhow::Result<Vec<f32>> {
    anyhow::ensure!(bytes.len() % 4 == 0, "checkpoint length not a multiple of 4");
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Save a parameter checkpoint.
pub fn save_checkpoint(path: &std::path::Path, params: &[f32]) -> anyhow::Result<()> {
    anyhow::ensure!(params.len() == N_PARAMS, "checkpoint has wrong length");
    std::fs::write(path, to_bytes(params))?;
    Ok(())
}

/// Load a parameter checkpoint, validating length.
pub fn load_checkpoint(path: &std::path::Path) -> anyhow::Result<Vec<f32>> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("reading checkpoint {path:?}: {e}"))?;
    let params = from_bytes(&bytes)?;
    anyhow::ensure!(
        params.len() == N_PARAMS,
        "checkpoint {path:?} has {} params, expected {}",
        params.len(),
        N_PARAMS
    );
    Ok(params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn n_params_matches_python() {
        // ref.py: 164*512 + 512 + 512*512 + 512 + 512 + 1
        assert_eq!(N_PARAMS, 347_649);
        assert_eq!(SIZES.iter().sum::<usize>(), N_PARAMS);
    }

    #[test]
    fn offsets_are_cumulative() {
        let off = offsets();
        assert_eq!(off[0], 0);
        for i in 1..6 {
            assert_eq!(off[i], off[i - 1] + SIZES[i - 1]);
        }
    }

    #[test]
    fn view_partitions_whole_vector() {
        let p: Vec<f32> = (0..N_PARAMS).map(|i| i as f32).collect();
        let v = view(&p);
        assert_eq!(v.w1.len(), N_FEATURES * HIDDEN);
        assert_eq!(v.b3.len(), 1);
        assert_eq!(v.w1[0], 0.0);
        assert_eq!(v.b3[0], (N_PARAMS - 1) as f32);
    }

    #[test]
    fn segment_of_boundaries() {
        let off = offsets();
        assert_eq!(segment_of(0), 0);
        assert_eq!(segment_of(off[1]), 1);
        assert_eq!(segment_of(off[1] - 1), 0);
        assert_eq!(segment_of(N_PARAMS - 1), 5);
    }

    #[test]
    fn init_is_deterministic_and_scaled() {
        let a = init_params(&mut Rng::new(1));
        let b = init_params(&mut Rng::new(1));
        assert_eq!(a, b);
        let v = view(&a);
        // Biases zero.
        assert!(v.b1.iter().all(|&x| x == 0.0));
        // Weights non-degenerate and small.
        let mean: f32 = v.w1.iter().sum::<f32>() / v.w1.len() as f32;
        assert!(mean.abs() < 0.01);
        assert!(v.w1.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn bytes_roundtrip() {
        let p = init_params(&mut Rng::new(2));
        let q = from_bytes(&to_bytes(&p)).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn checkpoint_roundtrip_and_validation() {
        let dir = std::env::temp_dir().join("moses_layout_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        let p = init_params(&mut Rng::new(3));
        save_checkpoint(&path, &p).unwrap();
        assert_eq!(load_checkpoint(&path).unwrap(), p);
        std::fs::write(&path, [0u8; 8]).unwrap();
        assert!(load_checkpoint(&path).is_err());
    }
}
