//! The learned cost model C() ~ Perf() (paper Eq. 2), split into a
//! **mutation plane** and a **zero-copy prediction plane**:
//!
//! * [`layout`] — flat-parameter geometry shared with the Python side.
//! * [`rust_mlp`] — pure-Rust mirror of the MLP / loss / update math.
//! * [`mask`] — lottery-ticket masks over the parameter vector.
//! * [`ModelState`] — an *immutable, versioned* snapshot of everything
//!   that learns (parameters + Adam moments + step counter) behind
//!   `Arc<[f32]>` shared storage.  Cloning or publishing a state is a
//!   pointer copy, never a parameter copy.
//! * [`Predictor`] — the read-only view the search plane consumes:
//!   `predict`/`xi`/`loss` over a pinned `Arc<ModelState>` and a
//!   pluggable [`Backend`].  A pinned predictor is unaffected by any
//!   later training — workers rank thousands of candidates per round
//!   against it without ever copying the ~350k-float parameter vector.
//! * [`CostModel`] — the single owner with mutating access.  Updates
//!   are copy-on-write: a train step detaches fresh parameter/moment
//!   vectors from the backend, wraps them in a new [`ModelState`] with
//!   a bumped version, and republishes; existing predictors keep their
//!   old snapshot untouched.
//!
//! The [`Backend`] executing the math is either the XLA/PJRT engine
//! running the AOT Pallas artifacts (production path) or the pure-Rust
//! mirror (tests, artifact-less fallback).

pub mod layout;
pub mod mask;
pub mod rust_mlp;

use std::sync::Arc;

use anyhow::Result;

pub use mask::Mask;

use crate::runtime::Engine;
use crate::util::rng::Rng;

/// Low-level compute backend with FIXED batch geometry.
///
/// Deliberately NOT `Send`/`Sync`: the `xla` crate's PJRT client is
/// `Rc`-based, so an [`XlaBackend`] is pinned to the thread that created
/// it.  Parallelism in the experiment harness happens at the
/// experiment/process level (or with the `Send`-safe [`RustBackend`]).
pub trait Backend {
    fn pred_batch(&self) -> usize;
    /// Small predict batch (0 = unsupported).  Lets the scoring hot path
    /// avoid padding evolutionary populations (~64 rows) up to the
    /// dataset-scoring shape (512).
    fn pred_batch_small(&self) -> usize {
        0
    }
    fn train_batch(&self) -> usize;
    /// Score exactly `pred_batch` rows.
    fn predict_fixed(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>>;
    /// Score exactly `pred_batch_small` rows (only if supported).
    fn predict_small_fixed(&self, _params: &[f32], _x: &[f32]) -> Result<Vec<f32>> {
        anyhow::bail!("backend has no small predict batch")
    }
    /// One masked-Adam step on exactly `train_batch` rows.
    #[allow(clippy::too_many_arguments)]
    fn train_step_fixed(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        x: &[f32],
        y: &[f32],
        w: &[f32],
        mask: &[f32],
        hp: [f32; 4],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)>;
    /// ξ saliency on exactly `train_batch` rows.
    fn xi_fixed(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<Vec<f32>>;
    /// Ranking loss on exactly `train_batch` rows.
    fn loss_fixed(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<f32>;
    /// Human-readable backend name for logs.
    fn name(&self) -> &'static str;
}

/// XLA/PJRT backend over the AOT artifacts (Pallas kernels inside).
pub struct XlaBackend {
    pub engine: Arc<Engine>,
}

impl Backend for XlaBackend {
    fn pred_batch(&self) -> usize {
        self.engine.meta.pred_batch
    }

    fn pred_batch_small(&self) -> usize {
        self.engine.meta.pred_batch_small
    }

    fn train_batch(&self) -> usize {
        self.engine.meta.train_batch
    }

    fn predict_fixed(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        self.engine.predict(params, x)
    }

    fn predict_small_fixed(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        self.engine.predict_small(params, x)
    }

    fn train_step_fixed(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        x: &[f32],
        y: &[f32],
        w: &[f32],
        mask: &[f32],
        hp: [f32; 4],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        let out = self.engine.train_step(params, m, v, x, y, w, mask, hp)?;
        Ok((out.params, out.m, out.v, out.loss))
    }

    fn xi_fixed(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        self.engine.xi(params, x, y, w)
    }

    fn loss_fixed(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<f32> {
        self.engine.loss_eval(params, x, y, w)
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Pure-Rust backend (same math, no PJRT dependency).
pub struct RustBackend {
    pub pred_batch: usize,
    pub train_batch: usize,
}

impl Default for RustBackend {
    fn default() -> Self {
        // Mirror the AOT geometry so parity tests compare like-for-like.
        RustBackend { pred_batch: 512, train_batch: 256 }
    }
}

impl Backend for RustBackend {
    fn pred_batch(&self) -> usize {
        self.pred_batch
    }

    fn pred_batch_small(&self) -> usize {
        // The Rust mirror computes exactly what it is given, so the small
        // variant mirrors the AOT geometry (64) capped by pred_batch.
        64.min(self.pred_batch)
    }

    fn train_batch(&self) -> usize {
        self.train_batch
    }

    fn predict_fixed(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        Ok(rust_mlp::forward(params, x, self.pred_batch))
    }

    fn predict_small_fixed(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        Ok(rust_mlp::forward(params, x, self.pred_batch_small()))
    }

    fn train_step_fixed(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        x: &[f32],
        y: &[f32],
        w: &[f32],
        mask: &[f32],
        hp: [f32; 4],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        let (loss, grads) = rust_mlp::backward(params, x, self.train_batch, y, w);
        let mut p = params.to_vec();
        let mut mm = m.to_vec();
        let mut vv = v.to_vec();
        rust_mlp::masked_adam_update(&mut p, &mut mm, &mut vv, &grads, mask, hp[0], hp[1], hp[2]);
        Ok((p, mm, vv, loss))
    }

    fn xi_fixed(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        Ok(rust_mlp::xi_scores(params, x, self.train_batch, y, w))
    }

    fn loss_fixed(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<f32> {
        Ok(rust_mlp::rank_loss(
            &rust_mlp::forward(params, x, self.train_batch),
            y,
            w,
        ))
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// Immutable, versioned learning state: parameters + Adam moments +
/// step counter behind `Arc<[f32]>` shared storage.
///
/// Cloning a `ModelState` clones three `Arc` pointers — it never copies
/// the ~350k floats.  Backends may be `Rc`-based and thread-pinned (see
/// [`Backend`]), so a model crosses thread boundaries as a `ModelState`
/// (which is `Send + Sync`) and is rebuilt against a backend constructed
/// on the receiving thread.
#[derive(Debug, Clone)]
pub struct ModelState {
    params: Arc<[f32]>,
    m: Arc<[f32]>,
    v: Arc<[f32]>,
    step: u64,
    version: u64,
}

impl ModelState {
    /// Fresh state with random parameter init and zeroed Adam moments.
    pub fn init(rng: &mut Rng) -> ModelState {
        ModelState::from_params(layout::init_params(rng))
    }

    /// State with given parameters (e.g. a pre-trained checkpoint) and
    /// zeroed Adam moments.
    pub fn from_params(params: Vec<f32>) -> ModelState {
        assert_eq!(params.len(), layout::N_PARAMS);
        ModelState {
            params: params.into(),
            m: vec![0.0; layout::N_PARAMS].into(),
            v: vec![0.0; layout::N_PARAMS].into(),
            step: 0,
            version: 0,
        }
    }

    /// The flat parameter vector (read-only).
    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// Adam step counter.
    pub fn step(&self) -> u64 {
        self.step
    }

    /// Monotone state version: bumped on every mutation the owning
    /// [`CostModel`] publishes (train steps, optimizer resets).
    pub fn version(&self) -> u64 {
        self.version
    }
}

/// A read-only prediction view over a pinned [`ModelState`].
///
/// This is what the search plane consumes: [`crate::search`] policies,
/// the task pipeline's re-ranking, the adaptive controller, and the
/// Moses mask refresh all take `&Predictor`.  Constructing one from a
/// state is two `Arc` clones; it is immune to any training that happens
/// after the pin.
#[derive(Clone)]
pub struct Predictor {
    backend: Arc<dyn Backend>,
    state: Arc<ModelState>,
}

impl Predictor {
    /// Pin `state` for prediction on `backend` (O(1) — pointer clones).
    pub fn new(backend: Arc<dyn Backend>, state: Arc<ModelState>) -> Predictor {
        assert_eq!(state.params.len(), layout::N_PARAMS);
        Predictor { backend, state }
    }

    /// The pinned state (pointer identity is observable: two predictors
    /// pinned between updates share storage).
    pub fn state(&self) -> &Arc<ModelState> {
        &self.state
    }

    /// Version of the pinned state.
    pub fn version(&self) -> u64 {
        self.state.version
    }

    /// The pinned flat parameter vector.
    pub fn params(&self) -> &[f32] {
        &self.state.params
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Score `rows` feature rows (row-major, `rows * N_FEATURES` f32).
    ///
    /// Chunks to the backend's fixed batch shapes, preferring the small
    /// predict variant when the remaining rows fit it (the evolutionary
    /// search's ~64-row populations then skip the 8× padding to 512).
    pub fn predict(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        let nf = layout::N_FEATURES;
        assert_eq!(x.len(), rows * nf);
        let params = self.params();
        let bp = self.backend.pred_batch();
        let bs = self.backend.pred_batch_small();
        let mut scores = Vec::with_capacity(rows);
        let mut start = 0;
        while start < rows {
            let remaining = rows - start;
            let use_small = bs > 0 && remaining <= bs;
            let batch = if use_small { bs } else { bp };
            let n = remaining.min(batch);
            let src = &x[start * nf..(start + n) * nf];
            let run = |data: &[f32]| {
                if use_small {
                    self.backend.predict_small_fixed(params, data)
                } else {
                    self.backend.predict_fixed(params, data)
                }
            };
            if n == batch {
                scores.extend_from_slice(&run(src)?[..n]);
            } else {
                let mut padded = vec![0.0f32; batch * nf];
                padded[..n * nf].copy_from_slice(src);
                scores.extend_from_slice(&run(&padded)?[..n]);
            }
            start += n;
        }
        Ok(scores)
    }

    /// The pinned MLP's end-to-end linear feature projection: collapse
    /// `w1 · (w2 · w3)` into one 164-float vector, i.e. the network's
    /// exact input→score map if both ReLUs were identity.
    ///
    /// This is what the draft tier (`search::draft`) distills against:
    /// it tells the linear draft how strongly — and with what sign —
    /// the live model reads each feature, keeping the draft derived
    /// from the model rather than a static heuristic (TLP, PAPERS.md).
    /// O(HIDDEN² + N_FEATURES·HIDDEN) ≈ one forward pass of a single
    /// row; deterministic for a given pinned state.
    pub fn feature_projection(&self) -> Vec<f32> {
        let v = layout::view(self.params());
        let h = layout::HIDDEN;
        // u = w2 · w3  (w2 is [HIDDEN x HIDDEN] row-major).
        let mut u = vec![0.0f32; h];
        for (i, ui) in u.iter_mut().enumerate() {
            let w2row = &v.w2[i * h..(i + 1) * h];
            let mut acc = 0.0f32;
            for (a, b) in w2row.iter().zip(v.w3) {
                acc += a * b;
            }
            *ui = acc;
        }
        // proj = w1 · u  (w1 is [N_FEATURES x HIDDEN] row-major).
        let mut proj = vec![0.0f32; layout::N_FEATURES];
        for (i, pi) in proj.iter_mut().enumerate() {
            let w1row = &v.w1[i * h..(i + 1) * h];
            let mut acc = 0.0f32;
            for (a, b) in w1row.iter().zip(&u) {
                acc += a * b;
            }
            *pi = acc;
        }
        proj
    }

    /// ξ saliency on up to `train_batch` labeled rows.
    pub fn xi(&self, x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        let (px, py, pw) = pad_train(self.backend.as_ref(), x, y);
        self.backend.xi_fixed(self.params(), &px, &py, &pw)
    }

    /// Held-out ranking loss on up to `train_batch` labeled rows.
    pub fn loss(&self, x: &[f32], y: &[f32]) -> Result<f32> {
        let (px, py, pw) = pad_train(self.backend.as_ref(), x, y);
        self.backend.loss_fixed(self.params(), &px, &py, &pw)
    }
}

fn pad_train(backend: &dyn Backend, x: &[f32], y: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let nf = layout::N_FEATURES;
    let bt = backend.train_batch();
    let rows = y.len().min(bt);
    assert!(x.len() >= rows * nf, "x shorter than y rows");
    let mut px = vec![0.0f32; bt * nf];
    px[..rows * nf].copy_from_slice(&x[..rows * nf]);
    let mut py = vec![0.0f32; bt];
    py[..rows].copy_from_slice(&y[..rows]);
    let mut pw = vec![0.0f32; bt];
    pw[..rows].iter_mut().for_each(|v| *v = 1.0);
    (px, py, pw)
}

/// The stateful cost model — the only type with mutating access to a
/// [`ModelState`].  Accepts arbitrary row counts; pads/chunks to the
/// backend's fixed batch geometry internally (padding rows get weight 0
/// so they never affect the ranking loss).
///
/// Mutation is copy-on-write: a train step computes fresh parameter and
/// moment vectors, wraps them in a new `Arc<ModelState>` with a bumped
/// version, and swaps the handle.  Snapshots taken earlier (via
/// [`CostModel::predictor`] or [`CostModel::shared_state`]) keep the
/// old storage alive and untouched.
pub struct CostModel {
    backend: Arc<dyn Backend>,
    state: Arc<ModelState>,
}

impl CostModel {
    /// Fresh model with random init.
    pub fn new(backend: Arc<dyn Backend>, rng: &mut Rng) -> CostModel {
        CostModel { backend, state: Arc::new(ModelState::init(rng)) }
    }

    /// Model with given parameters (e.g. a pre-trained checkpoint).
    pub fn with_params(backend: Arc<dyn Backend>, params: Vec<f32>) -> CostModel {
        CostModel { backend, state: Arc::new(ModelState::from_params(params)) }
    }

    /// Rebuild a model from an exported state on a (possibly new)
    /// backend — the inverse of [`CostModel::export_state`].
    pub fn from_state(backend: Arc<dyn Backend>, state: ModelState) -> CostModel {
        assert_eq!(state.params.len(), layout::N_PARAMS);
        assert_eq!(state.m.len(), layout::N_PARAMS);
        assert_eq!(state.v.len(), layout::N_PARAMS);
        CostModel { backend, state: Arc::new(state) }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// A second handle to the backend this model computes on.
    pub fn backend_handle(&self) -> Arc<dyn Backend> {
        self.backend.clone()
    }

    /// The backend's fixed training minibatch (rows per gradient step).
    pub fn train_batch(&self) -> usize {
        self.backend.train_batch()
    }

    /// The current flat parameter vector (read-only).
    pub fn params(&self) -> &[f32] {
        self.state.params()
    }

    /// Detach the full learning state (parameters + Adam moments +
    /// step), e.g. to move the model to another thread.  O(1): the
    /// state is immutable shared storage.
    pub fn export_state(&self) -> ModelState {
        (*self.state).clone()
    }

    /// The current state as a shareable snapshot handle (what the
    /// parallel tuner publishes through its snapshot cell).  O(1).
    pub fn shared_state(&self) -> Arc<ModelState> {
        self.state.clone()
    }

    /// A read-only prediction view pinned to the CURRENT state.  O(1);
    /// later `train_step`s do not affect it.
    pub fn predictor(&self) -> Predictor {
        Predictor { backend: self.backend.clone(), state: self.state.clone() }
    }

    /// Reset Adam state (used when adaptation starts on a new device).
    pub fn reset_optimizer(&mut self) {
        self.state = Arc::new(ModelState {
            params: self.state.params.clone(),
            m: vec![0.0; layout::N_PARAMS].into(),
            v: vec![0.0; layout::N_PARAMS].into(),
            step: 0,
            version: self.state.version + 1,
        });
    }

    /// Score `rows` feature rows against the current state (see
    /// [`Predictor::predict`] for the chunking contract).
    pub fn predict(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        self.predictor().predict(x, rows)
    }

    /// One gradient step on up to `train_batch` labeled rows (padded with
    /// zero-weight rows if fewer). Returns the batch ranking loss.
    pub fn train_step(&mut self, x: &[f32], y: &[f32], mask: &Mask, lr: f32, wd: f32) -> Result<f32> {
        let (px, py, pw) = pad_train(self.backend.as_ref(), x, y);
        let step = self.state.step + 1;
        let hp = [lr, wd, step as f32, 0.0];
        let (p, m, v, loss) = self.backend.train_step_fixed(
            &self.state.params,
            &self.state.m,
            &self.state.v,
            &px,
            &py,
            &pw,
            &mask.values,
            hp,
        )?;
        // Copy-on-write publish: the backend already detached fresh
        // vectors, so pinned snapshots keep the old storage untouched.
        self.state = Arc::new(ModelState {
            params: p.into(),
            m: m.into(),
            v: v.into(),
            step,
            version: self.state.version + 1,
        });
        Ok(loss)
    }

    /// One pass over a labeled set in shuffled mini-batches.
    /// Returns the mean batch loss.
    #[allow(clippy::too_many_arguments)]
    pub fn train_epoch(
        &mut self,
        x: &[f32],
        y: &[f32],
        mask: &Mask,
        lr: f32,
        wd: f32,
        rng: &mut Rng,
    ) -> Result<f32> {
        let nf = layout::N_FEATURES;
        let rows = y.len();
        assert_eq!(x.len(), rows * nf);
        let bt = self.backend.train_batch();
        let mut order: Vec<usize> = (0..rows).collect();
        rng.shuffle(&mut order);
        let mut bx = vec![0.0f32; bt * nf];
        let mut by = vec![0.0f32; bt];
        let mut losses = Vec::new();
        for chunk in order.chunks(bt) {
            for (slot, &row) in chunk.iter().enumerate() {
                bx[slot * nf..(slot + 1) * nf].copy_from_slice(&x[row * nf..(row + 1) * nf]);
                by[slot] = y[row];
            }
            losses.push(self.train_step(&bx[..chunk.len() * nf], &by[..chunk.len()], mask, lr, wd)?);
        }
        Ok(if losses.is_empty() {
            0.0
        } else {
            losses.iter().sum::<f32>() / losses.len() as f32
        })
    }

    /// ξ saliency on up to `train_batch` labeled rows.
    pub fn xi(&self, x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        self.predictor().xi(x, y)
    }

    /// Held-out ranking loss on up to `train_batch` labeled rows.
    pub fn loss(&self, x: &[f32], y: &[f32]) -> Result<f32> {
        self.predictor().loss(x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_backend() -> Arc<dyn Backend> {
        Arc::new(RustBackend { pred_batch: 8, train_batch: 8 })
    }

    fn rows(rng: &mut Rng, n: usize) -> (Vec<f32>, Vec<f32>) {
        let x: Vec<f32> = (0..n * layout::N_FEATURES).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
        (x, y)
    }

    #[test]
    fn predict_handles_partial_and_multi_chunk() {
        let mut rng = Rng::new(1);
        let model = CostModel::new(tiny_backend(), &mut rng);
        for n in [1, 7, 8, 9, 20] {
            let (x, _) = rows(&mut rng, n);
            let scores = model.predict(&x, n).unwrap();
            assert_eq!(scores.len(), n);
            assert!(scores.iter().all(|s| s.is_finite()));
        }
    }

    #[test]
    fn predict_chunking_matches_single_batch() {
        let mut rng = Rng::new(2);
        let model = CostModel::new(tiny_backend(), &mut rng);
        let (x, _) = rows(&mut rng, 16);
        let all = model.predict(&x, 16).unwrap();
        let first = model.predict(&x[..8 * layout::N_FEATURES], 8).unwrap();
        assert_eq!(&all[..8], &first[..]);
    }

    #[test]
    fn train_epoch_reduces_holdout_loss() {
        let mut rng = Rng::new(3);
        let mut model = CostModel::new(tiny_backend(), &mut rng);
        // Learnable target: score = first feature.
        let n = 64;
        let mut x = vec![0.0f32; n * layout::N_FEATURES];
        let mut y = vec![0.0f32; n];
        for i in 0..n {
            let v = rng.uniform() as f32;
            x[i * layout::N_FEATURES] = v;
            y[i] = v;
        }
        let mask = Mask::all_ones(layout::N_PARAMS);
        let before = model.loss(&x[..8 * layout::N_FEATURES], &y[..8]).unwrap();
        for _ in 0..10 {
            model.train_epoch(&x, &y, &mask, 1e-2, 0.0, &mut rng).unwrap();
        }
        let after = model.loss(&x[..8 * layout::N_FEATURES], &y[..8]).unwrap();
        assert!(after < before, "{before} -> {after}");
    }

    #[test]
    fn zero_mask_freezes_scores_up_to_decay() {
        let mut rng = Rng::new(4);
        let mut model = CostModel::new(tiny_backend(), &mut rng);
        let (x, y) = rows(&mut rng, 8);
        let before = model.predict(&x, 8).unwrap();
        let mask = Mask::all_zeros(layout::N_PARAMS);
        model.train_step(&x, &y, &mask, 1e-3, 0.0, /* wd=0 -> no decay */).unwrap();
        let after = model.predict(&x, 8).unwrap();
        assert_eq!(before, after);
    }

    #[test]
    fn state_roundtrip_resumes_training_identically() {
        let mut rng = Rng::new(6);
        let mut a = CostModel::new(tiny_backend(), &mut rng);
        let (x, y) = rows(&mut rng, 8);
        let mask = Mask::all_ones(layout::N_PARAMS);
        a.train_step(&x, &y, &mask, 1e-3, 0.0).unwrap();
        // Rebuild on a fresh backend from the exported state: the step
        // counter and Adam moments carry over, so one further identical
        // update lands both models on identical parameters.
        let mut b = CostModel::from_state(tiny_backend(), a.export_state());
        a.train_step(&x, &y, &mask, 1e-3, 0.0).unwrap();
        b.train_step(&x, &y, &mask, 1e-3, 0.0).unwrap();
        assert_eq!(a.params(), b.params());
    }

    #[test]
    fn pinned_predictor_is_immune_to_updates() {
        let mut rng = Rng::new(7);
        let mut model = CostModel::new(tiny_backend(), &mut rng);
        let (x, y) = rows(&mut rng, 8);
        let pinned = model.predictor();
        let v0 = pinned.version();
        let before = pinned.predict(&x, 8).unwrap();
        let mask = Mask::all_ones(layout::N_PARAMS);
        model.train_step(&x, &y, &mask, 1e-2, 0.0).unwrap();
        // The pin still scores with the pre-update parameters, while a
        // fresh view observes the update (new version, new storage).
        assert_eq!(pinned.predict(&x, 8).unwrap(), before);
        assert_eq!(pinned.version(), v0);
        let live = model.predictor();
        assert_eq!(live.version(), v0 + 1);
        assert!(!Arc::ptr_eq(pinned.state(), live.state()));
    }

    #[test]
    fn snapshots_share_storage_until_an_update() {
        let mut rng = Rng::new(8);
        let model = CostModel::new(tiny_backend(), &mut rng);
        let a = model.predictor();
        let b = model.predictor();
        // Publish/pin is a pointer copy: no parameter duplication.
        assert!(Arc::ptr_eq(a.state(), b.state()));
        assert!(Arc::ptr_eq(a.state(), &model.shared_state()));
    }

    #[test]
    fn feature_projection_matches_a_linearized_network() {
        // Build a state whose ReLUs are provably inactive-free: make
        // every weight non-negative and feed non-negative features, so
        // the network IS linear and predict must equal proj · x + bias
        // terms.  Simplest exact check: projection of a one-hot feature
        // equals the score delta it induces on a zero baseline when no
        // ReLU clips — use abs weights to guarantee that.
        let mut rng = Rng::new(9);
        let mut params = layout::init_params(&mut rng);
        for p in params.iter_mut() {
            *p = p.abs();
        }
        let model = CostModel::with_params(tiny_backend(), params);
        let pred = model.predictor();
        let proj = pred.feature_projection();
        assert_eq!(proj.len(), layout::N_FEATURES);
        assert!(proj.iter().all(|v| v.is_finite()));
        // With all-non-negative weights and zero biases the net is
        // exactly linear on non-negative inputs: score(e_i) - score(0)
        // == proj[i].
        let zero = vec![0.0f32; layout::N_FEATURES];
        let base = pred.predict(&zero, 1).unwrap()[0];
        for i in [0, 40, layout::N_FEATURES - 1] {
            let mut x = vec![0.0f32; layout::N_FEATURES];
            x[i] = 1.0;
            let s = pred.predict(&x, 1).unwrap()[0];
            let rel = (s - base - proj[i]).abs() / proj[i].abs().max(1e-6);
            assert!(rel < 1e-3, "feature {i}: {} vs {}", s - base, proj[i]);
        }
    }

    #[test]
    fn xi_shape_and_finite() {
        let mut rng = Rng::new(5);
        let model = CostModel::new(tiny_backend(), &mut rng);
        let (x, y) = rows(&mut rng, 8);
        let xi = model.xi(&x, &y).unwrap();
        assert_eq!(xi.len(), layout::N_PARAMS);
        assert!(xi.iter().all(|v| v.is_finite() && *v >= 0.0));
    }
}
