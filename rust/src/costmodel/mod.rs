//! The learned cost model C() ~ Perf() (paper Eq. 2).
//!
//! * [`layout`] — flat-parameter geometry shared with the Python side.
//! * [`rust_mlp`] — pure-Rust mirror of the MLP / loss / update math.
//! * [`mask`] — lottery-ticket masks over the parameter vector.
//! * [`CostModel`] — stateful model (params + Adam moments) over a
//!   pluggable [`Backend`]: the XLA/PJRT engine executing the AOT Pallas
//!   artifacts (production path) or the pure-Rust mirror (tests,
//!   artifact-less fallback).

pub mod layout;
pub mod mask;
pub mod rust_mlp;

use std::sync::Arc;

use anyhow::Result;

pub use mask::Mask;

use crate::runtime::Engine;
use crate::util::rng::Rng;

/// Low-level compute backend with FIXED batch geometry.
///
/// Deliberately NOT `Send`/`Sync`: the `xla` crate's PJRT client is
/// `Rc`-based, so an [`XlaBackend`] is pinned to the thread that created
/// it.  Parallelism in the experiment harness happens at the
/// experiment/process level (or with the `Send`-safe [`RustBackend`]).
pub trait Backend {
    fn pred_batch(&self) -> usize;
    /// Small predict batch (0 = unsupported).  Lets the scoring hot path
    /// avoid padding evolutionary populations (~64 rows) up to the
    /// dataset-scoring shape (512).
    fn pred_batch_small(&self) -> usize {
        0
    }
    fn train_batch(&self) -> usize;
    /// Score exactly `pred_batch` rows.
    fn predict_fixed(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>>;
    /// Score exactly `pred_batch_small` rows (only if supported).
    fn predict_small_fixed(&self, _params: &[f32], _x: &[f32]) -> Result<Vec<f32>> {
        anyhow::bail!("backend has no small predict batch")
    }
    /// One masked-Adam step on exactly `train_batch` rows.
    #[allow(clippy::too_many_arguments)]
    fn train_step_fixed(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        x: &[f32],
        y: &[f32],
        w: &[f32],
        mask: &[f32],
        hp: [f32; 4],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)>;
    /// ξ saliency on exactly `train_batch` rows.
    fn xi_fixed(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<Vec<f32>>;
    /// Ranking loss on exactly `train_batch` rows.
    fn loss_fixed(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<f32>;
    /// Human-readable backend name for logs.
    fn name(&self) -> &'static str;
}

/// XLA/PJRT backend over the AOT artifacts (Pallas kernels inside).
pub struct XlaBackend {
    pub engine: Arc<Engine>,
}

impl Backend for XlaBackend {
    fn pred_batch(&self) -> usize {
        self.engine.meta.pred_batch
    }

    fn pred_batch_small(&self) -> usize {
        self.engine.meta.pred_batch_small
    }

    fn train_batch(&self) -> usize {
        self.engine.meta.train_batch
    }

    fn predict_fixed(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        self.engine.predict(params, x)
    }

    fn predict_small_fixed(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        self.engine.predict_small(params, x)
    }

    fn train_step_fixed(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        x: &[f32],
        y: &[f32],
        w: &[f32],
        mask: &[f32],
        hp: [f32; 4],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        let out = self.engine.train_step(params, m, v, x, y, w, mask, hp)?;
        Ok((out.params, out.m, out.v, out.loss))
    }

    fn xi_fixed(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        self.engine.xi(params, x, y, w)
    }

    fn loss_fixed(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<f32> {
        self.engine.loss_eval(params, x, y, w)
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Pure-Rust backend (same math, no PJRT dependency).
pub struct RustBackend {
    pub pred_batch: usize,
    pub train_batch: usize,
}

impl Default for RustBackend {
    fn default() -> Self {
        // Mirror the AOT geometry so parity tests compare like-for-like.
        RustBackend { pred_batch: 512, train_batch: 256 }
    }
}

impl Backend for RustBackend {
    fn pred_batch(&self) -> usize {
        self.pred_batch
    }

    fn pred_batch_small(&self) -> usize {
        // The Rust mirror computes exactly what it is given, so the small
        // variant mirrors the AOT geometry (64) capped by pred_batch.
        64.min(self.pred_batch)
    }

    fn train_batch(&self) -> usize {
        self.train_batch
    }

    fn predict_fixed(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        Ok(rust_mlp::forward(params, x, self.pred_batch))
    }

    fn predict_small_fixed(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        Ok(rust_mlp::forward(params, x, self.pred_batch_small()))
    }

    fn train_step_fixed(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        x: &[f32],
        y: &[f32],
        w: &[f32],
        mask: &[f32],
        hp: [f32; 4],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, f32)> {
        let (loss, grads) = rust_mlp::backward(params, x, self.train_batch, y, w);
        let mut p = params.to_vec();
        let mut mm = m.to_vec();
        let mut vv = v.to_vec();
        rust_mlp::masked_adam_update(&mut p, &mut mm, &mut vv, &grads, mask, hp[0], hp[1], hp[2]);
        Ok((p, mm, vv, loss))
    }

    fn xi_fixed(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        Ok(rust_mlp::xi_scores(params, x, self.train_batch, y, w))
    }

    fn loss_fixed(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<f32> {
        Ok(rust_mlp::rank_loss(
            &rust_mlp::forward(params, x, self.train_batch),
            y,
            w,
        ))
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// Stateful cost model: parameters + Adam moments + step counter over a
/// backend.  Accepts arbitrary row counts; pads/chunks to the backend's
/// fixed batch geometry internally (padding rows get weight 0 so they
/// never affect the ranking loss).
pub struct CostModel {
    backend: Arc<dyn Backend>,
    pub params: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    step: u64,
}

/// Portable learning state of a [`CostModel`]: everything except the
/// backend handle.  Backends may be `Rc`-based and thread-pinned (see
/// [`Backend`]), so a model crosses thread boundaries as a `ModelState`
/// and is rebuilt against a backend constructed on the receiving thread.
#[derive(Debug, Clone)]
pub struct ModelState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
}

impl CostModel {
    /// Fresh model with random init.
    pub fn new(backend: Arc<dyn Backend>, rng: &mut Rng) -> CostModel {
        let params = layout::init_params(rng);
        CostModel::with_params(backend, params)
    }

    /// Model with given parameters (e.g. a pre-trained checkpoint).
    pub fn with_params(backend: Arc<dyn Backend>, params: Vec<f32>) -> CostModel {
        assert_eq!(params.len(), layout::N_PARAMS);
        CostModel {
            backend,
            params,
            m: vec![0.0; layout::N_PARAMS],
            v: vec![0.0; layout::N_PARAMS],
            step: 0,
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// A second handle to the backend this model computes on.
    pub fn backend_handle(&self) -> Arc<dyn Backend> {
        self.backend.clone()
    }

    /// The backend's fixed training minibatch (rows per gradient step).
    pub fn train_batch(&self) -> usize {
        self.backend.train_batch()
    }

    /// Detach the full learning state (parameters + Adam moments +
    /// step), e.g. to move the model to another thread.
    pub fn export_state(&self) -> ModelState {
        ModelState {
            params: self.params.clone(),
            m: self.m.clone(),
            v: self.v.clone(),
            step: self.step,
        }
    }

    /// Rebuild a model from an exported state on a (possibly new)
    /// backend — the inverse of [`CostModel::export_state`].
    pub fn from_state(backend: Arc<dyn Backend>, state: ModelState) -> CostModel {
        assert_eq!(state.params.len(), layout::N_PARAMS);
        assert_eq!(state.m.len(), layout::N_PARAMS);
        assert_eq!(state.v.len(), layout::N_PARAMS);
        CostModel { backend, params: state.params, m: state.m, v: state.v, step: state.step }
    }

    /// Reset Adam state (used when adaptation starts on a new device).
    pub fn reset_optimizer(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.step = 0;
    }

    /// Score `rows` feature rows (row-major, `rows * N_FEATURES` f32).
    ///
    /// Chunks to the backend's fixed batch shapes, preferring the small
    /// predict variant when the remaining rows fit it (the evolutionary
    /// search's ~64-row populations then skip the 8× padding to 512).
    pub fn predict(&self, x: &[f32], rows: usize) -> Result<Vec<f32>> {
        let nf = layout::N_FEATURES;
        assert_eq!(x.len(), rows * nf);
        let bp = self.backend.pred_batch();
        let bs = self.backend.pred_batch_small();
        let mut scores = Vec::with_capacity(rows);
        let mut start = 0;
        while start < rows {
            let remaining = rows - start;
            let use_small = bs > 0 && remaining <= bs;
            let batch = if use_small { bs } else { bp };
            let n = remaining.min(batch);
            let src = &x[start * nf..(start + n) * nf];
            let run = |data: &[f32]| {
                if use_small {
                    self.backend.predict_small_fixed(&self.params, data)
                } else {
                    self.backend.predict_fixed(&self.params, data)
                }
            };
            if n == batch {
                scores.extend_from_slice(&run(src)?[..n]);
            } else {
                let mut padded = vec![0.0f32; batch * nf];
                padded[..n * nf].copy_from_slice(src);
                scores.extend_from_slice(&run(&padded)?[..n]);
            }
            start += n;
        }
        Ok(scores)
    }

    /// One gradient step on up to `train_batch` labeled rows (padded with
    /// zero-weight rows if fewer). Returns the batch ranking loss.
    pub fn train_step(&mut self, x: &[f32], y: &[f32], mask: &Mask, lr: f32, wd: f32) -> Result<f32> {
        let (px, py, pw) = self.pad_train(x, y);
        self.step += 1;
        let hp = [lr, wd, self.step as f32, 0.0];
        let (p, m, v, loss) = self.backend.train_step_fixed(
            &self.params,
            &self.m,
            &self.v,
            &px,
            &py,
            &pw,
            &mask.values,
            hp,
        )?;
        self.params = p;
        self.m = m;
        self.v = v;
        Ok(loss)
    }

    /// One pass over a labeled set in shuffled mini-batches.
    /// Returns the mean batch loss.
    #[allow(clippy::too_many_arguments)]
    pub fn train_epoch(
        &mut self,
        x: &[f32],
        y: &[f32],
        mask: &Mask,
        lr: f32,
        wd: f32,
        rng: &mut Rng,
    ) -> Result<f32> {
        let nf = layout::N_FEATURES;
        let rows = y.len();
        assert_eq!(x.len(), rows * nf);
        let bt = self.backend.train_batch();
        let mut order: Vec<usize> = (0..rows).collect();
        rng.shuffle(&mut order);
        let mut bx = vec![0.0f32; bt * nf];
        let mut by = vec![0.0f32; bt];
        let mut losses = Vec::new();
        for chunk in order.chunks(bt) {
            for (slot, &row) in chunk.iter().enumerate() {
                bx[slot * nf..(slot + 1) * nf].copy_from_slice(&x[row * nf..(row + 1) * nf]);
                by[slot] = y[row];
            }
            losses.push(self.train_step(&bx[..chunk.len() * nf], &by[..chunk.len()], mask, lr, wd)?);
        }
        Ok(if losses.is_empty() {
            0.0
        } else {
            losses.iter().sum::<f32>() / losses.len() as f32
        })
    }

    /// ξ saliency on up to `train_batch` labeled rows.
    pub fn xi(&self, x: &[f32], y: &[f32]) -> Result<Vec<f32>> {
        let (px, py, pw) = self.pad_train(x, y);
        self.backend.xi_fixed(&self.params, &px, &py, &pw)
    }

    /// Held-out ranking loss on up to `train_batch` labeled rows.
    pub fn loss(&self, x: &[f32], y: &[f32]) -> Result<f32> {
        let (px, py, pw) = self.pad_train(x, y);
        self.backend.loss_fixed(&self.params, &px, &py, &pw)
    }

    fn pad_train(&self, x: &[f32], y: &[f32]) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let nf = layout::N_FEATURES;
        let bt = self.backend.train_batch();
        let rows = y.len().min(bt);
        assert!(x.len() >= rows * nf, "x shorter than y rows");
        let mut px = vec![0.0f32; bt * nf];
        px[..rows * nf].copy_from_slice(&x[..rows * nf]);
        let mut py = vec![0.0f32; bt];
        py[..rows].copy_from_slice(&y[..rows]);
        let mut pw = vec![0.0f32; bt];
        pw[..rows].iter_mut().for_each(|v| *v = 1.0);
        (px, py, pw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_backend() -> Arc<dyn Backend> {
        Arc::new(RustBackend { pred_batch: 8, train_batch: 8 })
    }

    fn rows(rng: &mut Rng, n: usize) -> (Vec<f32>, Vec<f32>) {
        let x: Vec<f32> = (0..n * layout::N_FEATURES).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
        (x, y)
    }

    #[test]
    fn predict_handles_partial_and_multi_chunk() {
        let mut rng = Rng::new(1);
        let model = CostModel::new(tiny_backend(), &mut rng);
        for n in [1, 7, 8, 9, 20] {
            let (x, _) = rows(&mut rng, n);
            let scores = model.predict(&x, n).unwrap();
            assert_eq!(scores.len(), n);
            assert!(scores.iter().all(|s| s.is_finite()));
        }
    }

    #[test]
    fn predict_chunking_matches_single_batch() {
        let mut rng = Rng::new(2);
        let model = CostModel::new(tiny_backend(), &mut rng);
        let (x, _) = rows(&mut rng, 16);
        let all = model.predict(&x, 16).unwrap();
        let first = model.predict(&x[..8 * layout::N_FEATURES], 8).unwrap();
        assert_eq!(&all[..8], &first[..]);
    }

    #[test]
    fn train_epoch_reduces_holdout_loss() {
        let mut rng = Rng::new(3);
        let mut model = CostModel::new(tiny_backend(), &mut rng);
        // Learnable target: score = first feature.
        let n = 64;
        let mut x = vec![0.0f32; n * layout::N_FEATURES];
        let mut y = vec![0.0f32; n];
        for i in 0..n {
            let v = rng.uniform() as f32;
            x[i * layout::N_FEATURES] = v;
            y[i] = v;
        }
        let mask = Mask::all_ones(layout::N_PARAMS);
        let before = model.loss(&x[..8 * layout::N_FEATURES], &y[..8]).unwrap();
        for _ in 0..10 {
            model.train_epoch(&x, &y, &mask, 1e-2, 0.0, &mut rng).unwrap();
        }
        let after = model.loss(&x[..8 * layout::N_FEATURES], &y[..8]).unwrap();
        assert!(after < before, "{before} -> {after}");
    }

    #[test]
    fn zero_mask_freezes_scores_up_to_decay() {
        let mut rng = Rng::new(4);
        let mut model = CostModel::new(tiny_backend(), &mut rng);
        let (x, y) = rows(&mut rng, 8);
        let before = model.predict(&x, 8).unwrap();
        let mask = Mask::all_zeros(layout::N_PARAMS);
        model.train_step(&x, &y, &mask, 1e-3, 0.0, /* wd=0 -> no decay */).unwrap();
        let after = model.predict(&x, 8).unwrap();
        assert_eq!(before, after);
    }

    #[test]
    fn state_roundtrip_resumes_training_identically() {
        let mut rng = Rng::new(6);
        let mut a = CostModel::new(tiny_backend(), &mut rng);
        let (x, y) = rows(&mut rng, 8);
        let mask = Mask::all_ones(layout::N_PARAMS);
        a.train_step(&x, &y, &mask, 1e-3, 0.0).unwrap();
        // Rebuild on a fresh backend from the exported state: the step
        // counter and Adam moments carry over, so one further identical
        // update lands both models on identical parameters.
        let mut b = CostModel::from_state(tiny_backend(), a.export_state());
        a.train_step(&x, &y, &mask, 1e-3, 0.0).unwrap();
        b.train_step(&x, &y, &mask, 1e-3, 0.0).unwrap();
        assert_eq!(a.params, b.params);
    }

    #[test]
    fn xi_shape_and_finite() {
        let mut rng = Rng::new(5);
        let model = CostModel::new(tiny_backend(), &mut rng);
        let (x, y) = rows(&mut rng, 8);
        let xi = model.xi(&x, &y).unwrap();
        assert_eq!(xi.len(), layout::N_PARAMS);
        assert!(xi.iter().all(|v| v.is_finite() && *v >= 0.0));
    }
}
