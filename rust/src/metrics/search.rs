//! Draft-tier observability: kept/pruned counters for the speculative
//! draft-then-verify search plane.
//!
//! The counters are named entries (`search.draft_kept`,
//! `search.draft_pruned`) in a private [`MetricsRegistry`], mirroring
//! [`crate::metrics::cache::CacheCounters`]: a traced session
//! [`MetricsRegistry::adopt`]s them into the session-wide registry so
//! `moses trace report` can show how much of each generation the draft
//! scorer pruned before the full predictor ran.  The struct is `Clone`
//! (counter storage is shared `Arc`s), so the tuner hands one handle to
//! every task pipeline under `--jobs N` and all bumps land in the same
//! counters.

use crate::obs::{Counter, MetricsRegistry};

/// Live counters owned by a tuning session's draft tier.
#[derive(Clone, Debug)]
pub struct DraftCounters {
    registry: MetricsRegistry,
    kept: Counter,
    pruned: Counter,
}

impl Default for DraftCounters {
    fn default() -> DraftCounters {
        let registry = MetricsRegistry::default();
        DraftCounters {
            kept: registry.counter("search.draft_kept"),
            pruned: registry.counter("search.draft_pruned"),
            registry,
        }
    }
}

impl DraftCounters {
    /// The registry holding these counters under their `search.*` names
    /// — adopt it into a session registry to surface them in traces.
    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    /// One draft-scored generation: `kept` rows went on to the full
    /// predictor, `pruned` rows were dropped on the draft score alone.
    pub fn record_generation(&self, kept: u64, pruned: u64) {
        self.kept.add(kept);
        self.pruned.add(pruned);
    }

    /// Total schedules the full predictor verified after draft scoring.
    pub fn kept(&self) -> u64 {
        self.kept.get()
    }

    /// Total schedules pruned on the draft score alone.
    pub fn pruned(&self) -> u64 {
        self.pruned.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_across_generations() {
        let c = DraftCounters::default();
        c.record_generation(7, 25);
        c.record_generation(3, 13);
        assert_eq!(c.kept(), 10);
        assert_eq!(c.pruned(), 38);
    }

    #[test]
    fn clones_share_storage_and_surface_through_registry() {
        let c = DraftCounters::default();
        let clone = c.clone();
        clone.record_generation(4, 12);
        let snap = c.registry().snapshot();
        assert_eq!(snap.get("search.draft_kept"), Some(&4));
        assert_eq!(snap.get("search.draft_pruned"), Some(&12));
        assert_eq!(snap.len(), 2);
    }
}
