//! Tuning-cache observability: hit/miss/seed/commit counters and their
//! point-in-time snapshot for session reports.
//!
//! The counters are named entries (`cache.hits`, `cache.misses`, …) in
//! a private [`MetricsRegistry`], so a traced session can
//! [`MetricsRegistry::adopt`] them into the session-wide registry and
//! fold them into the trace footer; counter storage is shared, not
//! copied.  They stay atomic because one
//! [`crate::tunecache::TuneCache`] is shared (behind an `Arc`) across
//! every tuning session on a host; the snapshot is a plain `Copy`
//! struct so sessions can embed it in their results without holding any
//! reference to the live cache.

use crate::obs::{Counter, MetricsRegistry};

/// Live counters owned by a tune cache.
#[derive(Debug)]
pub struct CacheCounters {
    registry: MetricsRegistry,
    hits: Counter,
    misses: Counter,
    cross_device_seeds: Counter,
    neighbor_seeds: Counter,
    commits: Counter,
    rejects: Counter,
    stale_dropped: Counter,
    append_failed: Counter,
    append_fsyncs: Counter,
    segments_merged: Counter,
    compactions: Counter,
}

impl Default for CacheCounters {
    fn default() -> CacheCounters {
        let registry = MetricsRegistry::default();
        CacheCounters {
            hits: registry.counter("cache.hits"),
            misses: registry.counter("cache.misses"),
            cross_device_seeds: registry.counter("cache.cross_device_seeds"),
            neighbor_seeds: registry.counter("cache.neighbor_seeds"),
            commits: registry.counter("cache.commits"),
            rejects: registry.counter("cache.rejects"),
            stale_dropped: registry.counter("cache.stale_dropped"),
            append_failed: registry.counter("cache.append_failed"),
            append_fsyncs: registry.counter("cache.append_fsyncs"),
            segments_merged: registry.counter("cache.segments_merged"),
            compactions: registry.counter("cache.compactions"),
            registry,
        }
    }
}

impl CacheCounters {
    /// The registry holding these counters under their `cache.*` names
    /// — adopt it into a session registry to surface them in traces.
    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    /// An exact (workload, device) lookup was served from cache.
    pub fn record_hit(&self) {
        self.hits.incr();
    }

    /// An exact (workload, device) lookup found nothing.
    pub fn record_miss(&self) {
        self.misses.incr();
    }

    /// `n` schedules from other devices were offered as search seeds.
    pub fn record_seeds(&self, n: usize) {
        self.cross_device_seeds.add(n as u64);
    }

    /// `n` schedules from *similar* workloads (nearest-neighbor
    /// retrieval) were offered as search seeds.
    pub fn record_neighbor_seeds(&self, n: usize) {
        self.neighbor_seeds.add(n as u64);
    }

    /// `n` records were dropped on load for carrying a stale
    /// featurizer/simulator version stamp.
    pub fn record_stale(&self, n: usize) {
        self.stale_dropped.add(n as u64);
    }

    /// A record passed top-k admission.
    pub fn record_commit(&self) {
        self.commits.incr();
    }

    /// A record was refused (duplicate-no-better, evicted, non-finite).
    pub fn record_reject(&self) {
        self.rejects.incr();
    }

    /// An admitted record could not be appended to its segment even
    /// after a retry — it lives in memory only for this session.
    pub fn record_append_failed(&self) {
        self.append_failed.incr();
    }

    /// An append was fsynced ([`crate::tunecache::FsyncPolicy::Always`]).
    pub fn record_append_fsync(&self) {
        self.append_fsyncs.incr();
    }

    /// `n` log files (checkpoint + segments, or one legacy file) were
    /// merged through admission on open.
    pub fn record_segments_merged(&self, n: usize) {
        self.segments_merged.add(n as u64);
    }

    /// A compaction folded the log back to the live frontier.
    pub fn record_compaction(&self) {
        self.compactions.incr();
    }

    pub fn snapshot(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.get() as usize,
            misses: self.misses.get() as usize,
            cross_device_seeds: self.cross_device_seeds.get() as usize,
            neighbor_seeds: self.neighbor_seeds.get() as usize,
            commits: self.commits.get() as usize,
            rejects: self.rejects.get() as usize,
            stale_dropped: self.stale_dropped.get() as usize,
            append_failed: self.append_failed.get() as usize,
            append_fsyncs: self.append_fsyncs.get() as usize,
            segments_merged: self.segments_merged.get() as usize,
            compactions: self.compactions.get() as usize,
        }
    }
}

/// Point-in-time counter values.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
    pub cross_device_seeds: usize,
    pub neighbor_seeds: usize,
    pub commits: usize,
    pub rejects: usize,
    pub stale_dropped: usize,
    pub append_failed: usize,
    pub append_fsyncs: usize,
    pub segments_merged: usize,
    pub compactions: usize,
}

impl CacheStats {
    /// Fraction of exact lookups answered from cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_into_snapshot() {
        let c = CacheCounters::default();
        c.record_hit();
        c.record_hit();
        c.record_miss();
        c.record_seeds(5);
        c.record_neighbor_seeds(3);
        c.record_commit();
        c.record_reject();
        c.record_stale(2);
        c.record_append_failed();
        c.record_append_fsync();
        c.record_segments_merged(3);
        c.record_compaction();
        let s = c.snapshot();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert_eq!(s.cross_device_seeds, 5);
        assert_eq!(s.neighbor_seeds, 3);
        assert_eq!(s.commits, 1);
        assert_eq!(s.rejects, 1);
        assert_eq!(s.stale_dropped, 2);
        assert_eq!(s.append_failed, 1);
        assert_eq!(s.append_fsyncs, 1);
        assert_eq!(s.segments_merged, 3);
        assert_eq!(s.compactions, 1);
        assert!((s.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_hit_rate_is_zero() {
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn counters_surface_through_registry() {
        let c = CacheCounters::default();
        c.record_hit();
        c.record_stale(4);
        let snap = c.registry().snapshot();
        assert_eq!(snap.get("cache.hits"), Some(&1));
        assert_eq!(snap.get("cache.stale_dropped"), Some(&4));
        assert_eq!(snap.len(), 11);
    }
}
