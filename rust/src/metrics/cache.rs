//! Tuning-cache observability: hit/miss/seed/commit counters and their
//! point-in-time snapshot for session reports.
//!
//! Counters are atomic because one [`crate::tunecache::TuneCache`] is
//! shared (behind an `Arc`) across every tuning session on a host; the
//! snapshot is a plain `Copy` struct so sessions can embed it in their
//! results without holding any reference to the live cache.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Live counters owned by a tune cache.
#[derive(Debug, Default)]
pub struct CacheCounters {
    hits: AtomicUsize,
    misses: AtomicUsize,
    cross_device_seeds: AtomicUsize,
    neighbor_seeds: AtomicUsize,
    commits: AtomicUsize,
    rejects: AtomicUsize,
    stale_dropped: AtomicUsize,
}

impl CacheCounters {
    /// An exact (workload, device) lookup was served from cache.
    pub fn record_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// An exact (workload, device) lookup found nothing.
    pub fn record_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// `n` schedules from other devices were offered as search seeds.
    pub fn record_seeds(&self, n: usize) {
        self.cross_device_seeds.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` schedules from *similar* workloads (nearest-neighbor
    /// retrieval) were offered as search seeds.
    pub fn record_neighbor_seeds(&self, n: usize) {
        self.neighbor_seeds.fetch_add(n, Ordering::Relaxed);
    }

    /// `n` records were dropped on load for carrying a stale
    /// featurizer/simulator version stamp.
    pub fn record_stale(&self, n: usize) {
        self.stale_dropped.fetch_add(n, Ordering::Relaxed);
    }

    /// A record passed top-k admission.
    pub fn record_commit(&self) {
        self.commits.fetch_add(1, Ordering::Relaxed);
    }

    /// A record was refused (duplicate-no-better, evicted, non-finite).
    pub fn record_reject(&self) {
        self.rejects.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            cross_device_seeds: self.cross_device_seeds.load(Ordering::Relaxed),
            neighbor_seeds: self.neighbor_seeds.load(Ordering::Relaxed),
            commits: self.commits.load(Ordering::Relaxed),
            rejects: self.rejects.load(Ordering::Relaxed),
            stale_dropped: self.stale_dropped.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time counter values.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
    pub cross_device_seeds: usize,
    pub neighbor_seeds: usize,
    pub commits: usize,
    pub rejects: usize,
    pub stale_dropped: usize,
}

impl CacheStats {
    /// Fraction of exact lookups answered from cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_into_snapshot() {
        let c = CacheCounters::default();
        c.record_hit();
        c.record_hit();
        c.record_miss();
        c.record_seeds(5);
        c.record_neighbor_seeds(3);
        c.record_commit();
        c.record_reject();
        c.record_stale(2);
        let s = c.snapshot();
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 1);
        assert_eq!(s.cross_device_seeds, 5);
        assert_eq!(s.neighbor_seeds, 3);
        assert_eq!(s.commits, 1);
        assert_eq!(s.rejects, 1);
        assert_eq!(s.stale_dropped, 2);
        assert!((s.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_hit_rate_is_zero() {
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }
}
