//! The experiment harness: regenerates every table and figure of the
//! paper's evaluation (§4) against the simulated testbed.
//!
//! | paper artifact | function |
//! |----------------|----------|
//! | Fig. 4 (inference-latency gains)  | [`run_grid`] + [`fig4_table`] |
//! | Fig. 5 (search-efficiency gains)  | [`run_grid`] + [`fig5_table`] |
//! | Table 1 (CMAT small/large trials) | [`table1`] |
//! | Fig. 6 (transferable-ratio ablation) | [`fig6_table`] |
//!
//! Scaling: trial counts are reduced vs the paper (200/20000/5000 →
//! configurable, defaults 48/192) so a full regeneration runs in minutes
//! on CPU; the comparative *shape* is the reproduction target
//! (DESIGN.md §4).  All runs are deterministic given `seed`.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::coordinator::{AutoTuner, BackendKind, Session, TuneConfig};
use crate::costmodel::{layout, Backend, CostModel, Mask, RustBackend, XlaBackend};
use crate::dataset::gen::{self, GenConfig, TaskSource};
use crate::device::{presets, DeviceArch};
use crate::metrics;
use crate::models::zoo;
use crate::runtime::Engine;
use crate::transfer::{MosesConfig, Strategy};
use crate::util::rng::Rng;
use crate::util::table::{pct_gain, Table};

/// Harness-wide configuration.
#[derive(Debug, Clone)]
pub struct ExpConfig {
    pub backend: BackendKind,
    pub seed: u64,
    /// Trials per task, small tier (paper: 200).
    pub trials_small: usize,
    /// Trials per task, large tier (paper: 20000 on 2060 / 5000 on TX2).
    pub trials_large: usize,
    /// Measure batch per round.
    pub measure_batch: usize,
    /// Pre-training corpus: random tasks × records per task.
    pub pretrain_tasks: usize,
    pub pretrain_records_per_task: usize,
    pub pretrain_epochs: usize,
    /// Where to cache the pre-trained source checkpoint.
    pub checkpoint_dir: PathBuf,
    /// Rust-backend batch geometry (tests shrink these; the XLA backend
    /// geometry is fixed by the AOT artifacts).
    pub rust_pred_batch: usize,
    pub rust_train_batch: usize,
    /// Self-scheduling workers over independent grid cells (`--jobs`):
    /// [`run_grid`] fans whole (target, model, strategy) sessions out
    /// across threads while each inner session stays sequential — the
    /// parallelism budget is spent where there is no coupling at all.
    pub jobs: usize,
}

impl Default for ExpConfig {
    fn default() -> Self {
        ExpConfig {
            // XLA when compiled in and artifacts exist, rust otherwise —
            // examples and benches then run in any environment.
            backend: BackendKind::auto(),
            seed: 0,
            trials_small: 48,
            trials_large: 192,
            measure_batch: 8,
            pretrain_tasks: 40,
            pretrain_records_per_task: 96,
            pretrain_epochs: 8,
            checkpoint_dir: Engine::default_dir(),
            rust_pred_batch: 512,
            rust_train_batch: 256,
            jobs: 1,
        }
    }
}

thread_local! {
    // One PJRT engine per thread for the whole experiment run: loading +
    // compiling the artifacts takes seconds, and a grid runs ~100
    // sessions.  (The xla crate is Rc-based, hence thread-local rather
    // than global.)
    static XLA_BACKEND_CACHE: std::cell::RefCell<Option<Arc<XlaBackend>>> =
        const { std::cell::RefCell::new(None) };
}

impl ExpConfig {
    pub fn backend_arc(&self) -> Result<Arc<dyn Backend>> {
        Ok(match self.backend {
            BackendKind::Rust => Arc::new(RustBackend {
                pred_batch: self.rust_pred_batch,
                train_batch: self.rust_train_batch,
            }),
            BackendKind::Xla => XLA_BACKEND_CACHE.with(|cell| -> Result<Arc<dyn Backend>> {
                let mut slot = cell.borrow_mut();
                if slot.is_none() {
                    let dir = Engine::default_dir();
                    *slot = Some(Arc::new(XlaBackend {
                        engine: Arc::new(Engine::load(&dir).context("loading AOT artifacts")?),
                    }));
                }
                Ok(slot.as_ref().unwrap().clone())
            })?,
        })
    }
}

/// Get (or build and cache) the source-device (K80) pre-trained
/// checkpoint: generate a Tenset-like corpus on the simulated K80 and
/// train the cost model offline (paper Step 1, §3.6).
pub fn pretrained_source_checkpoint(cfg: &ExpConfig) -> Result<Vec<f32>> {
    let path = cfg.checkpoint_dir.join(format!(
        "k80_pretrained_s{}_t{}_r{}_e{}.bin",
        cfg.seed, cfg.pretrain_tasks, cfg.pretrain_records_per_task, cfg.pretrain_epochs
    ));
    if path.exists() {
        if let Ok(p) = layout::load_checkpoint(&path) {
            return Ok(p);
        }
    }
    let params = pretrain_on(&presets::tesla_k80(), cfg)?;
    std::fs::create_dir_all(&cfg.checkpoint_dir).ok();
    layout::save_checkpoint(&path, &params).ok(); // cache best-effort
    Ok(params)
}

/// Train a fresh cost model on a generated corpus for `device`.
pub fn pretrain_on(device: &DeviceArch, cfg: &ExpConfig) -> Result<Vec<f32>> {
    let ds = gen::generate(
        device,
        TaskSource::Random { count: cfg.pretrain_tasks },
        &GenConfig { records_per_task: cfg.pretrain_records_per_task, seed: cfg.seed },
    );
    pretrain_on_dataset(&ds, cfg)
}

/// Train a fresh cost model on an explicit dataset — the shared tail of
/// [`pretrain_on`] and the `moses pretrain --from-tunecache` path, where
/// the corpus is real tuning history exported from a tunecache log
/// instead of random sampling.
pub fn pretrain_on_dataset(ds: &crate::dataset::Dataset, cfg: &ExpConfig) -> Result<Vec<f32>> {
    let (x, y) = ds.training_arrays();
    anyhow::ensure!(
        !y.is_empty(),
        "pretraining corpus for '{}' holds no records",
        ds.device
    );
    let backend = cfg.backend_arc()?;
    let mut rng = Rng::new(cfg.seed ^ 0x9E37);
    let mut model = CostModel::new(backend, &mut rng);
    let mask = Mask::all_ones(layout::N_PARAMS);
    for _ in 0..cfg.pretrain_epochs {
        model.train_epoch(&x, &y, &mask, 1e-3, 0.0, &mut rng)?;
    }
    Ok(model.params().to_vec())
}

/// Run one tuning session: `model_name` on `target` with `strategy`.
pub fn run_session(
    cfg: &ExpConfig,
    pretrained: &[f32],
    model_name: &str,
    target: &DeviceArch,
    strategy: Strategy,
    trials: usize,
) -> Result<Session> {
    let model = zoo::by_name(model_name)
        .with_context(|| format!("unknown model {model_name}"))?;
    let tune_cfg = TuneConfig {
        trials_per_task: trials,
        measure_batch: cfg.measure_batch,
        strategy: strategy.clone(),
        seed: cfg.seed ^ crate::util::rng::hash_bytes(
            format!("{model_name}/{}/{}/{trials}", target.name, strategy.name()).as_bytes(),
        ),
        backend: cfg.backend,
        // Grid parallelism lives at the cell level (`run_grid`): inner
        // sessions stay sequential so per-cell results are identical
        // whatever `cfg.jobs` says, and XLA-backed grids parallelize
        // too (one engine per worker thread; `--jobs` inside a session
        // would be rejected on that backend).
        jobs: 1,
        rust_pred_batch: cfg.rust_pred_batch,
        rust_train_batch: cfg.rust_train_batch,
        ..TuneConfig::default()
    };
    let backend = cfg.backend_arc()?;
    let mut rng = Rng::new(tune_cfg.seed);
    let cost_model = crate::transfer::init_model(
        &strategy,
        backend,
        strategy.uses_pretrained().then_some(pretrained),
        &mut rng,
    );
    let mut tuner = AutoTuner::builder(target.clone())
        .config(&tune_cfg)
        .model(cost_model)
        .build()?;
    tuner.tune(&model.tasks())
}

/// The four evaluation DNNs (paper §4.2) in Table-1 column order
/// (S, R, M, B).
pub const EVAL_MODELS: [&str; 4] = ["squeezenet", "resnet18", "mobilenet", "bert"];
/// The four compared strategies (paper §4.4 baselines 2-4 + Moses).
pub fn eval_strategies() -> Vec<Strategy> {
    vec![
        Strategy::AnsorRandom,
        Strategy::TensetPretrain,
        Strategy::TensetFinetune,
        Strategy::Moses(MosesConfig::default()),
    ]
}

/// One (pair, model, strategy) outcome used by fig4/fig5/table1.
#[derive(Debug, Clone)]
pub struct Outcome {
    pub target: String,
    pub model: String,
    pub strategy: String,
    pub latency_ms: f64,
    pub search_time_s: f64,
    pub measurements: usize,
    pub raw_latency_ms: f64,
}

/// Run the full (target × model × strategy) grid once.
///
/// Cells are fully independent sessions — each seeds itself from a hash
/// of `(model, target, strategy, trials)` — so `cfg.jobs > 1` fans them
/// out over self-scheduling worker threads
/// ([`crate::coordinator::sched::run_independent`]): an idle worker
/// always takes the next unstarted cell, and the outcome vector is in
/// grid order regardless of which thread ran what.
pub fn run_grid(cfg: &ExpConfig, trials: usize, targets: &[DeviceArch]) -> Result<Vec<Outcome>> {
    let pretrained = pretrained_source_checkpoint(cfg)?;
    let mut cells = Vec::new();
    for target in targets {
        for model in EVAL_MODELS {
            for strategy in eval_strategies() {
                cells.push((target, model, strategy));
            }
        }
    }
    let outcomes = crate::coordinator::sched::run_independent(cells.len(), cfg.jobs, |i| {
        let (target, model, strategy) = &cells[i];
        let session = run_session(cfg, &pretrained, model, target, strategy.clone(), trials)?;
        Ok(Outcome {
            target: target.name.clone(),
            model: model.to_string(),
            strategy: strategy.name().to_string(),
            latency_ms: session.total_best_latency_ms(),
            search_time_s: session.search_time_s(),
            measurements: session.total_measurements(),
            raw_latency_ms: session.total_default_latency_ms(),
        })
    });
    outcomes.into_iter().collect()
}

fn find<'a>(outs: &'a [Outcome], target: &str, model: &str, strategy: &str) -> &'a Outcome {
    outs.iter()
        .find(|o| o.target == target && o.model == model && o.strategy == strategy)
        .expect("grid outcome missing")
}

/// Fig. 4: end-to-end inference-latency gains of Moses over the
/// baselines, per transfer pair and model.
pub fn fig4_table(outs: &[Outcome], targets: &[&str]) -> Table {
    let mut t = Table::new(
        "Fig 4 — end-to-end inference latency (ms) and Moses gain",
        &[
            "pair", "model", "raw", "ansor-random", "tenset-pretrain", "tenset-finetune",
            "moses", "moses vs finetune", "moses vs pretrain",
        ],
    );
    for target in targets {
        for model in EVAL_MODELS {
            let ar = find(outs, target, model, "ansor-random");
            let tp = find(outs, target, model, "tenset-pretrain");
            let tf = find(outs, target, model, "tenset-finetune");
            let mo = find(outs, target, model, "moses");
            t.row(vec![
                format!("k80->{target}"),
                model.to_string(),
                format!("{:.2}", mo.raw_latency_ms),
                format!("{:.2}", ar.latency_ms),
                format!("{:.2}", tp.latency_ms),
                format!("{:.2}", tf.latency_ms),
                format!("{:.2}", mo.latency_ms),
                pct_gain(tf.latency_ms / mo.latency_ms),
                pct_gain(tp.latency_ms / mo.latency_ms),
            ]);
        }
    }
    t
}

/// Fig. 5: auto-tuning search-efficiency gains of Moses over baselines.
pub fn fig5_table(outs: &[Outcome], targets: &[&str]) -> Table {
    let mut t = Table::new(
        "Fig 5 — search time (virtual s) and Moses efficiency gain",
        &[
            "pair", "model", "ansor-random", "tenset-pretrain", "tenset-finetune", "moses",
            "moses vs finetune", "moses vs ansor",
        ],
    );
    for target in targets {
        for model in EVAL_MODELS {
            let ar = find(outs, target, model, "ansor-random");
            let tp = find(outs, target, model, "tenset-pretrain");
            let tf = find(outs, target, model, "tenset-finetune");
            let mo = find(outs, target, model, "moses");
            t.row(vec![
                format!("k80->{target}"),
                model.to_string(),
                format!("{:.0}", ar.search_time_s),
                format!("{:.0}", tp.search_time_s),
                format!("{:.0}", tf.search_time_s),
                format!("{:.0}", mo.search_time_s),
                pct_gain(metrics::search_gain(tf.search_time_s, mo.search_time_s)),
                pct_gain(metrics::search_gain(ar.search_time_s, mo.search_time_s)),
            ]);
        }
    }
    t
}

/// Table 1: CMAT of Moses vs Tenset-Finetune under small/large trials.
/// Columns follow the paper: 2060-S/R/M/B and TX2-S/R/M.
pub fn table1(cfg: &ExpConfig) -> Result<Table> {
    let pairs_2060: Vec<&str> = vec!["squeezenet", "resnet18", "mobilenet", "bert"];
    let pairs_tx2: Vec<&str> = vec!["squeezenet", "resnet18", "mobilenet"];
    let pretrained = pretrained_source_checkpoint(cfg)?;

    let mut header = vec!["CMAT (%)".to_string()];
    let initial = |m: &str| m.chars().next().map(|c| c.to_ascii_uppercase()).unwrap_or('?');
    for m in &pairs_2060 {
        header.push(format!("2060-{}", initial(m)));
    }
    for m in &pairs_tx2 {
        header.push(format!("TX2-{}", initial(m)));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table 1 — CMAT vs Tenset-Finetune", &header_refs);

    for (label, trials) in
        [("Small Trials", cfg.trials_small), ("Large Trials", cfg.trials_large)]
    {
        let mut row = vec![format!("{label} ({trials})")];
        for (target, models) in
            [(presets::rtx_2060(), &pairs_2060), (presets::jetson_tx2(), &pairs_tx2)]
        {
            for model in models {
                let tf = run_session(
                    cfg, &pretrained, model, &target, Strategy::TensetFinetune, trials,
                )?;
                let mo = run_session(
                    cfg,
                    &pretrained,
                    model,
                    &target,
                    Strategy::Moses(MosesConfig::default()),
                    trials,
                )?;
                let score = metrics::cmat(
                    metrics::search_gain(tf.search_time_s(), mo.search_time_s()),
                    metrics::latency_reduction(
                        tf.total_best_latency_ms(),
                        mo.total_best_latency_ms(),
                    ),
                );
                row.push(format!("{score:.1}"));
            }
        }
        t.row(row);
    }
    Ok(t)
}

/// Fig. 6: transferable-ratio ablation {0.01, 0.3, 0.5, 0.7} (mean ±
/// std of the Moses latency gain vs Tenset-Finetune across seeds).
pub fn fig6_table(cfg: &ExpConfig, model: &str, seeds: &[u64]) -> Result<Table> {
    let target = presets::rtx_2060();
    let mut t = Table::new(
        &format!("Fig 6 — transferable-ratio ablation ({model}, k80->2060)"),
        &["ratio", "latency gain vs finetune (mean)", "std", "CMAT (mean)"],
    );
    for ratio in [0.01, 0.3, 0.5, 0.7] {
        let mut gains = Vec::new();
        let mut cmats = Vec::new();
        for &seed in seeds {
            let mut c = cfg.clone();
            c.seed = seed;
            let pretrained = pretrained_source_checkpoint(&c)?;
            let tf = run_session(
                &c, &pretrained, model, &target, Strategy::TensetFinetune, c.trials_small,
            )?;
            let mo = run_session(
                &c,
                &pretrained,
                model,
                &target,
                Strategy::Moses(MosesConfig { ratio: Some(ratio), ..MosesConfig::default() }),
                c.trials_small,
            )?;
            let red = metrics::latency_reduction(
                tf.total_best_latency_ms(),
                mo.total_best_latency_ms(),
            );
            gains.push(red);
            cmats.push(metrics::cmat(
                metrics::search_gain(tf.search_time_s(), mo.search_time_s()),
                red,
            ));
        }
        let gs = crate::util::stats::Summary::of(&gains);
        let cs = crate::util::stats::Summary::of(&cmats);
        t.row(vec![
            format!("{ratio}"),
            pct_gain(gs.mean),
            format!("{:.1}%", gs.std * 100.0),
            format!("{:.1}", cs.mean),
        ]);
    }
    Ok(t)
}

/// Component ablation (design-choice study, DESIGN.md §4): which part of
/// Moses buys what?  Variants:
///  * full Moses (mask + decay + AC);
///  * no-AC (mask + decay, measure every round like finetune);
///  * no-mask (AC only on top of vanilla fine-tuning);
///  * no-decay (mask but wd = 0 — variant params frozen instead).
/// All compared against Tenset-Finetune on one (model, pair).
pub fn ablation_table(cfg: &ExpConfig, model: &str) -> Result<Table> {
    let target = presets::jetson_tx2();
    let pretrained = pretrained_source_checkpoint(cfg)?;
    let base = MosesConfig::default();
    let variants: Vec<(&str, Strategy)> = vec![
        ("tenset-finetune (ref)", Strategy::TensetFinetune),
        ("moses (full)", Strategy::Moses(base)),
        (
            "moses no-AC",
            Strategy::Moses(MosesConfig {
                ac_cv_threshold: 0.0, // CV never below 0 -> never terminates
                train_fraction: 1.0,
                ..base
            }),
        ),
        (
            "moses no-mask",
            Strategy::Moses(MosesConfig { ratio: Some(1.0), weight_decay: 0.0, ..base }),
        ),
        ("moses no-decay", Strategy::Moses(MosesConfig { weight_decay: 0.0, ..base })),
    ];
    let reference = run_session(
        cfg, &pretrained, model, &target, Strategy::TensetFinetune, cfg.trials_small,
    )?;
    let mut t = Table::new(
        &format!("Ablation — Moses components ({model}, k80->tx2)"),
        &["variant", "latency ms", "search s", "measurements", "CMAT vs finetune"],
    );
    for (label, strategy) in variants {
        let s = run_session(cfg, &pretrained, model, &target, strategy, cfg.trials_small)?;
        let cmat = metrics::cmat(
            metrics::search_gain(reference.search_time_s(), s.search_time_s()),
            metrics::latency_reduction(
                reference.total_best_latency_ms(),
                s.total_best_latency_ms(),
            ),
        );
        t.row(vec![
            label.to_string(),
            format!("{:.3}", s.total_best_latency_ms()),
            format!("{:.0}", s.search_time_s()),
            s.total_measurements().to_string(),
            format!("{cmat:.1}"),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig {
            backend: BackendKind::Rust,
            trials_small: 8,
            trials_large: 16,
            measure_batch: 4,
            pretrain_tasks: 3,
            pretrain_records_per_task: 16,
            pretrain_epochs: 1,
            checkpoint_dir: std::env::temp_dir().join("moses_exp_test"),
            seed: 1,
            rust_pred_batch: 64,
            rust_train_batch: 64,
            jobs: 1,
        }
    }

    #[test]
    fn pretrain_checkpoint_caches() {
        let cfg = tiny_cfg();
        let _ = std::fs::remove_dir_all(&cfg.checkpoint_dir);
        let a = pretrained_source_checkpoint(&cfg).unwrap();
        assert_eq!(a.len(), layout::N_PARAMS);
        // Second call loads the cache (same result).
        let b = pretrained_source_checkpoint(&cfg).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn run_session_executes_every_strategy() {
        let cfg = tiny_cfg();
        let pre = pretrained_source_checkpoint(&cfg).unwrap();
        let target = presets::rtx_2060();
        for strategy in eval_strategies() {
            let s = run_session(&cfg, &pre, "squeezenet", &target, strategy.clone(), 8)
                .unwrap();
            assert!(s.total_best_latency_ms() > 0.0, "{}", strategy.name());
            assert!(s.search_time_s() > 0.0);
        }
    }

    #[test]
    fn cmat_row_computes() {
        // End-to-end smoke of the table-1 math on one tiny cell.
        let cfg = tiny_cfg();
        let pre = pretrained_source_checkpoint(&cfg).unwrap();
        let target = presets::jetson_tx2();
        let tf =
            run_session(&cfg, &pre, "mobilenet", &target, Strategy::TensetFinetune, 8).unwrap();
        let mo = run_session(
            &cfg,
            &pre,
            "mobilenet",
            &target,
            Strategy::Moses(MosesConfig::default()),
            8,
        )
        .unwrap();
        let c = metrics::cmat(
            metrics::search_gain(tf.search_time_s(), mo.search_time_s()),
            metrics::latency_reduction(tf.total_best_latency_ms(), mo.total_best_latency_ms()),
        );
        assert!(c.is_finite());
    }
}
