//! Evaluation metrics (paper §4.3): end-to-end latency/throughput,
//! search-efficiency gain, the CMAT composite score, tuning-cache
//! hit/miss/seed counters ([`cache`]), and draft-tier prune counters
//! ([`search`]).

pub mod cache;
pub mod experiments;
pub mod search;

/// CMAT — Cost Model & Auto-tuning efficiency gain score (paper §4.3):
///
/// ```text
/// CMAT = (GainOnSearchEfficiency × ReductionOnTunedModelLatency − 1) × 100%
/// ```
///
/// where both factors are ratios vs a baseline (>1 means better than the
/// baseline).  A method that is 1.4× faster to search and reaches 1.05×
/// lower latency scores (1.4·1.05 − 1)·100 = 47.
pub fn cmat(search_efficiency_gain: f64, latency_reduction: f64) -> f64 {
    (search_efficiency_gain * latency_reduction - 1.0) * 100.0
}

/// Search-efficiency gain of `ours` vs `baseline` (both virtual
/// seconds; >1 == we search faster).
pub fn search_gain(baseline_time_s: f64, our_time_s: f64) -> f64 {
    baseline_time_s / our_time_s.max(1e-12)
}

/// Latency reduction of `ours` vs `baseline` (>1 == our tuned model is
/// faster).
pub fn latency_reduction(baseline_latency: f64, our_latency: f64) -> f64 {
    baseline_latency / our_latency.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cmat_matches_paper_example_shape() {
        // Paper §4.4: Tenset 15% efficiency gain but CMAT −14.75% ⇒
        // latency reduction must have been < 1.
        let c = cmat(1.15, 0.7413);
        assert!((c - (-14.75)).abs() < 0.3, "{c}");
        // Break-even.
        assert_eq!(cmat(1.0, 1.0), 0.0);
        // Better on both axes.
        assert!(cmat(1.4, 1.1) > 40.0);
    }

    #[test]
    fn gains_are_ratios() {
        assert!((search_gain(10.0, 5.0) - 2.0).abs() < 1e-12);
        assert!((latency_reduction(4e-3, 2e-3) - 2.0).abs() < 1e-12);
    }
}
