//! The Moses adapter: lottery-ticket masked fine-tuning (paper §3.4).
//!
//! Holds the current transferable/variant boundary (a [`Mask`]) and
//! refreshes it from fresh ξ = |w·∇w| saliencies as tuning phases
//! advance, blending with the previous boundary for stability
//! ("iteratively update the boundary ... during each online training
//! epoch").

use super::MosesConfig;
use crate::costmodel::{layout, Mask, Predictor};
use anyhow::Result;

/// Stateful Moses adaptation controller for one tuning session.
#[derive(Clone)]
pub struct MosesAdapter {
    pub config: MosesConfig,
    mask: Mask,
    rounds_since_refresh: usize,
    refreshes: usize,
}

impl MosesAdapter {
    pub fn new(config: MosesConfig) -> MosesAdapter {
        MosesAdapter {
            config,
            // Until the first ξ is computed everything is trainable —
            // the first refresh happens on the first observed batch.
            mask: Mask::all_ones(layout::N_PARAMS),
            rounds_since_refresh: usize::MAX / 2, // force refresh at start
            refreshes: 0,
        }
    }

    /// Current transferable-parameter mask.
    pub fn mask(&self) -> &Mask {
        &self.mask
    }

    pub fn refreshes(&self) -> usize {
        self.refreshes
    }

    /// Weight decay to apply to domain-variant parameters (Eq. 7).
    pub fn weight_decay(&self) -> f32 {
        self.config.weight_decay
    }

    /// Called once per adaptation round with the newest labeled batch;
    /// recomputes the boundary when due.  Takes the learner's read-only
    /// [`Predictor`] view (ξ only needs the pinned parameters).  Returns
    /// true if the mask was refreshed (costs one ξ computation on the
    /// virtual clock).
    pub fn maybe_refresh(
        &mut self,
        model: &Predictor,
        x: &[f32],
        y: &[f32],
    ) -> Result<bool> {
        self.rounds_since_refresh += 1;
        // `<` not `<=`: with `mask_refresh_every = N` the boundary is
        // recomputed on every Nth round after a refresh, as the config
        // documents (the old `<=` stretched the cadence to N+1).
        if self.rounds_since_refresh < self.config.mask_refresh_every {
            return Ok(false);
        }
        let xi = model.xi(x, y)?;
        let fresh = match self.config.ratio {
            Some(r) => Mask::from_xi_ratio(&xi, r),
            None => Mask::from_xi_threshold(&xi, self.config.theta),
        };
        self.mask = if self.refreshes == 0 {
            fresh
        } else {
            // Stabilize: previously-transferable parameters are retained
            // with moderate probability so the boundary drifts rather
            // than jumps.
            Mask::ema_refresh(&self.mask, &fresh, 0.3)
        };
        self.rounds_since_refresh = 0;
        self.refreshes += 1;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::{CostModel, RustBackend};
    use crate::program::N_FEATURES;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn model() -> Predictor {
        CostModel::new(
            Arc::new(RustBackend { pred_batch: 16, train_batch: 16 }),
            &mut Rng::new(7),
        )
        .predictor()
    }

    fn batch(rng: &mut Rng, n: usize) -> (Vec<f32>, Vec<f32>) {
        let x: Vec<f32> = (0..n * N_FEATURES).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
        (x, y)
    }

    #[test]
    fn first_round_refreshes_and_hits_ratio() {
        let cfg = MosesConfig { ratio: Some(0.5), ..MosesConfig::default() };
        let mut ad = MosesAdapter::new(cfg);
        let m = model();
        let mut rng = Rng::new(1);
        let (x, y) = batch(&mut rng, 16);
        assert!(ad.maybe_refresh(&m, &x, &y).unwrap());
        let ratio = ad.mask().ratio();
        assert!((ratio - 0.5).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn respects_refresh_cadence() {
        let cfg = MosesConfig { mask_refresh_every: 3, ..MosesConfig::default() };
        let mut ad = MosesAdapter::new(cfg);
        let m = model();
        let mut rng = Rng::new(2);
        let (x, y) = batch(&mut rng, 16);
        assert!(ad.maybe_refresh(&m, &x, &y).unwrap()); // initial
        assert!(!ad.maybe_refresh(&m, &x, &y).unwrap());
        assert!(!ad.maybe_refresh(&m, &x, &y).unwrap());
        assert!(ad.maybe_refresh(&m, &x, &y).unwrap()); // every 3rd round
        assert!(!ad.maybe_refresh(&m, &x, &y).unwrap());
        assert!(!ad.maybe_refresh(&m, &x, &y).unwrap());
        assert!(ad.maybe_refresh(&m, &x, &y).unwrap());
        assert_eq!(ad.refreshes(), 3);
    }

    #[test]
    fn threshold_mode_produces_some_boundary() {
        let cfg = MosesConfig { ratio: None, theta: 0.5, ..MosesConfig::default() };
        let mut ad = MosesAdapter::new(cfg);
        let m = model();
        let mut rng = Rng::new(3);
        let (x, y) = batch(&mut rng, 16);
        ad.maybe_refresh(&m, &x, &y).unwrap();
        let r = ad.mask().ratio();
        assert!(r > 0.0 && r < 1.0, "degenerate boundary {r}");
    }
}
