//! Adaptive controller (paper §3.5): early-terminates the hardware
//! data-collection phase of a task when the cost model's predictions
//! have stabilized.
//!
//! Per task, trials are split into measured (training) rounds and
//! prediction-only rounds with initial ratio `p`.  After each measured
//! batch the controller records the model's mean prediction over that
//! batch; once the coefficient of variation CV = σ/µ over the recorded
//! batch means drops below a threshold (and at least `min_batches` are
//! in), measurement stops early and the remaining trials run on model
//! predictions alone — saving the expensive on-device phase.

use anyhow::Result;

use crate::costmodel::Predictor;
use crate::util::stats;

/// CV-based early-termination controller for one task.
#[derive(Debug, Clone)]
pub struct AdaptiveController {
    pub cv_threshold: f64,
    pub min_batches: usize,
    /// Mean model prediction per measured batch, in arrival order.
    batch_means: Vec<f64>,
    /// Latched once terminated (never resumes within a task).
    terminated: bool,
}

impl AdaptiveController {
    pub fn new(cv_threshold: f64, min_batches: usize) -> AdaptiveController {
        AdaptiveController {
            cv_threshold,
            min_batches: min_batches.max(2),
            batch_means: Vec::new(),
            terminated: false,
        }
    }

    /// Score one measured batch's feature rows against a pinned
    /// [`Predictor`] view and record the batch mean — the post-update
    /// stability observation of §3.5.  The controller, like the search
    /// policies, only ever sees the read-only prediction plane.
    pub fn observe_scored(&mut self, model: &Predictor, x: &[f32], rows: usize) -> Result<()> {
        let preds = model.predict(x, rows)?;
        self.observe_batch(&preds);
        Ok(())
    }

    /// Record the model's predictions over one measured batch.
    pub fn observe_batch(&mut self, predictions: &[f32]) {
        if predictions.is_empty() {
            return;
        }
        let mean =
            predictions.iter().map(|&p| p as f64).sum::<f64>() / predictions.len() as f64;
        self.batch_means.push(mean);
        if self.batch_means.len() >= self.min_batches {
            // CV over the most recent window (stale early batches from a
            // still-untrained model shouldn't block termination forever).
            let window = &self.batch_means[self.batch_means.len().saturating_sub(self.min_batches)..];
            let cv = stats::coefficient_of_variation(window);
            if cv < self.cv_threshold {
                self.terminated = true;
            }
        }
    }

    /// Should the tuner keep doing on-device measurements for this task?
    pub fn keep_measuring(&self) -> bool {
        !self.terminated
    }

    /// Number of batches observed so far.
    pub fn batches_seen(&self) -> usize {
        self.batch_means.len()
    }

    /// Current CV over the observation window (∞ until enough batches).
    pub fn current_cv(&self) -> f64 {
        if self.batch_means.len() < self.min_batches {
            f64::INFINITY
        } else {
            let window =
                &self.batch_means[self.batch_means.len().saturating_sub(self.min_batches)..];
            stats::coefficient_of_variation(window)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn needs_min_batches_before_terminating() {
        let mut ac = AdaptiveController::new(0.5, 3);
        ac.observe_batch(&[1.0, 1.0]);
        ac.observe_batch(&[1.0, 1.0]);
        assert!(ac.keep_measuring(), "terminated after only 2 batches");
        ac.observe_batch(&[1.0, 1.0]);
        assert!(!ac.keep_measuring(), "stable predictions should terminate");
    }

    #[test]
    fn unstable_predictions_keep_measuring() {
        let mut ac = AdaptiveController::new(0.05, 3);
        for i in 0..10 {
            // Wildly varying batch means.
            let v = if i % 2 == 0 { 0.1 } else { 10.0 };
            ac.observe_batch(&[v as f32; 4]);
        }
        assert!(ac.keep_measuring());
        assert!(ac.current_cv() > 0.05);
    }

    #[test]
    fn stabilization_after_noise_terminates() {
        let mut ac = AdaptiveController::new(0.05, 3);
        ac.observe_batch(&[0.1; 4]);
        ac.observe_batch(&[5.0; 4]);
        ac.observe_batch(&[0.4; 4]);
        assert!(ac.keep_measuring());
        // Model converges: last 3 batches stable.
        ac.observe_batch(&[2.0; 4]);
        ac.observe_batch(&[2.02; 4]);
        ac.observe_batch(&[1.98; 4]);
        assert!(!ac.keep_measuring(), "cv={}", ac.current_cv());
    }

    #[test]
    fn termination_latches() {
        let mut ac = AdaptiveController::new(0.5, 2);
        ac.observe_batch(&[1.0; 4]);
        ac.observe_batch(&[1.0; 4]);
        assert!(!ac.keep_measuring());
        // Even a wild batch afterwards doesn't resume measurement.
        ac.observe_batch(&[99.0; 4]);
        assert!(!ac.keep_measuring());
    }

    #[test]
    fn empty_batch_ignored() {
        let mut ac = AdaptiveController::new(0.5, 2);
        ac.observe_batch(&[]);
        assert_eq!(ac.batches_seen(), 0);
    }
}
