//! Cross-device cost-model adaptation strategies (the paper's §3) and
//! the baselines it is evaluated against (§4.4):
//!
//! * `AnsorRandom`     — random-init model trained from scratch online;
//! * `TensetPretrain`  — pre-trained source model, frozen on target;
//! * `TensetFinetune`  — pre-trained source model, vanilla fine-tuning
//!   (all parameters);
//! * `Moses`           — pre-trained source model + lottery-ticket masked
//!   fine-tuning (ξ-ranked transferable parameters; variant parameters
//!   decay to zero) + the adaptive controller.

pub mod ac;
pub mod moses;

pub use ac::AdaptiveController;
pub use moses::MosesAdapter;

use crate::costmodel::{layout, CostModel, Mask};
use crate::util::rng::Rng;

/// How the cost model is initialized and updated during tuning.
#[derive(Debug, Clone, PartialEq)]
pub enum Strategy {
    /// No cost model guidance at all: pure random search with
    /// measurements ("Raw" uses the default schedule instead; this is an
    /// extra diagnostics baseline).
    RandomSearch,
    /// Random init + vanilla online training (Ansor default).
    AnsorRandom,
    /// Pre-trained on source; never updated on target.
    TensetPretrain,
    /// Pre-trained on source; vanilla full fine-tuning on target.
    TensetFinetune,
    /// Pre-trained on source; Moses lottery-ticket adaptation.
    Moses(MosesConfig),
}

/// Moses hyper-parameters (paper §4: ϑ = 0.5, ratio ablated in Fig. 6).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MosesConfig {
    /// If set, keep exactly this fraction of parameters transferable
    /// (ranking mechanism, Fig. 6 ablation); otherwise threshold ϑ.
    pub ratio: Option<f64>,
    /// Distilling boundary threshold ϑ on normalized ξ.
    pub theta: f32,
    /// Weight decay applied to domain-variant parameters (Eq. 7).
    pub weight_decay: f32,
    /// Refresh the mask every this many adaptation rounds ("iteratively
    /// update the boundary", §3.4).
    pub mask_refresh_every: usize,
    /// AC: coefficient-of-variation threshold for early termination of
    /// hardware data collection (§3.5).
    pub ac_cv_threshold: f64,
    /// AC: minimum measured batches before early termination can fire.
    pub ac_min_batches: usize,
    /// Initial fraction of trials allotted to measured (training) rounds
    /// (the p-split of §3.5).
    pub train_fraction: f64,
}

impl Default for MosesConfig {
    fn default() -> Self {
        MosesConfig {
            ratio: Some(0.5),
            theta: 0.5,
            weight_decay: 0.02,
            mask_refresh_every: 2,
            ac_cv_threshold: 0.08,
            ac_min_batches: 3,
            train_fraction: 0.7,
        }
    }
}

impl Strategy {
    /// Parse a CLI name.
    pub fn from_name(name: &str) -> Option<Strategy> {
        match name.to_ascii_lowercase().as_str() {
            "random" | "random-search" => Some(Strategy::RandomSearch),
            "ansor-random" | "ansor" => Some(Strategy::AnsorRandom),
            "tenset-pretrain" | "pretrain" => Some(Strategy::TensetPretrain),
            "tenset-finetune" | "finetune" => Some(Strategy::TensetFinetune),
            "moses" => Some(Strategy::Moses(MosesConfig::default())),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::RandomSearch => "random-search",
            Strategy::AnsorRandom => "ansor-random",
            Strategy::TensetPretrain => "tenset-pretrain",
            Strategy::TensetFinetune => "tenset-finetune",
            Strategy::Moses(_) => "moses",
        }
    }

    /// Does this strategy start from the pre-trained source checkpoint?
    pub fn uses_pretrained(&self) -> bool {
        matches!(
            self,
            Strategy::TensetPretrain | Strategy::TensetFinetune | Strategy::Moses(_)
        )
    }

    /// Does this strategy update the model online?
    pub fn trains_online(&self) -> bool {
        matches!(
            self,
            Strategy::AnsorRandom | Strategy::TensetFinetune | Strategy::Moses(_)
        )
    }

    /// The parameter mask used for online updates.
    pub fn initial_mask(&self) -> Mask {
        Mask::all_ones(layout::N_PARAMS)
    }
}

/// Initialize a cost model for a strategy.
pub fn init_model(
    strategy: &Strategy,
    backend: std::sync::Arc<dyn crate::costmodel::Backend>,
    pretrained: Option<&[f32]>,
    rng: &mut Rng,
) -> CostModel {
    if strategy.uses_pretrained() {
        let params = pretrained
            .expect("strategy requires a pre-trained checkpoint")
            .to_vec();
        CostModel::with_params(backend, params)
    } else {
        CostModel::new(backend, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::RustBackend;
    use std::sync::Arc;

    #[test]
    fn strategy_names_roundtrip() {
        for n in ["random", "ansor-random", "tenset-pretrain", "tenset-finetune", "moses"] {
            let s = Strategy::from_name(n).unwrap();
            assert!(Strategy::from_name(s.name()).is_some());
        }
        assert!(Strategy::from_name("autotvm").is_none());
    }

    #[test]
    fn pretrained_flags_consistent() {
        assert!(!Strategy::AnsorRandom.uses_pretrained());
        assert!(Strategy::AnsorRandom.trains_online());
        assert!(Strategy::TensetPretrain.uses_pretrained());
        assert!(!Strategy::TensetPretrain.trains_online());
        let moses = Strategy::Moses(MosesConfig::default());
        assert!(moses.uses_pretrained() && moses.trains_online());
    }

    #[test]
    fn init_model_uses_checkpoint() {
        let backend = Arc::new(RustBackend { pred_batch: 8, train_batch: 8 });
        let ckpt = vec![0.5f32; layout::N_PARAMS];
        let m = init_model(
            &Strategy::TensetFinetune,
            backend.clone(),
            Some(&ckpt),
            &mut Rng::new(1),
        );
        assert_eq!(m.params()[0], 0.5);
        let m2 = init_model(&Strategy::AnsorRandom, backend, None, &mut Rng::new(1));
        assert_ne!(m2.params()[0], 0.5);
    }

    #[test]
    #[should_panic]
    fn pretrained_strategy_without_checkpoint_panics() {
        let backend = Arc::new(RustBackend { pred_batch: 8, train_batch: 8 });
        init_model(&Strategy::TensetFinetune, backend, None, &mut Rng::new(1));
    }
}
