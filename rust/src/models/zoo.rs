//! Concrete model definitions with real layer shapes (inference, N=1).
//!
//! Shapes follow the standard torchvision / HuggingFace configurations.
//! Weight-shared or shape-identical layers are a single task with
//! `repeats` set, matching how TVM deduplicates tuning tasks.

use super::DnnModel;
use crate::program::{Subgraph, SubgraphKind};

fn conv(name: &str, h: usize, w: usize, cin: usize, cout: usize, k: usize, stride: usize, pad: usize) -> Subgraph {
    Subgraph::new(
        name,
        SubgraphKind::Conv2d { n: 1, h, w, cin, cout, kh: k, kw: k, stride, pad },
    )
}

fn dwconv(name: &str, h: usize, w: usize, c: usize, k: usize, stride: usize, pad: usize) -> Subgraph {
    Subgraph::new(
        name,
        SubgraphKind::DepthwiseConv2d { n: 1, h, w, c, kh: k, kw: k, stride, pad },
    )
}

fn dense(name: &str, m: usize, n: usize, k: usize) -> Subgraph {
    Subgraph::new(name, SubgraphKind::Dense { m, n, k })
}

fn bmm(name: &str, b: usize, m: usize, n: usize, k: usize) -> Subgraph {
    Subgraph::new(name, SubgraphKind::BatchMatmul { b, m, n, k })
}

fn pool(name: &str, h: usize, w: usize, c: usize, k: usize, stride: usize) -> Subgraph {
    Subgraph::new(name, SubgraphKind::Pool2d { n: 1, h, w, c, k, stride })
}

/// ResNet-18 (ImageNet, 224²): stem + 4 stages × 2 basic blocks + fc.
pub fn resnet18() -> DnnModel {
    DnnModel::new(
        "resnet18",
        vec![
            conv("resnet18.conv1", 224, 224, 3, 64, 7, 2, 3),
            pool("resnet18.maxpool", 112, 112, 64, 3, 2),
            // Stage 1: 56², 64ch. 2 blocks × 2 convs, all same shape.
            conv("resnet18.s1.conv3x3", 56, 56, 64, 64, 3, 1, 1).with_repeats(4),
            // Stage 2 entry: stride-2 + 1x1 downsample shortcut.
            conv("resnet18.s2.conv3x3_s2", 56, 56, 64, 128, 3, 2, 1),
            conv("resnet18.s2.down1x1", 56, 56, 64, 128, 1, 2, 0),
            conv("resnet18.s2.conv3x3", 28, 28, 128, 128, 3, 1, 1).with_repeats(3),
            // Stage 3.
            conv("resnet18.s3.conv3x3_s2", 28, 28, 128, 256, 3, 2, 1),
            conv("resnet18.s3.down1x1", 28, 28, 128, 256, 1, 2, 0),
            conv("resnet18.s3.conv3x3", 14, 14, 256, 256, 3, 1, 1).with_repeats(3),
            // Stage 4.
            conv("resnet18.s4.conv3x3_s2", 14, 14, 256, 512, 3, 2, 1),
            conv("resnet18.s4.down1x1", 14, 14, 256, 512, 1, 2, 0),
            conv("resnet18.s4.conv3x3", 7, 7, 512, 512, 3, 1, 1).with_repeats(3),
            pool("resnet18.avgpool", 7, 7, 512, 7, 7),
            dense("resnet18.fc", 1, 1000, 512),
            Subgraph::new(
                "resnet18.residual_add",
                SubgraphKind::Elementwise { len: 56 * 56 * 64, ops: 2 },
            )
            .with_repeats(8),
        ],
    )
}

/// MobileNetV1 (224², width 1.0): stem conv + 13 depthwise-separable
/// pairs + classifier.
pub fn mobilenet() -> DnnModel {
    // (h, cin, cout, stride of the depthwise)
    let cfg: [(usize, usize, usize, usize); 13] = [
        (112, 32, 64, 1),
        (112, 64, 128, 2),
        (56, 128, 128, 1),
        (56, 128, 256, 2),
        (28, 256, 256, 1),
        (28, 256, 512, 2),
        (14, 512, 512, 1),
        (14, 512, 512, 1),
        (14, 512, 512, 1),
        (14, 512, 512, 1),
        (14, 512, 512, 1),
        (14, 512, 1024, 2),
        (7, 1024, 1024, 1),
    ];
    let mut subs = vec![conv("mobilenet.conv1", 224, 224, 3, 32, 3, 2, 1)];
    let mut dedup: Vec<(String, Subgraph)> = Vec::new();
    for (i, &(h, cin, cout, stride)) in cfg.iter().enumerate() {
        let dw = dwconv(&format!("mobilenet.dw{}", i + 1), h, h, cin, 3, stride, 1);
        let oh = if stride == 2 { h / 2 } else { h };
        let pw = conv(&format!("mobilenet.pw{}", i + 1), oh, oh, cin, cout, 1, 1, 0);
        for sg in [dw, pw] {
            // Deduplicate identical shapes into repeats (TVM-style).
            let key = format!("{:?}", sg.kind);
            if let Some((_, existing)) = dedup.iter_mut().find(|(k, _)| *k == key) {
                existing.repeats += 1;
            } else {
                dedup.push((key, sg));
            }
        }
    }
    subs.extend(dedup.into_iter().map(|(_, s)| s));
    subs.push(pool("mobilenet.avgpool", 7, 7, 1024, 7, 7));
    subs.push(dense("mobilenet.fc", 1, 1000, 1024));
    DnnModel::new("mobilenet", subs)
}

/// SqueezeNet 1.1 (224²) — exactly 23 tuning tasks (paper §3.2: "the
/// subgraphs"), with shape-identical expand stages deduplicated into
/// repeats the way TVM merges identical tasks.
pub fn squeezenet() -> DnnModel {
    // Fire pair (two consecutive fires share expand shapes): squeeze
    // convs differ by input channels; expand convs are identical.
    fn fire_pair(
        subs: &mut Vec<Subgraph>,
        idx: usize,
        h: usize,
        cin_a: usize,
        cin_b: usize,
        sq: usize,
        ex: usize,
    ) {
        subs.push(conv(&format!("squeezenet.fire{idx}.squeeze1x1"), h, h, cin_a, sq, 1, 1, 0));
        subs.push(conv(&format!("squeezenet.fire{}.squeeze1x1", idx + 1), h, h, cin_b, sq, 1, 1, 0));
        subs.push(
            conv(&format!("squeezenet.fire{idx}_{}.expand1x1", idx + 1), h, h, sq, ex, 1, 1, 0)
                .with_repeats(2),
        );
        subs.push(
            conv(&format!("squeezenet.fire{idx}_{}.expand3x3", idx + 1), h, h, sq, ex, 3, 1, 1)
                .with_repeats(2),
        );
    }
    let mut subs = vec![
        conv("squeezenet.conv1", 224, 224, 3, 64, 3, 2, 0),
        pool("squeezenet.maxpool1", 111, 111, 64, 3, 2),
    ];
    fire_pair(&mut subs, 2, 55, 64, 128, 16, 64);
    subs.push(pool("squeezenet.maxpool3", 55, 55, 128, 3, 2));
    fire_pair(&mut subs, 4, 27, 128, 256, 32, 128);
    subs.push(pool("squeezenet.maxpool5", 27, 27, 256, 3, 2));
    fire_pair(&mut subs, 6, 13, 256, 384, 48, 192);
    fire_pair(&mut subs, 8, 13, 384, 512, 64, 256);
    subs.push(conv("squeezenet.conv10", 13, 13, 512, 1000, 1, 1, 0));
    subs.push(pool("squeezenet.avgpool", 13, 13, 1000, 13, 13));
    subs.push(Subgraph::new(
        "squeezenet.concat_relu",
        SubgraphKind::Elementwise { len: 55 * 55 * 128, ops: 1 },
    )
    .with_repeats(8));
    debug_assert_eq!(subs.len(), 23);
    DnnModel::new("squeezenet", subs)
}

/// BERT-base (seq 128, hidden 768, 12 layers, 12 heads, FFN 3072).
pub fn bert_base() -> DnnModel {
    let seq = 128;
    let hid = 768;
    let heads = 12;
    let dh = hid / heads; // 64
    let ffn = 3072;
    DnnModel::new(
        "bert",
        vec![
            // Per layer (×12): QKV projections, attention matmuls,
            // output projection, FFN up/down, layernorm+residual fusion.
            dense("bert.qkv_proj", seq, 3 * hid, hid).with_repeats(12),
            bmm("bert.attn_scores", heads, seq, seq, dh).with_repeats(12),
            bmm("bert.attn_context", heads, seq, dh, seq).with_repeats(12),
            dense("bert.attn_out", seq, hid, hid).with_repeats(12),
            dense("bert.ffn_up", seq, ffn, hid).with_repeats(12),
            dense("bert.ffn_down", seq, hid, ffn).with_repeats(12),
            Subgraph::new(
                "bert.softmax",
                SubgraphKind::Elementwise { len: heads * seq * seq, ops: 5 },
            )
            .with_repeats(12),
            Subgraph::new(
                "bert.layernorm_residual",
                SubgraphKind::Elementwise { len: seq * hid, ops: 8 },
            )
            .with_repeats(24),
            dense("bert.pooler", 1, hid, hid),
        ],
    )
}

/// mobileViT-XS-like hybrid (the §4.1 dataset mentions mobile
/// transformers) — used for dataset generation coverage.
pub fn mobilevit() -> DnnModel {
    let mut subs = vec![
        conv("mobilevit.conv1", 256, 256, 3, 16, 3, 2, 1),
        dwconv("mobilevit.mv2_dw1", 128, 128, 16, 3, 1, 1),
        conv("mobilevit.mv2_pw1", 128, 128, 16, 32, 1, 1, 0),
        dwconv("mobilevit.mv2_dw2", 128, 128, 32, 3, 2, 1),
        conv("mobilevit.mv2_pw2", 64, 64, 32, 48, 1, 1, 0),
    ];
    // Transformer blocks on 32×32 and 16×16 token grids.
    for (i, (tokens, dim)) in [(1024usize, 96usize), (256, 120), (64, 144)].iter().enumerate() {
        subs.push(dense(&format!("mobilevit.t{i}.qkv"), *tokens, 3 * dim, *dim).with_repeats(2));
        subs.push(bmm(&format!("mobilevit.t{i}.scores"), 4, *tokens, *tokens, dim / 4).with_repeats(2));
        subs.push(bmm(&format!("mobilevit.t{i}.ctx"), 4, *tokens, dim / 4, *tokens).with_repeats(2));
        subs.push(dense(&format!("mobilevit.t{i}.ffn_up"), *tokens, 2 * dim, *dim).with_repeats(2));
        subs.push(dense(&format!("mobilevit.t{i}.ffn_down"), *tokens, *dim, 2 * dim).with_repeats(2));
    }
    subs.push(conv("mobilevit.head", 8, 8, 144, 384, 1, 1, 0));
    subs.push(dense("mobilevit.fc", 1, 1000, 384));
    DnnModel::new("mobilevit", subs)
}

/// All evaluation models.
pub fn all() -> Vec<DnnModel> {
    vec![resnet18(), mobilenet(), squeezenet(), bert_base(), mobilevit()]
}

/// Lookup by CLI name (accepts a few aliases).
pub fn by_name(name: &str) -> Option<DnnModel> {
    match name.to_ascii_lowercase().as_str() {
        "resnet18" | "resnet" | "r" => Some(resnet18()),
        "mobilenet" | "m" => Some(mobilenet()),
        "squeezenet" | "s" => Some(squeezenet()),
        "bert" | "bert-base" | "bertbase" | "b" => Some(bert_base()),
        "mobilevit" => Some(mobilevit()),
        _ => None,
    }
}
