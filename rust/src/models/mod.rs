//! DNN model zoo: the workloads of the paper's evaluation (§4.2 —
//! ResNet-18, MobileNet, BERT-base, SqueezeNet) expressed as lists of
//! tuning tasks (subgraphs), the way TVM's graph-level optimizer hands
//! them to the tensor-level tuner.

pub mod zoo;

use crate::program::Subgraph;

/// A DNN model = an ordered list of tuning tasks.
#[derive(Debug, Clone)]
pub struct DnnModel {
    pub name: String,
    subgraphs: Vec<Subgraph>,
}

impl DnnModel {
    pub fn new(name: &str, subgraphs: Vec<Subgraph>) -> DnnModel {
        DnnModel { name: name.to_string(), subgraphs }
    }

    /// The tuning tasks (unique subgraphs; weight-shared repeats are
    /// recorded on each task and weighted into end-to-end latency).
    pub fn tasks(&self) -> Vec<Subgraph> {
        self.subgraphs.clone()
    }

    pub fn num_tasks(&self) -> usize {
        self.subgraphs.len()
    }

    /// Total FLOPs of one inference.
    pub fn total_flops(&self) -> f64 {
        self.subgraphs.iter().map(|s| s.flops() * s.repeats as f64).sum()
    }

    /// End-to-end latency given a per-task latency lookup (seconds).
    pub fn end_to_end_latency(&self, per_task: &dyn Fn(&Subgraph) -> f64) -> f64 {
        self.subgraphs.iter().map(|s| per_task(s) * s.repeats as f64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::zoo;

    #[test]
    fn zoo_models_nonempty_and_named() {
        for m in zoo::all() {
            assert!(m.num_tasks() > 0, "{}", m.name);
            assert!(m.total_flops() > 0.0);
            // Task names unique within a model.
            let mut names: Vec<String> =
                m.tasks().iter().map(|t| t.name.clone()).collect();
            let before = names.len();
            names.sort();
            names.dedup();
            assert_eq!(before, names.len(), "{} duplicate task names", m.name);
        }
    }

    #[test]
    fn squeezenet_task_count_matches_paper() {
        // Paper §3.2: "SqueezeNet consists of 23 tasks".
        assert_eq!(zoo::squeezenet().num_tasks(), 23);
    }

    #[test]
    fn resnet18_subgraph_count_plausible() {
        // Paper §2.2 notes ResNet-50 → 29 subgraphs; ResNet-18 is
        // smaller: expect 10..25 unique tasks.
        let n = zoo::resnet18().num_tasks();
        assert!((10..=25).contains(&n), "{n}");
    }

    #[test]
    fn flops_ordering_sane() {
        // BERT-base ≫ ResNet-18 ≫ SqueezeNet ≳ MobileNet in FLOPs.
        let bert = zoo::bert_base().total_flops();
        let resnet = zoo::resnet18().total_flops();
        let squeeze = zoo::squeezenet().total_flops();
        let mobile = zoo::mobilenet().total_flops();
        assert!(bert > resnet, "bert {bert} resnet {resnet}");
        assert!(resnet > squeeze, "resnet {resnet} squeeze {squeeze}");
        assert!(resnet > mobile, "resnet {resnet} mobile {mobile}");
    }

    #[test]
    fn by_name_lookup() {
        for key in ["resnet18", "mobilenet", "squeezenet", "bert"] {
            assert!(zoo::by_name(key).is_some(), "{key}");
        }
        assert!(zoo::by_name("vgg99").is_none());
    }

    #[test]
    fn end_to_end_latency_weights_repeats() {
        let m = zoo::bert_base();
        let flat = m.end_to_end_latency(&|_s| 1e-3);
        let total_invocations: usize = m.tasks().iter().map(|t| t.repeats).sum();
        assert!((flat - total_invocations as f64 * 1e-3).abs() < 1e-9);
        assert!(total_invocations > m.num_tasks()); // layers repeat
    }
}
