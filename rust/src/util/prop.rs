//! Seeded randomized property testing (proptest is not in the offline
//! crate cache).  `check` runs a property over many generated cases and
//! reports the failing case number + RNG seed so failures reproduce
//! exactly.  Used by the `*_prop` tests across the crate.

use crate::util::rng::Rng;

/// Default number of cases per property.
pub const DEFAULT_CASES: usize = 128;

/// Run `property` over `cases` random cases.  The property receives a
/// fresh forked RNG per case; panic (assert!) inside to signal failure.
/// On failure the case index and seed are attached to the panic message.
pub fn check_with(seed: u64, cases: usize, property: impl Fn(&mut Rng)) {
    let mut root = Rng::new(seed);
    for case in 0..cases {
        let mut rng = root.fork(case as u64);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!("property failed at case {case}/{cases} (root seed {seed}): {msg}");
        }
    }
}

/// Run with the default seed/case count.
pub fn check(property: impl Fn(&mut Rng)) {
    check_with(0xC0FFEE, DEFAULT_CASES, property);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(|rng| {
            let x = rng.uniform();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    fn failing_property_reports_case_and_seed() {
        let result = std::panic::catch_unwind(|| {
            check_with(7, 64, |rng| {
                // Fails for roughly half the cases.
                assert!(rng.uniform() < 0.5, "too big");
            });
        });
        let msg = match result {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("root seed 7"), "{msg}");
        assert!(msg.contains("too big"), "{msg}");
    }

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut first = Vec::new();
        check_with(3, 10, |rng| {
            // Record-only property.
            let _ = rng;
        });
        let mut root_a = Rng::new(3);
        let mut root_b = Rng::new(3);
        for i in 0..10 {
            first.push(root_a.fork(i).next_u64());
        }
        for (i, v) in first.iter().enumerate() {
            assert_eq!(*v, root_b.fork(i as u64).next_u64());
        }
    }
}
