//! ASCII/markdown table rendering for experiment output (`moses tables`)
//! — the same rows the paper's figures/tables report.

/// A simple table with a header row and string cells.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as a GitHub-flavoured markdown table with a title line.
    pub fn to_markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for width in &w {
            sep.push_str(&format!("{}|", "-".repeat(width + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        println!("{}", self.to_markdown());
    }
}

/// Format a ratio as a percentage gain string, e.g. 1.41 -> "+41.0%".
pub fn pct_gain(ratio: f64) -> String {
    format!("{:+.1}%", (ratio - 1.0) * 100.0)
}

/// Format a plain percentage, e.g. 0.458 -> "45.8".
pub fn pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["model", "gain"]);
        t.row(vec!["resnet18".into(), "+41.0%".into()]);
        t.row(vec!["mb".into(), "+9.6%".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| model    | gain   |"));
        assert!(md.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn pct_helpers() {
        assert_eq!(pct_gain(1.41), "+41.0%");
        assert_eq!(pct_gain(0.9), "-10.0%");
        assert_eq!(pct(0.458), "45.8");
    }
}
