//! Minimal leveled logging facade (the `log`/`env_logger` crates are
//! not in the offline crate cache).  Library code logs through the
//! [`crate::error!`]/[`crate::warn!`]/[`crate::info!`]/[`crate::debug!`]
//! macros instead of writing to stderr directly; binaries pick the
//! verbosity once at startup via [`init_from_env`] (`RUST_LOG` wins,
//! else a `--verbose` switch).
//!
//! Until a binary initializes the logger, the level defaults to
//! [`Level::Warn`] so library warnings stay visible in tests and
//! benches without any setup.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

impl Level {
    fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }

    /// Parse an `RUST_LOG`-style level name (`trace` maps to `Debug`,
    /// the finest level this facade has).
    pub fn parse(s: &str) -> Option<Option<Level>> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "none" => Some(None),
            "error" => Some(Some(Level::Error)),
            "warn" | "warning" => Some(Some(Level::Warn)),
            "info" => Some(Some(Level::Info)),
            "debug" | "trace" => Some(Some(Level::Debug)),
            _ => None,
        }
    }
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(Level::Warn as usize);

/// Set the global maximum level (`None` silences everything).
pub fn set_max_level(level: Option<Level>) {
    MAX_LEVEL.store(level.map_or(0, |l| l as usize), Ordering::Relaxed);
}

/// Would a record at `level` be emitted?
pub fn enabled(level: Level) -> bool {
    level as usize <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Initialize from the environment: `RUST_LOG` is authoritative when
/// set to a recognized level; otherwise `verbose` selects `Debug` over
/// the CLI default `Info`.
pub fn init_from_env(verbose: bool) {
    let from_env = std::env::var("RUST_LOG").ok().and_then(|v| Level::parse(&v));
    let level = from_env
        .unwrap_or(Some(if verbose { Level::Debug } else { Level::Info }));
    set_max_level(level);
}

/// Emit one record (the macros call this; prefer them).
pub fn log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    eprintln!("[{:<5} {}] {}", level.as_str(), target, args);
}

/// Log at [`Level::Error`].
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::util::log::log(
            $crate::util::log::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at [`Level::Warn`].
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::util::log::log(
            $crate::util::log::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at [`Level::Info`].
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log(
            $crate::util::log::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at [`Level::Debug`].
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log(
            $crate::util::log::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_accepts_env_logger_names() {
        assert_eq!(Level::parse("off"), Some(None));
        assert_eq!(Level::parse("ERROR"), Some(Some(Level::Error)));
        assert_eq!(Level::parse("warn"), Some(Some(Level::Warn)));
        assert_eq!(Level::parse("trace"), Some(Some(Level::Debug)));
        assert_eq!(Level::parse("nonsense"), None);
    }

    #[test]
    fn enabled_respects_global_level() {
        let saved = MAX_LEVEL.load(Ordering::Relaxed);
        set_max_level(Some(Level::Warn));
        assert!(enabled(Level::Error) && enabled(Level::Warn));
        assert!(!enabled(Level::Info) && !enabled(Level::Debug));
        set_max_level(None);
        assert!(!enabled(Level::Error));
        MAX_LEVEL.store(saved, Ordering::Relaxed);
    }
}
