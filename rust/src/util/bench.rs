//! Criterion-style micro/throughput bench harness (criterion is not in
//! the offline crate cache).  Used by the `rust/benches/*` binaries:
//! warmup, timed iterations, robust stats, and a stable one-line report
//! format so bench output diffs cleanly across the perf pass.
//!
//! Results accumulate on the [`Bencher`] and can be serialized to a
//! dated `BENCH_<date>.json` via [`Bencher::write_json`] — the artifact
//! EXPERIMENTS.md §Perf and the CI perf upload are fed from.

// The bench harness IS the wall clock: allowlisted for detlint's
// wall-clock rule in detlint.toml and for clippy's disallowed-methods
// cross-check here.
#![allow(clippy::disallowed_methods)]

use std::cell::RefCell;
use std::io;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use crate::util::json::Json;
use crate::util::stats;

/// One benchmark's collected timings.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in nanoseconds.
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 50.0)
    }

    pub fn p05_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 5.0)
    }

    pub fn p95_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 95.0)
    }

    /// Human-readable single line, e.g.
    /// `bench feature_extract        median 12.3 µs  [11.9 µs .. 13.0 µs]  n=64`.
    pub fn report(&self) -> String {
        format!(
            "bench {:<32} median {:>10}  [{} .. {}]  n={}",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.p05_ns()),
            fmt_ns(self.p95_ns()),
            self.samples_ns.len()
        )
    }

    /// Summary-statistics JSON object (samples are not serialized —
    /// medians are what the perf pass compares).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("median_ns", Json::Num(self.median_ns())),
            ("p05_ns", Json::Num(self.p05_ns())),
            ("p95_ns", Json::Num(self.p95_ns())),
            ("samples", Json::Num(self.samples_ns.len() as f64)),
        ])
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.1} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner with warmup + adaptive iteration count.
pub struct Bencher {
    /// Target total measurement time per bench.
    pub measure_time: Duration,
    /// Warmup time before sampling.
    pub warmup_time: Duration,
    /// Cap on sample count (to bound memory / long iterations).
    pub max_samples: usize,
    /// Every result produced by this bencher, in run order (for
    /// [`Bencher::write_json`]).
    collected: RefCell<Vec<BenchResult>>,
}

impl Default for Bencher {
    fn default() -> Self {
        // Fast-mode default keeps `cargo bench` minutes-scale across the
        // whole suite; override per-bench for the perf pass.
        Bencher {
            measure_time: Duration::from_millis(800),
            warmup_time: Duration::from_millis(200),
            max_samples: 200,
            collected: RefCell::new(Vec::new()),
        }
    }
}

impl Bencher {
    /// Run `f` repeatedly; `f` should perform ONE logical iteration and
    /// return a value which is passed through `std::hint::black_box` to
    /// defeat dead-code elimination.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let wstart = Instant::now();
        while wstart.elapsed() < self.warmup_time {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let mstart = Instant::now();
        while mstart.elapsed() < self.measure_time && samples.len() < self.max_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        if samples.is_empty() {
            // Single extremely slow iteration: measure once.
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let res = BenchResult { name: name.to_string(), samples_ns: samples };
        crate::info!("{}", res.report());
        self.collected.borrow_mut().push(res.clone());
        res
    }

    /// Time one single invocation (for end-to-end experiment drivers that
    /// are too slow to repeat).
    pub fn run_once<T, F: FnOnce() -> T>(&self, name: &str, f: F) -> (BenchResult, T) {
        let t0 = Instant::now();
        let out = f();
        let ns = t0.elapsed().as_nanos() as f64;
        let res = BenchResult { name: name.to_string(), samples_ns: vec![ns] };
        crate::info!("{}", res.report());
        self.collected.borrow_mut().push(res.clone());
        (res, out)
    }

    /// Every result run on this bencher so far, in run order.
    pub fn collected(&self) -> Vec<BenchResult> {
        self.collected.borrow().clone()
    }

    /// Serialize all collected results to `<dir>/BENCH_<yyyy-mm-dd>.json`
    /// (UTC date) and return the path written.
    pub fn write_json(&self, dir: &Path) -> io::Result<PathBuf> {
        let unix_secs = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map_err(|e| io::Error::other(e.to_string()))?
            .as_secs();
        let (y, m, d) = civil_from_unix(unix_secs as i64);
        let date = format!("{y:04}-{m:02}-{d:02}");
        let doc = Json::obj(vec![
            ("date", Json::Str(date.clone())),
            ("unix_secs", Json::Num(unix_secs as f64)),
            (
                "results",
                Json::Arr(self.collected.borrow().iter().map(BenchResult::to_json).collect()),
            ),
        ]);
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{date}.json"));
        std::fs::write(&path, doc.to_string())?;
        Ok(path)
    }
}

/// Unix seconds → (year, month, day) in UTC, via Howard Hinnant's
/// `civil_from_days` algorithm (chrono is not in the offline crate
/// cache).
fn civil_from_unix(unix_secs: i64) -> (i64, u32, u32) {
    let z = unix_secs.div_euclid(86_400) + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32;
    let m = if mp < 10 { mp + 3 } else { mp - 9 } as u32;
    (if m <= 2 { y + 1 } else { y }, m, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_reports() {
        let b = Bencher {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(2),
            max_samples: 50,
            ..Bencher::default()
        };
        let r = b.run("spin", || (0..100).sum::<u64>());
        assert!(!r.samples_ns.is_empty());
        assert!(r.median_ns() > 0.0);
        assert!(r.report().contains("spin"));
        assert_eq!(b.collected().len(), 1);
    }

    #[test]
    fn run_once_returns_value() {
        let b = Bencher::default();
        let (r, v) = b.run_once("once", || 42);
        assert_eq!(v, 42);
        assert_eq!(r.samples_ns.len(), 1);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(12_000_000_000.0).ends_with("s"));
    }

    #[test]
    fn civil_dates() {
        assert_eq!(civil_from_unix(0), (1970, 1, 1));
        assert_eq!(civil_from_unix(86_399), (1970, 1, 1));
        assert_eq!(civil_from_unix(86_400), (1970, 1, 2));
        // 2024-01-01T00:00:00Z.
        assert_eq!(civil_from_unix(1_704_067_200), (2024, 1, 1));
        // Leap day: 2024-02-29T12:00:00Z.
        assert_eq!(civil_from_unix(1_709_208_000), (2024, 2, 29));
    }

    #[test]
    fn write_json_roundtrips() {
        let b = Bencher {
            measure_time: Duration::from_millis(5),
            warmup_time: Duration::from_millis(1),
            max_samples: 8,
            ..Bencher::default()
        };
        b.run("spin", || (0..100).sum::<u64>());
        let dir = std::env::temp_dir().join(format!("moses_bench_{}", std::process::id()));
        let path = b.write_json(&dir).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let results = doc.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").and_then(Json::as_str), Some("spin"));
        assert!(results[0].get("median_ns").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(doc.get("date").and_then(Json::as_str).unwrap().len() == 10);
        std::fs::remove_dir_all(&dir).ok();
    }
}
