//! Criterion-style micro/throughput bench harness (criterion is not in
//! the offline crate cache).  Used by the `rust/benches/*` binaries:
//! warmup, timed iterations, robust stats, and a stable one-line report
//! format so bench output diffs cleanly across the perf pass.

use std::time::{Duration, Instant};

use crate::util::stats;

/// One benchmark's collected timings.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in nanoseconds.
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn median_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 50.0)
    }

    pub fn p05_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 5.0)
    }

    pub fn p95_ns(&self) -> f64 {
        stats::percentile(&self.samples_ns, 95.0)
    }

    /// Human-readable single line, e.g.
    /// `bench feature_extract        median 12.3 µs  [11.9 µs .. 13.0 µs]  n=64`.
    pub fn report(&self) -> String {
        format!(
            "bench {:<32} median {:>10}  [{} .. {}]  n={}",
            self.name,
            fmt_ns(self.median_ns()),
            fmt_ns(self.p05_ns()),
            fmt_ns(self.p95_ns()),
            self.samples_ns.len()
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.1} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Bench runner with warmup + adaptive iteration count.
pub struct Bencher {
    /// Target total measurement time per bench.
    pub measure_time: Duration,
    /// Warmup time before sampling.
    pub warmup_time: Duration,
    /// Cap on sample count (to bound memory / long iterations).
    pub max_samples: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        // Fast-mode default keeps `cargo bench` minutes-scale across the
        // whole suite; override per-bench for the perf pass.
        Bencher {
            measure_time: Duration::from_millis(800),
            warmup_time: Duration::from_millis(200),
            max_samples: 200,
        }
    }
}

impl Bencher {
    /// Run `f` repeatedly; `f` should perform ONE logical iteration and
    /// return a value which is passed through `std::hint::black_box` to
    /// defeat dead-code elimination.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup.
        let wstart = Instant::now();
        while wstart.elapsed() < self.warmup_time {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let mstart = Instant::now();
        while mstart.elapsed() < self.measure_time && samples.len() < self.max_samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        if samples.is_empty() {
            // Single extremely slow iteration: measure once.
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        let res = BenchResult { name: name.to_string(), samples_ns: samples };
        println!("{}", res.report());
        res
    }

    /// Time one single invocation (for end-to-end experiment drivers that
    /// are too slow to repeat).
    pub fn run_once<T, F: FnOnce() -> T>(&self, name: &str, f: F) -> (BenchResult, T) {
        let t0 = Instant::now();
        let out = f();
        let ns = t0.elapsed().as_nanos() as f64;
        let res = BenchResult { name: name.to_string(), samples_ns: vec![ns] };
        println!("{}", res.report());
        (res, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_reports() {
        let b = Bencher {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(2),
            max_samples: 50,
        };
        let r = b.run("spin", || (0..100).sum::<u64>());
        assert!(!r.samples_ns.is_empty());
        assert!(r.median_ns() > 0.0);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn run_once_returns_value() {
        let b = Bencher::default();
        let (r, v) = b.run_once("once", || 42);
        assert_eq!(v, 42);
        assert_eq!(r.samples_ns.len(), 1);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12_000.0).contains("µs"));
        assert!(fmt_ns(12_000_000.0).contains("ms"));
        assert!(fmt_ns(12_000_000_000.0).ends_with("s"));
    }
}
