//! Small statistics helpers used across the tuner: running summaries,
//! coefficient of variation (the AC module's certainty signal, paper
//! §3.5), and rank correlation (cost-model quality diagnostics).

/// Summary statistics over a sample.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for an empty slice.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
        }
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for &x in xs {
            min = min.min(x);
            max = max.max(x);
        }
        Summary { n: xs.len(), mean, std: var.sqrt(), min, max }
    }

    /// Coefficient of variation σ/µ — the paper's AC certainty statistic.
    /// Returns +inf when the mean is ~0 (maximally uncertain).
    pub fn cv(&self) -> f64 {
        if self.mean.abs() < 1e-12 {
            f64::INFINITY
        } else {
            self.std / self.mean.abs()
        }
    }
}

/// Coefficient of variation of a sample (σ/µ).
pub fn coefficient_of_variation(xs: &[f64]) -> f64 {
    Summary::of(xs).cv()
}

/// Percentile via linear interpolation (p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let f = rank - lo as f64;
        v[lo] * (1.0 - f) + v[hi] * f
    }
}

/// Ranks with average tie-handling (1-based).
fn ranks(xs: &[f64]) -> Vec<f64> {
    let n = xs.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut r = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            r[k] = avg;
        }
        i = j + 1;
    }
    r
}

/// Pearson correlation.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for i in 0..xs.len() {
        let a = xs[i] - mx;
        let b = ys[i] - my;
        num += a * b;
        dx += a * a;
        dy += b * b;
    }
    if dx <= 0.0 || dy <= 0.0 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

/// Spearman rank correlation — the standard cost-model quality metric
/// (what matters for tuning is ranking candidates, not absolute error).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

/// Fraction of ordered pairs ranked concordantly by `pred` w.r.t. `truth`
/// (pair accuracy; 1.0 = perfect ranking, 0.5 = random).
pub fn pair_accuracy(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..pred.len() {
        for j in (i + 1)..pred.len() {
            if truth[i] == truth[j] {
                continue;
            }
            total += 1;
            if (pred[i] - pred[j]) * (truth[i] - truth[j]) > 0.0 {
                correct += 1;
            }
        }
    }
    if total == 0 {
        0.5
    } else {
        correct as f64 / total as f64
    }
}

/// Top-k recall: of the true top-k items, what fraction appears in the
/// predicted top-k?  This is the metric that actually gates tuning
/// quality (the tuner measures only the predicted top-k).
pub fn top_k_recall(pred: &[f64], truth: &[f64], k: usize) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let k = k.min(pred.len());
    if k == 0 {
        return 0.0;
    }
    let top_by = |xs: &[f64]| {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&a, &b| xs[b].total_cmp(&xs[a]));
        idx.truncate(k);
        idx
    };
    let pt = top_by(pred);
    let tt = top_by(truth);
    let hits = tt.iter().filter(|i| pt.contains(i)).count();
    hits as f64 / k as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn summary_empty() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert!(s.cv().is_infinite());
    }

    #[test]
    fn cv_constant_is_zero() {
        assert_eq!(coefficient_of_variation(&[3.0, 3.0, 3.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_perfect_and_inverted() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [10.0, 20.0, 30.0, 40.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
        let inv = [40.0, 30.0, 20.0, 10.0];
        assert!((spearman(&xs, &inv) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_handles_ties() {
        let xs = [1.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 1.0, 2.0, 3.0];
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pair_accuracy_bounds() {
        let truth = [1.0, 2.0, 3.0];
        assert_eq!(pair_accuracy(&[1.0, 2.0, 3.0], &truth), 1.0);
        assert_eq!(pair_accuracy(&[3.0, 2.0, 1.0], &truth), 0.0);
    }

    #[test]
    fn top_k_recall_basic() {
        let truth = [0.1, 0.9, 0.5, 0.7];
        let pred = [0.0, 1.0, 0.2, 0.8]; // top-2 = {1,3} both ways
        assert_eq!(top_k_recall(&pred, &truth, 2), 1.0);
        let bad = [1.0, 0.0, 0.1, 0.2]; // top-2 = {0,3}; truth {1,3}
        assert_eq!(top_k_recall(&bad, &truth, 2), 0.5);
    }
}
