//! Shared utilities: deterministic RNG, statistics, and the offline
//! stand-ins for crates that are not available in this image's crate
//! cache (clap → [`cli`], serde_json → [`json`], criterion → [`bench`],
//! proptest → [`prop`], log/env_logger → [`log`]).

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::Rng;
pub use stats::Summary;
