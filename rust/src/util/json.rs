//! Minimal JSON reader/writer (serde_json is not in the offline crate
//! cache).  Supports the full JSON value model; used for `meta.json`
//! artifact validation, experiment result dumps, and dataset manifests.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        // The scanned range is all ASCII (digits, sign, dot, exponent),
        // so this cannot fail on input that began life as a &str; map
        // the impossible case to a parse error rather than a panic.
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_compact(self, &mut s);
        f.write_str(&s)
    }
}

fn write_compact(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape_into(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, it) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_compact(it, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_compact(val, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_compound() {
        let text = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "x\"y\n"}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1], Json::Num(2.5));
        assert_eq!(v.get("b").unwrap().get("c"), Some(&Json::Bool(true)));
        assert_eq!(v.get("s").unwrap().as_str(), Some("x\"y\n"));
        // Serialize + reparse is identity.
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parses_real_meta_like_doc() {
        let text = r#"{"n_params": 347649, "artifacts": {"predict": {"file": "predict.hlo.txt", "num_inputs": 2}}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("n_params").unwrap().as_usize(), Some(347649));
        assert_eq!(
            v.get("artifacts").unwrap().get("predict").unwrap().get("file").unwrap().as_str(),
            Some("predict.hlo.txt")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{",).is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""éAü""#).unwrap();
        assert_eq!(v.as_str(), Some("éAü"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
    }
}
