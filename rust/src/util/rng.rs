//! Deterministic pseudo-random number generation.
//!
//! Everything in the reproduction that involves randomness (search space
//! sampling, evolutionary mutation, simulated measurement noise, dataset
//! generation) flows through this [`Rng`] so experiments are exactly
//! reproducible from a seed.  The core generator is splitmix64 — tiny,
//! fast, and good enough statistical quality for simulation workloads.

/// Splitmix64 PRNG with convenience samplers.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box–Muller normal deviate.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a seed. Two generators with the same seed
    /// produce identical streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), spare_normal: None }
    }

    /// Derive an independent child stream, e.g. one per task or device.
    /// Mixing the label through the output function decorrelates children
    /// even for adjacent labels.
    pub fn fork(&mut self, label: u64) -> Rng {
        let s = self.next_u64() ^ splitmix(label ^ 0xA076_1D64_78BD_642F);
        Rng::new(s)
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        splitmix(self.state)
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> f64 mantissa.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift bounded sampling (Lemire); bias is negligible
        // for the n << 2^64 sizes used here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal deviate (Box–Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal multiplicative noise factor with the given sigma
    /// (median 1.0) — the measurement-noise model used by the device sim.
    pub fn lognormal_factor(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Pick a random element of a non-empty slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates: first k positions.
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// The splitmix64 output function — also used standalone as a cheap
/// stateless hash for the device simulator's "quirk" fields.
pub fn splitmix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateless hash of a byte string into u64 (FNV-1a folded through
/// splitmix for avalanche) — used to key deterministic noise on
/// (device, config) pairs.
pub fn hash_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01B3);
    }
    splitmix(h)
}

/// Hash to uniform f64 in [0,1).
pub fn hash_unit(x: u64) -> f64 {
    (splitmix(x) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_stream() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mut buckets = [0usize; 10];
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            buckets[(u * 10.0) as usize] += 1;
        }
        for &b in &buckets {
            let frac = b as f64 / n as f64;
            assert!((frac - 0.1).abs() < 0.02, "bucket {frac}");
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let k = rng.below(20) + 1;
            let idx = rng.sample_indices(30, k);
            assert_eq!(idx.len(), k);
            let mut sorted = idx.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in {idx:?}");
            assert!(idx.iter().all(|&i| i < 30));
        }
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Rng::new(0);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn lognormal_median_near_one() {
        let mut rng = Rng::new(13);
        let mut xs: Vec<f64> = (0..10_001).map(|_| rng.lognormal_factor(0.05)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[xs.len() / 2];
        assert!((median - 1.0).abs() < 0.01, "median {median}");
    }

    #[test]
    fn hash_bytes_distinguishes() {
        assert_ne!(hash_bytes(b"abc"), hash_bytes(b"abd"));
        assert_eq!(hash_bytes(b"abc"), hash_bytes(b"abc"));
    }
}
