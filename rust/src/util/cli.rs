//! Declarative command-line flag parsing (clap is not in the offline
//! crate cache).  Supports `--flag value`, `--flag=value`, boolean
//! switches, defaults, and generated `--help` text.

use std::collections::BTreeMap;
use std::fmt;

/// Specification of one flag.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None = required unless boolean.
    pub default: Option<&'static str>,
    pub boolean: bool,
}

/// A set of flags for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Flags {
    specs: Vec<FlagSpec>,
}

/// Parsed flag values.
#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    /// Positional (non-flag) arguments in order.
    pub positional: Vec<String>,
}

/// CLI parse error.
#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Flags {
    pub fn new() -> Flags {
        Flags { specs: Vec::new() }
    }

    /// Add a value flag with a default.
    pub fn opt(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, default: Some(default), boolean: false });
        self
    }

    /// Add a required value flag.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, default: None, boolean: false });
        self
    }

    /// Add a boolean switch (default false).
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(FlagSpec { name, help, default: Some("false"), boolean: true });
        self
    }

    /// Render help text for this flag set.
    pub fn help(&self, cmd: &str, about: &str) -> String {
        let mut out = format!("{about}\n\nUsage: moses {cmd} [flags]\n\nFlags:\n");
        for s in &self.specs {
            let default = match (&s.default, s.boolean) {
                (_, true) => " (switch)".to_string(),
                (Some(d), _) => format!(" (default: {d})"),
                (None, _) => " (required)".to_string(),
            };
            out.push_str(&format!("  --{:<24} {}{}\n", s.name, s.help, default));
        }
        out
    }

    /// Parse an argument list against the specs.
    pub fn parse(&self, args: &[String]) -> Result<Parsed, CliError> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| CliError(format!("unknown flag --{name}")))?;
                let value = if spec.boolean {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    i += 1;
                    args.get(i)
                        .cloned()
                        .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                };
                values.insert(name, value);
            } else {
                positional.push(arg.clone());
            }
            i += 1;
        }
        for s in &self.specs {
            if !values.contains_key(s.name) {
                match s.default {
                    Some(d) => {
                        values.insert(s.name.to_string(), d.to_string());
                    }
                    None => return Err(CliError(format!("missing required flag --{}", s.name))),
                }
            }
        }
        Ok(Parsed { values, positional })
    }
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag {name} not declared in spec"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError(format!("--{name} expects an integer, got '{}'", self.get(name))))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError(format!("--{name} expects an integer, got '{}'", self.get(name))))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError(format!("--{name} expects a number, got '{}'", self.get(name))))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), "true" | "1" | "yes")
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str) -> Vec<String> {
        self.get(name)
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strs(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    fn flags() -> Flags {
        Flags::new()
            .opt("trials", "128", "tuning trials")
            .req("model", "model name")
            .switch("verbose", "chatty output")
    }

    #[test]
    fn parses_defaults_and_values() {
        let p = flags().parse(&strs(&["--model", "resnet18"])).unwrap();
        assert_eq!(p.get("model"), "resnet18");
        assert_eq!(p.get_usize("trials").unwrap(), 128);
        assert!(!p.get_bool("verbose"));
    }

    #[test]
    fn parses_equals_and_switch() {
        let p = flags()
            .parse(&strs(&["--model=bert", "--trials=5", "--verbose"]))
            .unwrap();
        assert_eq!(p.get("model"), "bert");
        assert_eq!(p.get_usize("trials").unwrap(), 5);
        assert!(p.get_bool("verbose"));
    }

    #[test]
    fn missing_required_fails() {
        assert!(flags().parse(&strs(&["--trials", "3"])).is_err());
    }

    #[test]
    fn unknown_flag_fails() {
        assert!(flags().parse(&strs(&["--model", "x", "--nope", "1"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let p = flags().parse(&strs(&["pos1", "--model", "x", "pos2"])).unwrap();
        assert_eq!(p.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn list_flag() {
        let f = Flags::new().opt("devices", "tx2,xavier", "device list");
        let p = f.parse(&[]).unwrap();
        assert_eq!(p.get_list("devices"), vec!["tx2", "xavier"]);
    }

    #[test]
    fn bad_number_reports_flag() {
        let p = flags().parse(&strs(&["--model", "x", "--trials", "abc"])).unwrap();
        let err = p.get_usize("trials").unwrap_err();
        assert!(err.0.contains("trials"));
    }

    #[test]
    fn help_mentions_flags() {
        let h = flags().help("tune", "Tune a model");
        assert!(h.contains("--trials") && h.contains("required") && h.contains("switch"));
    }
}
