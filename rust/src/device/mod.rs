//! Simulated measurement substrate: the `Perf()` oracle of paper Eq. 1.
//!
//! The paper measures tensor programs on real GPUs (K80, RTX 2060/2080,
//! Jetson TX2, Xavier).  None of that hardware is available here, so this
//! module provides an **analytical GPU latency simulator** with per-device
//! architecture presets.  Design goals (DESIGN.md §2):
//!
//! 1. *Plausible physics*: roofline (compute vs memory bound) ×
//!    occupancy × penalty terms (divergence, register pressure,
//!    shared-memory oversubscription, padding waste, launch overhead).
//! 2. *The paper's transfer structure* (Eq. 3): the latency response
//!    decomposes into a device-shared structural term (learnable on the
//!    source device, transferable) and a device-specific term keyed on
//!    the architecture family (what adaptation must learn).
//! 3. *Measurement economics*: embedded devices charge much higher
//!    per-measurement overhead (virtual seconds), reproducing why search
//!    efficiency gains are larger on TX2 than on RTX 2060 (paper §4.4).

pub mod arch;
pub mod clock;
pub mod presets;
pub mod sim;

pub use arch::{ArchFamily, DeviceArch};
pub use clock::{SessionTiming, VirtualClock};
pub use sim::{DeviceSim, MeasureResult};
