//! Virtual time accounting for the search process.
//!
//! The paper's search-efficiency metric is wall-clock search time, which
//! is dominated by on-device measurements (paper §2.3 citing Chameleon's
//! breakdown).  The simulator charges every measurement to this clock:
//! `cost = measure_overhead + repeats × measured_latency`, plus a small
//! charge per cost-model query/update so cost-model-heavy strategies
//! aren't free.

/// Accumulates virtual seconds spent by a tuning session.
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    seconds: f64,
    measurements: usize,
    model_queries: usize,
    model_updates: usize,
}

/// Cost constants for non-measurement work (virtual seconds).  These are
/// calibrated to the paper's setting where model inference is ~ms and
/// measurement is ~seconds: the exact values only matter relatively.
pub const COST_MODEL_QUERY_S: f64 = 0.002; // per scored BATCH of candidates
pub const COST_MODEL_UPDATE_S: f64 = 0.02; // per gradient step
pub const COST_XI_S: f64 = 0.03; // per ξ saliency computation (Moses only)

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock::default()
    }

    /// Charge one on-device measurement.
    pub fn charge_measurement(&mut self, seconds: f64) {
        assert!(seconds >= 0.0 && seconds.is_finite());
        self.seconds += seconds;
        self.measurements += 1;
    }

    /// Charge one cost-model batch query.
    pub fn charge_query(&mut self) {
        self.seconds += COST_MODEL_QUERY_S;
        self.model_queries += 1;
    }

    /// Charge one cost-model gradient step.
    pub fn charge_update(&mut self) {
        self.seconds += COST_MODEL_UPDATE_S;
        self.model_updates += 1;
    }

    /// Charge one ξ saliency computation.
    pub fn charge_xi(&mut self) {
        self.seconds += COST_XI_S;
        self.model_updates += 1;
    }

    /// Total virtual seconds.
    pub fn seconds(&self) -> f64 {
        self.seconds
    }

    pub fn measurements(&self) -> usize {
        self.measurements
    }

    pub fn model_queries(&self) -> usize {
        self.model_queries
    }

    pub fn model_updates(&self) -> usize {
        self.model_updates
    }

    /// Merge another clock (e.g. per-task clocks into a session total).
    pub fn merge(&mut self, other: &VirtualClock) {
        self.seconds += other.seconds;
        self.measurements += other.measurements;
        self.model_queries += other.model_queries;
        self.model_updates += other.model_updates;
    }
}

/// Timing of a (possibly parallel) tuning session built from per-task
/// clocks.  `cost` sums every member's virtual seconds (what the device
/// bill sees); `wall` is the critical path of the schedule the members
/// actually ran under.  Two schedule models are supported:
///
/// * **Waves** (`add_wave`, the pre-scheduler accounting): tasks run in
///   sequential waves of up to `--jobs` members, so the wall charge is
///   the sum over waves of the per-wave maximum — every wave waits for
///   its slowest straggler.
/// * **Work stealing** (`from_schedule`): each task is placed on the
///   least-loaded of `jobs` lanes in task order (first lane wins ties),
///   and the wall charge is the makespan — the load of the fullest
///   lane.  This list-schedule model is deterministic per
///   `(tasks, jobs)` and never exceeds the wave accounting: when the
///   `m`-th task of a wave is placed, at most `m - 1` lanes carry work
///   from that wave, so some lane is still at or below the previous
///   waves' bound and the greedy choice keeps every lane within
///   `Σ per-wave max` (induction over waves).
///
/// With one lane (`--jobs 1`) both models degenerate to wall == cost,
/// reproducing the sequential accounting.
#[derive(Debug, Clone, Default)]
pub struct SessionTiming {
    cost: VirtualClock,
    wall_s: f64,
    wave_wall_s: f64,
}

impl SessionTiming {
    pub fn new() -> SessionTiming {
        SessionTiming::default()
    }

    /// Fold one wave of concurrently-run task clocks into the session.
    pub fn add_wave(&mut self, members: &[VirtualClock]) {
        let mut slowest = 0.0f64;
        for c in members {
            self.cost.merge(c);
            slowest = slowest.max(c.seconds());
        }
        self.wall_s += slowest;
        self.wave_wall_s += slowest;
    }

    /// Build session timing from a work-stealing schedule: `members` are
    /// the per-task clocks in task order.  Wall time is the greedy
    /// least-loaded makespan over `jobs` lanes; the wave accounting over
    /// the same members is retained as `wave_wall_s()` for comparison.
    pub fn from_schedule(members: &[VirtualClock], jobs: usize) -> SessionTiming {
        let jobs = jobs.max(1);
        let mut cost = VirtualClock::new();
        let mut lanes = vec![0.0f64; jobs];
        for c in members {
            cost.merge(c);
            let mut least = 0usize;
            for (i, load) in lanes.iter().enumerate() {
                if *load < lanes[least] {
                    least = i;
                }
            }
            lanes[least] += c.seconds();
        }
        let wall_s = lanes.iter().fold(0.0f64, |a, &b| a.max(b));
        SessionTiming { cost, wall_s, wave_wall_s: Self::wave_wall(members, jobs) }
    }

    /// Reference wall time under the wave model: chunk `members` into
    /// consecutive waves of `jobs` and sum the per-wave maxima.
    pub fn wave_wall(members: &[VirtualClock], jobs: usize) -> f64 {
        members
            .chunks(jobs.max(1))
            .map(|w| w.iter().fold(0.0f64, |a, c| a.max(c.seconds())))
            .sum()
    }

    /// Total virtual cost across all workers.
    pub fn cost(&self) -> &VirtualClock {
        &self.cost
    }

    pub fn into_cost(self) -> VirtualClock {
        self.cost
    }

    /// Critical-path virtual seconds (`<= cost().seconds()`).
    pub fn wall_s(&self) -> f64 {
        self.wall_s
    }

    /// What the same members would have cost under the wave model
    /// (`>= wall_s()`); kept so sessions can report the stealing win.
    pub fn wave_wall_s(&self) -> f64 {
        self.wave_wall_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_monotonically() {
        let mut c = VirtualClock::new();
        c.charge_measurement(2.0);
        c.charge_query();
        c.charge_update();
        assert!(c.seconds() > 2.0);
        assert_eq!(c.measurements(), 1);
        assert_eq!(c.model_queries(), 1);
        assert_eq!(c.model_updates(), 1);
        let before = c.seconds();
        c.charge_measurement(0.5);
        assert!(c.seconds() > before);
    }

    #[test]
    fn merge_sums() {
        let mut a = VirtualClock::new();
        a.charge_measurement(1.0);
        let mut b = VirtualClock::new();
        b.charge_measurement(2.0);
        b.charge_query();
        a.merge(&b);
        assert_eq!(a.measurements(), 2);
        assert!((a.seconds() - (3.0 + COST_MODEL_QUERY_S)).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn rejects_negative_charge() {
        VirtualClock::new().charge_measurement(-1.0);
    }

    #[test]
    fn session_timing_sums_cost_and_maxes_wall() {
        let mk = |s: f64| {
            let mut c = VirtualClock::new();
            c.charge_measurement(s);
            c
        };
        let mut t = SessionTiming::new();
        t.add_wave(&[mk(1.0), mk(3.0)]);
        t.add_wave(&[mk(2.0)]);
        assert!((t.cost().seconds() - 6.0).abs() < 1e-12);
        assert!((t.wall_s() - 5.0).abs() < 1e-12);
        assert_eq!(t.cost().measurements(), 3);
        // Waves of one degenerate to sequential accounting.
        let mut seq = SessionTiming::new();
        seq.add_wave(&[mk(1.0)]);
        seq.add_wave(&[mk(2.0)]);
        assert!((seq.wall_s() - seq.cost().seconds()).abs() < 1e-12);
        assert!((seq.wave_wall_s() - seq.wall_s()).abs() < 1e-12);
    }

    #[test]
    fn schedule_makespan_beats_waves_on_skew() {
        let mk = |s: f64| {
            let mut c = VirtualClock::new();
            c.charge_measurement(s);
            c
        };
        // One straggler per wave: waves pay 10 + 9 = 19, while the
        // least-loaded schedule packs the small tasks behind each other.
        let members = [mk(10.0), mk(1.0), mk(9.0), mk(1.0)];
        let t = SessionTiming::from_schedule(&members, 2);
        assert!((t.cost().seconds() - 21.0).abs() < 1e-12);
        assert!((t.wave_wall_s() - 19.0).abs() < 1e-12);
        // Lane A: 10 + 1 = 11; lane B: 1 + 9 = 10 → makespan 11.
        assert!((t.wall_s() - 11.0).abs() < 1e-12);
        assert!(t.wall_s() < t.wave_wall_s());
    }

    #[test]
    fn schedule_with_one_lane_is_sequential() {
        let mk = |s: f64| {
            let mut c = VirtualClock::new();
            c.charge_measurement(s);
            c
        };
        let members = [mk(1.0), mk(2.0), mk(3.0)];
        let t = SessionTiming::from_schedule(&members, 1);
        assert!((t.wall_s() - t.cost().seconds()).abs() < 1e-12);
        assert!((t.wave_wall_s() - t.cost().seconds()).abs() < 1e-12);
    }

    #[test]
    fn schedule_never_exceeds_wave_accounting() {
        let mk = |s: f64| {
            let mut c = VirtualClock::new();
            c.charge_measurement(s);
            c
        };
        let costs = [3.0, 7.0, 2.0, 11.0, 5.0, 1.0, 8.0, 4.0, 6.0];
        let members: Vec<VirtualClock> = costs.iter().map(|&s| mk(s)).collect();
        for jobs in 1..=5 {
            let t = SessionTiming::from_schedule(&members, jobs);
            assert!(
                t.wall_s() <= t.wave_wall_s() + 1e-12,
                "jobs={jobs}: makespan {} > wave wall {}",
                t.wall_s(),
                t.wave_wall_s()
            );
            assert!(t.wall_s() <= t.cost().seconds() + 1e-12);
        }
    }
}
