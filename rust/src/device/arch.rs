//! GPU architecture descriptions (the knobs of the latency simulator).

/// Microarchitecture family — drives the device-specific response term
/// and coalescing/vectorization sensitivities.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArchFamily {
    Kepler,
    Maxwell,
    Pascal,
    Volta,
    Turing,
}

impl ArchFamily {
    /// Stable id for hashing the device-specific quirk field.
    pub fn id(&self) -> u64 {
        match self {
            ArchFamily::Kepler => 1,
            ArchFamily::Maxwell => 2,
            ArchFamily::Pascal => 3,
            ArchFamily::Volta => 4,
            ArchFamily::Turing => 5,
        }
    }

    /// Sensitivity to uncoalesced access (older = worse).
    pub fn coalescing_sensitivity(&self) -> f64 {
        match self {
            ArchFamily::Kepler => 1.8,
            ArchFamily::Maxwell => 1.5,
            ArchFamily::Pascal => 1.3,
            ArchFamily::Volta => 1.15,
            ArchFamily::Turing => 1.1,
        }
    }

    /// How much efficient vectorized/128-bit access helps.
    pub fn vector_bonus(&self) -> f64 {
        match self {
            ArchFamily::Kepler => 1.08,
            ArchFamily::Maxwell => 1.12,
            ArchFamily::Pascal => 1.18,
            ArchFamily::Volta => 1.22,
            ArchFamily::Turing => 1.25,
        }
    }
}

/// One device's architectural parameters.
#[derive(Debug, Clone)]
pub struct DeviceArch {
    pub name: String,
    pub family: ArchFamily,
    pub sm_count: usize,
    pub cores_per_sm: usize,
    pub clock_ghz: f64,
    pub mem_bw_gbs: f64,
    pub l2_kb: usize,
    pub shared_per_sm_kb: usize,
    pub max_threads_per_sm: usize,
    pub max_blocks_per_sm: usize,
    /// Register file per SM in units of 1024 32-bit registers.
    pub regs_per_sm_k: usize,
    pub warp_size: usize,
    /// Kernel launch overhead.
    pub launch_overhead_us: f64,
    /// Fixed virtual cost of ONE on-device measurement (compile, upload,
    /// timing harness).  The dominant term of search time (paper §2.3);
    /// embedded boards pay ~10×.
    pub measure_overhead_s: f64,
    /// Strength of the device-specific (non-transferable) response.
    pub quirk_sigma: f64,
    /// Measurement noise σ (log-normal).
    pub noise_sigma: f64,
    /// Is this an embedded / shared-memory-SoC device?
    pub embedded: bool,
}

impl DeviceArch {
    /// Peak f32 throughput in GFLOP/s (FMA = 2 flops/cycle/core).
    pub fn peak_gflops(&self) -> f64 {
        (self.sm_count * self.cores_per_sm) as f64 * self.clock_ghz * 2.0
    }

    /// Peak memory bandwidth in bytes/s.
    pub fn mem_bw_bytes(&self) -> f64 {
        self.mem_bw_gbs * 1e9
    }

    /// Roofline ridge point (flops/byte where compute == memory bound).
    pub fn ridge_point(&self) -> f64 {
        self.peak_gflops() * 1e9 / self.mem_bw_bytes()
    }

    /// Stable fingerprint of the architecture's tuning-relevant
    /// parameters.  The display name is deliberately excluded: two
    /// identically-specced boards produce the same latency response, so
    /// they share tuning-cache records.
    pub fn fingerprint(&self) -> u64 {
        let mut bytes = Vec::with_capacity(128);
        bytes.extend_from_slice(&self.family.id().to_le_bytes());
        for v in [
            self.sm_count,
            self.cores_per_sm,
            self.l2_kb,
            self.shared_per_sm_kb,
            self.max_threads_per_sm,
            self.max_blocks_per_sm,
            self.regs_per_sm_k,
            self.warp_size,
        ] {
            bytes.extend_from_slice(&(v as u64).to_le_bytes());
        }
        for f in [
            self.clock_ghz,
            self.mem_bw_gbs,
            self.launch_overhead_us,
            self.measure_overhead_s,
            self.quirk_sigma,
            self.noise_sigma,
        ] {
            bytes.extend_from_slice(&f.to_bits().to_le_bytes());
        }
        bytes.push(self.embedded as u8);
        crate::util::rng::hash_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;

    #[test]
    fn peak_flops_sane() {
        let k80 = presets::tesla_k80();
        // One K80 die: 13 SMX × 192 cores × 0.82 GHz × 2 ≈ 4.1 TFLOPs.
        assert!((k80.peak_gflops() - 4092.0).abs() < 200.0, "{}", k80.peak_gflops());
        let tx2 = presets::jetson_tx2();
        // TX2: 2 SM × 128 × 1.3 GHz × 2 ≈ 0.665 TFLOPs.
        assert!((tx2.peak_gflops() - 665.0).abs() < 50.0, "{}", tx2.peak_gflops());
    }

    #[test]
    fn ridge_point_orders_devices() {
        // TX2 has weak bandwidth (58.4 GB/s LPDDR4) so its ridge point is
        // HIGHER than the 2060's relative to its compute... actually both
        // scale; just check positivity and plausible range.
        for arch in presets::all() {
            let r = arch.ridge_point();
            assert!((1.0..200.0).contains(&r), "{}: ridge {r}", arch.name);
        }
    }

    #[test]
    fn embedded_devices_cost_more_to_measure() {
        let tx2 = presets::jetson_tx2();
        let r2060 = presets::rtx_2060();
        assert!(tx2.measure_overhead_s > 5.0 * r2060.measure_overhead_s);
        assert!(tx2.embedded && !r2060.embedded);
    }

    #[test]
    fn fingerprint_is_stable_and_spec_sensitive() {
        let a = presets::jetson_tx2();
        assert_eq!(a.fingerprint(), presets::jetson_tx2().fingerprint());
        // Renaming alone does not move the fingerprint...
        let mut renamed = a.clone();
        renamed.name = "tx2-rev-b".into();
        assert_eq!(a.fingerprint(), renamed.fingerprint());
        // ...but any spec change does.
        let mut clocked = a.clone();
        clocked.clock_ghz += 0.1;
        assert_ne!(a.fingerprint(), clocked.fingerprint());
        // All presets are pairwise distinct.
        let fps: Vec<u64> = presets::all().iter().map(|d| d.fingerprint()).collect();
        for i in 0..fps.len() {
            for j in (i + 1)..fps.len() {
                assert_ne!(fps[i], fps[j], "presets {i} and {j} collide");
            }
        }
    }

    #[test]
    fn families_have_distinct_sensitivities() {
        assert!(
            ArchFamily::Kepler.coalescing_sensitivity()
                > ArchFamily::Turing.coalescing_sensitivity()
        );
        assert!(ArchFamily::Turing.vector_bonus() > ArchFamily::Kepler.vector_bonus());
    }
}
