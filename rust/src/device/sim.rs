//! Analytical GPU latency simulator — the ground-truth `Perf()`.
//!
//! For a program (geometry G, schedule S) on architecture A the model is
//!
//! ```text
//! latency = max(compute_time, memory_time) · structural_quirk(G,S)
//!                                          · device_quirk(G,S,A)
//!           + launch_overhead
//! ```
//!
//! * `compute_time = flops / (peak · efficiency)` with efficiency the
//!   product of occupancy, warp utilization, ILP, vectorization,
//!   unrolling (with register-spill backlash) and padding-waste factors;
//! * `memory_time = traffic(G,S) / (bandwidth · coalescing_eff)` with
//!   tiling-dependent operand re-reads and cache-fit discounts;
//! * `structural_quirk` is keyed ONLY on the program (shared across all
//!   devices → learnable on the source device, transferable);
//! * `device_quirk` is keyed on (program bucket, arch family) — the
//!   domain-variant response Moses must adapt to.
//!
//! Measurement adds log-normal noise and charges virtual time:
//! `overhead + repeats × latency` (paper §2.3: measurements dominate
//! search time).

use super::arch::DeviceArch;
use crate::program::TensorProgram;
use crate::util::rng::{hash_unit, splitmix, Rng};

/// Outcome of one (simulated) on-device measurement.
#[derive(Debug, Clone, Copy)]
pub struct MeasureResult {
    /// Measured kernel latency in seconds (noisy). `INFINITY` if the
    /// configuration failed to build/launch (e.g. shared-mem oversub).
    pub latency_s: f64,
    /// Achieved throughput in GFLOP/s (0 on failure).
    pub gflops: f64,
    /// Virtual seconds this measurement cost the tuner.
    pub cost_s: f64,
    /// Did the configuration run at all?
    pub ok: bool,
}

/// The simulator for one device.
#[derive(Debug, Clone)]
pub struct DeviceSim {
    pub arch: DeviceArch,
    /// Timing repeats per measurement (TVM default-ish).
    pub repeats: usize,
}

/// Map a hash to an approximately N(0,1) deviate (sum of 4 uniforms,
/// variance-corrected) — deterministic, cheap, smooth enough.
fn hash_normal(key: u64) -> f64 {
    let mut acc = 0.0;
    for i in 0..4u64 {
        acc += hash_unit(splitmix(key ^ (i.wrapping_mul(0x9E37_79B9_7F4A_7C15))));
    }
    // Sum of 4 U(0,1): mean 2, var 4/12 -> std sqrt(1/3).
    (acc - 2.0) * (3.0f64).sqrt()
}

impl DeviceSim {
    pub fn new(arch: DeviceArch) -> DeviceSim {
        DeviceSim { arch, repeats: 3 }
    }

    // ------------------------------------------------------------------
    // Occupancy: active blocks per SM limited by threads, shared memory,
    // registers and the block cap.  Returns None if unschedulable.
    // ------------------------------------------------------------------
    fn active_blocks_per_sm(&self, p: &TensorProgram) -> Option<usize> {
        let a = &self.arch;
        let s = &p.schedule;
        let tpb = s.threads_per_block();
        if tpb > 1024 {
            return None;
        }
        let by_threads = a.max_threads_per_sm / tpb.max(1);
        let shared = s.shared_bytes();
        let by_shared = if shared == 0 {
            a.max_blocks_per_sm
        } else {
            (a.shared_per_sm_kb * 1024) / shared
        };
        let regs_needed = s.regs_per_thread() * tpb;
        let by_regs = (a.regs_per_sm_k * 1024) / regs_needed.max(1);
        let limit = by_threads.min(by_shared).min(by_regs).min(a.max_blocks_per_sm);
        if limit == 0 {
            None
        } else {
            Some(limit)
        }
    }

    /// Occupancy in [0, 1].
    pub fn occupancy(&self, p: &TensorProgram) -> f64 {
        match self.active_blocks_per_sm(p) {
            None => 0.0,
            Some(blocks) => {
                let warps = (blocks * p.schedule.threads_per_block()) as f64;
                (warps / self.arch.max_threads_per_sm as f64).min(1.0)
            }
        }
    }

    // ------------------------------------------------------------------
    // Compute efficiency terms.
    // ------------------------------------------------------------------
    fn compute_efficiency(&self, p: &TensorProgram) -> f64 {
        let a = &self.arch;
        let s = &p.schedule;
        let g = p.subgraph.geometry();
        let occ = self.occupancy(p);
        if occ == 0.0 {
            return 0.0;
        }
        // Saturating occupancy curve: latency hiding saturates ~50%.
        let occ_eff = occ / (occ + 0.18);

        // Partial warps waste lanes.
        let tpb = s.threads_per_block();
        let warp_eff = {
            let rem = tpb % a.warp_size;
            if rem == 0 {
                1.0
            } else {
                let warps = tpb.div_ceil(a.warp_size);
                tpb as f64 / (warps * a.warp_size) as f64
            }
        };

        // ILP from serial work per thread.
        let ilp = (s.work_per_thread() as f64).min(8.0) / 8.0;
        let ilp_eff = 0.55 + 0.45 * ilp;

        // Vectorized loads help newer families more; only when the
        // layout actually supports it.
        let vec_eff = if s.vectorize >= 4 {
            let supported = matches!(
                s.layout,
                crate::program::schedule::Layout::Packed
                    | crate::program::schedule::Layout::ChannelsLast
            );
            if supported {
                a.family.vector_bonus()
            } else {
                1.02
            }
        } else if s.vectorize == 2 {
            1.0 + (a.family.vector_bonus() - 1.0) * 0.4
        } else {
            1.0
        };

        // Unrolling: modest gain, big backlash on register spill.
        let regs = s.regs_per_thread();
        let unroll_eff = if regs * tpb > a.regs_per_sm_k * 1024 {
            0.45 // spilled to local memory
        } else {
            match s.unroll {
                0 => 1.0,
                16 => 1.05,
                64 => 1.09,
                _ => {
                    if s.rt >= 8 {
                        1.14
                    } else {
                        1.02 // nothing to unroll
                    }
                }
            }
        };

        // Padding waste: launched-but-dead work.
        let pad_eff = 1.0 / s.padding_factor(&g);

        // Device fill: fewer blocks than SMs can't use the machine; and
        // wave quantization for small grids.
        let blocks = s.num_blocks(&g) as f64;
        let active = self.active_blocks_per_sm(p).unwrap_or(1) as f64;
        let slots = active * a.sm_count as f64;
        let fill_eff = if blocks >= slots {
            let waves = (blocks / slots).ceil();
            (blocks / slots) / waves
        } else {
            blocks / slots
        };

        occ_eff * warp_eff * ilp_eff * vec_eff * unroll_eff * pad_eff * fill_eff.max(0.02)
    }

    // ------------------------------------------------------------------
    // Memory traffic: tiling-dependent operand re-reads, cache-fit
    // discounts, coalescing efficiency.
    // ------------------------------------------------------------------
    fn memory_time(&self, p: &TensorProgram) -> f64 {
        let a = &self.arch;
        let s = &p.schedule;
        let g = p.subgraph.geometry();
        let (ba, bb, bo) = p.subgraph.kind.buffer_bytes();
        let (gx, gy) = s.grid(&g);

        // Blocked-GEMM style traffic: operand A is re-read once per
        // Y-tile, operand B once per X-tile; output written once.
        let mut traffic_a = ba * gy as f64;
        let mut traffic_b = bb * gx as f64;

        // Shared-memory staging (or small tiles hitting L2) filters
        // re-reads of the CURRENT tile within the reduction loop.
        let tile_bytes = 4.0 * s.rt as f64 * (s.block_tile_x() + s.block_tile_y()) as f64;
        if s.use_shared {
            // Staged: each element fetched from DRAM once per block.
            // (already modeled by the gx/gy factors — staging removes the
            // *additional* per-thread re-reads modeled below)
        } else {
            // Unstaged operands are re-fetched per consuming thread row;
            // L2 absorbs part of it if the tile fits.
            let refetch = if tile_bytes <= (a.l2_kb * 1024) as f64 * 0.5 {
                1.35
            } else {
                2.2
            };
            traffic_a *= refetch;
            traffic_b *= refetch;
        }

        // Coalescing: layout + vectorization quality vs family
        // sensitivity.
        let stride_quality: f64 = match s.layout {
            crate::program::schedule::Layout::RowMajor => 0.72,
            crate::program::schedule::Layout::ChannelsLast => 0.86,
            crate::program::schedule::Layout::Packed => {
                if s.vectorize >= 4 {
                    1.0
                } else {
                    0.8
                }
            }
        };
        let coalesce_eff =
            stride_quality.powf(a.family.coalescing_sensitivity()).clamp(0.15, 1.0);

        let total = traffic_a + traffic_b + bo;
        total / (a.mem_bw_bytes() * coalesce_eff)
    }

    // ------------------------------------------------------------------
    // Quirk fields (Eq. 3 decomposition).
    // ------------------------------------------------------------------
    /// Coarse schedule bucket: quirks apply to *regions* of the space so
    /// they are learnable patterns, not per-point noise.
    fn bucket(&self, p: &TensorProgram) -> u64 {
        let s = &p.schedule;
        let g = p.subgraph.geometry();
        let mut key = 0u64;
        let push = |key: &mut u64, v: u64, bits: u32| {
            *key = (*key << bits) | (v & ((1 << bits) - 1));
        };
        push(&mut key, s.threads_per_block().trailing_zeros() as u64, 4);
        push(&mut key, (s.work_per_thread() as u64).trailing_zeros() as u64, 3);
        push(&mut key, s.rt.trailing_zeros() as u64, 3);
        push(&mut key, s.vectorize.trailing_zeros() as u64, 2);
        push(&mut key, (s.unroll > 0) as u64, 1);
        push(&mut key, s.use_shared as u64, 1);
        push(&mut key, s.layout as u64, 2);
        // Problem-size bucket (log2 of x and r).
        push(&mut key, (64 - (g.x as u64).leading_zeros()) as u64, 6);
        push(&mut key, (64 - (g.r as u64).leading_zeros()) as u64, 6);
        key
    }

    /// Device-shared structural term (transferable).
    fn structural_quirk(&self, p: &TensorProgram) -> f64 {
        let z = hash_normal(self.bucket(p) ^ 0x57A7_1C00);
        (0.10 * z).exp()
    }

    /// Device-specific term (domain-variant; what adaptation learns).
    fn device_quirk(&self, p: &TensorProgram) -> f64 {
        let z = hash_normal(self.bucket(p) ^ splitmix(self.arch.family.id() << 32));
        (self.arch.quirk_sigma * z).exp()
    }

    // ------------------------------------------------------------------
    // Public API.
    // ------------------------------------------------------------------

    /// Noise-free ground-truth latency in seconds (INFINITY if the
    /// config cannot run on this device).
    pub fn true_latency(&self, p: &TensorProgram) -> f64 {
        let eff = self.compute_efficiency(p);
        if eff == 0.0 {
            return f64::INFINITY;
        }
        let flops = p.subgraph.kind.flops();
        let compute = flops / (self.arch.peak_gflops() * 1e9 * eff);
        let memory = self.memory_time(p);
        let body = compute.max(memory) * self.structural_quirk(p) * self.device_quirk(p);
        body + self.arch.launch_overhead_us * 1e-6
    }

    /// Noise-free throughput in GFLOP/s.
    pub fn true_gflops(&self, p: &TensorProgram) -> f64 {
        let lat = self.true_latency(p);
        if lat.is_finite() {
            p.subgraph.kind.flops() / lat / 1e9
        } else {
            0.0
        }
    }

    /// Simulate one on-device measurement: noisy latency + virtual cost.
    pub fn measure(&self, p: &TensorProgram, rng: &mut Rng) -> MeasureResult {
        let truth = self.true_latency(p);
        if !truth.is_finite() {
            // Failed build/launch still costs the overhead.
            return MeasureResult {
                latency_s: f64::INFINITY,
                gflops: 0.0,
                cost_s: self.arch.measure_overhead_s,
                ok: false,
            };
        }
        let noisy = truth * rng.lognormal_factor(self.arch.noise_sigma);
        MeasureResult {
            latency_s: noisy,
            gflops: p.subgraph.kind.flops() / noisy / 1e9,
            cost_s: self.arch.measure_overhead_s + self.repeats as f64 * noisy,
            ok: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::program::{Schedule, SpaceGenerator, Subgraph, SubgraphKind, TensorProgram};
    use crate::util::prop;

    fn conv_prog(sched: Schedule) -> TensorProgram {
        let sub = Subgraph::new(
            "t.conv",
            SubgraphKind::Conv2d {
                n: 1,
                h: 56,
                w: 56,
                cin: 64,
                cout: 128,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            },
        );
        TensorProgram::new(sub, sched)
    }

    fn default_prog() -> TensorProgram {
        let sub = Subgraph::new(
            "t.conv",
            SubgraphKind::Conv2d {
                n: 1,
                h: 56,
                w: 56,
                cin: 64,
                cout: 128,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            },
        );
        let s = Schedule::default_for(&sub.geometry());
        TensorProgram::new(sub, s)
    }

    #[test]
    fn latency_positive_and_finite_for_default() {
        for arch in presets::all() {
            let sim = DeviceSim::new(arch);
            let lat = sim.true_latency(&default_prog());
            assert!(lat.is_finite() && lat > 0.0, "{}: {lat}", sim.arch.name);
        }
    }

    #[test]
    fn faster_device_is_faster_on_average() {
        // RTX 2080 should beat TX2 across a schedule sample (≫ compute
        // and bandwidth).
        let p2080 = DeviceSim::new(presets::rtx_2080());
        let ptx2 = DeviceSim::new(presets::jetson_tx2());
        let gen = SpaceGenerator::new(default_prog().subgraph.geometry());
        let mut rng = Rng::new(1);
        let mut wins = 0;
        for _ in 0..50 {
            let s = gen.sample(&mut rng);
            let p = conv_prog(s);
            if p2080.true_latency(&p) < ptx2.true_latency(&p) {
                wins += 1;
            }
        }
        assert!(wins > 45, "2080 won only {wins}/50");
    }

    #[test]
    fn schedule_quality_matters() {
        // The spread between good and bad schedules must be large —
        // that's the whole point of tuning (paper: 2x over default).
        let sim = DeviceSim::new(presets::rtx_2060());
        let gen = SpaceGenerator::new(default_prog().subgraph.geometry());
        let mut rng = Rng::new(2);
        let lats: Vec<f64> = (0..200)
            .map(|_| sim.true_latency(&conv_prog(gen.sample(&mut rng))))
            .filter(|l| l.is_finite())
            .collect();
        let best = lats.iter().cloned().fold(f64::INFINITY, f64::min);
        let worst = lats.iter().cloned().fold(0.0, f64::max);
        assert!(worst / best > 3.0, "spread {}", worst / best);
    }

    #[test]
    fn deterministic_truth() {
        let sim = DeviceSim::new(presets::tesla_k80());
        let p = default_prog();
        assert_eq!(sim.true_latency(&p), sim.true_latency(&p));
    }

    #[test]
    fn measurement_noise_is_small_and_costed() {
        let sim = DeviceSim::new(presets::rtx_2060());
        let p = default_prog();
        let truth = sim.true_latency(&p);
        let mut rng = Rng::new(3);
        let m = sim.measure(&p, &mut rng);
        assert!(m.ok);
        assert!((m.latency_s / truth - 1.0).abs() < 0.25);
        assert!(m.cost_s >= sim.arch.measure_overhead_s);
        assert!(m.gflops > 0.0);
    }

    #[test]
    fn oversubscribed_shared_memory_fails() {
        let p = default_prog();
        let g = p.subgraph.geometry();
        // 16KB/block tile * huge rt with shared on → oversubscription at
        // high block counts is fine; construct an unrunnable one: shared
        // bytes > shared_per_sm.
        let s = Schedule {
            use_shared: true,
            rt: 64,
            tx: 256,
            ix: 16,
            ty: 4,
            iy: 16,
            ..Schedule::default_for(&g)
        };
        // shared = 4*64*(4096+64) > 64KB → no block fits.
        let sim = DeviceSim::new(presets::rtx_2060());
        let prog = conv_prog(s);
        if prog.schedule.is_valid(&g) {
            let lat = sim.true_latency(&prog);
            assert!(lat.is_infinite(), "expected unrunnable, got {lat}");
            let mut rng = Rng::new(4);
            let m = sim.measure(&prog, &mut rng);
            assert!(!m.ok && m.cost_s > 0.0);
        }
    }

    #[test]
    fn cross_device_correlation_is_partial() {
        // Eq. 3: rankings correlate across devices (shared structure)
        // but NOT perfectly (device-specific response) — this is the
        // property that makes transfer useful but non-trivial.
        let k80 = DeviceSim::new(presets::tesla_k80());
        let tx2 = DeviceSim::new(presets::jetson_tx2());
        let gen = SpaceGenerator::new(default_prog().subgraph.geometry());
        let mut rng = Rng::new(5);
        let mut a = Vec::new();
        let mut b = Vec::new();
        for _ in 0..300 {
            let p = conv_prog(gen.sample(&mut rng));
            let la = k80.true_latency(&p);
            let lb = tx2.true_latency(&p);
            if la.is_finite() && lb.is_finite() {
                a.push(-la.ln());
                b.push(-lb.ln());
            }
        }
        let rho = crate::util::stats::spearman(&a, &b);
        assert!(rho > 0.35, "devices should share structure: rho={rho}");
        assert!(rho < 0.97, "devices should differ: rho={rho}");
    }

    #[test]
    fn prop_latency_always_positive_or_infinite() {
        prop::check(|rng| {
            let gen = SpaceGenerator::new(default_prog().subgraph.geometry());
            let s = gen.sample(rng);
            let p = conv_prog(s);
            for arch in presets::all() {
                let lat = DeviceSim::new(arch).true_latency(&p);
                assert!(lat > 0.0);
            }
        });
    }
}
