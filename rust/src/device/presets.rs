//! Architecture presets for the devices in the paper's evaluation
//! (§4.2: K80 source; RTX 2060 & Jetson TX2 targets; GTX 2080 testbed;
//! §4.1: TX2 + Xavier embedded dataset) plus a couple of extras used in
//! the ablations.  Numbers are public spec-sheet values.

use super::arch::{ArchFamily, DeviceArch};

/// NVIDIA Tesla K80 (one GK210 die) — the paper's source device.
pub fn tesla_k80() -> DeviceArch {
    DeviceArch {
        name: "k80".into(),
        family: ArchFamily::Kepler,
        sm_count: 13,
        cores_per_sm: 192,
        clock_ghz: 0.82,
        mem_bw_gbs: 240.0,
        l2_kb: 1536,
        shared_per_sm_kb: 48,
        max_threads_per_sm: 2048,
        max_blocks_per_sm: 16,
        regs_per_sm_k: 128,
        warp_size: 32,
        launch_overhead_us: 8.0,
        measure_overhead_s: 1.2,
        quirk_sigma: 0.25,
        noise_sigma: 0.03,
        embedded: false,
    }
}

/// NVIDIA GeForce RTX 2060 — desktop target (K80 → 2060 task).
pub fn rtx_2060() -> DeviceArch {
    DeviceArch {
        name: "rtx2060".into(),
        family: ArchFamily::Turing,
        sm_count: 30,
        cores_per_sm: 64,
        clock_ghz: 1.68,
        mem_bw_gbs: 336.0,
        l2_kb: 3072,
        shared_per_sm_kb: 64,
        max_threads_per_sm: 1024,
        max_blocks_per_sm: 16,
        regs_per_sm_k: 64,
        warp_size: 32,
        launch_overhead_us: 4.0,
        measure_overhead_s: 1.0,
        quirk_sigma: 0.25,
        noise_sigma: 0.03,
        embedded: false,
    }
}

/// NVIDIA GeForce RTX 2080 — the paper's desktop testbed GPU.
pub fn rtx_2080() -> DeviceArch {
    DeviceArch {
        name: "rtx2080".into(),
        family: ArchFamily::Turing,
        sm_count: 46,
        cores_per_sm: 64,
        clock_ghz: 1.8,
        mem_bw_gbs: 448.0,
        l2_kb: 4096,
        shared_per_sm_kb: 64,
        max_threads_per_sm: 1024,
        max_blocks_per_sm: 16,
        regs_per_sm_k: 64,
        warp_size: 32,
        launch_overhead_us: 4.0,
        measure_overhead_s: 1.0,
        quirk_sigma: 0.25,
        noise_sigma: 0.03,
        embedded: false,
    }
}

/// NVIDIA Jetson TX2 (Pascal, 256 CUDA cores) — embedded target
/// (K80 → TX2 task; §4.2).
pub fn jetson_tx2() -> DeviceArch {
    DeviceArch {
        name: "tx2".into(),
        family: ArchFamily::Pascal,
        sm_count: 2,
        cores_per_sm: 128,
        clock_ghz: 1.3,
        mem_bw_gbs: 58.4,
        l2_kb: 512,
        shared_per_sm_kb: 64,
        max_threads_per_sm: 2048,
        max_blocks_per_sm: 32,
        regs_per_sm_k: 64,
        warp_size: 32,
        launch_overhead_us: 15.0,
        // Embedded measurement: cross-compile + flash + thermal settle;
        // the paper reports VGG16 measurements taking ~10h on TX2.
        measure_overhead_s: 12.0,
        quirk_sigma: 0.3,
        noise_sigma: 0.05,
        embedded: true,
    }
}

/// NVIDIA Jetson AGX Xavier (Volta, 512 cores) — the second embedded
/// device of the §4.1 dataset.
pub fn jetson_xavier() -> DeviceArch {
    DeviceArch {
        name: "xavier".into(),
        family: ArchFamily::Volta,
        sm_count: 8,
        cores_per_sm: 64,
        clock_ghz: 1.377,
        mem_bw_gbs: 137.0,
        l2_kb: 512,
        shared_per_sm_kb: 96,
        max_threads_per_sm: 2048,
        max_blocks_per_sm: 32,
        regs_per_sm_k: 64,
        warp_size: 32,
        launch_overhead_us: 12.0,
        measure_overhead_s: 10.0,
        quirk_sigma: 0.28,
        noise_sigma: 0.05,
        embedded: true,
    }
}

/// GTX 1080 Ti — extra Pascal desktop for ablations.
pub fn gtx_1080ti() -> DeviceArch {
    DeviceArch {
        name: "gtx1080ti".into(),
        family: ArchFamily::Pascal,
        sm_count: 28,
        cores_per_sm: 128,
        clock_ghz: 1.58,
        mem_bw_gbs: 484.0,
        l2_kb: 2816,
        shared_per_sm_kb: 96,
        max_threads_per_sm: 2048,
        max_blocks_per_sm: 32,
        regs_per_sm_k: 64,
        warp_size: 32,
        launch_overhead_us: 5.0,
        measure_overhead_s: 1.0,
        quirk_sigma: 0.25,
        noise_sigma: 0.03,
        embedded: false,
    }
}

/// All presets.
pub fn all() -> Vec<DeviceArch> {
    vec![
        tesla_k80(),
        rtx_2060(),
        rtx_2080(),
        jetson_tx2(),
        jetson_xavier(),
        gtx_1080ti(),
    ]
}

/// Look a preset up by name (CLI-facing).
pub fn by_name(name: &str) -> Option<DeviceArch> {
    all().into_iter().find(|a| a.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_finds_all() {
        for arch in all() {
            assert_eq!(by_name(&arch.name).unwrap().name, arch.name);
        }
        assert!(by_name("a100").is_none());
    }

    #[test]
    fn names_unique() {
        let names: Vec<String> = all().iter().map(|a| a.name.clone()).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(names.len(), dedup.len());
    }

    #[test]
    fn tx2_matches_paper_description() {
        let tx2 = jetson_tx2();
        // "Pascal GPU architecture with 256 NVIDIA CUDA cores" (§4.2).
        assert_eq!(tx2.family, ArchFamily::Pascal);
        assert_eq!(tx2.sm_count * tx2.cores_per_sm, 256);
    }
}
