//! Canonical cache identity: which record line a (subgraph, device)
//! tuning request maps to.
//!
//! The workload half is the *normalized* subgraph — shape parameters
//! only, invariant to task naming and weight-shared repeat counts
//! ([`Subgraph::workload_fingerprint`]) — so `resnet18.conv2_1` and a
//! same-shaped layer of another model share records.  The device half
//! fingerprints the architecture's tuning-relevant parameters rather
//! than its display name ([`DeviceArch::fingerprint`]), so two
//! identically-specced boards share records too.

use std::fmt;

use crate::device::DeviceArch;
use crate::program::Subgraph;

/// Cache key: (normalized workload, device architecture).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WorkloadKey {
    /// Shape-only subgraph fingerprint.
    pub workload: u64,
    /// Architecture fingerprint.
    pub device: u64,
}

impl WorkloadKey {
    pub fn new(task: &Subgraph, arch: &DeviceArch) -> WorkloadKey {
        WorkloadKey { workload: task.workload_fingerprint(), device: arch.fingerprint() }
    }
}

impl fmt::Display for WorkloadKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:016x}@{:016x}", self.workload, self.device)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::program::SubgraphKind;

    fn conv(name: &str) -> Subgraph {
        Subgraph::new(
            name,
            SubgraphKind::Conv2d {
                n: 1, h: 28, w: 28, cin: 64, cout: 64, kh: 3, kw: 3, stride: 1, pad: 1,
            },
        )
    }

    #[test]
    fn key_normalizes_names_but_separates_devices() {
        let arch = presets::rtx_2060();
        assert_eq!(
            WorkloadKey::new(&conv("a.1"), &arch),
            WorkloadKey::new(&conv("b.2").with_repeats(3), &arch)
        );
        assert_ne!(
            WorkloadKey::new(&conv("a.1"), &presets::rtx_2060()),
            WorkloadKey::new(&conv("a.1"), &presets::jetson_tx2())
        );
    }

    #[test]
    fn display_is_fixed_width_hex() {
        let k = WorkloadKey { workload: 0xAB, device: 1 };
        let s = k.to_string();
        assert_eq!(s.len(), 33);
        assert!(s.starts_with("00000000000000ab@"));
    }
}
