//! `tunecache` — a sharded, persistent store of measured tuning records
//! with cross-device warm start.
//!
//! Moses transfers cost-model *parameters* across devices; this layer
//! reuses what transfers at the *schedule-record* level, so a
//! production tuner serving many models × many devices stops burning
//! measured trials on workloads it has already solved:
//!
//! * [`key`] — canonical [`WorkloadKey`]: normalized-subgraph hash ×
//!   device-architecture fingerprint (naming-invariant on both sides);
//! * [`store`] — [`TuneStore`], an `RwLock`-striped concurrent map
//!   holding the top-k measured `(schedule, latency)` records per
//!   (workload, device) with eviction;
//! * [`persist`] — JSONL load-on-open / append-on-commit / compaction,
//!   so tuning logs survive across sessions and hosts;
//! * [`index`] — [`WorkloadIndex`], a feature-space map from workload
//!   descriptors to cached workloads, queried by nearest-neighbor
//!   distance so genuinely new shapes can borrow similar shapes' seeds;
//! * [`warmstart`] — on a miss for the target device, records for the
//!   *same workload on other devices* become seeds for the evolutionary
//!   search's initial population, and the nearest-neighbor tier fills
//!   the rest: schedule-level transfer complementing the paper's
//!   parameter-level transfer.
//!
//! [`TuneCache`] ties the pieces together and feeds the
//! hit/miss/seed/stale counters in [`crate::metrics::cache`].

pub mod index;
pub mod key;
pub mod persist;
pub mod store;
pub mod warmstart;

pub use index::{WorkloadIndex, DEFAULT_NN_K, DEFAULT_NN_RADIUS};
pub use key::WorkloadKey;
pub use store::{TuneRecord, TuneStore};
pub use warmstart::{SeedRecord, WarmStartOptions, WarmStartPlan};

/// Version stamp of the featurizer/simulator semantics records are
/// measured under.  Bump whenever [`crate::program::features`], the
/// descriptor layout ([`crate::program::Subgraph::descriptor`]), or the
/// latency model ([`crate::device::sim`]) changes meaning: stamped
/// records from older versions are dropped on load and refused by the
/// neighbor index, so a model change can never serve stale results.
pub const RECORD_VERSION: u32 = 1;

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::metrics::cache::{CacheCounters, CacheStats};
use crate::obs::{Lane, Recorder, TraceScope};

/// Default top-k records kept per (workload, device).
pub const DEFAULT_TOPK: usize = 8;

/// The persistent cache: in-memory sharded store + JSONL append log +
/// hit/miss/seed counters.  Share one instance per host via `Arc`.
pub struct TuneCache {
    store: TuneStore,
    /// Workload-descriptor index over everything in `store` — the
    /// retrieval side of the cache (nearest-neighbor warm start).
    index: WorkloadIndex,
    path: Option<PathBuf>,
    file: Mutex<Option<File>>,
    counters: CacheCounters,
    /// Lines appended since open/compaction (compaction debt).
    appended: AtomicUsize,
    /// Trace emitter for open/compaction events (disabled unless
    /// [`TuneCache::attach_recorder`] ran).  Mutex'd because commits —
    /// and thus debt-triggered compactions — happen from worker
    /// threads.
    scope: Mutex<TraceScope>,
}

impl TuneCache {
    /// Open (or create) a cache backed by a JSONL file.  Existing
    /// records are loaded through top-k admission; malformed lines are
    /// skipped with a warning, and records stamped by a different
    /// featurizer/simulator version ([`RECORD_VERSION`]) are dropped —
    /// their latencies and descriptors are no longer comparable.
    pub fn open(path: &Path, topk: usize) -> Result<TuneCache> {
        let store = TuneStore::new(topk);
        let index = WorkloadIndex::new();
        let counters = CacheCounters::default();
        let mut dropped = 0usize;
        if path.exists() {
            let (records, skipped) = persist::load_records(path)?;
            if skipped > 0 {
                crate::warn!("tunecache: skipped {skipped} malformed line(s) in {path:?}");
            }
            let mut stale = 0usize;
            for r in &records {
                if r.version != RECORD_VERSION {
                    stale += 1;
                    continue;
                }
                if store.commit(r) {
                    index.insert(r.workload, r.desc, r.version);
                }
            }
            if stale > 0 {
                counters.record_stale(stale);
                crate::warn!(
                    "tunecache: dropped {stale} stale record(s) in {path:?} \
                     (featurizer/simulator version != {RECORD_VERSION})"
                );
            }
            dropped = stale + skipped;
        } else if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {parent:?}"))?;
            }
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening {path:?} for append"))?;
        let cache = TuneCache {
            store,
            index,
            path: Some(path.to_path_buf()),
            file: Mutex::new(Some(file)),
            counters,
            appended: AtomicUsize::new(0),
            scope: Mutex::new(TraceScope::disabled()),
        };
        // Purge dropped (stale/malformed) lines from disk once, here:
        // the debt-triggered compaction in commit() never fires for
        // them, so without this every future open would re-parse and
        // re-warn about the same dead lines forever.
        if dropped > 0 {
            if let Err(e) = cache.compact() {
                crate::warn!("tunecache: open-time compaction failed: {e:#}");
            }
        }
        Ok(cache)
    }

    /// Purely in-memory cache (tests, benches, ephemeral sessions).
    pub fn in_memory(topk: usize) -> TuneCache {
        TuneCache {
            store: TuneStore::new(topk),
            index: WorkloadIndex::new(),
            path: None,
            file: Mutex::new(None),
            counters: CacheCounters::default(),
            appended: AtomicUsize::new(0),
            scope: Mutex::new(TraceScope::disabled()),
        }
    }

    /// Surface this cache in a session trace: its `cache.*` counters
    /// join the recorder's metrics registry (shared storage, so every
    /// later bump is visible there), and open/compaction events are
    /// recorded on the cache lane.  High-frequency lookups/commits stay
    /// counters-only by design — see [`crate::obs`].
    pub fn attach_recorder(&mut self, rec: &Recorder) {
        if let Some(m) = rec.metrics() {
            m.adopt(self.counters.registry());
        }
        let mut scope = rec.scope(Lane::Cache, "tunecache");
        scope.instant(
            0,
            "open",
            0.0,
            &[],
            &[
                ("records", self.total_records() as f64),
                ("stale_dropped", self.stats().stale_dropped as f64),
                ("workloads", self.num_workloads() as f64),
            ],
        );
        *self.scope.lock().expect("tunecache scope poisoned") = scope;
    }

    /// Backing file, if any.
    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    pub fn counters(&self) -> &CacheCounters {
        &self.counters
    }

    pub fn stats(&self) -> CacheStats {
        self.counters.snapshot()
    }

    /// Commit one measured record: top-k admission, then append to the
    /// log if admitted (rejected records are never encoded).  Compacts
    /// automatically once the append debt exceeds 4× the live frontier.
    pub fn commit(&self, rec: TuneRecord) -> bool {
        let kept = self.store.commit(&rec);
        if !kept {
            self.counters.record_reject();
            return false;
        }
        self.counters.record_commit();
        self.index.insert(rec.workload, rec.desc, rec.version);
        if self.path.is_some() {
            {
                let mut guard = self.file.lock().expect("tunecache file poisoned");
                if let Some(f) = guard.as_mut() {
                    let line = persist::encode_line(&rec);
                    if writeln!(f, "{line}").is_err() {
                        crate::warn!("tunecache: append failed; record kept in memory only");
                    }
                }
            }
            let appended = self.appended.fetch_add(1, Ordering::Relaxed) + 1;
            // Short-circuit keeps the O(records) store walk off the
            // commit path until real append debt has built up.
            if appended > 64 && appended > 4 * self.store.total_records() {
                if let Err(e) = self.compact() {
                    crate::warn!("tunecache: compaction failed: {e:#}");
                }
            }
        }
        true
    }

    /// Rewrite the log to exactly the live frontier.
    pub fn compact(&self) -> Result<()> {
        let Some(path) = &self.path else {
            return Ok(());
        };
        let mut guard = self.file.lock().expect("tunecache file poisoned");
        persist::rewrite(path, &self.store.snapshot())?;
        *guard = Some(
            OpenOptions::new()
                .append(true)
                .open(path)
                .with_context(|| format!("reopening {path:?}"))?,
        );
        self.appended.store(0, Ordering::Relaxed);
        self.scope.lock().expect("tunecache scope poisoned").instant(
            0,
            "compact",
            0.0,
            &[],
            &[("records", self.store.total_records() as f64)],
        );
        Ok(())
    }

    // ------------------------------------------------- store delegates --

    pub fn best(&self, key: &WorkloadKey) -> Option<TuneRecord> {
        self.store.best(key)
    }

    pub fn records(&self, key: &WorkloadKey) -> Vec<TuneRecord> {
        self.store.get(key)
    }

    pub fn cross_device(&self, workload: u64, exclude_device: u64) -> Vec<TuneRecord> {
        self.store.cross_device(workload, exclude_device)
    }

    /// All records for one workload across every device (neighbor-seed
    /// retrieval).
    pub fn workload_records(&self, workload: u64) -> Vec<TuneRecord> {
        self.store.workload_records(workload)
    }

    /// The `k` nearest *cached* workloads within `radius` of a
    /// descriptor, closest first, excluding the querying workload.
    pub fn neighbors(
        &self,
        desc: &[f64; crate::program::DESC_DIM],
        k: usize,
        radius: f64,
        exclude_workload: u64,
    ) -> Vec<(u64, f64)> {
        self.index.nearest(desc, k, radius, exclude_workload)
    }

    /// Deterministic dump of the live frontier, sorted by (workload,
    /// device, latency) — dataset export, diagnostics.
    pub fn snapshot(&self) -> Vec<TuneRecord> {
        self.store.snapshot()
    }

    pub fn total_records(&self) -> usize {
        self.store.total_records()
    }

    pub fn num_workloads(&self) -> usize {
        self.store.num_workloads()
    }
}
