//! `tunecache` — a sharded, persistent store of measured tuning records
//! with cross-device warm start.
//!
//! Moses transfers cost-model *parameters* across devices; this layer
//! reuses what transfers at the *schedule-record* level, so a
//! production tuner serving many models × many devices stops burning
//! measured trials on workloads it has already solved:
//!
//! * [`key`] — canonical [`WorkloadKey`]: normalized-subgraph hash ×
//!   device-architecture fingerprint (naming-invariant on both sides);
//! * [`store`] — [`TuneStore`], an `RwLock`-striped concurrent map
//!   holding the top-k measured `(schedule, latency)` records per
//!   (workload, device) with eviction;
//! * [`persist`] — the JSONL line format: load-on-open,
//!   append-on-commit, atomic checkpoint rewrite;
//! * [`seglog`] — the multi-writer directory layout: per-writer
//!   exclusively-owned segments, a folded checkpoint, and the advisory
//!   compaction lock, so concurrent `moses tune` processes share one
//!   logical store without data loss;
//! * [`index`] — [`WorkloadIndex`], a feature-space map from workload
//!   descriptors to cached workloads, queried by nearest-neighbor
//!   distance so genuinely new shapes can borrow similar shapes' seeds;
//! * [`warmstart`] — on a miss for the target device, records for the
//!   *same workload on other devices* become seeds for the evolutionary
//!   search's initial population, and the nearest-neighbor tier fills
//!   the rest: schedule-level transfer complementing the paper's
//!   parameter-level transfer.
//!
//! [`TuneCache`] ties the pieces together and feeds the
//! hit/miss/seed/stale counters in [`crate::metrics::cache`].

pub mod index;
pub mod key;
pub mod persist;
pub mod seglog;
pub mod store;
pub mod warmstart;

pub use index::{WorkloadIndex, DEFAULT_NN_K, DEFAULT_NN_RADIUS};
pub use key::WorkloadKey;
pub use seglog::FsyncPolicy;
pub use store::{TuneRecord, TuneStore};
pub use warmstart::{SeedRecord, WarmStartOptions, WarmStartPlan};

/// Version stamp of the featurizer/simulator semantics records are
/// measured under.  Bump whenever [`crate::program::features`], the
/// descriptor layout ([`crate::program::Subgraph::descriptor`]), or the
/// latency model ([`crate::device::sim`]) changes meaning: stamped
/// records from older versions are dropped on load and refused by the
/// neighbor index, so a model change can never serve stale results.
pub const RECORD_VERSION: u32 = 1;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::metrics::cache::{CacheCounters, CacheStats};
use crate::obs::{Lane, Recorder, TraceScope};

/// Default top-k records kept per (workload, device).
pub const DEFAULT_TOPK: usize = 8;

/// What a [`TuneCache`] persists to, fixed at open time.
enum Backing {
    /// No persistence (tests, benches, ephemeral sessions).
    Memory,
    /// A legacy single-file JSONL log, imported read-only: commits stay
    /// in memory and compaction is a no-op, so a pre-directory log is
    /// still a valid warm-start source but never mutated (two processes
    /// appending to one file is exactly what the segmented layout
    /// exists to prevent).
    Legacy { path: PathBuf },
    /// A segmented cache directory ([`seglog`]): this instance appends
    /// to its own exclusively-owned segment.
    Segmented {
        dir: PathBuf,
        writer: Mutex<seglog::SegmentWriter>,
    },
}

/// Configures and opens a [`TuneCache`] — see [`TuneCache::builder`].
pub struct TuneCacheBuilder {
    path: PathBuf,
    topk: usize,
    fsync: FsyncPolicy,
}

impl TuneCacheBuilder {
    /// Top-k records kept per (workload, device).
    pub fn topk(mut self, topk: usize) -> TuneCacheBuilder {
        self.topk = topk;
        self
    }

    /// Durability policy for segment appends (directories only; a
    /// legacy file import never writes).
    pub fn fsync(mut self, fsync: FsyncPolicy) -> TuneCacheBuilder {
        self.fsync = fsync;
        self
    }

    /// Open the cache: an existing *file* is imported read-only
    /// (legacy single-file log); anything else is treated as a cache
    /// directory and created if absent.
    pub fn open(self) -> Result<TuneCache> {
        anyhow::ensure!(self.topk > 0, "tunecache topk must be > 0");
        if self.path.is_file() {
            TuneCache::open_legacy(&self.path, self.topk)
        } else {
            TuneCache::open_dir(&self.path, self.topk, self.fsync)
        }
    }
}

/// The persistent cache: in-memory sharded store + segmented append
/// log + hit/miss/seed counters.  Share one instance per process via
/// `Arc`; independent *processes* share the store by opening the same
/// cache directory — each appends to its own segment and merges the
/// others' on open.
pub struct TuneCache {
    store: TuneStore,
    /// Workload-descriptor index over everything in `store` — the
    /// retrieval side of the cache (nearest-neighbor warm start).
    index: WorkloadIndex,
    backing: Backing,
    fsync: FsyncPolicy,
    counters: CacheCounters,
    /// Lines appended since open/compaction (compaction debt).
    appended: AtomicUsize,
    /// Trace emitter for open/compaction events (disabled unless
    /// [`TuneCache::attach_recorder`] ran).  Mutex'd because commits —
    /// and thus debt-triggered compactions — happen from worker
    /// threads.
    scope: Mutex<TraceScope>,
}

impl TuneCache {
    /// Start configuring a cache at `path` (a segmented cache
    /// directory, or a legacy single-file log imported read-only).
    pub fn builder(path: impl Into<PathBuf>) -> TuneCacheBuilder {
        TuneCacheBuilder {
            path: path.into(),
            topk: DEFAULT_TOPK,
            fsync: FsyncPolicy::default(),
        }
    }

    /// Open (or create) a cache at `path` with default options — see
    /// [`TuneCache::builder`] for the fsync knob.  Existing records are
    /// loaded through top-k admission; malformed lines are skipped with
    /// a warning, and records stamped by a different
    /// featurizer/simulator version ([`RECORD_VERSION`]) are dropped —
    /// their latencies and descriptors are no longer comparable.
    pub fn open(path: &Path, topk: usize) -> Result<TuneCache> {
        TuneCache::builder(path).topk(topk).open()
    }

    /// Purely in-memory cache (tests, benches, ephemeral sessions).
    pub fn in_memory(topk: usize) -> TuneCache {
        TuneCache {
            store: TuneStore::new(topk),
            index: WorkloadIndex::new(),
            backing: Backing::Memory,
            fsync: FsyncPolicy::default(),
            counters: CacheCounters::default(),
            appended: AtomicUsize::new(0),
            scope: Mutex::new(TraceScope::disabled()),
        }
    }

    /// Read-only import of a legacy single-file JSONL log.
    fn open_legacy(path: &Path, topk: usize) -> Result<TuneCache> {
        let store = TuneStore::new(topk);
        let index = WorkloadIndex::new();
        let counters = CacheCounters::default();
        let (records, skipped) = persist::load_records(path)?;
        if skipped > 0 {
            crate::warn!("tunecache: skipped {skipped} malformed line(s) in {path:?}");
        }
        let mut stale = 0usize;
        for r in &records {
            if r.version != RECORD_VERSION {
                stale += 1;
                continue;
            }
            if store.commit(r) {
                index.insert(r.workload, r.desc, r.version);
            }
        }
        if stale > 0 {
            counters.record_stale(stale);
            crate::warn!(
                "tunecache: dropped {stale} stale record(s) in {path:?} \
                 (featurizer/simulator version != {RECORD_VERSION})"
            );
        }
        counters.record_segments_merged(1);
        crate::warn!(
            "tunecache: {path:?} is a legacy single-file log, imported read-only; \
             new records persist only when --tune-cache points at a cache directory"
        );
        Ok(TuneCache {
            store,
            index,
            backing: Backing::Legacy { path: path.to_path_buf() },
            fsync: FsyncPolicy::Never,
            counters,
            appended: AtomicUsize::new(0),
            scope: Mutex::new(TraceScope::disabled()),
        })
    }

    /// Open (creating if needed) a segmented cache directory:
    /// merge-on-open of checkpoint + every segment, then a fresh
    /// exclusively-owned segment for this instance's appends.
    fn open_dir(dir: &Path, topk: usize, fsync: FsyncPolicy) -> Result<TuneCache> {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
        // A concurrent compactor may fold a segment into the checkpoint
        // and unlink it between our listing and our read; those records
        // are then only in the *new* checkpoint.  Retry the whole merge
        // on a vanished file so the merged view is a consistent cut
        // (the last attempt accepts whatever is readable).
        let mut merged = None;
        for last_attempt in [false, false, true] {
            match Self::merge_dir(dir, topk, last_attempt)? {
                Some(m) => {
                    merged = Some(m);
                    break;
                }
                None => continue,
            }
        }
        // The final attempt accepts partial reads, so this is only
        // reachable if that invariant breaks — propagate instead of
        // panicking a tuning session over a cache directory.
        let m = merged
            .with_context(|| format!("could not assemble a consistent merge of {dir:?}"))?;
        if m.skipped > 0 {
            crate::warn!(
                "tunecache: skipped {} malformed line(s) in {dir:?}",
                m.skipped
            );
        }
        if m.stale > 0 {
            m.counters.record_stale(m.stale);
            crate::warn!(
                "tunecache: dropped {} stale record(s) in {dir:?} \
                 (featurizer/simulator version != {RECORD_VERSION})",
                m.stale
            );
        }
        m.counters.record_segments_merged(m.segments);
        let writer = seglog::SegmentWriter::create(dir)?;
        let cache = TuneCache {
            store: m.store,
            index: m.index,
            backing: Backing::Segmented {
                dir: dir.to_path_buf(),
                writer: Mutex::new(writer),
            },
            fsync,
            counters: m.counters,
            appended: AtomicUsize::new(0),
            scope: Mutex::new(TraceScope::disabled()),
        };
        // Purge dead lines from disk once, here: stale/malformed lines
        // AND frontier-evicted duplicates (lines that parse fine but
        // lose top-k admission) never add append debt, so without this
        // every future open would re-parse the same dead lines forever.
        if m.stale + m.skipped + m.evicted > 0 {
            if let Err(e) = cache.compact() {
                crate::warn!("tunecache: open-time compaction failed: {e:#}");
            }
        }
        Ok(cache)
    }

    /// One merge pass over the directory's log files.  Returns `None`
    /// when a file vanished mid-merge (unless `accept_partial`).
    fn merge_dir(dir: &Path, topk: usize, accept_partial: bool) -> Result<Option<MergedDir>> {
        let store = TuneStore::new(topk);
        let index = WorkloadIndex::new();
        let mut m = MergedDir {
            store,
            index,
            counters: CacheCounters::default(),
            segments: 0,
            stale: 0,
            skipped: 0,
            evicted: 0,
        };
        for file in seglog::log_files(dir)? {
            let Some((records, skipped)) = persist::load_records_opt(&file)? else {
                if accept_partial {
                    continue;
                }
                return Ok(None);
            };
            m.segments += 1;
            m.skipped += skipped;
            for r in &records {
                if r.version != RECORD_VERSION {
                    m.stale += 1;
                    continue;
                }
                if m.store.commit(r) {
                    m.index.insert(r.workload, r.desc, r.version);
                } else {
                    m.evicted += 1;
                }
            }
        }
        Ok(Some(m))
    }

    /// Surface this cache in a session trace: its `cache.*` counters
    /// join the recorder's metrics registry (shared storage, so every
    /// later bump is visible there), and open/compaction events are
    /// recorded on the cache lane.  High-frequency lookups/commits stay
    /// counters-only by design — see [`crate::obs`].
    pub fn attach_recorder(&mut self, rec: &Recorder) {
        if let Some(m) = rec.metrics() {
            m.adopt(self.counters.registry());
        }
        let mut scope = rec.scope(Lane::Cache, "tunecache");
        let stats = self.stats();
        scope.instant(
            0,
            "open",
            0.0,
            &[],
            &[
                ("records", self.total_records() as f64),
                ("stale_dropped", stats.stale_dropped as f64),
                ("workloads", self.num_workloads() as f64),
                ("segments", stats.segments_merged as f64),
            ],
        );
        *self.scope.lock().expect("tunecache scope poisoned") = scope;
    }

    /// Backing path, if any: the cache directory, or the legacy log
    /// file when one was imported.
    pub fn path(&self) -> Option<&Path> {
        match &self.backing {
            Backing::Memory => None,
            Backing::Legacy { path } => Some(path),
            Backing::Segmented { dir, .. } => Some(dir),
        }
    }

    pub fn counters(&self) -> &CacheCounters {
        &self.counters
    }

    pub fn stats(&self) -> CacheStats {
        self.counters.snapshot()
    }

    /// Commit one measured record: top-k admission, then append to this
    /// instance's segment if admitted (rejected records are never
    /// encoded).  A failed append is retried once on a reopened handle;
    /// a definitively failed append counts into `cache.append_failed`
    /// and adds *no* compaction debt (nothing reached disk).  Compacts
    /// automatically once the append debt exceeds 4× the live frontier.
    pub fn commit(&self, rec: TuneRecord) -> bool {
        let kept = self.store.commit(&rec);
        if !kept {
            self.counters.record_reject();
            return false;
        }
        self.counters.record_commit();
        self.index.insert(rec.workload, rec.desc, rec.version);
        if let Backing::Segmented { writer, .. } = &self.backing {
            let line = persist::encode_line(&rec);
            let landed = {
                let mut w = writer.lock().expect("tunecache writer poisoned");
                w.append(&line, self.fsync)
            };
            match landed {
                Ok(()) => {
                    if self.fsync == FsyncPolicy::Always {
                        self.counters.record_append_fsync();
                    }
                    let appended = self.appended.fetch_add(1, Ordering::Relaxed) + 1;
                    // Short-circuit keeps the O(records) store walk off
                    // the commit path until real debt has built up.
                    if appended > 64 && appended > 4 * self.store.total_records() {
                        if let Err(e) = self.compact() {
                            crate::warn!("tunecache: compaction failed: {e:#}");
                        }
                    }
                }
                Err(e) => {
                    self.counters.record_append_failed();
                    crate::warn!(
                        "tunecache: append failed twice ({e}); record kept in memory only"
                    );
                }
            }
        }
        true
    }

    /// Fold the on-disk log back to the live frontier.  Directory mode
    /// takes the advisory compaction lock (skipping silently if another
    /// live compactor holds it), rotates this instance's segment so
    /// concurrent commits keep landing, then rewrites the checkpoint
    /// from: our in-memory frontier (which covers our retired segment),
    /// the on-disk checkpoint (re-read under the lock — it may hold
    /// records folded by another process that we never saw), and every
    /// foldable segment (sealed by a clean close, or owned by a dead
    /// pid).  Live writers' segments are never read or removed.  Only
    /// after the checkpoint rename + directory sync land are the folded
    /// files unlinked, so a crash at any point loses nothing.
    pub fn compact(&self) -> Result<()> {
        let Backing::Segmented { dir, writer } = &self.backing else {
            return Ok(());
        };
        let Some(_lock) = seglog::try_lock(dir)? else {
            crate::debug!("tunecache: compaction skipped, {dir:?} is locked");
            return Ok(());
        };
        // Rotate BEFORE snapshotting: a record committed after the
        // rotation lands in the fresh segment (which survives), and a
        // record appended to the retired segment before it was rotated
        // away is already in the store — either way the snapshot plus
        // surviving segments cover everything.
        let (retired, own) = {
            let mut w = writer.lock().expect("tunecache writer poisoned");
            let retired = w.rotate()?;
            (retired, w.path().to_path_buf())
        };
        let merged = TuneStore::new(self.store.topk());
        for r in self.store.snapshot() {
            merged.commit(&r);
        }
        let mut folded = 1usize; // our retired segment, covered by the snapshot
        let mut dead_segments = Vec::new();
        for file in seglog::log_files(dir)? {
            if file == own || file == retired {
                continue;
            }
            let name = file.file_name().and_then(|n| n.to_str()).unwrap_or("");
            let is_checkpoint = seglog::is_checkpoint(name);
            let foldable = is_checkpoint
                || seglog::is_sealed(name)
                || seglog::segment_pid(name).is_some_and(|pid| !seglog::pid_alive(pid));
            if !foldable {
                continue;
            }
            let Some((records, _skipped)) = persist::load_records_opt(&file)? else {
                continue;
            };
            for r in &records {
                if r.version == RECORD_VERSION {
                    merged.commit(r);
                }
            }
            folded += 1;
            if !is_checkpoint {
                // The checkpoint is replaced by the rename below, never
                // unlinked — only folded segments are.
                dead_segments.push(file);
            }
        }
        let frontier = merged.snapshot();
        persist::rewrite(&dir.join(seglog::CHECKPOINT), &frontier)?;
        // The checkpoint is durable; now the folded files are garbage.
        let _ = std::fs::remove_file(&retired);
        for p in &dead_segments {
            let _ = std::fs::remove_file(p);
        }
        seglog::sweep_orphan_tmps(dir);
        let _ = seglog::fsync_dir(dir);
        self.appended.store(0, Ordering::Relaxed);
        self.counters.record_compaction();
        self.scope.lock().expect("tunecache scope poisoned").instant(
            0,
            "compact",
            0.0,
            &[],
            &[
                ("records", frontier.len() as f64),
                ("segments_folded", folded as f64),
            ],
        );
        Ok(())
    }

    // ------------------------------------------------- store delegates --

    pub fn best(&self, key: &WorkloadKey) -> Option<TuneRecord> {
        self.store.best(key)
    }

    pub fn records(&self, key: &WorkloadKey) -> Vec<TuneRecord> {
        self.store.get(key)
    }

    pub fn cross_device(&self, workload: u64, exclude_device: u64) -> Vec<TuneRecord> {
        self.store.cross_device(workload, exclude_device)
    }

    /// All records for one workload across every device (neighbor-seed
    /// retrieval).
    pub fn workload_records(&self, workload: u64) -> Vec<TuneRecord> {
        self.store.workload_records(workload)
    }

    /// The `k` nearest *cached* workloads within `radius` of a
    /// descriptor, closest first, excluding the querying workload.
    pub fn neighbors(
        &self,
        desc: &[f64; crate::program::DESC_DIM],
        k: usize,
        radius: f64,
        exclude_workload: u64,
    ) -> Vec<(u64, f64)> {
        self.index.nearest(desc, k, radius, exclude_workload)
    }

    /// Deterministic dump of the live frontier, sorted by (workload,
    /// device, latency) — dataset export, diagnostics.
    pub fn snapshot(&self) -> Vec<TuneRecord> {
        self.store.snapshot()
    }

    pub fn total_records(&self) -> usize {
        self.store.total_records()
    }

    pub fn num_workloads(&self) -> usize {
        self.store.num_workloads()
    }
}

impl Drop for TuneCache {
    /// Clean close of this instance's segment: unlink it if nothing
    /// was appended, else seal it so any compactor may fold it without
    /// waiting for this pid to exit.
    fn drop(&mut self) {
        if let Backing::Segmented { writer, .. } = &self.backing {
            if let Ok(mut w) = writer.lock() {
                w.close();
            }
        }
    }
}

/// Accumulator for one [`TuneCache::merge_dir`] pass.
struct MergedDir {
    store: TuneStore,
    index: WorkloadIndex,
    counters: CacheCounters,
    segments: usize,
    stale: usize,
    skipped: usize,
    evicted: usize,
}
