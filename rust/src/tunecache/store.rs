//! Sharded, concurrent, top-k tuning-record store.
//!
//! A `RwLock`-striped hash map keyed by the normalized workload hash:
//! lookups take one shard read lock, commits one shard write lock, and
//! the stripe count bounds contention when many tuning sessions share
//! one store.  Within a workload, records are grouped per device and
//! kept sorted by latency, with the worst evicted beyond `topk` — the
//! store holds the *useful frontier* of tuning history, not the full
//! log (the [`super::seglog`] segment files, in the [`super::persist`]
//! line format, are the log).  Top-k admission doubles as the
//! merge-on-open policy: replaying any set of segments through
//! [`TuneStore::commit`] in any order converges to a latency-identical
//! frontier (ordering matters only for exact-tie knob vectors at the
//! eviction boundary), which is what lets concurrent writers share one
//! cache directory without coordinating on reads.
//!
//! Sharding by workload (not by the combined key) is deliberate: all
//! devices' records for one workload live in one shard, so the
//! cross-device warm-start query is a single shard read.

// Outside the deterministic planes (detlint [rules.unordered-collections]):
// shard maps never leak iteration order into session results — drains that
// feed deterministic consumers go through top-k admission or sorting.
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;
use std::sync::RwLock;

use crate::program::{Schedule, Subgraph, DESC_DIM};

use super::key::WorkloadKey;
use super::RECORD_VERSION;

/// Number of lock stripes (power of two).
const N_SHARDS: usize = 16;

/// One measured tuning outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneRecord {
    /// Normalized workload fingerprint.
    pub workload: u64,
    /// Architecture fingerprint of the measuring device.
    pub device: u64,
    /// Human-readable device name (seed-origin reporting).
    pub device_name: String,
    /// Encoded schedule knobs ([`Schedule::encode`]).
    pub knobs: [u32; 9],
    /// Noise-free latency of the schedule on `device`, seconds.
    pub latency_s: f64,
    /// Achieved throughput, GFLOP/s.
    pub gflops: f64,
    /// Trial budget of the session that produced the record.  A cached
    /// result only satisfies a later request with an equal-or-smaller
    /// budget; a bigger one re-searches (seeded) instead of being
    /// short-circuited by a cheap earlier run.
    pub trials: usize,
    /// Feature-space descriptor of the workload
    /// ([`crate::program::Subgraph::descriptor`]) — what the
    /// nearest-neighbor index retrieves along.
    pub desc: [f64; DESC_DIM],
    /// Featurizer/simulator version that produced this record
    /// ([`super::RECORD_VERSION`]); stale records are dropped on load.
    pub version: u32,
    /// The concrete task the record was measured for, when the producer
    /// attached it ([`TuneRecord::with_task`]).  The workload hash is
    /// one-way, so this is what lets `moses export-dataset` rebuild a
    /// `(task, schedule, latency)` pretraining corpus from the log.
    /// `None` on pre-v3 log lines and synthetic records.
    pub task: Option<Subgraph>,
}

impl TuneRecord {
    pub fn new(
        key: WorkloadKey,
        desc: [f64; DESC_DIM],
        device_name: &str,
        schedule: &Schedule,
        latency_s: f64,
        gflops: f64,
        trials: usize,
    ) -> TuneRecord {
        TuneRecord {
            workload: key.workload,
            device: key.device,
            device_name: device_name.to_string(),
            knobs: schedule.encode(),
            latency_s,
            gflops,
            trials,
            desc,
            version: RECORD_VERSION,
            task: None,
        }
    }

    /// Attach the concrete task, making the record exportable as a
    /// dataset row (`moses export-dataset`).
    pub fn with_task(mut self, task: &Subgraph) -> TuneRecord {
        self.task = Some(task.clone());
        self
    }

    pub fn key(&self) -> WorkloadKey {
        WorkloadKey { workload: self.workload, device: self.device }
    }

    pub fn schedule(&self) -> Schedule {
        Schedule::decode(&self.knobs)
    }
}

/// Per-workload map: device fingerprint → records sorted best-first.
type DeviceRecords = HashMap<u64, Vec<TuneRecord>>;

/// The sharded in-memory store.
pub struct TuneStore {
    shards: Vec<RwLock<HashMap<u64, DeviceRecords>>>,
    topk: usize,
}

impl TuneStore {
    /// Create a store keeping the best `topk` records per
    /// (workload, device).
    pub fn new(topk: usize) -> TuneStore {
        assert!(topk > 0, "topk must be positive");
        TuneStore {
            shards: (0..N_SHARDS).map(|_| RwLock::new(HashMap::new())).collect(),
            topk,
        }
    }

    pub fn topk(&self) -> usize {
        self.topk
    }

    fn shard(&self, workload: u64) -> &RwLock<HashMap<u64, DeviceRecords>> {
        &self.shards[(workload as usize) & (N_SHARDS - 1)]
    }

    /// Insert a record, keeping the per-(workload, device) list sorted by
    /// latency and capped at `topk`.  A duplicate schedule keeps its best
    /// latency (and the larger trial budget).  Non-finite/non-positive
    /// latencies are refused.  Returns whether the commit changed the
    /// store (and therefore must reach the append log).
    pub fn commit(&self, rec: &TuneRecord) -> bool {
        if !rec.latency_s.is_finite() || rec.latency_s <= 0.0 {
            return false;
        }
        let mut shard = self.shard(rec.workload).write().expect("tunecache shard poisoned");
        let recs = shard.entry(rec.workload).or_default().entry(rec.device).or_default();
        if let Some(pos) = recs.iter().position(|r| r.knobs == rec.knobs) {
            if rec.latency_s < recs[pos].latency_s {
                let trials = recs[pos].trials.max(rec.trials);
                recs[pos] = rec.clone();
                recs[pos].trials = trials;
                recs.sort_by(|a, b| a.latency_s.total_cmp(&b.latency_s));
                return true;
            }
            if rec.trials > recs[pos].trials {
                // Same schedule, not better — but measured under a bigger
                // budget: remember that so the hit test stays honest.
                recs[pos].trials = rec.trials;
                return true;
            }
            return false;
        }
        recs.push(rec.clone());
        recs.sort_by(|a, b| a.latency_s.total_cmp(&b.latency_s));
        recs.truncate(self.topk);
        recs.iter().any(|r| r.knobs == rec.knobs)
    }

    /// All records for one (workload, device), best-first.
    pub fn get(&self, key: &WorkloadKey) -> Vec<TuneRecord> {
        let shard = self.shard(key.workload).read().expect("tunecache shard poisoned");
        shard
            .get(&key.workload)
            .and_then(|devices| devices.get(&key.device))
            .cloned()
            .unwrap_or_default()
    }

    /// Best record for one (workload, device).
    pub fn best(&self, key: &WorkloadKey) -> Option<TuneRecord> {
        let shard = self.shard(key.workload).read().expect("tunecache shard poisoned");
        shard.get(&key.workload)?.get(&key.device)?.first().cloned()
    }

    /// Records for one workload, round-robin by per-device rank (each
    /// device's best first) so no single source device monopolizes a
    /// seed list.  Device order is fixed by fingerprint for determinism;
    /// `Some(fingerprint)` filters out that device, `None` includes all.
    fn round_robin(&self, workload: u64, exclude_device: Option<u64>) -> Vec<TuneRecord> {
        let shard = self.shard(workload).read().expect("tunecache shard poisoned");
        let Some(devices) = shard.get(&workload) else {
            return Vec::new();
        };
        let mut groups: Vec<(&u64, &Vec<TuneRecord>)> = devices
            .iter()
            .filter(|(d, _)| Some(**d) != exclude_device)
            .collect();
        groups.sort_by_key(|(d, _)| **d);
        let max_rank = groups.iter().map(|(_, v)| v.len()).max().unwrap_or(0);
        let mut out = Vec::new();
        for rank in 0..max_rank {
            for (_, v) in &groups {
                if let Some(r) = v.get(rank) {
                    out.push(r.clone());
                }
            }
        }
        out
    }

    /// Records for the same workload on *other* devices (cross-device
    /// warm start).
    pub fn cross_device(&self, workload: u64, exclude_device: u64) -> Vec<TuneRecord> {
        self.round_robin(workload, Some(exclude_device))
    }

    /// All records for one workload across every device (neighbor-seed
    /// retrieval: for a *similar* workload even the target device's own
    /// records are foreign, so none are excluded).
    pub fn workload_records(&self, workload: u64) -> Vec<TuneRecord> {
        self.round_robin(workload, None)
    }

    /// Total live records across all shards.
    pub fn total_records(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                s.read()
                    .expect("tunecache shard poisoned")
                    .values()
                    .map(|d| d.values().map(Vec::len).sum::<usize>())
                    .sum::<usize>()
            })
            .sum()
    }

    /// Number of distinct workloads.
    pub fn num_workloads(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().expect("tunecache shard poisoned").len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total_records() == 0
    }

    /// Deterministic dump, sorted by (workload, device, latency) — used
    /// for persistence rewrites and tests.
    pub fn snapshot(&self) -> Vec<TuneRecord> {
        let mut out = Vec::new();
        for s in &self.shards {
            let shard = s.read().expect("tunecache shard poisoned");
            for devices in shard.values() {
                for recs in devices.values() {
                    out.extend(recs.iter().cloned());
                }
            }
        }
        out.sort_by(|a, b| {
            (a.workload, a.device)
                .cmp(&(b.workload, b.device))
                .then(a.latency_s.total_cmp(&b.latency_s))
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(workload: u64, device: u64) -> WorkloadKey {
        WorkloadKey { workload, device }
    }

    fn rec(workload: u64, device: u64, knob0: u32, latency_s: f64) -> TuneRecord {
        TuneRecord {
            workload,
            device,
            device_name: format!("dev{device}"),
            knobs: [knob0, 1, 1, 1, 1, 1, 0, 0, 0],
            latency_s,
            gflops: 1.0,
            trials: 64,
            desc: [0.0; DESC_DIM],
            version: RECORD_VERSION,
            task: None,
        }
    }

    #[test]
    fn topk_keeps_best_sorted_and_evicts_worst() {
        let store = TuneStore::new(3);
        for i in 0..6u32 {
            // Latencies 6,5,4,3,2,1 ms in commit order.
            assert!(store.commit(&rec(7, 1, i, (6 - i) as f64 * 1e-3)) || i < 3);
        }
        let got = store.get(&key(7, 1));
        assert_eq!(got.len(), 3);
        let lats: Vec<f64> = got.iter().map(|r| r.latency_s).collect();
        assert_eq!(lats, vec![1e-3, 2e-3, 3e-3]);
        assert_eq!(store.best(&key(7, 1)).unwrap().knobs[0], 5);
        // A worse-than-frontier record is refused.
        assert!(!store.commit(&rec(7, 1, 99, 1.0)));
        assert_eq!(store.get(&key(7, 1)).len(), 3);
    }

    #[test]
    fn duplicate_schedule_keeps_best_latency_and_max_trials() {
        let store = TuneStore::new(4);
        assert!(store.commit(&rec(1, 1, 7, 5e-3)));
        // Same knobs, worse latency, same budget: refused.
        assert!(!store.commit(&rec(1, 1, 7, 9e-3)));
        assert_eq!(store.get(&key(1, 1)).len(), 1);
        // Same knobs, worse latency but BIGGER budget: trials merged so
        // the workload counts as searched at the larger budget.
        let mut bigger = rec(1, 1, 7, 9e-3);
        bigger.trials = 512;
        assert!(store.commit(&bigger));
        let got = store.get(&key(1, 1));
        assert!((got[0].latency_s - 5e-3).abs() < 1e-15);
        assert_eq!(got[0].trials, 512);
        // Same knobs, better latency: upgraded in place, trials kept.
        assert!(store.commit(&rec(1, 1, 7, 2e-3)));
        let got = store.get(&key(1, 1));
        assert_eq!(got.len(), 1);
        assert!((got[0].latency_s - 2e-3).abs() < 1e-15);
        assert_eq!(got[0].trials, 512);
    }

    #[test]
    fn rejects_unusable_latencies() {
        let store = TuneStore::new(2);
        assert!(!store.commit(&rec(1, 1, 0, f64::INFINITY)));
        assert!(!store.commit(&rec(1, 1, 1, f64::NAN)));
        assert!(!store.commit(&rec(1, 1, 2, 0.0)));
        assert!(store.is_empty());
    }

    #[test]
    fn cross_device_round_robins_and_excludes_target() {
        let store = TuneStore::new(4);
        for i in 0..3u32 {
            store.commit(&rec(9, 100, i, (i + 1) as f64 * 1e-3));
            store.commit(&rec(9, 200, 10 + i, (i + 1) as f64 * 1e-3));
        }
        store.commit(&rec(9, 300, 42, 1e-3)); // the "target" device
        let seeds = store.cross_device(9, 300);
        assert_eq!(seeds.len(), 6);
        assert!(seeds.iter().all(|r| r.device != 300));
        // Rank 0 of each source device comes before any rank 1.
        assert_eq!(seeds[0].knobs[0] % 10, 0);
        assert_eq!(seeds[1].knobs[0] % 10, 0);
        assert_eq!(seeds[2].knobs[0] % 10, 1);
        // Unknown workload: empty, not a panic.
        assert!(store.cross_device(0xDEAD, 300).is_empty());
        // workload_records excludes nothing (neighbor-seed retrieval).
        let all = store.workload_records(9);
        assert_eq!(all.len(), 7);
        assert!(all.iter().any(|r| r.device == 300));
        assert!(store.workload_records(0xDEAD).is_empty());
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let store = TuneStore::new(8);
        store.commit(&rec(2, 1, 0, 3e-3));
        store.commit(&rec(1, 2, 1, 2e-3));
        store.commit(&rec(1, 1, 2, 4e-3));
        store.commit(&rec(1, 1, 3, 1e-3));
        let snap = store.snapshot();
        assert_eq!(snap.len(), 4);
        assert_eq!(store.total_records(), 4);
        assert_eq!(store.num_workloads(), 2);
        for w in snap.windows(2) {
            assert!(
                (w[0].workload, w[0].device) <= (w[1].workload, w[1].device),
                "snapshot out of order"
            );
        }
        assert!((snap[0].latency_s - 1e-3).abs() < 1e-15); // (1,1) best first
    }
}
