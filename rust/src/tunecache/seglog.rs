//! Segmented append-log layout for a multi-writer tunecache directory.
//!
//! A cache *directory* holds one `checkpoint.jsonl` (the folded
//! frontier, rewritten atomically by compaction) plus any number of
//! `seg-<pid>-<nonce>.jsonl` segments.  Every writer owns exactly one
//! segment exclusively (`create_new` guarantees no two writers share a
//! file), so appends never interleave across processes and no writer
//! can clobber another's tail.  Readers merge *all* log files through
//! top-k admission on open; nothing here requires cross-process
//! coordination except compaction, which folds dead segments into the
//! checkpoint under an advisory lockfile.
//!
//! Segment lifecycle:
//!
//! * **live** — `seg-<pid>-<nonce>.jsonl`, exclusively appended by the
//!   writer that created it.  Never folded or deleted by anyone else
//!   while the owning pid is alive.
//! * **sealed** — `seg-<pid>-<nonce>.sealed.jsonl`, renamed on clean
//!   close ([`SegmentWriter::close`]).  Foldable by any compactor.
//! * **orphaned** — a live-named segment whose owning pid is dead (the
//!   writer crashed before sealing).  Foldable: its owner can no longer
//!   append.
//!
//! Empty segments are unlinked on clean close so read-mostly sessions
//! do not litter the directory.

use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

/// File name of the folded frontier inside a cache directory.  Sorts
/// before `seg-*` lexicographically and is listed first by
/// [`log_files`] regardless, so merge order is deterministic.
pub const CHECKPOINT: &str = "checkpoint.jsonl";

/// Advisory compaction lockfile name.
pub const LOCK: &str = "compact.lock";

const SEG_PREFIX: &str = "seg-";
const SEG_SUFFIX: &str = ".jsonl";
const SEALED_SUFFIX: &str = ".sealed.jsonl";

/// A lock older than this is presumed leaked even when the holder pid
/// cannot be proven dead (pid liveness is unknowable off-linux, and
/// pids recycle): compaction is short, so ten minutes is generous.
const LOCK_STALE_AFTER: std::time::Duration = std::time::Duration::from_secs(600);

/// Process-global nonce so several caches in one process never race on
/// a segment (or temp-file) name.
static NONCE: AtomicU64 = AtomicU64::new(0);

fn next_nonce() -> u64 {
    NONCE.fetch_add(1, Ordering::Relaxed)
}

/// Durability knob for segment appends.  Compaction always syncs its
/// checkpoint regardless — this only governs the per-record append.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FsyncPolicy {
    /// Appends go through the OS page cache (the pre-segmented-log
    /// behavior): an OS crash can lose the unsynced tail, a mere
    /// process crash cannot.
    #[default]
    Never,
    /// `sync_data` after every appended record: a committed record is
    /// durable when `commit` returns, at the cost of one fsync per
    /// admitted record.
    Always,
}

impl FsyncPolicy {
    /// Parse a CLI-facing policy name.
    pub fn from_name(name: &str) -> Option<FsyncPolicy> {
        match name.to_ascii_lowercase().as_str() {
            "never" | "off" => Some(FsyncPolicy::Never),
            "always" | "on" => Some(FsyncPolicy::Always),
            _ => None,
        }
    }
}

/// Is this file name the checkpoint?
pub fn is_checkpoint(name: &str) -> bool {
    name == CHECKPOINT
}

/// Does this file name denote any log file (checkpoint or segment)
/// that [`log_files`] would merge?
fn is_log_name(name: &str) -> bool {
    is_checkpoint(name) || (name.starts_with(SEG_PREFIX) && name.ends_with(SEG_SUFFIX))
}

/// Was this segment sealed by a clean close (foldable by anyone)?
pub fn is_sealed(name: &str) -> bool {
    name.starts_with(SEG_PREFIX) && name.ends_with(SEALED_SUFFIX)
}

/// The pid embedded in a `seg-<pid>-<nonce>[.sealed].jsonl` name.
pub fn segment_pid(name: &str) -> Option<u32> {
    let rest = name.strip_prefix(SEG_PREFIX)?;
    let (pid, _) = rest.split_once('-')?;
    pid.parse().ok()
}

/// Best-effort pid liveness.  On linux, `/proc/<pid>` existence is
/// authoritative enough for garbage collection (a recycled pid merely
/// delays folding).  Elsewhere we cannot tell, so claim *alive* — the
/// conservative answer: an unfoldable segment is still merged on open,
/// it is only garbage-collected later.
pub fn pid_alive(pid: u32) -> bool {
    #[cfg(target_os = "linux")]
    {
        Path::new(&format!("/proc/{pid}")).exists()
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = pid;
        true
    }
}

/// Every log file of a cache directory in deterministic merge order:
/// the checkpoint first (oldest data — later segments win ties through
/// admission), then segments sorted by file name.
pub fn log_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut segments = Vec::new();
    let mut checkpoint = None;
    let rd = std::fs::read_dir(dir).with_context(|| format!("listing {dir:?}"))?;
    for entry in rd {
        let entry = entry.with_context(|| format!("listing {dir:?}"))?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if is_checkpoint(name) {
            checkpoint = Some(entry.path());
        } else if is_log_name(name) {
            segments.push(entry.path());
        }
    }
    segments.sort();
    let mut files = Vec::with_capacity(segments.len() + 1);
    files.extend(checkpoint);
    files.extend(segments);
    Ok(files)
}

/// A unique sibling temp name for atomically rewriting `path`:
/// `<name>.tmp-<pid>-<nonce>`.  Unique per process (pid) and per call
/// (nonce), so concurrent compactors can never clobber each other's
/// in-flight temp file; a crash strands at most one orphan, which
/// [`sweep_orphan_tmps`] removes once its owner is dead.  The name
/// matches neither the checkpoint nor the segment pattern, so readers
/// never merge a half-written temp.
pub fn unique_tmp(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_default();
    path.with_file_name(format!(
        "{name}.tmp-{}-{}",
        // detlint: allow(ambient) -- the owner pid in the temp name is the durability design
        std::process::id(),
        next_nonce()
    ))
}

/// The owning pid of a `*.tmp-<pid>-<nonce>` orphan, if the name is one.
fn tmp_pid(name: &str) -> Option<u32> {
    let (_, rest) = name.rsplit_once(".tmp-")?;
    let (pid, _) = rest.split_once('-')?;
    pid.parse().ok()
}

/// Remove temp files stranded by crashed compactors (owner pid dead).
/// Best-effort: a vanished or unremovable file is someone else's
/// progress, not an error.
pub fn sweep_orphan_tmps(dir: &Path) {
    let Ok(rd) = std::fs::read_dir(dir) else { return };
    for entry in rd.flatten() {
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(pid) = tmp_pid(name) {
            if !pid_alive(pid) {
                let _ = std::fs::remove_file(entry.path());
            }
        }
    }
}

/// Flush directory metadata (creations, renames, unlinks) to disk.  On
/// non-unix platforms directories cannot be opened for syncing; the
/// call degrades to a no-op there.
pub fn fsync_dir(dir: &Path) -> std::io::Result<()> {
    #[cfg(unix)]
    {
        File::open(dir)?.sync_all()
    }
    #[cfg(not(unix))]
    {
        let _ = dir;
        Ok(())
    }
}

/// One writer's exclusively-owned append handle onto its segment.
pub struct SegmentWriter {
    dir: PathBuf,
    path: PathBuf,
    file: File,
    /// Whether any append has landed — an untouched segment is simply
    /// unlinked on close instead of sealed.
    wrote: bool,
}

impl SegmentWriter {
    /// Create a fresh exclusively-owned segment in `dir`.  `create_new`
    /// makes ownership unambiguous even across pid recycling: a
    /// leftover same-named file just pushes us to the next nonce.
    pub fn create(dir: &Path) -> Result<SegmentWriter> {
        // detlint: allow(ambient) -- segment names embed the owner pid (exclusive-writer design)
        let pid = std::process::id();
        for _ in 0..1024 {
            let path = dir.join(format!("{SEG_PREFIX}{pid}-{}{SEG_SUFFIX}", next_nonce()));
            match OpenOptions::new().append(true).create_new(true).open(&path) {
                Ok(file) => {
                    return Ok(SegmentWriter {
                        dir: dir.to_path_buf(),
                        path,
                        file,
                        wrote: false,
                    })
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => continue,
                Err(e) => {
                    return Err(e).with_context(|| format!("creating segment {path:?}"))
                }
            }
        }
        anyhow::bail!("could not allocate a unique segment name under {dir:?}")
    }

    /// The segment this writer owns.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one encoded line.  On an I/O error the handle is reopened
    /// and the write retried once; the retry leads with a newline so a
    /// torn first attempt is terminated into a skippable partial line
    /// instead of corrupting the retried record.
    pub fn append(&mut self, line: &str, fsync: FsyncPolicy) -> std::io::Result<()> {
        if let Err(first) = self.try_append(line, false, fsync) {
            self.reopen().map_err(|_| first)?;
            self.try_append(line, true, fsync)?;
        }
        self.wrote = true;
        Ok(())
    }

    fn try_append(
        &mut self,
        line: &str,
        lead_newline: bool,
        fsync: FsyncPolicy,
    ) -> std::io::Result<()> {
        if lead_newline {
            self.file.write_all(b"\n")?;
        }
        self.file.write_all(line.as_bytes())?;
        self.file.write_all(b"\n")?;
        if fsync == FsyncPolicy::Always {
            self.file.sync_data()?;
        }
        Ok(())
    }

    fn reopen(&mut self) -> std::io::Result<()> {
        self.file = OpenOptions::new().create(true).append(true).open(&self.path)?;
        Ok(())
    }

    /// Swap in a fresh segment (compaction rotates *before* folding so
    /// concurrent commits land in the new segment) and return the
    /// retired segment's path for the caller to fold away.
    pub fn rotate(&mut self) -> Result<PathBuf> {
        let fresh = SegmentWriter::create(&self.dir)?;
        let old = std::mem::replace(self, fresh);
        Ok(old.path)
    }

    /// Clean close: unlink an untouched segment, otherwise seal it
    /// (rename to `*.sealed.jsonl`) so compactors may fold it without
    /// waiting for this pid to die.  Best-effort — an unsealed segment
    /// is still correct, it just garbage-collects later.
    pub fn close(&mut self) {
        if !self.wrote {
            let _ = std::fs::remove_file(&self.path);
            return;
        }
        let _ = self.file.flush();
        if let Some(name) = self.path.file_name().and_then(|n| n.to_str()) {
            if let Some(stem) = name.strip_suffix(SEG_SUFFIX) {
                let sealed = self.path.with_file_name(format!("{stem}{SEALED_SUFFIX}"));
                let _ = std::fs::rename(&self.path, &sealed);
            }
        }
    }
}

/// RAII advisory compaction lock: a `compact.lock` file created with
/// `create_new`, holding the owner's pid.  Dropped (best-effort
/// unlinked) when the guard goes out of scope — including on unwind,
/// so a failed compaction never wedges the directory.
pub struct CompactLock {
    path: PathBuf,
}

impl Drop for CompactLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

/// Try to take the advisory compaction lock.  `Ok(None)` means another
/// live compactor holds it — callers skip compaction rather than wait,
/// because compaction is an optimization, never required for
/// correctness.  A stale lock (holder pid dead, or untouched for over
/// ten minutes) is broken and the acquisition retried once.
pub fn try_lock(dir: &Path) -> Result<Option<CompactLock>> {
    let path = dir.join(LOCK);
    for attempt in 0..2 {
        match OpenOptions::new().write(true).create_new(true).open(&path) {
            Ok(mut f) => {
                // detlint: allow(ambient) -- the lock records its holder pid for dead-holder stealing
                let _ = writeln!(f, "{}", std::process::id());
                return Ok(Some(CompactLock { path }));
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                if attempt == 0 && lock_is_stale(&path) {
                    let _ = std::fs::remove_file(&path);
                    continue;
                }
                return Ok(None);
            }
            Err(e) => return Err(e).with_context(|| format!("creating {path:?}")),
        }
    }
    Ok(None)
}

/// A lock is stale when its recorded holder pid is provably dead, or —
/// failing that (unparseable, or liveness unknowable) — when the file
/// has sat untouched far longer than any compaction runs.
fn lock_is_stale(path: &Path) -> bool {
    if let Ok(contents) = std::fs::read_to_string(path) {
        if let Ok(pid) = contents.trim().parse::<u32>() {
            if cfg!(target_os = "linux") {
                return !pid_alive(pid);
            }
        }
    }
    match std::fs::metadata(path).and_then(|m| m.modified()) {
        Ok(mtime) => match mtime.elapsed() {
            Ok(age) => age > LOCK_STALE_AFTER,
            Err(_) => false,
        },
        Err(_) => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("moses_seglog_test").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn names_parse_and_filter() {
        assert!(is_checkpoint("checkpoint.jsonl"));
        assert!(!is_checkpoint("seg-1-2.jsonl"));
        assert_eq!(segment_pid("seg-1234-7.jsonl"), Some(1234));
        assert_eq!(segment_pid("seg-1234-7.sealed.jsonl"), Some(1234));
        assert!(is_sealed("seg-1234-7.sealed.jsonl"));
        assert!(!is_sealed("seg-1234-7.jsonl"));
        assert_eq!(segment_pid("checkpoint.jsonl"), None);
        assert_eq!(tmp_pid("checkpoint.jsonl.tmp-99-3"), Some(99));
        assert_eq!(tmp_pid("seg-1-2.jsonl"), None);
        // Temp files match no log pattern: readers never merge them.
        assert!(!is_log_name("checkpoint.jsonl.tmp-99-3"));
        assert!(is_log_name("seg-1-2.sealed.jsonl"));
    }

    #[test]
    fn log_files_lists_checkpoint_first_then_sorted_segments() {
        let dir = tmp_dir("order");
        for name in ["seg-2-0.jsonl", "checkpoint.jsonl", "seg-1-0.sealed.jsonl", "junk.txt"] {
            std::fs::write(dir.join(name), "").unwrap();
        }
        let files: Vec<String> = log_files(&dir)
            .unwrap()
            .iter()
            .map(|p| p.file_name().unwrap().to_string_lossy().into_owned())
            .collect();
        assert_eq!(files, ["checkpoint.jsonl", "seg-1-0.sealed.jsonl", "seg-2-0.jsonl"]);
    }

    #[test]
    fn writers_own_distinct_segments_and_seal_on_close() {
        let dir = tmp_dir("writers");
        let mut a = SegmentWriter::create(&dir).unwrap();
        let mut b = SegmentWriter::create(&dir).unwrap();
        assert_ne!(a.path(), b.path());
        a.append("line-a", FsyncPolicy::Never).unwrap();
        a.close();
        b.close();
        // a sealed (it wrote), b unlinked (it did not).
        let files = log_files(&dir).unwrap();
        assert_eq!(files.len(), 1);
        assert!(is_sealed(files[0].file_name().unwrap().to_str().unwrap()));
        assert_eq!(std::fs::read_to_string(&files[0]).unwrap(), "line-a\n");
    }

    #[test]
    fn lock_excludes_and_releases() {
        let dir = tmp_dir("lock");
        let lock = try_lock(&dir).unwrap().expect("first lock");
        // Held by a live pid (ours): second acquisition must back off.
        assert!(try_lock(&dir).unwrap().is_none());
        drop(lock);
        assert!(try_lock(&dir).unwrap().is_some(), "released on drop");
    }

    #[test]
    fn stale_lock_from_dead_pid_is_broken() {
        if !cfg!(target_os = "linux") {
            return; // pid liveness unknowable; covered by the age path
        }
        let dir = tmp_dir("stale-lock");
        // No pid on this box plausibly has this id (pid_max caps well
        // below u32::MAX).
        std::fs::write(dir.join(LOCK), format!("{}\n", u32::MAX)).unwrap();
        let lock = try_lock(&dir).unwrap();
        assert!(lock.is_some(), "dead holder's lock must be stolen");
    }
}
