//! JSONL persistence for tuning records.
//!
//! One JSON object per line, append-on-commit: a crash loses at most
//! the final partial line, which the tolerant loader skips.  Repeated
//! runs append duplicate and later-evicted lines; [`super::TuneCache`]
//! compacts back to the live top-k frontier once the append debt
//! grows.  The line format is shared by legacy single-file logs and
//! the segment/checkpoint files of a [`super::seglog`] cache
//! directory — [`load_log`] reads either.  Hashes are hex *strings*
//! because the JSON number model (f64) cannot carry a full 64-bit
//! value.

use std::io::{BufRead, BufReader, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::program::{Subgraph, SubgraphKind, DESC_DIM};
use crate::util::json::Json;

use super::store::TuneRecord;

/// Schema version stamped on every line (v2 added `desc`/`version`;
/// v3 added the optional `task_*` payload for dataset export).
const VERSION: f64 = 3.0;

/// Encode one record as a single JSONL line (no trailing newline).
pub fn encode_line(r: &TuneRecord) -> String {
    let mut fields = vec![
        ("v", Json::Num(VERSION)),
        ("workload", Json::Str(format!("{:016x}", r.workload))),
        ("device", Json::Str(format!("{:016x}", r.device))),
        ("device_name", Json::Str(r.device_name.clone())),
        ("knobs", Json::Arr(r.knobs.iter().map(|&k| Json::Num(k as f64)).collect())),
        ("latency_s", Json::Num(r.latency_s)),
        ("gflops", Json::Num(r.gflops)),
        ("trials", Json::Num(r.trials as f64)),
        ("desc", Json::Arr(r.desc.iter().map(|&d| Json::Num(d)).collect())),
        ("version", Json::Num(r.version as f64)),
    ];
    if let Some(task) = &r.task {
        let (tag, params) = task.kind.encode_tagged();
        fields.push(("task_kind", Json::Num(tag as f64)));
        fields.push((
            "task_shape",
            Json::Arr(params.iter().map(|&p| Json::Num(p as f64)).collect()),
        ));
        fields.push(("task_name", Json::Str(task.name.clone())));
        fields.push(("task_repeats", Json::Num(task.repeats as f64)));
    }
    Json::obj(fields).to_string()
}

/// Decode the optional v3 task payload.  Absent or corrupt payloads
/// yield `None` — the record is still usable for warm starts, it just
/// cannot be exported as a dataset row.
fn decode_task(v: &Json) -> Option<Subgraph> {
    let tag = v.get("task_kind")?.as_f64()? as u8;
    let arr = v.get("task_shape").and_then(Json::as_arr)?;
    let mut params = Vec::with_capacity(arr.len());
    for j in arr {
        params.push(j.as_f64()? as u32);
    }
    let kind = SubgraphKind::decode_tagged(tag, &params)?;
    let name = v.get("task_name").and_then(Json::as_str).unwrap_or("tunecache.task");
    let repeats = v.get("task_repeats").and_then(Json::as_usize).unwrap_or(1).max(1);
    let mut task = Subgraph::new(name, kind);
    task.repeats = repeats;
    Some(task)
}

/// Decode one JSONL line.
pub fn decode_line(line: &str) -> Result<TuneRecord> {
    let v = Json::parse(line).context("parsing tunecache line")?;
    let hex = |k: &str| -> Result<u64> {
        let s = v
            .get(k)
            .and_then(Json::as_str)
            .with_context(|| format!("missing hex field '{k}'"))?;
        u64::from_str_radix(s, 16).with_context(|| format!("field '{k}' is not hex"))
    };
    let num = |k: &str| -> Result<f64> {
        v.get(k)
            .and_then(Json::as_f64)
            .with_context(|| format!("missing numeric field '{k}'"))
    };
    let knobs_arr = v.get("knobs").and_then(Json::as_arr).context("missing 'knobs'")?;
    anyhow::ensure!(knobs_arr.len() == 9, "expected 9 knobs, got {}", knobs_arr.len());
    let mut knobs = [0u32; 9];
    for (slot, j) in knobs.iter_mut().zip(knobs_arr) {
        *slot = j.as_f64().context("knob is not a number")? as u32;
    }
    let latency_s = num("latency_s")?;
    // Sanity bounds: launch overhead alone is microseconds, and no
    // simulated kernel runs for hours.  A bit-flipped but still-valid
    // JSON line must not become an undisplaceable per-key best (nor,
    // via an absurd `trials`, satisfy every future hit test).
    anyhow::ensure!(
        (1e-9..=1e4).contains(&latency_s),
        "implausible latency_s {latency_s}"
    );
    let trials = v.get("trials").and_then(Json::as_usize).unwrap_or(0);
    anyhow::ensure!(trials <= 1_000_000, "implausible trials {trials}");
    // `desc`/`version` are absent in pre-v2 lines: version 0 means
    // "unknown featurizer", which the load path drops as stale.  The
    // two travel together — a line with a version but no descriptor
    // (truncated/hand-edited) is downgraded to 0 too, so an all-zero
    // descriptor can never enter the nearest-neighbor index.
    let mut desc = [0.0f64; DESC_DIM];
    let mut has_desc = false;
    if let Some(arr) = v.get("desc").and_then(Json::as_arr) {
        anyhow::ensure!(arr.len() == DESC_DIM, "expected {DESC_DIM}-d desc, got {}", arr.len());
        for (slot, j) in desc.iter_mut().zip(arr) {
            *slot = j.as_f64().context("desc entry is not a number")?;
            anyhow::ensure!(slot.is_finite(), "non-finite desc entry");
        }
        has_desc = true;
    }
    let version = if has_desc {
        v.get("version").and_then(Json::as_usize).unwrap_or(0) as u32
    } else {
        0
    };
    Ok(TuneRecord {
        workload: hex("workload")?,
        device: hex("device")?,
        device_name: v
            .get("device_name")
            .and_then(Json::as_str)
            .unwrap_or("?")
            .to_string(),
        knobs,
        latency_s,
        gflops: num("gflops")?,
        // `trials` is absent in pre-trials log lines: 0 means "budget
        // unknown", which never satisfies a hit test.
        trials,
        desc,
        version,
        task: decode_task(&v),
    })
}

/// Load every parseable record from a JSONL file.  Malformed lines are
/// skipped and counted — an interrupted append must not poison the
/// whole store.
pub fn load_records(path: &Path) -> Result<(Vec<TuneRecord>, usize)> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut records = Vec::new();
    let mut skipped = 0usize;
    for line in BufReader::new(file).lines() {
        let line = line.with_context(|| format!("reading {path:?}"))?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        match decode_line(trimmed) {
            Ok(r) => records.push(r),
            Err(_) => skipped += 1,
        }
    }
    Ok((records, skipped))
}

/// Like [`load_records`], but a file that vanished between listing and
/// opening reads as `None`: a concurrent compactor may fold a dead
/// segment away mid-merge, and its records are then in the checkpoint.
pub fn load_records_opt(path: &Path) -> Result<Option<(Vec<TuneRecord>, usize)>> {
    if !path.exists() {
        return Ok(None);
    }
    match load_records(path) {
        Ok(out) => Ok(Some(out)),
        Err(e)
            if e.downcast_ref::<std::io::Error>()
                .is_some_and(|io| io.kind() == std::io::ErrorKind::NotFound) =>
        {
            Ok(None)
        }
        Err(e) => Err(e),
    }
}

/// Load every parseable record from a tuning log — a legacy single-file
/// JSONL log *or* a segmented cache directory (checkpoint plus all
/// segments, in [`super::seglog::log_files`] order).  Returns records
/// and the malformed-line count.  Duplicates and evicted lines are
/// returned as-is; callers wanting the frontier run them through
/// admission.
pub fn load_log(path: &Path) -> Result<(Vec<TuneRecord>, usize)> {
    if !path.is_dir() {
        return load_records(path);
    }
    let mut records = Vec::new();
    let mut skipped = 0usize;
    for file in super::seglog::log_files(path)? {
        if let Some((mut r, s)) = load_records_opt(&file)? {
            records.append(&mut r);
            skipped += s;
        }
    }
    Ok((records, skipped))
}

/// Atomically rewrite `path` to exactly `records` (compaction): write a
/// uniquely-named sibling temp file, fsync it, rename it over the
/// original, then fsync the parent directory so the rename itself is
/// durable.  The unique temp name (pid + nonce) keeps concurrent
/// compactors from clobbering each other's in-flight temp; a crash
/// strands at most an orphaned `*.tmp-*` sibling that no reader ever
/// merges.
pub fn rewrite(path: &Path, records: &[TuneRecord]) -> Result<()> {
    let tmp = super::seglog::unique_tmp(path);
    let file = std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
    let mut w = std::io::BufWriter::new(file);
    for r in records {
        writeln!(w, "{}", encode_line(r))?;
    }
    w.flush()?;
    // Rename-before-sync can surface as an *empty* log after a power
    // loss: the rename's metadata may land while the data does not.
    // Force the contents down first; only then is the rename an atomic
    // old-or-new switch.
    w.get_ref().sync_all().with_context(|| format!("syncing {tmp:?}"))?;
    drop(w);
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            super::seglog::fsync_dir(parent)
                .with_context(|| format!("syncing directory {parent:?}"))?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::program::{Subgraph, SubgraphKind};
    use crate::tunecache::RECORD_VERSION;

    fn sample() -> TuneRecord {
        TuneRecord {
            // Deliberately above 2^53: must survive the f64 number model.
            workload: 0xFEDC_BA98_7654_3210,
            device: 0x0123_4567_89AB_CDEF,
            device_name: "rtx2060".into(),
            knobs: [32, 2, 8, 4, 8, 1, 0, 0, 0],
            latency_s: 1.25e-3,
            gflops: 812.5,
            trials: 200,
            // A real descriptor, so the roundtrip exercises non-trivial
            // f64 shortest-representation printing.
            desc: Subgraph::new(
                "s",
                SubgraphKind::Conv2d {
                    n: 1, h: 28, w: 28, cin: 64, cout: 64, kh: 3, kw: 3, stride: 1, pad: 1,
                },
            )
            .descriptor(),
            version: RECORD_VERSION,
            task: None,
        }
    }

    #[test]
    fn line_roundtrip_preserves_full_u64_hashes() {
        let r = sample();
        let line = encode_line(&r);
        assert!(!line.contains('\n'));
        let back = decode_line(&line).unwrap();
        assert_eq!(back, r);
    }

    #[test]
    fn decode_rejects_malformed() {
        assert!(decode_line("not json").is_err());
        assert!(decode_line("{}").is_err());
        // Wrong knob count.
        let mut r = sample();
        r.device_name = "x".into();
        let bad = encode_line(&r).replace("[32,2,8,4,8,1,0,0,0]", "[1,2]");
        assert!(decode_line(&bad).is_err());
        // Implausible values (a corrupt-but-parseable line) are refused
        // rather than becoming an undisplaceable cache entry.
        let tiny = encode_line(&sample()).replace("0.00125", "1e-30");
        assert!(decode_line(&tiny).is_err());
        let huge_trials = encode_line(&sample()).replace("\"trials\":200", "\"trials\":4000000000");
        assert!(decode_line(&huge_trials).is_err());
    }

    #[test]
    fn decode_tolerates_pre_trials_lines() {
        // A line written before the `trials` field existed loads with
        // budget 0 ("unknown"), which never satisfies a hit test.
        let old = encode_line(&sample()).replace(",\"trials\":200", "");
        let r = decode_line(&old).unwrap();
        assert_eq!(r.trials, 0);
        assert_eq!(r.knobs, sample().knobs);
    }

    #[test]
    fn decode_tolerates_pre_descriptor_lines() {
        // A pre-v2 line (no desc, no version) still decodes — version 0
        // marks it stale so the load path can drop it, rather than the
        // whole log being refused.
        // "desc" sorts first in the object, so strip `"desc":[...],`.
        let mut line = encode_line(&sample());
        let start = line.find("\"desc\":[").unwrap();
        let end = line[start..].find("],").unwrap() + start + 2;
        line.replace_range(start..end, "");
        let line = line.replace(&format!(",\"version\":{RECORD_VERSION}"), "");
        let r = decode_line(&line).unwrap();
        assert_eq!(r.version, 0);
        assert_eq!(r.desc, [0.0; DESC_DIM]);
        assert_eq!(r.knobs, sample().knobs);
        // A line that kept its version but LOST the descriptor must be
        // downgraded to stale too, never indexed at the origin.
        let mut no_desc = encode_line(&sample());
        let ds = no_desc.find("\"desc\":[").unwrap();
        let de = no_desc[ds..].find("],").unwrap() + ds + 2;
        no_desc.replace_range(ds..de, "");
        let r = decode_line(&no_desc).unwrap();
        assert_eq!(r.version, 0, "version without desc must read as stale");
        // A mutilated desc (wrong arity) is corrupt, not tolerable.
        let mut short = encode_line(&sample());
        let s = short.find("\"desc\":[").unwrap() + "\"desc\":[".len();
        let e = short[s..].find(']').unwrap() + s;
        short.replace_range(s..e, "1,2");
        assert!(decode_line(&short).is_err());
    }

    #[test]
    fn task_payload_roundtrips_and_tolerates_corruption() {
        let task = Subgraph::new(
            "rn.conv",
            SubgraphKind::Conv2d {
                n: 1, h: 14, w: 14, cin: 32, cout: 64, kh: 3, kw: 3, stride: 1, pad: 1,
            },
        )
        .with_repeats(2);
        let r = sample().with_task(&task);
        let line = encode_line(&r);
        let back = decode_line(&line).unwrap();
        assert_eq!(back.task.as_ref(), Some(&task));
        assert_eq!(back, r);
        // A corrupt task payload downgrades to None — the record stays
        // usable for warm starts, it just cannot be exported.
        let bad = line.replace("\"task_kind\":0", "\"task_kind\":99");
        let b = decode_line(&bad).unwrap();
        assert!(b.task.is_none());
        assert_eq!(b.knobs, r.knobs);
        // Pre-v3 lines (no task fields) keep decoding with task: None.
        assert!(decode_line(&encode_line(&sample())).unwrap().task.is_none());
    }

    #[test]
    fn file_roundtrip_and_tolerant_load() {
        let dir = std::env::temp_dir().join("moses_tunecache_persist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("log.jsonl");
        let records = vec![sample(), {
            let mut r = sample();
            r.knobs[0] = 64;
            r.latency_s = 2e-3;
            r
        }];
        rewrite(&path, &records).unwrap();
        let (back, skipped) = load_records(&path).unwrap();
        assert_eq!(back, records);
        assert_eq!(skipped, 0);

        // Append garbage (simulating a torn write) — loader skips it.
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            writeln!(f, "{{\"workload\": trunca").unwrap();
        }
        let (back2, skipped2) = load_records(&path).unwrap();
        assert_eq!(back2, records);
        assert_eq!(skipped2, 1);
    }

    #[test]
    fn rewrite_uses_unique_temp_names_and_cleans_up() {
        let dir = std::env::temp_dir().join("moses_tunecache_rewrite_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("log.jsonl");
        rewrite(&path, &[sample()]).unwrap();
        rewrite(&path, &[sample()]).unwrap();
        // No temp droppings survive a successful rewrite, and the
        // temp name is not the old fixed `.tmp` that two processes
        // could collide on.
        let names: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, ["log.jsonl"]);
        let (back, skipped) = load_records(&path).unwrap();
        assert_eq!(back, vec![sample()]);
        assert_eq!(skipped, 0);
    }
}
