//! Cross-device and cross-workload warm start: schedule-level transfer
//! complementing the paper's parameter-level transfer.
//!
//! On an exact (workload, device) hit the tuner can skip search
//! entirely.  On a miss the plan falls back through two seed tiers:
//!
//! 1. records for the *same workload on other devices* — good-schedule
//!    structure (tiling shapes, vectorization, staging) transfers
//!    across GPUs even where absolute latencies do not, exactly the
//!    Eq. 3 decomposition the cost-model transfer relies on;
//! 2. records for *similar workloads* on any device, retrieved from the
//!    feature-space index ([`super::index`]) within a configurable
//!    radius, their schedules remapped onto the new geometry
//!    ([`crate::program::Schedule::remap_for`]) — so a genuinely new
//!    shape still starts from a neighbor's solution instead of random.

use crate::device::DeviceArch;
use crate::program::{Schedule, Subgraph};

use super::index::{DEFAULT_NN_K, DEFAULT_NN_RADIUS};
use super::key::WorkloadKey;
use super::store::TuneRecord;
use super::TuneCache;

/// One warm-start seed candidate.
#[derive(Debug, Clone)]
pub struct SeedRecord {
    pub schedule: Schedule,
    /// Device the record was measured on.
    pub source_device: String,
    /// Latency on the *source* device — not comparable across devices,
    /// meaningful only for per-device ranking.
    pub source_latency_s: f64,
    /// Descriptor-space distance of the source workload (0.0 for the
    /// same workload; positive for nearest-neighbor seeds).
    pub distance: f64,
}

/// How a warm-start query is scoped.
#[derive(Debug, Clone, Copy)]
pub struct WarmStartOptions {
    /// Cap on seeds offered across both tiers.
    pub max_seeds: usize,
    /// Trial budget of the requesting session: a hit requires records
    /// searched at this budget or more.
    pub requested_trials: usize,
    /// Neighbor workloads consulted per query (k in kNN).
    pub nn_k: usize,
    /// Normalized-L2 retrieval radius; `None` disables the
    /// nearest-neighbor tier entirely.
    pub nn_radius: Option<f64>,
}

impl WarmStartOptions {
    pub fn new(max_seeds: usize, requested_trials: usize) -> WarmStartOptions {
        WarmStartOptions {
            max_seeds,
            requested_trials,
            nn_k: DEFAULT_NN_K,
            nn_radius: Some(DEFAULT_NN_RADIUS),
        }
    }
}

/// What the cache knows about one (task, target device) pair.
#[derive(Debug, Clone, Default)]
pub struct WarmStartPlan {
    /// Best record measured on the target device itself — `Some` ONLY
    /// when the cached search budget satisfies the requested one, i.e.
    /// the tuner may short-circuit with zero measured trials.
    pub exact: Option<TuneRecord>,
    /// Largest trial budget any cached record of this (workload, device)
    /// was produced under (0 = never searched here).
    pub searched_trials: usize,
    /// This device's own cached schedules, best-first — re-seeds for a
    /// bigger-budget search (their true latencies are already known, so
    /// the tuner grounds on them without spending measurements).
    pub local_seeds: Vec<Schedule>,
    /// Same-workload cross-device seeds: best-first round-robin across
    /// source devices, deduplicated, validated against the task
    /// geometry, capped.
    pub seeds: Vec<SeedRecord>,
    /// Similar-workload seeds (nearest-neighbor tier): closest workload
    /// first, schedules remapped onto this task's geometry, filling
    /// whatever seed budget the cross-device tier left.
    pub neighbor_seeds: Vec<SeedRecord>,
}

/// Query the cache for a task on a target device, recording
/// hit/miss/seed counters.
///
/// A hit requires records searched at `opts.requested_trials` or more:
/// a cheap earlier run must not silently satisfy a bigger requested
/// search (and a tiny-budget default-only result must not poison the
/// workload forever).
pub fn plan(
    cache: &TuneCache,
    task: &Subgraph,
    target: &DeviceArch,
    opts: &WarmStartOptions,
) -> WarmStartPlan {
    let key = WorkloadKey::new(task, target);
    let geometry = task.geometry();
    // Drop records whose knobs don't decode to a valid schedule for
    // this geometry (corrupt log lines): they must neither satisfy the
    // hit test nor silently suppress the seed lists.
    let local: Vec<TuneRecord> = cache
        .records(&key)
        .into_iter()
        .filter(|r| r.schedule().is_valid(&geometry))
        .collect();
    let searched_trials = local.iter().map(|r| r.trials).max().unwrap_or(0);
    if !local.is_empty() && searched_trials >= opts.requested_trials {
        cache.counters().record_hit();
        return WarmStartPlan {
            exact: local.first().cloned(),
            searched_trials,
            ..WarmStartPlan::default()
        };
    }
    cache.counters().record_miss();

    let local_seeds: Vec<Schedule> = local.iter().map(|r| r.schedule()).collect();
    let mut seeds = Vec::new();
    // Don't re-offer schedules this device already has records for.
    let mut seen: Vec<[u32; 9]> = local.iter().map(|r| r.knobs).collect();
    for rec in cache.cross_device(key.workload, key.device) {
        if seeds.len() >= opts.max_seeds {
            break;
        }
        if seen.contains(&rec.knobs) {
            continue;
        }
        let schedule = rec.schedule();
        if !schedule.is_valid(&geometry) {
            continue;
        }
        seen.push(rec.knobs);
        seeds.push(SeedRecord {
            schedule,
            source_device: rec.device_name.clone(),
            source_latency_s: rec.latency_s,
            distance: 0.0,
        });
    }
    cache.counters().record_seeds(seeds.len());

    // Nearest-neighbor tier: fill the remaining seed budget from
    // similar workloads' records.  Schedules are remapped onto this
    // task's geometry and re-validated; even the target device's own
    // records count here (a similar workload tuned on this very device
    // is the best neighbor there is).
    //
    // Candidates are ordered by a DISTANCE-WEIGHTED rank rather than
    // exhausting the closest workload first: weight = (1 + rank within
    // the source workload's best-first records) × (1 + distance /
    // radius).  The tuner probes seeds in list order, so the best
    // record of a slightly-farther neighbor outranks the k-th-best
    // record of the closest one — descriptor distance discounts
    // source-side quality instead of gating it.
    let mut neighbor_seeds = Vec::new();
    // Skip the index scan entirely when the cross-device tier already
    // filled the budget — this runs on the check-before-search hot path.
    if let Some(radius) = opts.nn_radius.filter(|_| seeds.len() < opts.max_seeds) {
        let desc = task.descriptor();
        // Weigh first, materialize later: the sort key needs only
        // (weight, distance), so the expensive per-candidate work —
        // schedule remap + validation + the SeedRecord's String clone —
        // is deferred to the selection loop below, which stops as soon
        // as the seed budget fills (this runs on the
        // check-before-search hot path).
        let remaining = opts.max_seeds - seeds.len();
        let mut candidates: Vec<(f64, f64, TuneRecord)> = Vec::new();
        for (workload, dist) in cache.neighbors(&desc, opts.nn_k, radius, key.workload) {
            let penalty = 1.0 + if radius > 0.0 { dist / radius } else { 0.0 };
            // Per workload only the first `remaining` records can ever
            // fill the budget (ranks beyond it lose to every earlier
            // same-source rank), so the gather is bounded by
            // nn_k × remaining, not the store's full record lists.
            for (rank, rec) in
                cache.workload_records(workload).into_iter().take(remaining).enumerate()
            {
                let weight = (1.0 + rank as f64) * penalty;
                candidates.push((weight, dist, rec));
            }
        }
        // Stable sort on the weight (distance tiebreak): equal-weight
        // candidates keep the deterministic closest-first order the
        // index query produced.
        candidates.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
        for (_, dist, rec) in candidates {
            if seeds.len() + neighbor_seeds.len() >= opts.max_seeds {
                break;
            }
            let schedule = rec.schedule().remap_for(&geometry);
            if !schedule.is_valid(&geometry) {
                continue;
            }
            let knobs = schedule.encode();
            if seen.contains(&knobs) {
                continue;
            }
            seen.push(knobs);
            neighbor_seeds.push(SeedRecord {
                schedule,
                source_latency_s: rec.latency_s,
                source_device: rec.device_name,
                distance: dist,
            });
        }
        cache.counters().record_neighbor_seeds(neighbor_seeds.len());
    }
    WarmStartPlan { exact: None, searched_trials, local_seeds, seeds, neighbor_seeds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::program::{SpaceGenerator, SubgraphKind};
    use crate::util::rng::Rng;

    fn task() -> Subgraph {
        conv_task("ws.conv", 64)
    }

    fn conv_task(name: &str, cout: usize) -> Subgraph {
        Subgraph::new(
            name,
            SubgraphKind::Conv2d {
                n: 1, h: 28, w: 28, cin: 64, cout, kh: 3, kw: 3, stride: 1, pad: 1,
            },
        )
    }

    fn populate_task(
        cache: &TuneCache,
        t: &Subgraph,
        arch: &DeviceArch,
        n: usize,
        seed: u64,
        trials: usize,
    ) {
        let key = WorkloadKey::new(t, arch);
        let gen = SpaceGenerator::new(t.geometry());
        let mut rng = Rng::new(seed);
        for (i, s) in gen.sample_distinct(&mut rng, n).iter().enumerate() {
            cache.commit(TuneRecord::new(
                key,
                t.descriptor(),
                &arch.name,
                s,
                (i + 1) as f64 * 1e-3,
                1.0,
                trials,
            ));
        }
    }

    fn populate(cache: &TuneCache, arch: &DeviceArch, n: usize, seed: u64, trials: usize) {
        populate_task(cache, &task(), arch, n, seed, trials);
    }

    fn opts(max_seeds: usize, requested_trials: usize) -> WarmStartOptions {
        WarmStartOptions::new(max_seeds, requested_trials)
    }

    #[test]
    fn miss_yields_cross_device_seeds() {
        let cache = TuneCache::in_memory(8);
        populate(&cache, &presets::rtx_2060(), 5, 1, 64);
        populate(&cache, &presets::tesla_k80(), 5, 2, 64);

        let p = plan(&cache, &task(), &presets::jetson_tx2(), &opts(6, 64));
        assert!(p.exact.is_none());
        assert_eq!(p.searched_trials, 0);
        assert!(p.local_seeds.is_empty());
        // Up to 6 seeds; identical schedules sampled on both devices
        // dedup, so allow a small shortfall.
        assert!(p.seeds.len() >= 5, "expected >=5 seeds, got {}", p.seeds.len());
        assert!(p.seeds.iter().all(|s| s.distance == 0.0));
        // Both source devices contribute (round-robin).
        assert!(p.seeds.iter().any(|s| s.source_device == "rtx2060"));
        assert!(p.seeds.iter().any(|s| s.source_device == "k80"));
        let stats = cache.stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.cross_device_seeds, p.seeds.len());
    }

    #[test]
    fn exact_hit_short_circuits_seeding() {
        let cache = TuneCache::in_memory(8);
        populate(&cache, &presets::jetson_tx2(), 3, 3, 64);
        populate(&cache, &presets::rtx_2060(), 3, 4, 64);

        let p = plan(&cache, &task(), &presets::jetson_tx2(), &opts(8, 64));
        let exact = p.exact.expect("expected an exact hit");
        assert!((exact.latency_s - 1e-3).abs() < 1e-15);
        assert_eq!(p.searched_trials, 64);
        assert!(p.seeds.is_empty() && p.local_seeds.is_empty());
        assert!(p.neighbor_seeds.is_empty());
        assert_eq!(cache.stats().hits, 1);
    }

    #[test]
    fn bigger_budget_downgrades_hit_to_local_reseed() {
        let cache = TuneCache::in_memory(8);
        populate(&cache, &presets::jetson_tx2(), 3, 5, 16);
        populate(&cache, &presets::rtx_2060(), 3, 6, 16);

        // Requesting more trials than ever searched: no short-circuit,
        // but this device's own records come back as local seeds and the
        // other device's as cross-device seeds.
        let p = plan(&cache, &task(), &presets::jetson_tx2(), &opts(8, 200));
        assert!(p.exact.is_none());
        assert_eq!(p.searched_trials, 16);
        assert_eq!(p.local_seeds.len(), 3);
        assert!(!p.seeds.is_empty());
        assert_eq!(cache.stats().misses, 1);
    }

    #[test]
    fn empty_cache_plans_nothing() {
        let cache = TuneCache::in_memory(8);
        let p = plan(&cache, &task(), &presets::rtx_2060(), &opts(8, 64));
        assert!(p.exact.is_none() && p.seeds.is_empty() && p.local_seeds.is_empty());
        assert!(p.neighbor_seeds.is_empty());
        assert_eq!(cache.stats().misses, 1);
    }

    #[test]
    fn never_seen_workload_gets_neighbor_seeds() {
        let cache = TuneCache::in_memory(8);
        // Cache holds only a *similar* conv (48 output channels).
        let similar = conv_task("ws.similar", 48);
        populate_task(&cache, &similar, &presets::rtx_2060(), 4, 7, 64);

        let novel = task(); // 64 channels — never cached
        let p = plan(&cache, &novel, &presets::rtx_2060(), &opts(8, 64));
        assert!(p.exact.is_none());
        assert!(p.seeds.is_empty(), "no same-workload records exist");
        assert!(!p.neighbor_seeds.is_empty(), "similar workload should seed");
        let g = novel.geometry();
        for s in &p.neighbor_seeds {
            assert!(s.schedule.is_valid(&g));
            assert!(s.distance > 0.0 && s.distance <= DEFAULT_NN_RADIUS);
        }
        assert_eq!(cache.stats().neighbor_seeds, p.neighbor_seeds.len());
    }

    #[test]
    fn nn_tier_respects_disable_and_radius() {
        let cache = TuneCache::in_memory(8);
        populate_task(&cache, &conv_task("ws.similar", 48), &presets::rtx_2060(), 4, 8, 64);

        // Disabled entirely.
        let mut o = opts(8, 64);
        o.nn_radius = None;
        let p = plan(&cache, &task(), &presets::rtx_2060(), &o);
        assert!(p.neighbor_seeds.is_empty());
        // A radius too tight to reach the 48-channel conv.
        let mut o = opts(8, 64);
        o.nn_radius = Some(1e-6);
        let p = plan(&cache, &task(), &presets::rtx_2060(), &o);
        assert!(p.neighbor_seeds.is_empty());
        // A dissimilar workload (dense) is outside the default radius.
        let far = Subgraph::new("ws.far", SubgraphKind::Dense { m: 64, n: 4096, k: 4096 });
        let p = plan(&cache, &far, &presets::rtx_2060(), &opts(8, 64));
        assert!(p.neighbor_seeds.is_empty(), "dense must not borrow conv seeds");
    }

    #[test]
    fn neighbor_probe_order_is_distance_weighted() {
        let cache = TuneCache::in_memory(8);
        // Two similar workloads: a 60-channel conv (close to the
        // 64-channel target) and a 48-channel conv (farther).
        let near = conv_task("ws.near", 60);
        let far = conv_task("ws.far48", 48);
        populate_task(&cache, &near, &presets::rtx_2060(), 6, 11, 64);
        populate_task(&cache, &far, &presets::rtx_2060(), 6, 12, 64);

        let p = plan(&cache, &task(), &presets::jetson_tx2(), &opts(4, 64));
        assert!(p.exact.is_none() && p.seeds.is_empty());
        assert_eq!(p.neighbor_seeds.len(), 4);
        let dmin =
            p.neighbor_seeds.iter().map(|s| s.distance).fold(f64::INFINITY, f64::min);
        assert_eq!(
            p.neighbor_seeds[0].distance, dmin,
            "the closest neighbor's best record is probed first"
        );
        // Distance WEIGHTS rather than gates: the farther workload's
        // best-ranked records outweigh the nearest workload's tail, so
        // both sources land inside the cap (the old closest-first scan
        // spent the whole budget on the nearest workload).
        assert!(
            p.neighbor_seeds.iter().any(|s| s.distance > dmin),
            "farther neighbor's best record must interleave into the probe list: {:?}",
            p.neighbor_seeds.iter().map(|s| s.distance).collect::<Vec<_>>()
        );
    }

    #[test]
    fn cross_device_tier_takes_priority_over_neighbors() {
        let cache = TuneCache::in_memory(8);
        // Same workload on another device AND a similar workload.
        populate(&cache, &presets::rtx_2060(), 3, 9, 64);
        populate_task(&cache, &conv_task("ws.similar", 48), &presets::rtx_2060(), 3, 10, 64);

        let p = plan(&cache, &task(), &presets::jetson_tx2(), &opts(4, 64));
        assert_eq!(p.seeds.len(), 3, "same-workload seeds fill first");
        assert!(p.seeds.len() + p.neighbor_seeds.len() <= 4, "budget shared");
    }
}
