//! Cross-device warm start: schedule-level transfer complementing the
//! paper's parameter-level transfer.
//!
//! On an exact (workload, device) hit the tuner can skip search
//! entirely.  On a miss, records for the *same workload on other
//! devices* become seeds for the evolutionary search's initial
//! population — good-schedule structure (tiling shapes, vectorization,
//! staging) transfers across GPUs even where absolute latencies do
//! not, exactly the Eq. 3 decomposition the cost-model transfer relies
//! on.

use crate::device::DeviceArch;
use crate::program::{Schedule, Subgraph};

use super::key::WorkloadKey;
use super::store::TuneRecord;
use super::TuneCache;

/// One cross-device seed candidate.
#[derive(Debug, Clone)]
pub struct SeedRecord {
    pub schedule: Schedule,
    /// Device the record was measured on.
    pub source_device: String,
    /// Latency on the *source* device — not comparable across devices,
    /// meaningful only for per-device ranking.
    pub source_latency_s: f64,
}

/// What the cache knows about one (task, target device) pair.
#[derive(Debug, Clone, Default)]
pub struct WarmStartPlan {
    /// Best record measured on the target device itself — `Some` ONLY
    /// when the cached search budget satisfies the requested one, i.e.
    /// the tuner may short-circuit with zero measured trials.
    pub exact: Option<TuneRecord>,
    /// Largest trial budget any cached record of this (workload, device)
    /// was produced under (0 = never searched here).
    pub searched_trials: usize,
    /// This device's own cached schedules, best-first — re-seeds for a
    /// bigger-budget search (their true latencies are already known, so
    /// the tuner grounds on them without spending measurements).
    pub local_seeds: Vec<Schedule>,
    /// Cross-device seeds: best-first round-robin across source devices,
    /// deduplicated, validated against the task geometry, capped.
    pub seeds: Vec<SeedRecord>,
}

/// Query the cache for a task on a target device at a given trial
/// budget, recording hit/miss and seed-origin counters.
///
/// A hit requires records searched at `requested_trials` or more: a
/// cheap earlier run must not silently satisfy a bigger requested
/// search (and a tiny-budget default-only result must not poison the
/// workload forever).
pub fn plan(
    cache: &TuneCache,
    task: &Subgraph,
    target: &DeviceArch,
    max_seeds: usize,
    requested_trials: usize,
) -> WarmStartPlan {
    let key = WorkloadKey::new(task, target);
    let geometry = task.geometry();
    // Drop records whose knobs don't decode to a valid schedule for
    // this geometry (corrupt log lines): they must neither satisfy the
    // hit test nor silently suppress the seed lists.
    let local: Vec<TuneRecord> = cache
        .records(&key)
        .into_iter()
        .filter(|r| r.schedule().is_valid(&geometry))
        .collect();
    let searched_trials = local.iter().map(|r| r.trials).max().unwrap_or(0);
    if !local.is_empty() && searched_trials >= requested_trials {
        cache.counters().record_hit();
        return WarmStartPlan {
            exact: local.first().cloned(),
            searched_trials,
            local_seeds: Vec::new(),
            seeds: Vec::new(),
        };
    }
    cache.counters().record_miss();

    let local_seeds: Vec<Schedule> = local.iter().map(|r| r.schedule()).collect();
    let mut seeds = Vec::new();
    // Don't re-offer schedules this device already has records for.
    let mut seen: Vec<[u32; 9]> = local.iter().map(|r| r.knobs).collect();
    for rec in cache.cross_device(key.workload, key.device) {
        if seeds.len() >= max_seeds {
            break;
        }
        if seen.contains(&rec.knobs) {
            continue;
        }
        let schedule = rec.schedule();
        if !schedule.is_valid(&geometry) {
            continue;
        }
        seen.push(rec.knobs);
        seeds.push(SeedRecord {
            schedule,
            source_device: rec.device_name.clone(),
            source_latency_s: rec.latency_s,
        });
    }
    cache.counters().record_seeds(seeds.len());
    WarmStartPlan { exact: None, searched_trials, local_seeds, seeds }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::program::{SpaceGenerator, SubgraphKind};
    use crate::util::rng::Rng;

    fn task() -> Subgraph {
        Subgraph::new(
            "ws.conv",
            SubgraphKind::Conv2d {
                n: 1, h: 28, w: 28, cin: 64, cout: 64, kh: 3, kw: 3, stride: 1, pad: 1,
            },
        )
    }

    fn populate(cache: &TuneCache, arch: &DeviceArch, n: usize, seed: u64, trials: usize) {
        let t = task();
        let key = WorkloadKey::new(&t, arch);
        let gen = SpaceGenerator::new(t.geometry());
        let mut rng = Rng::new(seed);
        for (i, s) in gen.sample_distinct(&mut rng, n).iter().enumerate() {
            cache.commit(TuneRecord::new(
                key,
                &arch.name,
                s,
                (i + 1) as f64 * 1e-3,
                1.0,
                trials,
            ));
        }
    }

    #[test]
    fn miss_yields_cross_device_seeds() {
        let cache = TuneCache::in_memory(8);
        populate(&cache, &presets::rtx_2060(), 5, 1, 64);
        populate(&cache, &presets::tesla_k80(), 5, 2, 64);

        let p = plan(&cache, &task(), &presets::jetson_tx2(), 6, 64);
        assert!(p.exact.is_none());
        assert_eq!(p.searched_trials, 0);
        assert!(p.local_seeds.is_empty());
        // Up to 6 seeds; identical schedules sampled on both devices
        // dedup, so allow a small shortfall.
        assert!(p.seeds.len() >= 5, "expected >=5 seeds, got {}", p.seeds.len());
        // Both source devices contribute (round-robin).
        assert!(p.seeds.iter().any(|s| s.source_device == "rtx2060"));
        assert!(p.seeds.iter().any(|s| s.source_device == "k80"));
        let stats = cache.stats();
        assert_eq!(stats.misses, 1);
        assert_eq!(stats.cross_device_seeds, p.seeds.len());
    }

    #[test]
    fn exact_hit_short_circuits_seeding() {
        let cache = TuneCache::in_memory(8);
        populate(&cache, &presets::jetson_tx2(), 3, 3, 64);
        populate(&cache, &presets::rtx_2060(), 3, 4, 64);

        let p = plan(&cache, &task(), &presets::jetson_tx2(), 8, 64);
        let exact = p.exact.expect("expected an exact hit");
        assert!((exact.latency_s - 1e-3).abs() < 1e-15);
        assert_eq!(p.searched_trials, 64);
        assert!(p.seeds.is_empty() && p.local_seeds.is_empty());
        assert_eq!(cache.stats().hits, 1);
    }

    #[test]
    fn bigger_budget_downgrades_hit_to_local_reseed() {
        let cache = TuneCache::in_memory(8);
        populate(&cache, &presets::jetson_tx2(), 3, 5, 16);
        populate(&cache, &presets::rtx_2060(), 3, 6, 16);

        // Requesting more trials than ever searched: no short-circuit,
        // but this device's own records come back as local seeds and the
        // other device's as cross-device seeds.
        let p = plan(&cache, &task(), &presets::jetson_tx2(), 8, 200);
        assert!(p.exact.is_none());
        assert_eq!(p.searched_trials, 16);
        assert_eq!(p.local_seeds.len(), 3);
        assert!(!p.seeds.is_empty());
        assert_eq!(cache.stats().misses, 1);
    }

    #[test]
    fn empty_cache_plans_nothing() {
        let cache = TuneCache::in_memory(8);
        let p = plan(&cache, &task(), &presets::rtx_2060(), 8, 64);
        assert!(p.exact.is_none() && p.seeds.is_empty() && p.local_seeds.is_empty());
        assert_eq!(cache.stats().misses, 1);
    }
}
