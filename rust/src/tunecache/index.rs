//! Feature-space workload index: nearest-neighbor retrieval over
//! cached workload descriptors.  Rebuilt from scratch at every
//! [`super::TuneCache`] open by the same segment merge that fills the
//! store, so it always reflects the union of every writer's records.
//!
//! The exact-hash cache ([`super::store`]) only helps when a workload
//! has been seen *identically* before; this index turns the cache into
//! a retrieval system.  Every admitted record carries its workload's
//! compact descriptor ([`crate::program::Subgraph::descriptor`]):
//! log2-scaled geometry extents (spatial × spatial × reduction), a MAC
//! flag, log2 flops, log2 bytes per logical buffer, and log2 arithmetic
//! intensity.  Because every continuous dimension is log-scaled, the
//! **normalized L2 distance** used here —
//! `sqrt(Σ_i (a_i − b_i)² / DESC_DIM)` — measures average per-dimension
//! *shape ratio* in octaves: distance 1.0 means the two workloads
//! differ by about a factor of two per dimension.  Workloads within a
//! configurable radius are close enough that their tuned schedules
//! (tiling structure, vectorization, staging) transfer as search seeds,
//! which is exactly the feature-space-similarity transfer TLP/TCL
//! demonstrate for tensor programs.
//!
//! Entries are version-stamped: a descriptor computed by an older
//! featurizer/simulator ([`super::RECORD_VERSION`]) is refused at
//! insert, so a latency-model change can never leak stale neighbors
//! into a fresh session.

// Outside the deterministic planes (detlint [rules.unordered-collections]):
// neighbor queries sort by (distance, workload) before returning, so map
// iteration order never reaches a session.
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;
use std::sync::RwLock;

use crate::program::DESC_DIM;

use super::RECORD_VERSION;

/// Default retrieval radius in normalized-L2 descriptor space
/// (~one octave of average per-dimension shape difference).
pub const DEFAULT_NN_RADIUS: f64 = 1.0;

/// Default number of neighbor workloads consulted per query.
pub const DEFAULT_NN_K: usize = 4;

/// Normalized L2 distance between two workload descriptors.
pub fn distance(a: &[f64; DESC_DIM], b: &[f64; DESC_DIM]) -> f64 {
    let sum: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (sum / DESC_DIM as f64).sqrt()
}

/// Concurrent map from workload fingerprint to descriptor, queried by
/// k-nearest-neighbor under a radius.  Sized for thousands of distinct
/// workloads, where a linear scan (a few µs) is far below the cost of
/// even one schedule featurization — no spatial structure needed yet.
#[derive(Debug, Default)]
pub struct WorkloadIndex {
    entries: RwLock<HashMap<u64, [f64; DESC_DIM]>>,
}

impl WorkloadIndex {
    pub fn new() -> WorkloadIndex {
        WorkloadIndex::default()
    }

    /// Register a workload's descriptor.  Returns whether the entry was
    /// accepted: descriptors stamped by a different featurizer/simulator
    /// version are refused (their distances are not comparable), as are
    /// non-finite descriptors (corrupt log lines).
    pub fn insert(&self, workload: u64, desc: [f64; DESC_DIM], version: u32) -> bool {
        if version != RECORD_VERSION || desc.iter().any(|v| !v.is_finite()) {
            return false;
        }
        self.entries.write().expect("workload index poisoned").insert(workload, desc);
        true
    }

    /// The `k` nearest indexed workloads within `radius` of `query`,
    /// closest first, excluding `exclude` (the querying workload
    /// itself).  Ties break on the workload fingerprint so retrieval is
    /// deterministic across runs.
    pub fn nearest(
        &self,
        query: &[f64; DESC_DIM],
        k: usize,
        radius: f64,
        exclude: u64,
    ) -> Vec<(u64, f64)> {
        let entries = self.entries.read().expect("workload index poisoned");
        let mut hits: Vec<(u64, f64)> = entries
            .iter()
            .filter(|(w, _)| **w != exclude)
            .map(|(w, d)| (*w, distance(query, d)))
            .filter(|(_, dist)| *dist <= radius)
            .collect();
        hits.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        hits.truncate(k);
        hits
    }

    /// Number of indexed workloads.
    pub fn len(&self) -> usize {
        self.entries.read().expect("workload index poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::program::{Subgraph, SubgraphKind};

    fn conv(cout: usize) -> [f64; DESC_DIM] {
        Subgraph::new(
            "t",
            SubgraphKind::Conv2d {
                n: 1, h: 28, w: 28, cin: 64, cout, kh: 3, kw: 3, stride: 1, pad: 1,
            },
        )
        .descriptor()
    }

    #[test]
    fn self_distance_is_zero_and_symmetric() {
        let a = conv(64);
        let b = conv(128);
        assert_eq!(distance(&a, &a), 0.0);
        assert!(distance(&a, &b) > 0.0);
        assert!((distance(&a, &b) - distance(&b, &a)).abs() < 1e-15);
    }

    #[test]
    fn nearest_orders_by_distance_and_respects_radius() {
        let idx = WorkloadIndex::new();
        assert!(idx.insert(1, conv(48), RECORD_VERSION));
        assert!(idx.insert(2, conv(96), RECORD_VERSION));
        let dense = Subgraph::new("d", SubgraphKind::Dense { m: 64, n: 4096, k: 4096 })
            .descriptor();
        assert!(idx.insert(3, dense, RECORD_VERSION));
        assert_eq!(idx.len(), 3);

        let q = conv(64);
        let near = idx.nearest(&q, 8, DEFAULT_NN_RADIUS, 0);
        // Both convs are within an octave; the big dense matmul is not.
        assert_eq!(near.len(), 2, "got {near:?}");
        assert_eq!(near[0].0, 1, "48-channel conv is closest to 64");
        assert!(near[0].1 <= near[1].1);
        // k truncates.
        assert_eq!(idx.nearest(&q, 1, DEFAULT_NN_RADIUS, 0).len(), 1);
        // The querying workload itself is excluded.
        assert!(idx.nearest(&conv(48), 8, 10.0, 1).iter().all(|(w, _)| *w != 1));
        // A zero radius returns nothing for a novel query.
        assert!(idx.nearest(&q, 8, 0.0, 0).is_empty());
    }

    #[test]
    fn stale_version_stamps_are_rejected() {
        let idx = WorkloadIndex::new();
        assert!(!idx.insert(7, conv(64), RECORD_VERSION + 1));
        assert!(!idx.insert(8, conv(64), 0));
        assert!(idx.is_empty());
        // Non-finite descriptors (corrupt lines) are refused too.
        let mut bad = conv(64);
        bad[0] = f64::NAN;
        assert!(!idx.insert(9, bad, RECORD_VERSION));
        assert!(idx.is_empty());
    }
}
