//! `moses` — CLI for the Moses reproduction.
//!
//! Subcommands:
//!   tune            Tune a DNN on a (simulated) target device with a strategy.
//!   pretrain        Pre-train the source-device cost model (Tenset-style).
//!   dataset         Generate a program-performance dataset (paper §4.1).
//!   export-dataset  Convert tunecache records into pretraining corpora.
//!   eval            Evaluate a checkpoint's ranking quality on a device.
//!   tables          Regenerate the paper's tables/figures (fig4|fig5|table1|fig6).
//!   trace           Inspect a session trace (report | chrome export).
//!   devices         List simulated device presets.
//!
//! Python never runs here: the cost model executes through AOT-compiled
//! HLO artifacts (`make artifacts`) on the PJRT CPU client.

// The CLI drivers time whole sessions on the wall clock for the
// human-facing footers; the deterministic engine itself never reads it
// (enforced by detlint's wall-clock rule — each driver read below
// carries a pragma — and cross-checked by clippy disallowed-methods).
#![allow(clippy::disallowed_methods)]

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use moses::coordinator::{AutoTuner, BackendKind, TuneConfig};
use moses::costmodel::layout;
use moses::dataset::gen::{generate, GenConfig, TaskSource};
use moses::dataset::io as ds_io;
use moses::device::presets;
use moses::metrics::experiments::{self, ExpConfig};
use moses::models::zoo;
use moses::obs::{chrome, Recorder, Trace, TraceHeader, TRACE_VERSION};
use moses::program::{featurize, SpaceGenerator, TensorProgram, N_FEATURES};
use moses::transfer::Strategy;
use moses::tunecache::{FsyncPolicy, TuneCache, DEFAULT_TOPK};
use moses::util::cli::Flags;
use moses::util::rng::Rng;
use moses::util::stats;
use moses::util::table::Table;

fn backend_kind(name: &str) -> Result<BackendKind> {
    match name {
        "auto" => Ok(BackendKind::auto()),
        "xla" => Ok(BackendKind::Xla),
        "rust" => Ok(BackendKind::Rust),
        other => bail!("unknown backend '{other}' (use auto|xla|rust)"),
    }
}

fn main() {
    // Default verbosity until a subcommand re-initializes from its own
    // flags (`RUST_LOG` always wins — see `util::log`).
    moses::util::log::init_from_env(false);
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "tune" => cmd_tune(rest),
        "pretrain" => cmd_pretrain(rest),
        "dataset" => cmd_dataset(rest),
        "export-dataset" => cmd_export_dataset(rest),
        "eval" => cmd_eval(rest),
        "tables" => cmd_tables(rest),
        "trace" => cmd_trace(rest),
        "devices" => cmd_devices(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' — run `moses help`"),
    }
}

fn print_usage() {
    println!(
        "moses — cross-device cost-model adaptation for tensor program optimization\n\n\
         Usage: moses <command> [flags]\n\n\
         Commands:\n\
         \x20 tune            Tune a DNN on a simulated target device\n\
         \x20 pretrain        Pre-train the source-device (K80) cost model\n\
         \x20 dataset         Generate a program-performance dataset (paper §4.1)\n\
         \x20 export-dataset  Convert tunecache records into pretraining corpora\n\
         \x20 eval            Evaluate a checkpoint's ranking quality\n\
         \x20 tables          Regenerate paper tables/figures (fig4|fig5|table1|fig6|all)\n\
         \x20 trace           Inspect a session trace (report | chrome export)\n\
         \x20 devices         List simulated device presets\n\n\
         Run `moses <command> --help` for flags."
    );
}

// ---------------------------------------------------------------- tune ----

fn cmd_tune(args: &[String]) -> Result<()> {
    let flags = Flags::new()
        .opt("model", "squeezenet", "DNN to tune (resnet18|mobilenet|squeezenet|bert|mobilevit)")
        .opt("target", "tx2", "target device preset")
        .opt("strategy", "moses", "moses|tenset-finetune|tenset-pretrain|ansor-random|random")
        .opt("trials", "64", "candidate trials per task")
        .opt("batch", "8", "measurements per round")
        .opt("seed", "0", "RNG seed")
        .opt("backend", "auto", "cost-model backend (auto|xla|rust)")
        .opt(
            "jobs",
            "1",
            "work-stealing tuning workers (deterministic per (seed, tasks); rust backend only)",
        )
        .switch(
            "fast-nondeterministic",
            "drop per-task snapshot pinning at --jobs N: workers read the freshest \
             model snapshot, trading bit-reproducibility for lower coordination",
        )
        .switch(
            "draft",
            "speculative draft-then-verify search: a cheap linear scorer distilled \
             from the live cost model prunes each generation before the full model \
             ranks the survivors (rust backend only)",
        )
        .switch("no-draft", "force the draft tier off (overrides --draft)")
        .opt(
            "draft-keep",
            "0.2",
            "fraction of each draft-scored generation the full model verifies \
             (0 < keep <= 1; 1.0 is bit-identical to draft off)",
        )
        .opt("pretrained", "", "checkpoint path (default: auto-pretrain+cache)")
        .opt(
            "tune-cache",
            "artifacts/tunecache",
            "persistent tuning-record store: a cache directory safe to share across \
             concurrent tuners (a legacy single-file .jsonl log is imported read-only)",
        )
        .opt(
            "cache-fsync",
            "never",
            "segment-append durability (never|always): 'always' fsyncs every \
             committed record, 'never' leaves the tail to the OS page cache",
        )
        .switch("no-cache", "disable the tuning-record store")
        .opt(
            "nn-radius",
            "",
            "nearest-neighbor warm-start radius, normalized log2 descriptor distance \
             (empty = built-in default)",
        )
        .switch("no-nn", "disable nearest-neighbor warm start (exact cache hits only)")
        .opt("tasks", "0", "tune only the first N tasks of the model (0 = all)")
        .opt("trace", "", "write a JSONL session trace to this path (see `moses trace`)")
        .switch("verbose", "per-task output");
    if args.iter().any(|a| a == "--help") {
        print!("{}", flags.help("tune", "Tune a DNN on a simulated target device."));
        return Ok(());
    }
    let p = flags.parse(args)?;
    moses::util::log::init_from_env(p.get_bool("verbose"));

    let target = presets::by_name(p.get("target"))
        .with_context(|| format!("unknown device '{}' — see `moses devices`", p.get("target")))?;
    let strategy = Strategy::from_name(p.get("strategy"))
        .with_context(|| format!("unknown strategy '{}'", p.get("strategy")))?;
    let model =
        zoo::by_name(p.get("model")).with_context(|| format!("unknown model '{}'", p.get("model")))?;
    let backend = backend_kind(p.get("backend"))?;

    let mut exp = ExpConfig { backend, seed: p.get_u64("seed")?, ..ExpConfig::default() };
    if backend == BackendKind::Rust {
        exp.rust_pred_batch = 256;
        exp.rust_train_batch = 128;
    }
    let pretrained: Option<Vec<f32>> = if strategy.uses_pretrained() {
        let path = p.get("pretrained");
        Some(if path.is_empty() {
            moses::info!("pre-training source cost model on simulated K80 (cached)");
            experiments::pretrained_source_checkpoint(&exp)?
        } else {
            layout::load_checkpoint(&PathBuf::from(path))?
        })
    } else {
        None
    };

    // Empty string defers to the library default so the CLI can never
    // drift from a retuned DEFAULT_NN_RADIUS.
    let nn_radius = if p.get("nn-radius").is_empty() {
        moses::tunecache::DEFAULT_NN_RADIUS
    } else {
        p.get_f64("nn-radius")?
    };
    anyhow::ensure!(
        nn_radius.is_finite() && nn_radius >= 0.0,
        "--nn-radius must be a non-negative number"
    );
    let jobs = p.get_usize("jobs")?.max(1);
    let mut cfg = TuneConfig {
        trials_per_task: p.get_usize("trials")?,
        measure_batch: p.get_usize("batch")?,
        strategy: strategy.clone(),
        seed: p.get_u64("seed")?,
        backend,
        nn_radius: if p.get_bool("no-nn") { None } else { Some(nn_radius) },
        jobs,
        deterministic: !p.get_bool("fast-nondeterministic"),
        draft: p.get_bool("draft") && !p.get_bool("no-draft"),
        draft_keep: p.get_f64("draft-keep")?,
        ..TuneConfig::default()
    };
    if backend == BackendKind::Rust {
        // Keep the parallel learner/worker backends on the same batch
        // geometry the model was initialized with.
        cfg.rust_pred_batch = exp.rust_pred_batch;
        cfg.rust_train_batch = exp.rust_train_batch;
    }
    let cost_model = moses::transfer::init_model(
        &strategy,
        exp.backend_arc()?,
        pretrained.as_deref(),
        &mut Rng::new(cfg.seed),
    );
    let trace_path = p.get("trace").to_string();
    let recorder = if trace_path.is_empty() { Recorder::disabled() } else { Recorder::enabled() };
    let cache: Option<Arc<TuneCache>> = if p.get_bool("no-cache") {
        None
    } else {
        let path = PathBuf::from(p.get("tune-cache"));
        let fsync = FsyncPolicy::from_name(p.get("cache-fsync")).with_context(|| {
            format!("--cache-fsync must be never|always, got '{}'", p.get("cache-fsync"))
        })?;
        let mut tc = TuneCache::builder(&path).topk(DEFAULT_TOPK).fsync(fsync).open()?;
        tc.attach_recorder(&recorder);
        Some(Arc::new(tc))
    };
    let mut builder = AutoTuner::builder(target.clone())
        .config(&cfg)
        .model(cost_model)
        .trace(recorder.clone());
    if let Some(c) = &cache {
        builder = builder.cache(c.clone());
    }
    let mut tuner = builder.build()?;

    let mut tasks = model.tasks();
    let task_limit = p.get_usize("tasks")?;
    if task_limit > 0 && task_limit < tasks.len() {
        tasks.truncate(task_limit);
    }
    moses::info!(
        "tuning {} on {} with {} ({} trials/task, backend {})",
        model.name,
        target.name,
        strategy.name(),
        cfg.trials_per_task,
        p.get("backend"),
    );
    // detlint: allow(wall-clock) -- driver-only session timing for the CLI footer
    let t0 = std::time::Instant::now();
    let session = tuner.tune(&tasks)?;
    let wall = t0.elapsed().as_secs_f64();

    if p.get_bool("verbose") {
        let mut t = Table::new(
            "Per-task results",
            &["task", "default ms", "tuned ms", "speedup", "measured", "pred-only", "seeds", "cache"],
        );
        for r in &session.tasks {
            t.row(vec![
                r.task.name.clone(),
                format!("{:.3}", r.default_latency_s * 1e3),
                format!("{:.3}", r.best_latency_s * 1e3),
                format!("{:.2}x", r.speedup()),
                r.measured.to_string(),
                r.predicted_only.to_string(),
                format!("{}+{}nn", r.warm_seeds, r.neighbor_seeds),
                if r.cache_hit { "hit" } else { "miss" }.to_string(),
            ]);
        }
        t.print();
    }

    println!(
        "\nend-to-end latency : {:.3} ms (default {:.3} ms, {:.2}x speedup)",
        session.total_best_latency_ms(),
        session.total_default_latency_ms(),
        session.speedup()
    );
    if jobs > 1 {
        println!(
            "virtual search time: {:.1} s wall at --jobs {jobs} ({:.1} s under wave \
             scheduling, {:.1} s device cost, {} measurements)",
            session.wall_time_s(),
            session.wave_wall_time_s(),
            session.search_time_s(),
            session.total_measurements()
        );
    } else {
        println!(
            "virtual search time: {:.1} s ({} measurements)",
            session.search_time_s(),
            session.total_measurements()
        );
    }
    if let Some(c) = &cache {
        let s = c.stats();
        println!(
            "tune cache         : {} hit / {} miss ({:.0}% hit rate), {} cross-device seeds, \
             {} neighbor seeds, {} stale-dropped, {} records over {} workloads at {}",
            s.hits,
            s.misses,
            100.0 * s.hit_rate(),
            s.cross_device_seeds,
            s.neighbor_seeds,
            s.stale_dropped,
            c.total_records(),
            c.num_workloads(),
            c.path().map(|p| p.display().to_string()).unwrap_or_else(|| "<memory>".into()),
        );
        if s.stale_dropped > 0 {
            println!(
                "                     ({} stale record(s) dropped on load — \
                 featurizer/simulator version changed)",
                s.stale_dropped
            );
        }
    }
    println!("harness wall time  : {wall:.1} s");
    if !trace_path.is_empty() {
        let trace = Trace {
            header: TraceHeader {
                version: TRACE_VERSION,
                device: target.name.clone(),
                strategy: strategy.name().to_string(),
                model: model.name.clone(),
                jobs,
                seed: cfg.seed,
            },
            events: recorder.drain(),
            metrics: recorder.metrics_snapshot(),
        };
        let path = PathBuf::from(&trace_path);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {parent:?}"))?;
            }
        }
        let n_events = trace.events.len();
        std::fs::write(&path, trace.to_jsonl())
            .with_context(|| format!("writing trace to {path:?}"))?;
        println!("trace              : {} ({n_events} events)", path.display());
    }
    Ok(())
}

// -------------------------------------------------------------- trace ----

fn cmd_trace(args: &[String]) -> Result<()> {
    let flags = Flags::new().opt("out", "", "chrome export path (default: <trace>.chrome.json)");
    if args.is_empty() || args.iter().any(|a| a == "--help") {
        print!(
            "{}",
            flags.help(
                "trace <report|chrome> <trace.jsonl>",
                "Inspect a session trace written by `moses tune --trace`.\n\
                 \x20 report    per-task and per-stage virtual-time breakdown + counters\n\
                 \x20 chrome    convert to Chrome trace-event JSON (chrome://tracing, Perfetto)",
            )
        );
        return Ok(());
    }
    let p = flags.parse(args)?;
    let action = p.positional.first().map(String::as_str).unwrap_or_default();
    let path = p
        .positional
        .get(1)
        .context("usage: moses trace <report|chrome> <trace.jsonl>")?;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path:?}"))?;
    let trace = Trace::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    match action {
        "report" => {
            println!(
                "trace v{}: {} on {} with {} (--jobs {}, seed {}) — {} events",
                trace.header.version,
                trace.header.model,
                trace.header.device,
                trace.header.strategy,
                trace.header.jobs,
                trace.header.seed,
                trace.events.len(),
            );
            trace.per_task_table().print();
            trace.per_stage_table().print();
            if let Some(t) = trace.draft_table() {
                t.print();
            }
            if let Some(t) = trace.sched_table() {
                t.print();
            }
            println!("virtual search time in spans: {:.1} s", trace.vt_total_s());
            if !trace.metrics.is_empty() {
                let mut t = Table::new("Session counters", &["counter", "value"]);
                for (k, v) in &trace.metrics {
                    t.row(vec![k.clone(), v.to_string()]);
                }
                t.print();
            }
        }
        "chrome" => {
            let out = if p.get("out").is_empty() {
                format!("{path}.chrome.json")
            } else {
                p.get("out").to_string()
            };
            std::fs::write(&out, chrome::to_chrome(&trace).to_string())
                .with_context(|| format!("writing {out:?}"))?;
            println!("wrote {out} ({} events)", trace.events.len());
        }
        other => anyhow::bail!("unknown trace action '{other}' (expected report|chrome)"),
    }
    Ok(())
}

// ----------------------------------------------------------- pretrain ----

fn cmd_pretrain(args: &[String]) -> Result<()> {
    let flags = Flags::new()
        .opt("out", "artifacts/k80_pretrained.bin", "output checkpoint path")
        .opt("source", "k80", "source device preset")
        .opt("tasks", "40", "random tasks in the corpus")
        .opt("records", "96", "records per task")
        .opt("epochs", "8", "training epochs")
        .opt("seed", "0", "RNG seed")
        .opt("backend", "auto", "cost-model backend (auto|xla|rust)")
        .opt(
            "from-tunecache",
            "",
            "pretrain on REAL tuning history: export this tunecache store \
             (cache directory or legacy JSONL file) and train on the source \
             device's records instead of a random-sampled corpus",
        );
    if args.iter().any(|a| a == "--help") {
        print!("{}", flags.help("pretrain", "Pre-train the source-device cost model."));
        return Ok(());
    }
    let p = flags.parse(args)?;
    let device = presets::by_name(p.get("source"))
        .with_context(|| format!("unknown device '{}'", p.get("source")))?;
    let cfg = ExpConfig {
        backend: backend_kind(p.get("backend"))?,
        seed: p.get_u64("seed")?,
        pretrain_tasks: p.get_usize("tasks")?,
        pretrain_records_per_task: p.get_usize("records")?,
        pretrain_epochs: p.get_usize("epochs")?,
        ..ExpConfig::default()
    };
    // detlint: allow(wall-clock) -- driver-only session timing for the CLI footer
    let t0 = std::time::Instant::now();
    let from_cache = p.get("from-tunecache");
    let params = if from_cache.is_empty() {
        println!(
            "pre-training on {}: {} tasks x {} records, {} epochs",
            device.name, cfg.pretrain_tasks, cfg.pretrain_records_per_task, cfg.pretrain_epochs
        );
        experiments::pretrain_on(&device, &cfg)?
    } else {
        // The PR 3 export → pretrain loop in one command: group the
        // tuning log by device and train on the source device's slice.
        let log = PathBuf::from(from_cache);
        anyhow::ensure!(log.exists(), "no tuning log at {log:?} (run `moses tune` first)");
        let (records, malformed) = moses::tunecache::persist::load_log(&log)?;
        let report = moses::dataset::export::from_records(&records);
        let ds = report
            .datasets
            .iter()
            .find(|d| d.device == device.name)
            .with_context(|| {
                format!(
                    "tuning log {log:?} holds no exportable records for device '{}' \
                     (devices present: {}; {} skipped stale, {} without task payload, \
                     {} invalid, {malformed} malformed lines)",
                    device.name,
                    report
                        .datasets
                        .iter()
                        .map(|d| d.device.as_str())
                        .collect::<Vec<_>>()
                        .join(", "),
                    report.skipped_stale,
                    report.skipped_no_task,
                    report.skipped_invalid,
                )
            })?;
        println!(
            "pre-training on {} from tuning history {}: {} tasks x {} records, {} epochs",
            device.name,
            log.display(),
            ds.tasks.len(),
            ds.len(),
            cfg.pretrain_epochs
        );
        experiments::pretrain_on_dataset(ds, &cfg)?
    };
    let out = PathBuf::from(p.get("out"));
    if let Some(parent) = out.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    layout::save_checkpoint(&out, &params)?;
    println!(
        "wrote {} ({} params) in {:.1}s",
        out.display(),
        params.len(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

// ------------------------------------------------------------ dataset ----

fn cmd_dataset(args: &[String]) -> Result<()> {
    let flags = Flags::new()
        .opt("devices", "tx2,xavier", "comma-separated device presets")
        .opt("tasks", "50", "random tasks ('over 50 DNN models' stand-in)")
        .opt("records", "200", "records per task")
        .opt("zoo", "true", "also include the evaluation model zoo tasks")
        .opt("seed", "0", "RNG seed")
        .opt("out", "artifacts", "output directory");
    if args.iter().any(|a| a == "--help") {
        print!(
            "{}",
            flags.help("dataset", "Generate program-performance datasets (paper §4.1).")
        );
        return Ok(());
    }
    let p = flags.parse(args)?;
    let out_dir = PathBuf::from(p.get("out"));
    std::fs::create_dir_all(&out_dir)?;
    for name in p.get_list("devices") {
        let device =
            presets::by_name(&name).with_context(|| format!("unknown device '{name}'"))?;
        let cfg = GenConfig {
            records_per_task: p.get_usize("records")?,
            seed: p.get_u64("seed")?,
        };
        let mut ds =
            generate(&device, TaskSource::Random { count: p.get_usize("tasks")? }, &cfg);
        if p.get_bool("zoo") {
            let zoo_ds = generate(&device, TaskSource::Zoo, &cfg);
            for r in &zoo_ds.records {
                let idx = ds.add_task(zoo_ds.tasks[r.task_idx].clone());
                let sched = moses::program::Schedule::decode(&r.knobs);
                ds.push(idx, &sched, r.gflops, r.latency_s);
            }
        }
        let path = out_dir.join(format!("{name}.moses-ds"));
        ds_io::save(&ds, &path)?;
        println!("wrote {}: {} tasks, {} records", path.display(), ds.tasks.len(), ds.len());
    }
    Ok(())
}

// ----------------------------------------------------- export-dataset ----

fn cmd_export_dataset(args: &[String]) -> Result<()> {
    let flags = Flags::new()
        .opt(
            "tune-cache",
            "artifacts/tunecache",
            "tuning-record store to export (cache directory or legacy JSONL file)",
        )
        .opt("out", "artifacts", "output directory for per-device .moses-ds files")
        .opt("suffix", "tunecache", "output file suffix: <device>-<suffix>.moses-ds");
    if args.iter().any(|a| a == "--help") {
        print!(
            "{}",
            flags.help(
                "export-dataset",
                "Convert tunecache records into per-device pretraining corpora \
                 (dataset::io format), so the cost model pretrains on real tuning \
                 history instead of random sampling.",
            )
        );
        return Ok(());
    }
    let p = flags.parse(args)?;
    let path = PathBuf::from(p.get("tune-cache"));
    anyhow::ensure!(path.exists(), "no tuning log at {path:?} (run `moses tune` first)");
    let (records, malformed) = moses::tunecache::persist::load_log(&path)?;
    let report = moses::dataset::export::from_records(&records);
    let out_dir = PathBuf::from(p.get("out"));
    std::fs::create_dir_all(&out_dir)?;
    let suffix = p.get("suffix");
    for ds in &report.datasets {
        let out = out_dir.join(format!("{}-{}.moses-ds", ds.device, suffix));
        ds_io::save(ds, &out)?;
        println!(
            "wrote {}: {} tasks, {} records",
            out.display(),
            ds.tasks.len(),
            ds.len()
        );
    }
    println!(
        "exported {} of {} records ({} stale, {} without task payload, {} invalid, \
         {} malformed lines)",
        report.exported,
        records.len(),
        report.skipped_stale,
        report.skipped_no_task,
        report.skipped_invalid,
        malformed
    );
    if report.datasets.is_empty() {
        println!(
            "(nothing to export — records carry task payloads only from schema v3 on; \
             re-run `moses tune` to regenerate)"
        );
    }
    Ok(())
}

// --------------------------------------------------------------- eval ----

fn cmd_eval(args: &[String]) -> Result<()> {
    let flags = Flags::new()
        .req("checkpoint", "checkpoint to evaluate")
        .opt("device", "rtx2060", "device whose labels to rank against")
        .opt("tasks", "8", "random eval tasks")
        .opt("records", "64", "records per task")
        .opt("seed", "123", "RNG seed")
        .opt("backend", "auto", "cost-model backend (auto|xla|rust)");
    if args.iter().any(|a| a == "--help") {
        print!(
            "{}",
            flags.help("eval", "Evaluate a checkpoint's ranking quality on a device.")
        );
        return Ok(());
    }
    let p = flags.parse(args)?;
    let device = presets::by_name(p.get("device"))
        .with_context(|| format!("unknown device '{}'", p.get("device")))?;
    let params = layout::load_checkpoint(&PathBuf::from(p.get("checkpoint")))?;
    let exp = ExpConfig { backend: backend_kind(p.get("backend"))?, ..ExpConfig::default() };
    let model = moses::costmodel::CostModel::with_params(exp.backend_arc()?, params);

    let cfg = GenConfig { records_per_task: p.get_usize("records")?, seed: p.get_u64("seed")? };
    let ds = generate(&device, TaskSource::Random { count: p.get_usize("tasks")? }, &cfg);
    let mut t = Table::new(
        &format!("Ranking quality on {}", device.name),
        &["task", "spearman", "pair-acc", "top-8 recall"],
    );
    let mut spearman_all = Vec::new();
    for (i, task) in ds.tasks.iter().enumerate() {
        let recs: Vec<&moses::dataset::Record> =
            ds.records.iter().filter(|r| r.task_idx == i).collect();
        let mut x = Vec::with_capacity(recs.len() * N_FEATURES);
        let mut truth = Vec::with_capacity(recs.len());
        for r in &recs {
            x.extend_from_slice(&featurize(task, &moses::program::Schedule::decode(&r.knobs)));
            truth.push(r.gflops);
        }
        let preds: Vec<f64> = model.predict(&x, recs.len())?.iter().map(|&v| v as f64).collect();
        let rho = stats::spearman(&preds, &truth);
        spearman_all.push(rho);
        t.row(vec![
            task.name.clone(),
            format!("{rho:.3}"),
            format!("{:.3}", stats::pair_accuracy(&preds, &truth)),
            format!("{:.3}", stats::top_k_recall(&preds, &truth, 8)),
        ]);
    }
    t.print();
    println!("mean spearman: {:.3}", stats::Summary::of(&spearman_all).mean);
    Ok(())
}

// ------------------------------------------------------------- tables ----

fn cmd_tables(args: &[String]) -> Result<()> {
    let flags = Flags::new()
        .opt("exp", "all", "fig4|fig5|table1|fig6|all")
        .opt("trials-small", "48", "small-tier trials per task (paper: 200)")
        .opt("trials-large", "192", "large-tier trials per task (paper: 20000/5000)")
        .opt("seed", "0", "RNG seed")
        .opt("backend", "auto", "cost-model backend (auto|xla|rust)")
        .opt("jobs", "1", "parallel grid cells for the fig4/fig5 sweep")
        .opt("fig6-model", "mobilenet", "model for the ratio ablation")
        .opt("fig6-seeds", "0,1,2", "seeds for the ratio ablation")
        .opt("out", "", "also append markdown to this file");
    if args.iter().any(|a| a == "--help") {
        print!("{}", flags.help("tables", "Regenerate the paper's tables and figures."));
        return Ok(());
    }
    let p = flags.parse(args)?;
    let jobs = p.get_usize("jobs")?.max(1);
    let cfg = ExpConfig {
        backend: backend_kind(p.get("backend"))?,
        seed: p.get_u64("seed")?,
        trials_small: p.get_usize("trials-small")?,
        trials_large: p.get_usize("trials-large")?,
        jobs,
        ..ExpConfig::default()
    };
    let exp = p.get("exp").to_string();
    let mut rendered = String::new();
    // detlint: allow(wall-clock) -- driver-only session timing for the CLI footer
    let t0 = std::time::Instant::now();

    if exp == "fig4" || exp == "fig5" || exp == "all" {
        let targets = [presets::rtx_2060(), presets::jetson_tx2()];
        println!(
            "running (target × model × strategy) grid at {} trials/task (--jobs {jobs}) ...",
            cfg.trials_small
        );
        // detlint: allow(wall-clock) -- driver-only grid timing for the CLI footer
        let g0 = std::time::Instant::now();
        let outs = experiments::run_grid(&cfg, cfg.trials_small, &targets)?;
        println!("(grid finished in {:.1}s at --jobs {jobs})", g0.elapsed().as_secs_f64());
        let names: Vec<&str> = targets.iter().map(|t| t.name.as_str()).collect();
        if exp == "fig4" || exp == "all" {
            let t = experiments::fig4_table(&outs, &names);
            t.print();
            rendered.push_str(&t.to_markdown());
        }
        if exp == "fig5" || exp == "all" {
            let t = experiments::fig5_table(&outs, &names);
            t.print();
            rendered.push_str(&t.to_markdown());
        }
    }
    if exp == "table1" || exp == "all" {
        println!(
            "running Table 1 grid (small {} / large {} trials) ...",
            cfg.trials_small, cfg.trials_large
        );
        let t = experiments::table1(&cfg)?;
        t.print();
        rendered.push_str(&t.to_markdown());
    }
    if exp == "fig6" || exp == "all" {
        let seeds: Vec<u64> =
            p.get_list("fig6-seeds").iter().map(|s| s.parse().unwrap_or(0)).collect();
        println!("running Fig 6 ratio ablation ({} seeds) ...", seeds.len());
        let t = experiments::fig6_table(&cfg, p.get("fig6-model"), &seeds)?;
        t.print();
        rendered.push_str(&t.to_markdown());
    }
    println!("(tables generated in {:.1}s at --jobs {jobs})", t0.elapsed().as_secs_f64());

    let out = p.get("out");
    if !out.is_empty() {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(out)?;
        writeln!(f, "{rendered}")?;
        println!("appended markdown to {out}");
    }
    Ok(())
}

// ------------------------------------------------------------ devices ----

fn cmd_devices() -> Result<()> {
    let mut t = Table::new(
        "Simulated device presets",
        &["name", "family", "SMs", "cores", "peak GFLOPs", "BW GB/s", "measure cost s", "embedded"],
    );
    for a in presets::all() {
        t.row(vec![
            a.name.clone(),
            format!("{:?}", a.family),
            a.sm_count.to_string(),
            (a.sm_count * a.cores_per_sm).to_string(),
            format!("{:.0}", a.peak_gflops()),
            format!("{:.0}", a.mem_bw_gbs),
            format!("{:.1}", a.measure_overhead_s),
            if a.embedded { "yes".into() } else { "no".to_string() },
        ]);
    }
    t.print();
    // Show one example tensor program space like the paper's Fig. 1.
    let sub = zoo::resnet18().tasks()[0].clone();
    let g = sub.geometry();
    let sched = moses::program::Schedule::default_for(&g);
    let prog = TensorProgram::new(sub, sched);
    println!(
        "example task: {} — space size ≈ {:.0} raw configs/task, features {}d",
        prog.subgraph.name,
        SpaceGenerator::new(g).space_size(),
        N_FEATURES
    );
    Ok(())
}
