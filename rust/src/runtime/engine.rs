//! The XLA/PJRT execution engine for the cost model's AOT artifacts.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::costmodel::layout;
use crate::util::json::Json;

/// Metadata written by `python/compile/aot.py` alongside the HLO text.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub n_params: usize,
    pub n_features: usize,
    pub hidden: usize,
    pub pred_batch: usize,
    /// Small-batch predict variant (0 when the artifact set predates it).
    pub pred_batch_small: usize,
    pub train_batch: usize,
}

impl ArtifactMeta {
    /// Parse `meta.json` and sanity-check it against the compiled-in
    /// layout constants (the Rust layout mirrors `kernels/ref.py`).
    pub fn load(dir: &Path) -> Result<ArtifactMeta> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let v = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;
        let get = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("meta.json missing numeric field '{k}'"))
        };
        let meta = ArtifactMeta {
            n_params: get("n_params")?,
            n_features: get("n_features")?,
            hidden: get("hidden")?,
            pred_batch: get("pred_batch")?,
            pred_batch_small: v
                .get("pred_batch_small")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            train_batch: get("train_batch")?,
        };
        if meta.n_params != layout::N_PARAMS
            || meta.n_features != layout::N_FEATURES
            || meta.hidden != layout::HIDDEN
        {
            bail!(
                "artifact geometry {:?} does not match compiled-in layout \
                 (N_PARAMS={}, N_FEATURES={}, HIDDEN={}) — re-run `make artifacts`",
                meta,
                layout::N_PARAMS,
                layout::N_FEATURES,
                layout::HIDDEN
            );
        }
        Ok(meta)
    }
}

/// Output of one training step.
#[derive(Debug)]
pub struct TrainOutput {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub loss: f32,
}

impl Engine {
    /// Find the artifact dir: `$MOSES_ARTIFACTS` or `artifacts/` relative
    /// to the working dir or the crate root.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("MOSES_ARTIFACTS") {
            return PathBuf::from(d);
        }
        let cwd = PathBuf::from("artifacts");
        if cwd.join("meta.json").exists() {
            return cwd;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Why the XLA/PJRT path cannot run right now, or `None` if it can.
    /// The single source of truth for every "use XLA?" decision
    /// (backend auto-selection, bench/test skip messages).
    pub fn xla_skip_reason() -> Option<&'static str> {
        if !cfg!(feature = "xla") {
            Some("built without the `xla` cargo feature")
        } else if !Engine::default_dir().join("meta.json").exists() {
            Some("no artifacts — run `make artifacts`")
        } else {
            None
        }
    }

    /// Is the XLA/PJRT path usable (compiled in AND artifacts present)?
    pub fn xla_available() -> bool {
        Engine::xla_skip_reason().is_none()
    }
}

/// PJRT CPU engine holding the four compiled executables.
#[cfg(feature = "xla")]
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    predict: xla::PjRtLoadedExecutable,
    /// Small-batch predict variant (evolutionary-population scoring);
    /// absent in pre-upgrade artifact sets.
    predict_small: Option<xla::PjRtLoadedExecutable>,
    train_step: xla::PjRtLoadedExecutable,
    xi: xla::PjRtLoadedExecutable,
    loss_eval: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
    pub artifact_dir: PathBuf,
}

#[cfg(feature = "xla")]
fn load_exe(
    client: &xla::PjRtClient,
    dir: &Path,
    name: &str,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = dir.join(format!("{name}.hlo.txt"));
    let proto = xla::HloModuleProto::from_text_file(&path)
        .map_err(|e| anyhow::anyhow!("loading {path:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))
}

#[cfg(feature = "xla")]
fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal to_vec: {e:?}"))
}

#[cfg(feature = "xla")]
impl Engine {
    /// Load and compile all artifacts from `dir` (default `artifacts/`).
    pub fn load(dir: &Path) -> Result<Engine> {
        let meta = ArtifactMeta::load(dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        let predict_small = if meta.pred_batch_small > 0
            && dir.join("predict_small.hlo.txt").exists()
        {
            Some(load_exe(&client, dir, "predict_small")?)
        } else {
            None
        };
        Ok(Engine {
            predict: load_exe(&client, dir, "predict")?,
            predict_small,
            train_step: load_exe(&client, dir, "train_step")?,
            xi: load_exe(&client, dir, "xi")?,
            loss_eval: load_exe(&client, dir, "loss_eval")?,
            client,
            meta,
            artifact_dir: dir.to_path_buf(),
        })
    }

    /// Upload a host slice as a device buffer.
    ///
    /// NOTE: all execution goes through `execute_b` with buffers this
    /// wrapper owns.  The vendored `xla` crate's literal-taking
    /// `execute()` leaks every input (`BufferFromHostLiteral(...).release()`
    /// with no matching free in xla_rs.cc), which OOMs a tuning session
    /// after a few thousand cost-model calls; `execute_b` leaves input
    /// ownership with our `PjRtBuffer`s, whose Drop frees them.
    fn buf(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("buffer_from_host_buffer {dims:?}: {e:?}"))
    }

    fn exec_tuple(
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::PjRtBuffer],
    ) -> Result<xla::Literal> {
        let out = exe.execute_b(args).map_err(|e| anyhow::anyhow!("execute_b: {e:?}"))?;
        out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal_sync: {e:?}"))
    }

    fn exec1(exe: &xla::PjRtLoadedExecutable, args: &[xla::PjRtBuffer]) -> Result<xla::Literal> {
        // All entry points are lowered with return_tuple=True.
        Self::exec_tuple(exe, args)?
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("to_tuple1: {e:?}"))
    }

    /// Score a full prediction batch. `x` is row-major
    /// `[pred_batch, n_features]`; returns `pred_batch` scores.
    pub fn predict(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let b = self.meta.pred_batch;
        anyhow::ensure!(params.len() == self.meta.n_params, "params len");
        anyhow::ensure!(x.len() == b * self.meta.n_features, "x len");
        let args = [self.buf(params, &[params.len()])?, self.buf(x, &[b, self.meta.n_features])?];
        to_vec_f32(&Self::exec1(&self.predict, &args)?)
    }

    /// Small-batch predict (`pred_batch_small` rows); errors if the
    /// artifact set lacks the variant.
    pub fn predict_small(&self, params: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let b = self.meta.pred_batch_small;
        let exe = self
            .predict_small
            .as_ref()
            .context("artifacts lack predict_small — re-run `make artifacts`")?;
        anyhow::ensure!(params.len() == self.meta.n_params, "params len");
        anyhow::ensure!(x.len() == b * self.meta.n_features, "x len");
        let args = [self.buf(params, &[params.len()])?, self.buf(x, &[b, self.meta.n_features])?];
        to_vec_f32(&Self::exec1(exe, &args)?)
    }

    /// One masked-Adam training step (see `python/compile/model.py`).
    /// `hp = [lr, wd, adam_step, reserved]`.
    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        x: &[f32],
        y: &[f32],
        w: &[f32],
        mask: &[f32],
        hp: [f32; 4],
    ) -> Result<TrainOutput> {
        let b = self.meta.train_batch;
        let p = self.meta.n_params;
        anyhow::ensure!(params.len() == p && m.len() == p && v.len() == p && mask.len() == p);
        anyhow::ensure!(x.len() == b * self.meta.n_features && y.len() == b && w.len() == b);
        let args = [
            self.buf(params, &[p])?,
            self.buf(m, &[p])?,
            self.buf(v, &[p])?,
            self.buf(x, &[b, self.meta.n_features])?,
            self.buf(y, &[b])?,
            self.buf(w, &[b])?,
            self.buf(mask, &[p])?,
            self.buf(&hp, &[4])?,
        ];
        let out = Self::exec_tuple(&self.train_step, &args)?;
        let (p_new, m_new, v_new, loss) =
            out.to_tuple4().map_err(|e| anyhow::anyhow!("to_tuple4: {e:?}"))?;
        Ok(TrainOutput {
            params: to_vec_f32(&p_new)?,
            m: to_vec_f32(&m_new)?,
            v: to_vec_f32(&v_new)?,
            loss: to_vec_f32(&loss)?[0],
        })
    }

    /// Per-parameter saliency ξ = |w · ∇w| (paper Eq. 5).
    pub fn xi(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        let b = self.meta.train_batch;
        anyhow::ensure!(params.len() == self.meta.n_params);
        anyhow::ensure!(x.len() == b * self.meta.n_features && y.len() == b && w.len() == b);
        let args = [
            self.buf(params, &[params.len()])?,
            self.buf(x, &[b, self.meta.n_features])?,
            self.buf(y, &[b])?,
            self.buf(w, &[b])?,
        ];
        to_vec_f32(&Self::exec1(&self.xi, &args)?)
    }

    /// Held-out ranking loss on one batch.
    pub fn loss_eval(&self, params: &[f32], x: &[f32], y: &[f32], w: &[f32]) -> Result<f32> {
        let b = self.meta.train_batch;
        let args = [
            self.buf(params, &[params.len()])?,
            self.buf(x, &[b, self.meta.n_features])?,
            self.buf(y, &[b])?,
            self.buf(w, &[b])?,
        ];
        Ok(to_vec_f32(&Self::exec1(&self.loss_eval, &args)?)?[0])
    }
}

/// Artifact-less stub compiled when the `xla` feature is off (the
/// vendored `xla` crate is not in the offline crate cache).  Keeps the
/// whole crate — including every `BackendKind::Xla` code path —
/// type-checking and building everywhere; `load` always errors, so the
/// execution methods below are unreachable in practice but mirror the
/// real signatures.
#[cfg(not(feature = "xla"))]
pub struct Engine {
    pub meta: ArtifactMeta,
    pub artifact_dir: PathBuf,
}

#[cfg(not(feature = "xla"))]
impl Engine {
    const NO_XLA: &'static str =
        "this build has no XLA/PJRT support (compile with `--features xla` after vendoring \
         the xla crate, or use the pure-Rust backend: `--backend rust`)";

    /// Validate the artifact metadata for precise errors, then refuse:
    /// there is no PJRT runtime to execute with in this build.
    pub fn load(dir: &Path) -> Result<Engine> {
        let _ = ArtifactMeta::load(dir)?;
        bail!("{} (artifacts found at {dir:?})", Self::NO_XLA)
    }

    pub fn predict(&self, _params: &[f32], _x: &[f32]) -> Result<Vec<f32>> {
        bail!(Self::NO_XLA)
    }

    pub fn predict_small(&self, _params: &[f32], _x: &[f32]) -> Result<Vec<f32>> {
        bail!(Self::NO_XLA)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn train_step(
        &self,
        _params: &[f32],
        _m: &[f32],
        _v: &[f32],
        _x: &[f32],
        _y: &[f32],
        _w: &[f32],
        _mask: &[f32],
        _hp: [f32; 4],
    ) -> Result<TrainOutput> {
        bail!(Self::NO_XLA)
    }

    pub fn xi(&self, _params: &[f32], _x: &[f32], _y: &[f32], _w: &[f32]) -> Result<Vec<f32>> {
        bail!(Self::NO_XLA)
    }

    pub fn loss_eval(&self, _params: &[f32], _x: &[f32], _y: &[f32], _w: &[f32]) -> Result<f32> {
        bail!(Self::NO_XLA)
    }
}
