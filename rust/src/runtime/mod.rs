//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place the `xla` crate is touched.  Python is never on
//! the tuning path: `make artifacts` runs once at build time, and from
//! then on the Rust binary is self-contained.
//!
//! Interchange format is HLO **text** — jax ≥ 0.5 serializes
//! HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see DESIGN.md §3).

mod engine;
pub use engine::{ArtifactMeta, Engine, TrainOutput};
