//! The AutoTuner: per-task tuning loop + session orchestration.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::session::{Session, TaskResult};
use crate::costmodel::{layout, CostModel, Mask, RustBackend, XlaBackend};
use crate::device::{DeviceArch, DeviceSim, VirtualClock};
use crate::program::{featurize, Schedule, Subgraph, TensorProgram, N_FEATURES};
use crate::runtime::Engine;
use crate::search::{EvolutionarySearch, RandomSearch, SearchPolicy};
use crate::transfer::{self, AdaptiveController, MosesAdapter, Strategy};
use crate::tunecache::{
    warmstart, TuneCache, TuneRecord, WorkloadKey, DEFAULT_NN_K, DEFAULT_NN_RADIUS,
};
use crate::util::rng::Rng;

/// Which compute backend executes the cost model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT Pallas/JAX artifacts via PJRT (production path).
    Xla,
    /// Pure-Rust mirror (artifact-less fallback, tests).
    Rust,
}

impl BackendKind {
    /// Pick the best available backend: XLA when compiled in
    /// (`--features xla`) and the AOT artifacts are present, the
    /// pure-Rust mirror otherwise.
    pub fn auto() -> BackendKind {
        if Engine::xla_available() {
            BackendKind::Xla
        } else {
            BackendKind::Rust
        }
    }
}

/// Cap on warm-start schedules (cross-device plus nearest-neighbor)
/// injected into one task's search population (the evolutionary engine
/// holds up to 32 seeds).
const MAX_WARM_SEEDS: usize = 8;

/// Tuning configuration (one model × one device × one strategy).
#[derive(Debug, Clone)]
pub struct TuneConfig {
    /// Candidate budget per task (TVM's "trials").
    pub trials_per_task: usize,
    /// Candidates measured per round (TVM measure batch).
    pub measure_batch: usize,
    pub strategy: Strategy,
    /// Online learning rate (paper §4: α = 0.001).
    pub lr: f32,
    /// Training epochs over the replay buffer per measured round.
    pub epochs_per_round: usize,
    /// Replay-buffer row cap (most recent kept).
    pub replay_cap: usize,
    pub seed: u64,
    pub backend: BackendKind,
    /// Pre-trained source checkpoint (required by pretrain strategies).
    pub pretrained_path: Option<PathBuf>,
    /// Evolutionary engine parameters.
    pub population: usize,
    pub generations: usize,
    /// On a cache miss with cross-device seeds: how many of the most
    /// promising seeds to verify on-device before the search rounds
    /// (grounds the session's best immediately; the rest only seed the
    /// evolutionary population).
    pub seed_probe: usize,
    /// Nearest-neighbor warm-start radius in normalized descriptor
    /// space; `None` disables the neighbor tier.
    pub nn_radius: Option<f64>,
    /// Neighbor workloads consulted per nearest-neighbor query.
    pub nn_k: usize,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            trials_per_task: 64,
            measure_batch: 8,
            strategy: Strategy::Moses(transfer::MosesConfig::default()),
            lr: 1e-3,
            // One epoch over a 1k replay per round: measured as the best
            // wall-time/quality tradeoff on this CPU testbed
            // (EXPERIMENTS.md §Perf) — the train step is the hot call.
            epochs_per_round: 1,
            replay_cap: 1024,
            seed: 0,
            backend: BackendKind::Rust,
            pretrained_path: None,
            population: 64,
            generations: 3,
            seed_probe: 2,
            nn_radius: Some(DEFAULT_NN_RADIUS),
            nn_k: DEFAULT_NN_K,
        }
    }
}

/// Replay buffer entry: raw measurement for one schedule of one task.
struct Sample {
    task_ord: usize,
    feats: [f32; N_FEATURES],
    gflops: f64,
}

/// The tuner for one (device, strategy) pair.  Reusable across models;
/// the cost model persists across `tune` calls (continual learning).
pub struct AutoTuner {
    pub config: TuneConfig,
    sim: DeviceSim,
    model: CostModel,
    adapter: Option<MosesAdapter>,
    replay: Vec<Sample>,
    best_gflops_per_task: Vec<f64>,
    rng: Rng,
    /// Shared tuning-record store (check-before-search,
    /// commit-after-measure, cross-device warm start).
    cache: Option<Arc<TuneCache>>,
}

impl AutoTuner {
    /// Build a tuner; loads the backend and (if required) the
    /// pre-trained checkpoint.
    pub fn from_config(config: &TuneConfig, target: DeviceArch) -> Result<AutoTuner> {
        let backend: Arc<dyn crate::costmodel::Backend> = match config.backend {
            BackendKind::Rust => Arc::new(RustBackend::default()),
            BackendKind::Xla => {
                let dir = Engine::default_dir();
                Arc::new(XlaBackend { engine: Arc::new(Engine::load(&dir)?) })
            }
        };
        let mut rng = Rng::new(config.seed);
        let pretrained: Option<Vec<f32>> = if config.strategy.uses_pretrained() {
            let path = config
                .pretrained_path
                .as_ref()
                .context("strategy requires --pretrained checkpoint")?;
            Some(layout::load_checkpoint(path)?)
        } else {
            None
        };
        let model =
            transfer::init_model(&config.strategy, backend, pretrained.as_deref(), &mut rng);
        let adapter = match &config.strategy {
            Strategy::Moses(cfg) => Some(MosesAdapter::new(*cfg)),
            _ => None,
        };
        Ok(AutoTuner {
            config: config.clone(),
            sim: DeviceSim::new(target),
            model,
            adapter,
            replay: Vec::new(),
            best_gflops_per_task: Vec::new(),
            rng,
            cache: None,
        })
    }

    /// Build with an externally-constructed model (tests, custom
    /// checkpoints already in memory).
    pub fn with_model(config: &TuneConfig, target: DeviceArch, model: CostModel) -> AutoTuner {
        let adapter = match &config.strategy {
            Strategy::Moses(cfg) => Some(MosesAdapter::new(*cfg)),
            _ => None,
        };
        AutoTuner {
            config: config.clone(),
            sim: DeviceSim::new(target),
            model,
            adapter,
            replay: Vec::new(),
            best_gflops_per_task: Vec::new(),
            rng: Rng::new(config.seed),
            cache: None,
        }
    }

    /// Attach a shared tuning-record store: tasks are checked against it
    /// before searching (an exact hit costs zero measured trials), every
    /// measured outcome is committed back, and on a miss records from
    /// other devices seed the evolutionary population.
    pub fn attach_cache(&mut self, cache: Arc<TuneCache>) {
        self.cache = Some(cache);
    }

    /// Access the underlying cost model (diagnostics).
    pub fn model(&self) -> &CostModel {
        &self.model
    }

    /// The device being tuned for.
    pub fn device_name(&self) -> &str {
        &self.sim.arch.name
    }

    /// Tune a list of tasks; returns the session with aggregate metrics.
    pub fn tune(&mut self, tasks: &[Subgraph]) -> Result<Session> {
        let mut results = Vec::with_capacity(tasks.len());
        let mut clock = VirtualClock::new();
        for (i, task) in tasks.iter().enumerate() {
            let mut task_rng = self.rng.fork(i as u64);
            let res = self.tune_task(task, &mut task_rng, &mut clock)?;
            results.push(res);
        }
        Ok(Session {
            device: self.sim.arch.name.clone(),
            strategy: self.config.strategy.name().to_string(),
            tasks: results,
            clock,
            cache: self.cache.as_ref().map(|c| c.stats()),
        })
    }

    /// Rebuild training arrays from the replay buffer with labels
    /// normalized per task by its best-so-far throughput.
    fn training_arrays(&self) -> (Vec<f32>, Vec<f32>) {
        let mut x = Vec::with_capacity(self.replay.len() * N_FEATURES);
        let mut y = Vec::with_capacity(self.replay.len());
        for s in &self.replay {
            x.extend_from_slice(&s.feats);
            let denom = self.best_gflops_per_task[s.task_ord];
            y.push(if denom > 0.0 { (s.gflops / denom) as f32 } else { 0.0 });
        }
        (x, y)
    }

    fn push_replay(&mut self, sample: Sample) {
        self.replay.push(sample);
        if self.replay.len() > self.config.replay_cap {
            let drop = self.replay.len() - self.config.replay_cap;
            self.replay.drain(..drop);
        }
    }

    /// One task's tuning loop.
    fn tune_task(
        &mut self,
        task: &Subgraph,
        rng: &mut Rng,
        clock: &mut VirtualClock,
    ) -> Result<TaskResult> {
        let geometry = task.geometry();
        let default_sched = Schedule::default_for(&geometry);
        let default_latency =
            self.sim.true_latency(&TensorProgram::new(task.clone(), default_sched));

        // Check the tune cache before searching.  An exact-device hit at
        // a sufficient trial budget reuses the cached best schedule
        // outright — zero measured trials; otherwise the miss may still
        // yield this device's own records (bigger-budget re-search) and
        // cross-device seeds below.
        let mut warm_seeds: Vec<Schedule> = Vec::new();
        let mut neighbor_seeds: Vec<Schedule> = Vec::new();
        let mut local_seeds: Vec<Schedule> = Vec::new();
        if let Some(cache) = self.cache.clone() {
            let plan = warmstart::plan(
                &cache,
                task,
                &self.sim.arch,
                &warmstart::WarmStartOptions {
                    max_seeds: MAX_WARM_SEEDS,
                    requested_trials: self.config.trials_per_task,
                    nn_k: self.config.nn_k,
                    nn_radius: self.config.nn_radius,
                },
            );
            if let Some(rec) = plan.exact {
                let cached = rec.schedule();
                if cached.is_valid(&geometry) {
                    let cached_latency =
                        self.sim.true_latency(&TensorProgram::new(task.clone(), cached));
                    // The default fallback applies to cached choices too.
                    let (best_latency, best_sched) =
                        if cached_latency.is_finite() && cached_latency <= default_latency {
                            (cached_latency, cached)
                        } else {
                            (default_latency, default_sched)
                        };
                    let rounds =
                        (self.config.trials_per_task / self.config.measure_batch).max(1);
                    return Ok(TaskResult {
                        task: task.clone(),
                        best_latency_s: best_latency,
                        best_schedule: best_sched,
                        default_latency_s: default_latency,
                        measured: 0,
                        predicted_only: 0,
                        history: vec![best_latency; rounds],
                        cache_hit: true,
                        warm_seeds: 0,
                        neighbor_seeds: 0,
                    });
                }
            }
            warm_seeds = plan.seeds.iter().map(|s| s.schedule).collect();
            neighbor_seeds = plan.neighbor_seeds.iter().map(|s| s.schedule).collect();
            local_seeds = plan.local_seeds;
        }

        // Non-compute tasks (tiny elementwise/pool) are barely tunable;
        // the loop below handles them fine, they just converge instantly.
        let rounds = (self.config.trials_per_task / self.config.measure_batch).max(1);
        let task_ord = self.best_gflops_per_task.len();
        self.best_gflops_per_task.push(0.0);

        let mut evo = EvolutionarySearch::new(task.clone());
        evo.population = self.config.population;
        evo.generations = self.config.generations;
        let mut random = RandomSearch::new(evo.generator.clone());

        let mut ac = match &self.config.strategy {
            Strategy::Moses(cfg) => {
                Some(AdaptiveController::new(cfg.ac_cv_threshold, cfg.ac_min_batches))
            }
            _ => None,
        };
        let measured_round_budget = match &self.config.strategy {
            Strategy::Moses(cfg) => {
                ((rounds as f64) * cfg.train_fraction).ceil() as usize
            }
            _ => rounds,
        };

        let mut seen_fps: Vec<u64> = Vec::new();
        let fp = |task: &Subgraph, s: &Schedule| {
            TensorProgram::new(task.clone(), *s).fingerprint()
        };

        let mut best_latency = f64::INFINITY;
        let mut best_sched = default_sched;
        let mut measured = 0usize;
        let mut predicted_only = 0usize;
        let mut history = Vec::with_capacity(rounds);
        // Best prediction-only candidate awaiting final verification.
        let mut pending_predicted: Option<(Schedule, f32)> = None;
        // Measured-OK (schedule, true latency) pairs for cache commit.
        let mut cache_outcomes: Vec<(Schedule, f64)> = Vec::new();

        // Re-seed from this device's own cached records (present when a
        // bigger budget than any previous session was requested): their
        // latencies are deterministic ground truth, so ground the best
        // and mark them seen at zero measurement cost.
        for s in &local_seeds {
            let prog = TensorProgram::new(task.clone(), *s);
            let true_lat = self.sim.true_latency(&prog);
            if true_lat < best_latency {
                best_latency = true_lat;
                best_sched = *s;
            }
            seen_fps.push(prog.fingerprint());
            evo.add_seed(*s);
        }

        // Warm start: verify the most promising seeds on device first
        // (grounds the session's best immediately), then hand ALL seeds
        // to the evolutionary engine's population.  Same-workload
        // cross-device seeds rank ahead of similar-workload neighbor
        // seeds in the probe order — they carry no shape mismatch.
        let probe_order: Vec<Schedule> =
            warm_seeds.iter().chain(neighbor_seeds.iter()).copied().collect();
        for (i, s) in probe_order.iter().enumerate() {
            if i < self.config.seed_probe {
                let prog = TensorProgram::new(task.clone(), *s);
                let m = self.sim.measure(&prog, rng);
                clock.charge_measurement(m.cost_s);
                measured += 1;
                seen_fps.push(prog.fingerprint());
                let feats = featurize(task, s);
                let gflops = if m.ok { m.gflops } else { 0.0 };
                if m.ok {
                    let true_lat = self.sim.true_latency(&prog);
                    cache_outcomes.push((*s, true_lat));
                    if true_lat < best_latency {
                        best_latency = true_lat;
                        best_sched = *s;
                    }
                    if gflops > self.best_gflops_per_task[task_ord] {
                        self.best_gflops_per_task[task_ord] = gflops;
                    }
                }
                self.push_replay(Sample { task_ord, feats, gflops });
            }
            evo.add_seed(*s);
        }

        for round in 0..rounds {
            let seen = |s: &Schedule| seen_fps.contains(&fp(task, s));
            let mut charge = || clock.charge_query();
            let candidates = match &self.config.strategy {
                Strategy::RandomSearch => random.propose(
                    self.config.measure_batch,
                    &self.model,
                    &seen,
                    rng,
                    &mut charge,
                ),
                _ => evo.propose(
                    self.config.measure_batch,
                    &self.model,
                    &seen,
                    rng,
                    &mut charge,
                ),
            };
            if candidates.is_empty() {
                break;
            }

            let do_measure = match &self.config.strategy {
                Strategy::TensetPretrain => round == 0 || round == rounds - 1,
                Strategy::Moses(_) => {
                    round < measured_round_budget
                        && ac.as_ref().map(|a| a.keep_measuring()).unwrap_or(true)
                }
                _ => true,
            };

            if do_measure {
                // For pretrain: only verify the single top prediction.
                let to_measure: &[Schedule] = match &self.config.strategy {
                    Strategy::TensetPretrain => &candidates[..1],
                    _ => &candidates[..],
                };
                let mut batch_x = Vec::with_capacity(to_measure.len() * N_FEATURES);
                let mut batch_y = Vec::with_capacity(to_measure.len());
                for s in to_measure {
                    let prog = TensorProgram::new(task.clone(), *s);
                    let m = self.sim.measure(&prog, rng);
                    clock.charge_measurement(m.cost_s);
                    measured += 1;
                    seen_fps.push(prog.fingerprint());
                    let feats = featurize(task, s);
                    let gflops = if m.ok { m.gflops } else { 0.0 };
                    if m.ok {
                        let true_lat = self.sim.true_latency(&prog);
                        cache_outcomes.push((*s, true_lat));
                        if true_lat < best_latency {
                            best_latency = true_lat;
                            best_sched = *s;
                        }
                        evo.add_seed(*s);
                        if gflops > self.best_gflops_per_task[task_ord] {
                            self.best_gflops_per_task[task_ord] = gflops;
                        }
                    }
                    batch_x.extend_from_slice(&feats);
                    batch_y.push(gflops as f32);
                    self.push_replay(Sample { task_ord, feats, gflops });
                }

                if self.config.strategy.trains_online() {
                    // Mask + variant decay per strategy.
                    let denom = self.best_gflops_per_task[task_ord].max(1e-9) as f32;
                    let y_norm: Vec<f32> = batch_y.iter().map(|g| g / denom).collect();
                    let (mask, wd) = if let Some(ad) = self.adapter.as_mut() {
                        if ad.maybe_refresh(&self.model, &batch_x, &y_norm)? {
                            clock.charge_xi();
                        }
                        (ad.mask().clone(), ad.weight_decay())
                    } else {
                        (Mask::all_ones(layout::N_PARAMS), 0.0)
                    };
                    let (tx, ty) = self.training_arrays();
                    let bt = 256; // backend train batch (both backends)
                    let steps_per_epoch = ty.len().div_ceil(bt).max(1);
                    for _ in 0..self.config.epochs_per_round {
                        self.model.train_epoch(&tx, &ty, &mask, self.config.lr, wd, rng)?;
                        for _ in 0..steps_per_epoch {
                            clock.charge_update();
                        }
                    }
                }

                // AC watches post-update prediction stability on the
                // just-measured batch.
                if let Some(a) = ac.as_mut() {
                    let preds = self.model.predict(&batch_x, batch_y.len())?;
                    clock.charge_query();
                    a.observe_batch(&preds);
                }
            } else {
                // Prediction-only round: trust the model's ranking for
                // the batch, but VERIFY the top prediction with one cheap
                // measurement (1 vs measure_batch) so the final choice is
                // grounded — the AC saves the other 7/8ths.
                predicted_only += candidates.len().saturating_sub(1);
                let mut cx = Vec::with_capacity(candidates.len() * N_FEATURES);
                for s in &candidates {
                    cx.extend_from_slice(&featurize(task, s));
                    seen_fps.push(fp(task, s));
                }
                let preds = self.model.predict(&cx, candidates.len())?;
                clock.charge_query();
                // Non-finite predictions must neither panic the ranking
                // nor win it; all-NaN degrades to the first candidate.
                let top = preds
                    .iter()
                    .enumerate()
                    .filter(|(_, p)| p.is_finite())
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                let prog = TensorProgram::new(task.clone(), candidates[top]);
                let meas = self.sim.measure(&prog, rng);
                clock.charge_measurement(meas.cost_s);
                measured += 1;
                if meas.ok {
                    let true_lat = self.sim.true_latency(&prog);
                    cache_outcomes.push((candidates[top], true_lat));
                    if true_lat < best_latency {
                        best_latency = true_lat;
                        best_sched = candidates[top];
                    }
                    evo.add_seed(candidates[top]);
                }
                for (i, (s, &p)) in candidates.iter().zip(&preds).enumerate() {
                    if i == top {
                        continue;
                    }
                    if pending_predicted.map(|(_, bp)| p > bp).unwrap_or(true) {
                        pending_predicted = Some((*s, p));
                    }
                }
            }
            history.push(if best_latency.is_finite() { best_latency } else { default_latency });
        }

        // Verify the best prediction-only candidate with one final
        // measurement (TVM always builds/measures the final choice).
        if let Some((s, _)) = pending_predicted {
            let prog = TensorProgram::new(task.clone(), s);
            let m = self.sim.measure(&prog, rng);
            clock.charge_measurement(m.cost_s);
            measured += 1;
            if m.ok {
                let true_lat = self.sim.true_latency(&prog);
                cache_outcomes.push((s, true_lat));
                if true_lat < best_latency {
                    best_latency = true_lat;
                    best_sched = s;
                }
            }
        }

        // The default schedule is always available at deploy time: if the
        // search never beat it (tiny budgets, unlucky measurements), ship
        // the default — as TVM's fallback configuration does.
        if !best_latency.is_finite() || best_latency > default_latency {
            best_latency = default_latency;
            best_sched = default_sched;
        }

        // Commit measured outcomes plus the final choice, so later
        // sessions — on this device or others — can warm start.
        if let Some(cache) = &self.cache {
            let key = WorkloadKey::new(task, &self.sim.arch);
            let desc = task.descriptor();
            cache_outcomes.push((best_sched, best_latency));
            for (sched, lat) in &cache_outcomes {
                let gflops = task.flops() / lat.max(1e-12) / 1e9;
                cache.commit(TuneRecord::new(
                    key,
                    desc,
                    &self.sim.arch.name,
                    sched,
                    *lat,
                    gflops,
                    self.config.trials_per_task,
                ));
            }
        }

        Ok(TaskResult {
            task: task.clone(),
            best_latency_s: best_latency,
            best_schedule: best_sched,
            default_latency_s: default_latency,
            measured,
            predicted_only,
            history,
            cache_hit: false,
            warm_seeds: warm_seeds.len(),
            neighbor_seeds: neighbor_seeds.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::program::SubgraphKind;

    fn small_cfg(strategy: Strategy) -> TuneConfig {
        TuneConfig {
            trials_per_task: 24,
            measure_batch: 4,
            strategy,
            epochs_per_round: 1,
            population: 24,
            generations: 2,
            backend: BackendKind::Rust,
            seed: 42,
            ..TuneConfig::default()
        }
    }

    fn tiny_tasks() -> Vec<Subgraph> {
        vec![
            Subgraph::new(
                "tt.conv",
                SubgraphKind::Conv2d {
                    n: 1, h: 28, w: 28, cin: 64, cout: 64, kh: 3, kw: 3, stride: 1, pad: 1,
                },
            ),
            Subgraph::new("tt.dense", SubgraphKind::Dense { m: 64, n: 512, k: 512 }),
        ]
    }

    #[test]
    fn ansor_random_improves_over_default() {
        let cfg = small_cfg(Strategy::AnsorRandom);
        let mut tuner = AutoTuner::from_config(&cfg, presets::rtx_2060()).unwrap();
        let session = tuner.tune(&tiny_tasks()).unwrap();
        assert_eq!(session.tasks.len(), 2);
        assert!(
            session.speedup() > 1.0,
            "tuning should beat the default schedule: {}",
            session.speedup()
        );
        assert!(session.search_time_s() > 0.0);
        assert!(session.total_measurements() > 0);
    }

    #[test]
    fn random_search_also_works() {
        let cfg = small_cfg(Strategy::RandomSearch);
        let mut tuner = AutoTuner::from_config(&cfg, presets::jetson_tx2()).unwrap();
        let session = tuner.tune(&tiny_tasks()[..1]).unwrap();
        assert!(session.tasks[0].best_latency_s.is_finite());
        assert!(session.tasks[0].best_latency_s <= session.tasks[0].default_latency_s * 1.01);
    }

    #[test]
    fn moses_uses_fewer_measurements_than_finetune() {
        let mut rng = Rng::new(0);
        let backend: Arc<dyn crate::costmodel::Backend> = Arc::new(RustBackend::default());
        let pre = layout::init_params(&mut rng);

        let cfg_ft = small_cfg(Strategy::TensetFinetune);
        let model_ft = CostModel::with_params(backend.clone(), pre.clone());
        let mut t_ft = AutoTuner::with_model(&cfg_ft, presets::jetson_tx2(), model_ft);
        let s_ft = t_ft.tune(&tiny_tasks()).unwrap();

        let cfg_mo = small_cfg(Strategy::Moses(transfer::MosesConfig::default()));
        let model_mo = CostModel::with_params(backend, pre);
        let mut t_mo = AutoTuner::with_model(&cfg_mo, presets::jetson_tx2(), model_mo);
        let s_mo = t_mo.tune(&tiny_tasks()).unwrap();

        assert!(
            s_mo.total_measurements() < s_ft.total_measurements(),
            "moses {} vs finetune {}",
            s_mo.total_measurements(),
            s_ft.total_measurements()
        );
        assert!(s_mo.search_time_s() < s_ft.search_time_s());
    }

    #[test]
    fn history_is_monotone_nonincreasing() {
        let cfg = small_cfg(Strategy::AnsorRandom);
        let mut tuner = AutoTuner::from_config(&cfg, presets::rtx_2080()).unwrap();
        let session = tuner.tune(&tiny_tasks()[..1]).unwrap();
        let h = &session.tasks[0].history;
        for w in h.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "history not monotone: {h:?}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg(Strategy::AnsorRandom);
        let run = || {
            let mut tuner = AutoTuner::from_config(&cfg, presets::rtx_2060()).unwrap();
            tuner.tune(&tiny_tasks()).unwrap().total_best_latency_ms()
        };
        assert_eq!(run(), run());
    }
}
