//! The AutoTuner: session orchestration over the staged task pipeline.
//!
//! Per-task tuning state lives in [`super::pipeline::TaskPipeline`];
//! everything that learns lives in [`super::learner::Learner`].  The
//! tuner is the driver tying them together, in one of two modes:
//!
//! * `jobs == 1` — **inline**: tasks run one after another on the
//!   calling thread, the learner absorbs each stage's batch
//!   synchronously, and predictions read the live model through a
//!   fresh [`crate::costmodel::Predictor`] view per stage.  This is
//!   exactly the classic sequential tuning loop.
//! * `jobs > 1` — **scheduled**: tasks become stealable units on the
//!   work-stealing board ([`super::sched`]), driven by `jobs` always-
//!   saturated workers while one learner actor consumes their batches.
//!   The learner applies batches in the fixed `(round, task)` order and
//!   publishes per-task `Arc<ModelState>` snapshots that units pin
//!   their next predictions to — publish and pin are pointer swaps, so
//!   the hot prediction path never copies the parameter vector.  Each
//!   task's next round pins exactly the snapshot its own last batch
//!   produced, so results are a deterministic function of
//!   `(seed, tasks)` — independent even of the worker count — while
//!   the schedule itself stays free to chase stragglers.
//!   [`AutoTunerBuilder::fast_nondeterministic`] drops the pinning for
//!   maximum throughput at the cost of bit-reproducibility.
//!
//! Tuners are constructed through [`AutoTuner::builder`], which
//! validates incompatible knob combinations (XLA backend with worker
//! threads, pretrain strategies without a checkpoint, empty budgets) at
//! build time instead of deep inside a running session.  [`TuneConfig`]
//! remains the flat serialized form the builder produces.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::learner::{
    run_learner_actor, Learner, LearnerConfig, LearnerState, ModelSnapshot, ToLearner,
};
use super::pipeline::{StageOutput, TaskPipeline};
use super::sched::{self, Board, TaskUnit};
use super::session::{Session, TaskResult};
use crate::costmodel::{layout, Backend, CostModel, RustBackend, XlaBackend};
use crate::device::{DeviceArch, DeviceSim, SessionTiming};
use crate::metrics::search::DraftCounters;
use crate::obs::{Lane, Recorder};
use crate::program::Subgraph;
use crate::runtime::Engine;
use crate::transfer::{self, MosesAdapter, Strategy};
use crate::tunecache::{FsyncPolicy, TuneCache, DEFAULT_NN_K, DEFAULT_NN_RADIUS};
use crate::util::rng::Rng;

/// Which compute backend executes the cost model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT Pallas/JAX artifacts via PJRT (production path).
    Xla,
    /// Pure-Rust mirror (artifact-less fallback, tests).
    Rust,
}

impl BackendKind {
    /// Pick the best available backend: XLA when compiled in
    /// (`--features xla`) and the AOT artifacts are present, the
    /// pure-Rust mirror otherwise.
    pub fn auto() -> BackendKind {
        if Engine::xla_available() {
            BackendKind::Xla
        } else {
            BackendKind::Rust
        }
    }
}

/// Tuning configuration (one model × one device × one strategy).
///
/// This is the *serialized* form of a tuner: flat, `Clone`, and stable
/// across CLI flags and experiment grids.  Construct tuners through
/// [`AutoTuner::builder`] (which produces and validates one of these);
/// pass an existing config through
/// [`AutoTunerBuilder::config`] to migrate mechanically.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    /// Candidate budget per task (TVM's "trials").
    pub trials_per_task: usize,
    /// Candidates measured per round (TVM measure batch).
    pub measure_batch: usize,
    pub strategy: Strategy,
    /// Online learning rate (paper §4: α = 0.001).
    pub lr: f32,
    /// Training epochs over the replay buffer per measured round.
    pub epochs_per_round: usize,
    /// Replay-buffer row cap (most recent kept).
    pub replay_cap: usize,
    pub seed: u64,
    pub backend: BackendKind,
    /// Pre-trained source checkpoint (required by pretrain strategies).
    pub pretrained_path: Option<PathBuf>,
    /// Evolutionary engine parameters.
    pub population: usize,
    pub generations: usize,
    /// On a cache miss with cross-device seeds: how many of the most
    /// promising seeds to verify on-device before the search rounds
    /// (grounds the session's best immediately; the rest only seed the
    /// evolutionary population).
    pub seed_probe: usize,
    /// Nearest-neighbor warm-start radius in normalized descriptor
    /// space; `None` disables the neighbor tier.
    pub nn_radius: Option<f64>,
    /// Neighbor workloads consulted per nearest-neighbor query.
    pub nn_k: usize,
    /// Concurrent task pipelines per session (1 = the classic
    /// sequential loop).  Requires the rust backend when > 1.
    pub jobs: usize,
    /// Deterministic scheduled sessions (the default): the learner
    /// applies batches in the fixed `(round, task)` order and each task
    /// pins the snapshot its own last batch produced, so results are a
    /// pure function of `(seed, tasks)`.  `false` is the documented
    /// `--fast-nondeterministic` mode: units pin the newest published
    /// model instead and never park — valid results, no bit-pinning.
    /// Ignored at `jobs == 1` (the inline loop is inherently ordered).
    pub deterministic: bool,
    /// Rust-backend batch geometry (the parallel learner/worker threads
    /// construct their own backends from these; the XLA geometry is
    /// fixed by the AOT artifacts).
    pub rust_pred_batch: usize,
    pub rust_train_batch: usize,
    /// Speculative draft-then-verify search: the learner distills a
    /// cheap linear draft scorer from the live cost model and publishes
    /// it alongside each snapshot; the evolutionary engine lets the
    /// draft prune each generation and asks the full predictor to
    /// verify only the survivors.  Requires the rust backend.
    pub draft: bool,
    /// Fraction of each draft-scored generation the full predictor
    /// verifies (`0 < keep <= 1`; `1.0` reproduces `draft: false` bit
    /// for bit).
    pub draft_keep: f64,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            trials_per_task: 64,
            measure_batch: 8,
            strategy: Strategy::Moses(transfer::MosesConfig::default()),
            lr: 1e-3,
            // One epoch over a 1k replay per round: measured as the best
            // wall-time/quality tradeoff on this CPU testbed
            // (EXPERIMENTS.md §Perf) — the train step is the hot call.
            epochs_per_round: 1,
            replay_cap: 1024,
            seed: 0,
            backend: BackendKind::Rust,
            pretrained_path: None,
            population: 64,
            generations: 3,
            seed_probe: 2,
            nn_radius: Some(DEFAULT_NN_RADIUS),
            nn_k: DEFAULT_NN_K,
            jobs: 1,
            deterministic: true,
            rust_pred_batch: 512,
            rust_train_batch: 256,
            draft: false,
            draft_keep: 0.2,
        }
    }
}

impl TuneConfig {
    fn learner_config(&self) -> LearnerConfig {
        LearnerConfig {
            lr: self.lr,
            epochs_per_round: self.epochs_per_round,
            replay_cap: self.replay_cap,
            draft: self.draft,
        }
    }
}

/// Builder for [`AutoTuner`]: typed knobs with build-time validation.
///
/// ```no_run
/// # fn main() -> anyhow::Result<()> {
/// use moses::coordinator::AutoTuner;
/// use moses::device::presets;
/// use moses::transfer::Strategy;
///
/// let mut tuner = AutoTuner::builder(presets::jetson_tx2())
///     .trials(64)
///     .strategy(Strategy::AnsorRandom)
///     .jobs(4)
///     .build()?;
/// # Ok(())
/// # }
/// ```
///
/// Incompatible combinations (worker threads on the thread-pinned XLA
/// backend, a pretrain strategy without a checkpoint or in-memory
/// model, zero budgets, a non-finite neighbor radius) are rejected by
/// [`AutoTunerBuilder::build`] with an error — never a panic deep
/// inside a running session.
#[must_use = "call .build() to construct the tuner"]
pub struct AutoTunerBuilder {
    target: DeviceArch,
    cfg: TuneConfig,
    model: Option<CostModel>,
    cache: Option<Arc<TuneCache>>,
    cache_path: Option<PathBuf>,
    cache_fsync: FsyncPolicy,
    recorder: Recorder,
}

impl AutoTunerBuilder {
    /// Start from an existing serialized [`TuneConfig`] (CLI flags,
    /// experiment grids) instead of the defaults.  This REPLACES the
    /// builder's whole config, so call it first: typed setters invoked
    /// before it are discarded, setters invoked after it override
    /// individual fields of `cfg`.
    pub fn config(mut self, cfg: &TuneConfig) -> Self {
        self.cfg = cfg.clone();
        self
    }

    /// Candidate budget per task (TVM's "trials").
    pub fn trials(mut self, trials: usize) -> Self {
        self.cfg.trials_per_task = trials;
        self
    }

    /// Candidates measured per round (TVM measure batch).
    pub fn measure_batch(mut self, batch: usize) -> Self {
        self.cfg.measure_batch = batch;
        self
    }

    /// Cost-model initialization/update strategy.
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.cfg.strategy = strategy;
        self
    }

    /// RNG seed; sessions are bit-reproducible per `(seed, jobs)`.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Compute backend for the cost model.
    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.cfg.backend = backend;
        self
    }

    /// Concurrent task pipelines per session (rust backend only for
    /// `jobs > 1` — validated at build time).
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.cfg.jobs = jobs;
        self
    }

    /// Drop the scheduler's deterministic snapshot pinning
    /// (`--fast-nondeterministic`): blocked tasks pin the newest
    /// published model instead of the one their own last batch produced,
    /// and the learner absorbs batches in arrival order.  Results stay
    /// valid but are no longer bit-reproducible across runs.  Only
    /// meaningful with `jobs > 1`.
    pub fn fast_nondeterministic(mut self, fast: bool) -> Self {
        self.cfg.deterministic = !fast;
        self
    }

    /// Evolutionary engine population/generation parameters.
    pub fn search_params(mut self, population: usize, generations: usize) -> Self {
        self.cfg.population = population;
        self.cfg.generations = generations;
        self
    }

    /// Nearest-neighbor warm-start radius (`None` disables the tier).
    pub fn nn(mut self, radius: Option<f64>) -> Self {
        self.cfg.nn_radius = radius;
        self
    }

    /// Neighbor workloads consulted per nearest-neighbor query.
    pub fn nn_k(mut self, k: usize) -> Self {
        self.cfg.nn_k = k;
        self
    }

    /// Pre-trained source checkpoint to load at build time (required by
    /// pretrain strategies unless an in-memory [`AutoTunerBuilder::model`]
    /// is supplied).
    pub fn pretrained(mut self, path: impl Into<PathBuf>) -> Self {
        self.cfg.pretrained_path = Some(path.into());
        self
    }

    /// Rust-backend batch geometry (predict rows, train rows).
    pub fn rust_batches(mut self, pred: usize, train: usize) -> Self {
        self.cfg.rust_pred_batch = pred;
        self.cfg.rust_train_batch = train;
        self
    }

    /// Enable the speculative draft-then-verify search tier: a cheap
    /// linear draft scorer (distilled from the live cost model) prunes
    /// each evolutionary generation before the full predictor ranks the
    /// survivors.  Requires the rust backend — validated at build time.
    pub fn draft(mut self, on: bool) -> Self {
        self.cfg.draft = on;
        self
    }

    /// Fraction of each draft-scored generation the full predictor
    /// verifies (`0 < keep <= 1` — validated at build time; `1.0` is
    /// bit-identical to draft off).
    pub fn draft_keep(mut self, keep: f64) -> Self {
        self.cfg.draft_keep = keep;
        self
    }

    /// Use an externally-constructed cost model (tests, checkpoints
    /// already in memory) instead of initializing one per the strategy.
    pub fn model(mut self, model: CostModel) -> Self {
        self.model = Some(model);
        self
    }

    /// Attach a shared tuning-record store: tasks are checked against it
    /// before searching (an exact hit costs zero measured trials), every
    /// measured outcome is committed back, and on a miss records from
    /// other devices seed the evolutionary population.
    pub fn cache(mut self, cache: Arc<TuneCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Open (or create) the tuning-record store at `path` during
    /// [`AutoTunerBuilder::build`] — the convenience form of
    /// [`AutoTunerBuilder::cache`] for callers without their own
    /// [`TuneCache`] handle.  `path` is a segmented cache directory
    /// safe to share across concurrent tuner processes; a legacy
    /// single-file JSONL log is imported read-only.  Mutually
    /// exclusive with `.cache(..)`.
    pub fn cache_path(mut self, path: impl Into<PathBuf>) -> Self {
        self.cache_path = Some(path.into());
        self
    }

    /// Segment-append durability for a [`AutoTunerBuilder::cache_path`]
    /// store (ignored for an externally-opened `.cache(..)`).
    pub fn cache_fsync(mut self, fsync: FsyncPolicy) -> Self {
        self.cache_fsync = fsync;
        self
    }

    /// Record sessions into `recorder` (see [`crate::obs`]): pipeline
    /// stages, learner batches and snapshot publish/pin events become
    /// trace spans.  The default is a disabled recorder, whose
    /// instrumentation cost is one branch per span.
    pub fn trace(mut self, recorder: Recorder) -> Self {
        self.recorder = recorder;
        self
    }

    /// Validate the configuration and construct the tuner.
    pub fn build(self) -> Result<AutoTuner> {
        let cfg = &self.cfg;
        anyhow::ensure!(cfg.trials_per_task >= 1, "trials_per_task must be at least 1");
        anyhow::ensure!(cfg.measure_batch >= 1, "measure_batch must be at least 1");
        anyhow::ensure!(
            cfg.population >= 2,
            "evolutionary population must hold at least 2 members (got {})",
            cfg.population
        );
        anyhow::ensure!(cfg.jobs >= 1, "jobs must be at least 1");
        anyhow::ensure!(
            cfg.jobs == 1 || cfg.backend == BackendKind::Rust,
            "--jobs {} requires the rust cost-model backend: the XLA/PJRT client \
             is pinned to its creating thread",
            cfg.jobs
        );
        if let Some(r) = cfg.nn_radius {
            anyhow::ensure!(
                r.is_finite() && r >= 0.0,
                "nearest-neighbor radius must be a non-negative finite number (got {r})"
            );
        }
        anyhow::ensure!(
            cfg.rust_pred_batch >= 1 && cfg.rust_train_batch >= 1,
            "rust backend batch geometry must be non-zero"
        );
        anyhow::ensure!(
            cfg.draft_keep.is_finite() && cfg.draft_keep > 0.0 && cfg.draft_keep <= 1.0,
            "draft_keep must be in (0, 1] (got {})",
            cfg.draft_keep
        );
        anyhow::ensure!(
            !cfg.draft || cfg.backend == BackendKind::Rust,
            "--draft requires the rust cost-model backend: the draft scorer distills \
             from the in-memory parameter vector"
        );
        anyhow::ensure!(
            self.cache.is_none() || self.cache_path.is_none(),
            "supply either .cache(..) or .cache_path(..), not both"
        );
        let cache = match (&self.cache, &self.cache_path) {
            (Some(c), _) => Some(c.clone()),
            (None, Some(path)) => Some(Arc::new(
                TuneCache::builder(path)
                    .fsync(self.cache_fsync)
                    .open()
                    .with_context(|| format!("opening tune cache at {path:?}"))?,
            )),
            (None, None) => None,
        };

        let mut rng = Rng::new(cfg.seed);
        let model = match self.model {
            Some(model) => model,
            None => {
                let backend: Arc<dyn Backend> = match cfg.backend {
                    // The configured geometry, so inline (`--jobs 1`)
                    // training partitions minibatches exactly like the
                    // parallel learner actor rebuilding its backend from
                    // the same fields.
                    BackendKind::Rust => Arc::new(RustBackend {
                        pred_batch: cfg.rust_pred_batch,
                        train_batch: cfg.rust_train_batch,
                    }),
                    BackendKind::Xla => {
                        let dir = Engine::default_dir();
                        Arc::new(XlaBackend { engine: Arc::new(Engine::load(&dir)?) })
                    }
                };
                let pretrained: Option<Vec<f32>> = if cfg.strategy.uses_pretrained() {
                    let Some(path) = cfg.pretrained_path.as_ref() else {
                        anyhow::bail!(
                            "strategy '{}' requires a pre-trained checkpoint: supply \
                             .pretrained(path) or an in-memory .model(..)",
                            cfg.strategy.name()
                        );
                    };
                    Some(layout::load_checkpoint(path)?)
                } else {
                    None
                };
                transfer::init_model(&cfg.strategy, backend, pretrained.as_deref(), &mut rng)
            }
        };
        let adapter = match &cfg.strategy {
            Strategy::Moses(c) => Some(MosesAdapter::new(*c)),
            _ => None,
        };
        Ok(AutoTuner {
            config: self.cfg.clone(),
            sim: DeviceSim::new(self.target),
            rng,
            cache,
            learner: Some(Learner::new(self.cfg.learner_config(), model, adapter)),
            recorder: self.recorder,
        })
    }
}

/// The tuner for one (device, strategy) pair.  Reusable across models;
/// the learner (cost model + replay) persists across `tune` calls
/// (continual learning).  Construct via [`AutoTuner::builder`].
pub struct AutoTuner {
    pub config: TuneConfig,
    sim: DeviceSim,
    rng: Rng,
    /// Shared tuning-record store (check-before-search,
    /// commit-after-measure, cross-device warm start).
    cache: Option<Arc<TuneCache>>,
    /// The learning plane.  `None` only transiently while a parallel
    /// session owns the state on the actor thread.
    learner: Option<Learner>,
    /// Session trace sink (disabled by default).
    recorder: Recorder,
}

impl AutoTuner {
    /// Start building a tuner for `target` with default knobs.
    pub fn builder(target: DeviceArch) -> AutoTunerBuilder {
        AutoTunerBuilder {
            target,
            cfg: TuneConfig::default(),
            model: None,
            cache: None,
            cache_path: None,
            cache_fsync: FsyncPolicy::default(),
            recorder: Recorder::default(),
        }
    }

    /// Access the underlying cost model (diagnostics).
    pub fn model(&self) -> &CostModel {
        self.learner.as_ref().expect("learner state present").model()
    }

    /// The device being tuned for.
    pub fn device_name(&self) -> &str {
        &self.sim.arch.name
    }

    /// Tune a list of tasks; returns the session with aggregate metrics.
    pub fn tune(&mut self, tasks: &[Subgraph]) -> Result<Session> {
        let jobs = self.config.jobs.max(1).min(tasks.len().max(1));
        if jobs < self.config.jobs {
            crate::warn!(
                "--jobs {} exceeds the session's {} task(s); running {} worker(s)",
                self.config.jobs,
                tasks.len(),
                jobs
            );
        }
        if jobs <= 1 {
            self.tune_inline(tasks)
        } else {
            // Backstop for configs mutated after build(): the builder
            // already rejects this combination.
            anyhow::ensure!(
                self.config.backend == BackendKind::Rust,
                "--jobs {jobs} requires the rust cost-model backend: the XLA/PJRT client \
                 is pinned to its creating thread"
            );
            self.tune_parallel(tasks, jobs)
        }
    }

    /// Fresh per-session draft kept/pruned counters (`None` with the
    /// draft tier off), adopted into the session recorder's metrics
    /// registry so traced sessions fold them into the trace footer.
    fn draft_counters(&self) -> Option<DraftCounters> {
        if !self.config.draft {
            return None;
        }
        let counters = DraftCounters::default();
        if let Some(m) = self.recorder.metrics() {
            m.adopt(counters.registry());
        }
        Some(counters)
    }

    fn session(&self, tasks: Vec<TaskResult>, timing: SessionTiming) -> Session {
        Session {
            device: self.sim.arch.name.clone(),
            strategy: self.config.strategy.name().to_string(),
            tasks,
            wall_s: timing.wall_s(),
            wave_wall_s: timing.wave_wall_s(),
            clock: timing.into_cost(),
            cache: self.cache.as_ref().map(|c| c.stats()),
        }
    }

    /// The classic sequential loop: one pipeline at a time, the learner
    /// absorbing synchronously, every stage predicting through a fresh
    /// view of the live model.
    fn tune_inline(&mut self, tasks: &[Subgraph]) -> Result<Session> {
        let draft_counters = self.draft_counters();
        let use_draft = self.config.draft;
        let learner = self.learner.as_mut().expect("learner state present");
        learner.reset_task_clocks();
        learner.set_scope(self.recorder.scope(Lane::Learner, "learner"));
        let ord_base = learner.task_count();
        let mut results = Vec::with_capacity(tasks.len());
        let mut timing = SessionTiming::new();
        for (i, task) in tasks.iter().enumerate() {
            let trng = self.rng.fork(i as u64);
            let mut pipe = TaskPipeline::new(
                task.clone(),
                ord_base + i,
                &self.config,
                self.sim.clone(),
                self.cache.clone(),
                trng,
                self.recorder.scope(Lane::Task(ord_base + i), &task.name),
            );
            if let Some(c) = &draft_counters {
                pipe.set_draft_counters(c.clone());
            }
            let result = match pipe.warm_start()? {
                StageOutput::Complete(r) => *r,
                StageOutput::Learn(batch) => {
                    learner.absorb(batch, pipe.rng_mut())?;
                    loop {
                        // A fresh O(1) view per round: inline predictions
                        // track the live model exactly as the sequential
                        // loop did.  The draft (when on) re-distills at
                        // the same points the model view refreshes, so
                        // the pair stays as consistent as a published
                        // snapshot's.
                        let draft = if use_draft { Some(learner.draft_state()) } else { None };
                        match pipe.run_round(&learner.predictor(), draft.as_deref())? {
                            StageOutput::Learn(b) => learner.absorb(b, pipe.rng_mut())?,
                            StageOutput::Exhausted => break,
                            StageOutput::Complete(_) => unreachable!("rounds never complete"),
                        }
                    }
                    pipe.finalize(&learner.predictor())?
                }
                StageOutput::Exhausted => unreachable!("warm start never exhausts"),
            };
            let mut task_clock = pipe.clock();
            task_clock.merge(&learner.task_clock(ord_base + i));
            timing.add_wave(std::slice::from_ref(&task_clock));
            results.push(result);
        }
        Ok(self.session(results, timing))
    }

    /// Scheduled sessions: tasks become stealable [`TaskUnit`]s on a
    /// work-stealing [`Board`], driven by `jobs` workers that stay
    /// saturated (steal-on-idle) while one learner actor consumes their
    /// batches in the deterministic `(round, task)` order and publishes
    /// per-task model snapshots.  Wall time is the makespan of the
    /// schedule the task costs induce
    /// ([`SessionTiming::from_schedule`]); cache commits are deferred
    /// and landed in task order after the scheduler is done.
    fn tune_parallel(&mut self, tasks: &[Subgraph], jobs: usize) -> Result<Session> {
        let lcfg = self.config.learner_config();
        let deterministic = self.config.deterministic;
        let (ord_base, backend_home, state) = {
            let learner = self.learner.as_mut().expect("learner state present");
            learner.reset_task_clocks();
            let ord_base = learner.task_count();
            let backend_home = learner.model().backend_handle();
            let state = self.learner.take().expect("learner state present").into_state();
            (ord_base, backend_home, state)
        };
        let backup = state.clone();
        let cfg = self.config.clone();
        let n_tasks = tasks.len();

        let (tx, rx) = mpsc::channel::<ToLearner>();
        // Slot 0 of every task: the pre-session state, shared by
        // pointer.  Its draft is None — before any batch is absorbed
        // there is nothing to distill from, so round 0 verifies
        // everything (exactly what a passthrough draft would do).
        let init = ModelSnapshot::from_model(Arc::new(state.model.clone()));
        let draft_counters = self.draft_counters();
        let mut units = Vec::with_capacity(n_tasks);
        for (i, task) in tasks.iter().enumerate() {
            let mut pipe = TaskPipeline::new(
                task.clone(),
                ord_base + i,
                &cfg,
                self.sim.clone(),
                self.cache.clone(),
                self.rng.fork(i as u64),
                self.recorder.scope(Lane::Task(ord_base + i), &task.name),
            );
            if self.cache.is_some() {
                pipe.defer_cache_commits();
            }
            if let Some(c) = &draft_counters {
                pipe.set_draft_counters(c.clone());
            }
            units.push(TaskUnit::new(i, ord_base + i, pipe, tx.clone()));
        }
        // The units hold the only senders the learner should wait on.
        drop(tx);
        let board = Board::new(ord_base, jobs, deterministic, init, units);
        let board_ref = &board;

        let mut actor_err: Option<anyhow::Error> = None;
        let mut worker_panic = false;
        let learner_state: Option<LearnerState> = std::thread::scope(|s| {
            let actor = {
                let pred_batch = cfg.rust_pred_batch;
                let train_batch = cfg.rust_train_batch;
                let actor_rec = self.recorder.clone();
                let ords: Vec<usize> = (0..n_tasks).map(|i| ord_base + i).collect();
                s.spawn(move || -> Result<LearnerState> {
                    // Poison the board on EVERY actor exit — including
                    // panics, which would otherwise leave parked units
                    // waiting forever.  On a normal exit every unit has
                    // already finished, so the extra poison wakes
                    // nobody.
                    struct PoisonOnExit<'a>(&'a Board);
                    impl Drop for PoisonOnExit<'_> {
                        fn drop(&mut self) {
                            self.0.poison();
                        }
                    }
                    let _poison_guard = PoisonOnExit(board_ref);
                    let backend: Arc<dyn Backend> =
                        Arc::new(RustBackend { pred_batch, train_batch });
                    let mut learner = Learner::from_state(lcfg, backend, state);
                    learner.set_scope(actor_rec.scope(Lane::Learner, "learner"));
                    run_learner_actor(learner, ords, rx, board_ref, deterministic)
                        .map(Learner::into_state)
                })
            };
            // The workers: each owns its backend handle (the rust
            // backend is cheap to clone-construct; the XLA backend is
            // rejected at build time for jobs > 1) and a sched-lane
            // trace scope for its steal/park/resume events.
            let workers: Vec<_> = (0..jobs)
                .map(|w| {
                    let backend: Arc<dyn Backend> = Arc::new(RustBackend {
                        pred_batch: cfg.rust_pred_batch,
                        train_batch: cfg.rust_train_batch,
                    });
                    let scope = self.recorder.scope(Lane::Sched(w), "sched");
                    s.spawn(move || sched::run_worker(w, board_ref, backend, scope))
                })
                .collect();
            for h in workers {
                if h.join().is_err() {
                    worker_panic = true;
                }
            }
            // Safety valve: drop any units a crashed worker left behind
            // so their Finished markers release the learner's sweep (a
            // clean run leaves nothing to abandon).
            board_ref.abandon();
            match actor.join() {
                Ok(Ok(st)) => Some(st),
                Ok(Err(e)) => {
                    // The learner's own error is the root cause; the
                    // units' "no further snapshots" failures are its
                    // side effects — report the cause, not a symptom.
                    actor_err = Some(e);
                    None
                }
                Err(_) => {
                    actor_err = Some(anyhow::anyhow!("learner thread panicked"));
                    None
                }
            }
        });

        // Restore the learning plane (continual learning across calls);
        // fall back to the pre-session state if the actor was lost.
        let (outputs, sched_err) = board.into_results();
        let mut lstate = learner_state.unwrap_or(backup);
        let learn_clocks = std::mem::take(&mut lstate.task_clocks);
        self.learner = Some(Learner::from_state(lcfg, backend_home, lstate));
        if let Some(e) = actor_err {
            return Err(e);
        }
        if let Some(e) = sched_err {
            return Err(e);
        }
        anyhow::ensure!(!worker_panic, "scheduler worker panicked");

        let mut results = Vec::with_capacity(n_tasks);
        let mut members = Vec::with_capacity(n_tasks);
        let mut deferred = Vec::with_capacity(n_tasks);
        for (i, out) in outputs.into_iter().enumerate() {
            let out = out.expect("task output present");
            let mut clock = out.clock;
            if let Some(lc) = learn_clocks.get(ord_base + i) {
                clock.merge(lc);
            }
            members.push(clock);
            results.push(out.result);
            deferred.push(out.commits);
        }
        // Land the deferred cache commits in task order: what future
        // sessions warm start from is independent of this session's
        // thread timing (siblings within the session never observe
        // mid-flight commits at all).
        if let Some(cache) = &self.cache {
            for rec in deferred.into_iter().flatten() {
                cache.commit(rec);
            }
        }
        Ok(self.session(results, SessionTiming::from_schedule(&members, jobs)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::program::SubgraphKind;

    fn small_cfg(strategy: Strategy) -> TuneConfig {
        TuneConfig {
            trials_per_task: 24,
            measure_batch: 4,
            strategy,
            epochs_per_round: 1,
            population: 24,
            generations: 2,
            backend: BackendKind::Rust,
            seed: 42,
            ..TuneConfig::default()
        }
    }

    fn tiny_tasks() -> Vec<Subgraph> {
        vec![
            Subgraph::new(
                "tt.conv",
                SubgraphKind::Conv2d {
                    n: 1, h: 28, w: 28, cin: 64, cout: 64, kh: 3, kw: 3, stride: 1, pad: 1,
                },
            ),
            Subgraph::new("tt.dense", SubgraphKind::Dense { m: 64, n: 512, k: 512 }),
        ]
    }

    #[test]
    fn ansor_random_improves_over_default() {
        let cfg = small_cfg(Strategy::AnsorRandom);
        let mut tuner =
            AutoTuner::builder(presets::rtx_2060()).config(&cfg).build().unwrap();
        let session = tuner.tune(&tiny_tasks()).unwrap();
        assert_eq!(session.tasks.len(), 2);
        assert!(
            session.speedup() > 1.0,
            "tuning should beat the default schedule: {}",
            session.speedup()
        );
        assert!(session.search_time_s() > 0.0);
        assert!(session.total_measurements() > 0);
    }

    #[test]
    fn random_search_also_works() {
        let cfg = small_cfg(Strategy::RandomSearch);
        let mut tuner =
            AutoTuner::builder(presets::jetson_tx2()).config(&cfg).build().unwrap();
        let session = tuner.tune(&tiny_tasks()[..1]).unwrap();
        assert!(session.tasks[0].best_latency_s.is_finite());
        assert!(session.tasks[0].best_latency_s <= session.tasks[0].default_latency_s * 1.01);
    }

    #[test]
    fn moses_uses_fewer_measurements_than_finetune() {
        let mut rng = Rng::new(0);
        let backend: Arc<dyn Backend> = Arc::new(RustBackend::default());
        let pre = layout::init_params(&mut rng);

        let cfg_ft = small_cfg(Strategy::TensetFinetune);
        let model_ft = CostModel::with_params(backend.clone(), pre.clone());
        let mut t_ft = AutoTuner::builder(presets::jetson_tx2())
            .config(&cfg_ft)
            .model(model_ft)
            .build()
            .unwrap();
        let s_ft = t_ft.tune(&tiny_tasks()).unwrap();

        let cfg_mo = small_cfg(Strategy::Moses(transfer::MosesConfig::default()));
        let model_mo = CostModel::with_params(backend, pre);
        let mut t_mo = AutoTuner::builder(presets::jetson_tx2())
            .config(&cfg_mo)
            .model(model_mo)
            .build()
            .unwrap();
        let s_mo = t_mo.tune(&tiny_tasks()).unwrap();

        assert!(
            s_mo.total_measurements() < s_ft.total_measurements(),
            "moses {} vs finetune {}",
            s_mo.total_measurements(),
            s_ft.total_measurements()
        );
        assert!(s_mo.search_time_s() < s_ft.search_time_s());
    }

    #[test]
    fn history_is_monotone_nonincreasing() {
        let cfg = small_cfg(Strategy::AnsorRandom);
        let mut tuner =
            AutoTuner::builder(presets::rtx_2080()).config(&cfg).build().unwrap();
        let session = tuner.tune(&tiny_tasks()[..1]).unwrap();
        let h = &session.tasks[0].history;
        for w in h.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "history not monotone: {h:?}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg(Strategy::AnsorRandom);
        let run = || {
            let mut tuner =
                AutoTuner::builder(presets::rtx_2060()).config(&cfg).build().unwrap();
            tuner.tune(&tiny_tasks()).unwrap().total_best_latency_ms()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn inline_wall_clock_equals_total_cost() {
        let cfg = small_cfg(Strategy::AnsorRandom);
        let mut tuner =
            AutoTuner::builder(presets::rtx_2060()).config(&cfg).build().unwrap();
        let session = tuner.tune(&tiny_tasks()).unwrap();
        assert!((session.wall_time_s() - session.search_time_s()).abs() < 1e-9);
    }

    #[test]
    fn parallel_jobs_produce_valid_deterministic_sessions() {
        let mut cfg = small_cfg(Strategy::AnsorRandom);
        cfg.jobs = 2;
        let run = || {
            let mut tuner =
                AutoTuner::builder(presets::rtx_2060()).config(&cfg).build().unwrap();
            tuner.tune(&tiny_tasks()).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.tasks.len(), 2);
        assert_eq!(a.total_best_latency_ms(), b.total_best_latency_ms());
        assert_eq!(a.total_measurements(), b.total_measurements());
        assert!(a.speedup() >= 1.0);
        // Two concurrent tasks: the critical path is shorter than the
        // summed cost, but never shorter than the slowest member — and
        // the stealing schedule never loses to the wave accounting.
        assert!(a.wall_time_s() <= a.search_time_s() + 1e-9);
        assert!(a.wall_time_s() <= a.wave_wall_time_s() + 1e-9);
        assert!(a.wall_time_s() > 0.0);
    }

    #[test]
    fn scheduled_results_are_independent_of_the_worker_count() {
        // The per-task snapshot pinning makes scheduled results a pure
        // function of (seed, tasks): any jobs >= 2 bit-agrees.
        let tasks: Vec<Subgraph> = [(64, 256, 256), (32, 512, 128), (128, 128, 64), (48, 384, 192)]
            .iter()
            .enumerate()
            .map(|(i, &(m, n, k))| {
                Subgraph::new(&format!("wc.dense{i}"), SubgraphKind::Dense { m, n, k })
            })
            .collect();
        let run = |jobs: usize| {
            let mut cfg = small_cfg(Strategy::AnsorRandom);
            cfg.jobs = jobs;
            let mut tuner =
                AutoTuner::builder(presets::rtx_2060()).config(&cfg).build().unwrap();
            tuner.tune(&tasks).unwrap()
        };
        let a = run(2);
        let b = run(4);
        assert_eq!(a.total_best_latency_ms(), b.total_best_latency_ms());
        assert_eq!(a.total_measurements(), b.total_measurements());
        assert_eq!(a.search_time_s(), b.search_time_s());
    }

    #[test]
    fn fast_nondeterministic_sessions_are_valid() {
        let cfg = small_cfg(Strategy::AnsorRandom);
        let mut tuner = AutoTuner::builder(presets::rtx_2060())
            .config(&cfg)
            .jobs(2)
            .fast_nondeterministic(true)
            .build()
            .unwrap();
        let s = tuner.tune(&tiny_tasks()).unwrap();
        assert_eq!(s.tasks.len(), 2);
        assert!(s.speedup() >= 1.0);
        assert!(s.total_measurements() > 0);
        assert!(s.wall_time_s() > 0.0 && s.wall_time_s() <= s.search_time_s() + 1e-9);
    }

    #[test]
    fn builder_refuses_jobs_on_the_xla_backend() {
        let err = AutoTuner::builder(presets::rtx_2060())
            .strategy(Strategy::RandomSearch)
            .backend(BackendKind::Xla)
            .jobs(4)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("rust cost-model backend"), "{err}");
    }

    #[test]
    fn builder_refuses_draft_on_the_xla_backend() {
        let err = AutoTuner::builder(presets::rtx_2060())
            .strategy(Strategy::RandomSearch)
            .backend(BackendKind::Xla)
            .draft(true)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("rust cost-model backend"), "{err}");
    }

    #[test]
    fn builder_refuses_out_of_range_draft_keep() {
        for bad in [0.0, -0.25, 1.5, f64::NAN, f64::INFINITY] {
            let err = AutoTuner::builder(presets::rtx_2060())
                .draft_keep(bad)
                .build()
                .unwrap_err();
            assert!(err.to_string().contains("draft_keep"), "{bad}: {err}");
        }
        // The boundary keep == 1.0 is legal (bit-identical to draft off).
        AutoTuner::builder(presets::rtx_2060()).draft(true).draft_keep(1.0).build().unwrap();
    }

    #[test]
    fn draft_sessions_produce_valid_results() {
        let mut cfg = small_cfg(Strategy::Moses(transfer::MosesConfig::default()));
        cfg.draft = true;
        cfg.draft_keep = 0.25;
        let mut tuner = AutoTuner::builder(presets::rtx_2060()).config(&cfg).build().unwrap();
        let s = tuner.tune(&tiny_tasks()).unwrap();
        assert_eq!(s.tasks.len(), 2);
        assert!(s.speedup() >= 1.0);
        assert!(s.total_measurements() > 0);
    }
}
