//! The AutoTuner: session orchestration over the staged task pipeline.
//!
//! Per-task tuning state lives in [`super::pipeline::TaskPipeline`];
//! everything that learns lives in [`super::learner::Learner`].  The
//! tuner is the driver tying them together, in one of two modes:
//!
//! * `jobs == 1` — **inline**: tasks run one after another on the
//!   calling thread, the learner absorbs each stage's batch
//!   synchronously, and predictions read the live model through a
//!   fresh [`Predictor`] view per stage.  This is exactly the classic
//!   sequential tuning loop.
//! * `jobs > 1` — **parallel**: tasks run in sequential *waves* of
//!   `jobs` worker threads driving one learner actor.  Workers overlap
//!   their search + measurement work; the learner applies each round's
//!   batches in ascending task order and publishes versioned
//!   `Arc<ModelState>` snapshots that workers pin their next
//!   predictions to — publish and pin are pointer swaps, so the hot
//!   prediction path never copies the parameter vector.  The schedule
//!   is a deterministic function of `(seed, jobs, tasks)`, so parallel
//!   sessions are exactly reproducible.
//!
//! Tuners are constructed through [`AutoTuner::builder`], which
//! validates incompatible knob combinations (XLA backend with worker
//! threads, pretrain strategies without a checkpoint, empty budgets) at
//! build time instead of deep inside a running session.  [`TuneConfig`]
//! remains the flat serialized form the builder produces.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::Result;

use super::learner::{
    run_learner_actor, Learner, LearnerConfig, LearnerState, SnapshotCell, ToLearner,
};
use super::pipeline::{StageOutput, TaskPipeline};
use super::session::{Session, TaskResult};
use crate::costmodel::{layout, Backend, CostModel, Predictor, RustBackend, XlaBackend};
use crate::device::{DeviceArch, DeviceSim, SessionTiming, VirtualClock};
use crate::obs::{Lane, Recorder, TraceScope};
use crate::program::Subgraph;
use crate::runtime::Engine;
use crate::transfer::{self, MosesAdapter, Strategy};
use crate::tunecache::{TuneCache, DEFAULT_NN_K, DEFAULT_NN_RADIUS};
use crate::util::rng::Rng;

/// Which compute backend executes the cost model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT Pallas/JAX artifacts via PJRT (production path).
    Xla,
    /// Pure-Rust mirror (artifact-less fallback, tests).
    Rust,
}

impl BackendKind {
    /// Pick the best available backend: XLA when compiled in
    /// (`--features xla`) and the AOT artifacts are present, the
    /// pure-Rust mirror otherwise.
    pub fn auto() -> BackendKind {
        if Engine::xla_available() {
            BackendKind::Xla
        } else {
            BackendKind::Rust
        }
    }
}

/// Tuning configuration (one model × one device × one strategy).
///
/// This is the *serialized* form of a tuner: flat, `Clone`, and stable
/// across CLI flags and experiment grids.  Construct tuners through
/// [`AutoTuner::builder`] (which produces and validates one of these);
/// pass an existing config through
/// [`AutoTunerBuilder::config`] to migrate mechanically.
#[derive(Debug, Clone)]
pub struct TuneConfig {
    /// Candidate budget per task (TVM's "trials").
    pub trials_per_task: usize,
    /// Candidates measured per round (TVM measure batch).
    pub measure_batch: usize,
    pub strategy: Strategy,
    /// Online learning rate (paper §4: α = 0.001).
    pub lr: f32,
    /// Training epochs over the replay buffer per measured round.
    pub epochs_per_round: usize,
    /// Replay-buffer row cap (most recent kept).
    pub replay_cap: usize,
    pub seed: u64,
    pub backend: BackendKind,
    /// Pre-trained source checkpoint (required by pretrain strategies).
    pub pretrained_path: Option<PathBuf>,
    /// Evolutionary engine parameters.
    pub population: usize,
    pub generations: usize,
    /// On a cache miss with cross-device seeds: how many of the most
    /// promising seeds to verify on-device before the search rounds
    /// (grounds the session's best immediately; the rest only seed the
    /// evolutionary population).
    pub seed_probe: usize,
    /// Nearest-neighbor warm-start radius in normalized descriptor
    /// space; `None` disables the neighbor tier.
    pub nn_radius: Option<f64>,
    /// Neighbor workloads consulted per nearest-neighbor query.
    pub nn_k: usize,
    /// Concurrent task pipelines per session (1 = the classic
    /// sequential loop).  Requires the rust backend when > 1.
    pub jobs: usize,
    /// Rust-backend batch geometry (the parallel learner/worker threads
    /// construct their own backends from these; the XLA geometry is
    /// fixed by the AOT artifacts).
    pub rust_pred_batch: usize,
    pub rust_train_batch: usize,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            trials_per_task: 64,
            measure_batch: 8,
            strategy: Strategy::Moses(transfer::MosesConfig::default()),
            lr: 1e-3,
            // One epoch over a 1k replay per round: measured as the best
            // wall-time/quality tradeoff on this CPU testbed
            // (EXPERIMENTS.md §Perf) — the train step is the hot call.
            epochs_per_round: 1,
            replay_cap: 1024,
            seed: 0,
            backend: BackendKind::Rust,
            pretrained_path: None,
            population: 64,
            generations: 3,
            seed_probe: 2,
            nn_radius: Some(DEFAULT_NN_RADIUS),
            nn_k: DEFAULT_NN_K,
            jobs: 1,
            rust_pred_batch: 512,
            rust_train_batch: 256,
        }
    }
}

impl TuneConfig {
    fn learner_config(&self) -> LearnerConfig {
        LearnerConfig {
            lr: self.lr,
            epochs_per_round: self.epochs_per_round,
            replay_cap: self.replay_cap,
        }
    }
}

/// Builder for [`AutoTuner`]: typed knobs with build-time validation.
///
/// ```no_run
/// # fn main() -> anyhow::Result<()> {
/// use moses::coordinator::AutoTuner;
/// use moses::device::presets;
/// use moses::transfer::Strategy;
///
/// let mut tuner = AutoTuner::builder(presets::jetson_tx2())
///     .trials(64)
///     .strategy(Strategy::AnsorRandom)
///     .jobs(4)
///     .build()?;
/// # Ok(())
/// # }
/// ```
///
/// Incompatible combinations (worker threads on the thread-pinned XLA
/// backend, a pretrain strategy without a checkpoint or in-memory
/// model, zero budgets, a non-finite neighbor radius) are rejected by
/// [`AutoTunerBuilder::build`] with an error — never a panic deep
/// inside a running session.
#[must_use = "call .build() to construct the tuner"]
pub struct AutoTunerBuilder {
    target: DeviceArch,
    cfg: TuneConfig,
    model: Option<CostModel>,
    cache: Option<Arc<TuneCache>>,
    recorder: Recorder,
}

impl AutoTunerBuilder {
    /// Start from an existing serialized [`TuneConfig`] (CLI flags,
    /// experiment grids) instead of the defaults.  This REPLACES the
    /// builder's whole config, so call it first: typed setters invoked
    /// before it are discarded, setters invoked after it override
    /// individual fields of `cfg`.
    pub fn config(mut self, cfg: &TuneConfig) -> Self {
        self.cfg = cfg.clone();
        self
    }

    /// Candidate budget per task (TVM's "trials").
    pub fn trials(mut self, trials: usize) -> Self {
        self.cfg.trials_per_task = trials;
        self
    }

    /// Candidates measured per round (TVM measure batch).
    pub fn measure_batch(mut self, batch: usize) -> Self {
        self.cfg.measure_batch = batch;
        self
    }

    /// Cost-model initialization/update strategy.
    pub fn strategy(mut self, strategy: Strategy) -> Self {
        self.cfg.strategy = strategy;
        self
    }

    /// RNG seed; sessions are bit-reproducible per `(seed, jobs)`.
    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Compute backend for the cost model.
    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.cfg.backend = backend;
        self
    }

    /// Concurrent task pipelines per session (rust backend only for
    /// `jobs > 1` — validated at build time).
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.cfg.jobs = jobs;
        self
    }

    /// Evolutionary engine population/generation parameters.
    pub fn search_params(mut self, population: usize, generations: usize) -> Self {
        self.cfg.population = population;
        self.cfg.generations = generations;
        self
    }

    /// Nearest-neighbor warm-start radius (`None` disables the tier).
    pub fn nn(mut self, radius: Option<f64>) -> Self {
        self.cfg.nn_radius = radius;
        self
    }

    /// Neighbor workloads consulted per nearest-neighbor query.
    pub fn nn_k(mut self, k: usize) -> Self {
        self.cfg.nn_k = k;
        self
    }

    /// Pre-trained source checkpoint to load at build time (required by
    /// pretrain strategies unless an in-memory [`AutoTunerBuilder::model`]
    /// is supplied).
    pub fn pretrained(mut self, path: impl Into<PathBuf>) -> Self {
        self.cfg.pretrained_path = Some(path.into());
        self
    }

    /// Rust-backend batch geometry (predict rows, train rows).
    pub fn rust_batches(mut self, pred: usize, train: usize) -> Self {
        self.cfg.rust_pred_batch = pred;
        self.cfg.rust_train_batch = train;
        self
    }

    /// Use an externally-constructed cost model (tests, checkpoints
    /// already in memory) instead of initializing one per the strategy.
    pub fn model(mut self, model: CostModel) -> Self {
        self.model = Some(model);
        self
    }

    /// Attach a shared tuning-record store: tasks are checked against it
    /// before searching (an exact hit costs zero measured trials), every
    /// measured outcome is committed back, and on a miss records from
    /// other devices seed the evolutionary population.
    pub fn cache(mut self, cache: Arc<TuneCache>) -> Self {
        self.cache = Some(cache);
        self
    }

    /// Record sessions into `recorder` (see [`crate::obs`]): pipeline
    /// stages, learner batches and snapshot publish/pin events become
    /// trace spans.  The default is a disabled recorder, whose
    /// instrumentation cost is one branch per span.
    pub fn trace(mut self, recorder: Recorder) -> Self {
        self.recorder = recorder;
        self
    }

    /// Validate the configuration and construct the tuner.
    pub fn build(self) -> Result<AutoTuner> {
        let cfg = &self.cfg;
        anyhow::ensure!(cfg.trials_per_task >= 1, "trials_per_task must be at least 1");
        anyhow::ensure!(cfg.measure_batch >= 1, "measure_batch must be at least 1");
        anyhow::ensure!(
            cfg.population >= 2,
            "evolutionary population must hold at least 2 members (got {})",
            cfg.population
        );
        anyhow::ensure!(cfg.jobs >= 1, "jobs must be at least 1");
        anyhow::ensure!(
            cfg.jobs == 1 || cfg.backend == BackendKind::Rust,
            "--jobs {} requires the rust cost-model backend: the XLA/PJRT client \
             is pinned to its creating thread",
            cfg.jobs
        );
        if let Some(r) = cfg.nn_radius {
            anyhow::ensure!(
                r.is_finite() && r >= 0.0,
                "nearest-neighbor radius must be a non-negative finite number (got {r})"
            );
        }
        anyhow::ensure!(
            cfg.rust_pred_batch >= 1 && cfg.rust_train_batch >= 1,
            "rust backend batch geometry must be non-zero"
        );

        let mut rng = Rng::new(cfg.seed);
        let model = match self.model {
            Some(model) => model,
            None => {
                let backend: Arc<dyn Backend> = match cfg.backend {
                    // The configured geometry, so inline (`--jobs 1`)
                    // training partitions minibatches exactly like the
                    // parallel learner actor rebuilding its backend from
                    // the same fields.
                    BackendKind::Rust => Arc::new(RustBackend {
                        pred_batch: cfg.rust_pred_batch,
                        train_batch: cfg.rust_train_batch,
                    }),
                    BackendKind::Xla => {
                        let dir = Engine::default_dir();
                        Arc::new(XlaBackend { engine: Arc::new(Engine::load(&dir)?) })
                    }
                };
                let pretrained: Option<Vec<f32>> = if cfg.strategy.uses_pretrained() {
                    let Some(path) = cfg.pretrained_path.as_ref() else {
                        anyhow::bail!(
                            "strategy '{}' requires a pre-trained checkpoint: supply \
                             .pretrained(path) or an in-memory .model(..)",
                            cfg.strategy.name()
                        );
                    };
                    Some(layout::load_checkpoint(path)?)
                } else {
                    None
                };
                transfer::init_model(&cfg.strategy, backend, pretrained.as_deref(), &mut rng)
            }
        };
        let adapter = match &cfg.strategy {
            Strategy::Moses(c) => Some(MosesAdapter::new(*c)),
            _ => None,
        };
        Ok(AutoTuner {
            config: self.cfg.clone(),
            sim: DeviceSim::new(self.target),
            rng,
            cache: self.cache,
            learner: Some(Learner::new(self.cfg.learner_config(), model, adapter)),
            recorder: self.recorder,
        })
    }
}

/// The tuner for one (device, strategy) pair.  Reusable across models;
/// the learner (cost model + replay) persists across `tune` calls
/// (continual learning).  Construct via [`AutoTuner::builder`].
pub struct AutoTuner {
    pub config: TuneConfig,
    sim: DeviceSim,
    rng: Rng,
    /// Shared tuning-record store (check-before-search,
    /// commit-after-measure, cross-device warm start).
    cache: Option<Arc<TuneCache>>,
    /// The learning plane.  `None` only transiently while a parallel
    /// session owns the state on the actor thread.
    learner: Option<Learner>,
    /// Session trace sink (disabled by default).
    recorder: Recorder,
}

impl AutoTuner {
    /// Start building a tuner for `target` with default knobs.
    pub fn builder(target: DeviceArch) -> AutoTunerBuilder {
        AutoTunerBuilder {
            target,
            cfg: TuneConfig::default(),
            model: None,
            cache: None,
            recorder: Recorder::default(),
        }
    }

    /// Access the underlying cost model (diagnostics).
    pub fn model(&self) -> &CostModel {
        self.learner.as_ref().expect("learner state present").model()
    }

    /// The device being tuned for.
    pub fn device_name(&self) -> &str {
        &self.sim.arch.name
    }

    /// Tune a list of tasks; returns the session with aggregate metrics.
    pub fn tune(&mut self, tasks: &[Subgraph]) -> Result<Session> {
        let jobs = self.config.jobs.max(1).min(tasks.len().max(1));
        if jobs <= 1 {
            self.tune_inline(tasks)
        } else {
            // Backstop for configs mutated after build(): the builder
            // already rejects this combination.
            anyhow::ensure!(
                self.config.backend == BackendKind::Rust,
                "--jobs {jobs} requires the rust cost-model backend: the XLA/PJRT client \
                 is pinned to its creating thread"
            );
            self.tune_parallel(tasks, jobs)
        }
    }

    fn session(&self, tasks: Vec<TaskResult>, timing: SessionTiming) -> Session {
        Session {
            device: self.sim.arch.name.clone(),
            strategy: self.config.strategy.name().to_string(),
            tasks,
            wall_s: timing.wall_s(),
            clock: timing.into_cost(),
            cache: self.cache.as_ref().map(|c| c.stats()),
        }
    }

    /// The classic sequential loop: one pipeline at a time, the learner
    /// absorbing synchronously, every stage predicting through a fresh
    /// view of the live model.
    fn tune_inline(&mut self, tasks: &[Subgraph]) -> Result<Session> {
        let learner = self.learner.as_mut().expect("learner state present");
        learner.reset_task_clocks();
        learner.set_scope(self.recorder.scope(Lane::Learner, "learner"));
        let ord_base = learner.task_count();
        let mut results = Vec::with_capacity(tasks.len());
        let mut timing = SessionTiming::new();
        for (i, task) in tasks.iter().enumerate() {
            let trng = self.rng.fork(i as u64);
            let mut pipe = TaskPipeline::new(
                task.clone(),
                ord_base + i,
                &self.config,
                self.sim.clone(),
                self.cache.clone(),
                trng,
                self.recorder.scope(Lane::Task(ord_base + i), &task.name),
            );
            let result = match pipe.warm_start()? {
                StageOutput::Complete(r) => *r,
                StageOutput::Learn(batch) => {
                    learner.absorb(batch, pipe.rng_mut())?;
                    loop {
                        // A fresh O(1) view per round: inline predictions
                        // track the live model exactly as the sequential
                        // loop did.
                        match pipe.run_round(&learner.predictor())? {
                            StageOutput::Learn(b) => learner.absorb(b, pipe.rng_mut())?,
                            StageOutput::Exhausted => break,
                            StageOutput::Complete(_) => unreachable!("rounds never complete"),
                        }
                    }
                    pipe.finalize(&learner.predictor())?
                }
                StageOutput::Exhausted => unreachable!("warm start never exhausts"),
            };
            let mut task_clock = pipe.clock();
            task_clock.merge(&learner.task_clock(ord_base + i));
            timing.add_wave(std::slice::from_ref(&task_clock));
            results.push(result);
        }
        Ok(self.session(results, timing))
    }

    /// Wave-parallel sessions: `jobs` worker threads drive one task
    /// pipeline each against versioned model snapshots, while the
    /// learner actor consumes their batches over a channel in a
    /// deterministic order.  Waves are sequential; workers inside a
    /// wave run concurrently (wall-clock = max over members).
    fn tune_parallel(&mut self, tasks: &[Subgraph], jobs: usize) -> Result<Session> {
        let lcfg = self.config.learner_config();
        let (ord_base, backend_home, state) = {
            let learner = self.learner.as_mut().expect("learner state present");
            learner.reset_task_clocks();
            let ord_base = learner.task_count();
            let backend_home = learner.model().backend_handle();
            let state = self.learner.take().expect("learner state present").into_state();
            (ord_base, backend_home, state)
        };
        let backup = state.clone();
        let cfg = self.config.clone();
        let n_tasks = tasks.len();
        let task_rngs: Vec<Rng> = (0..n_tasks).map(|i| self.rng.fork(i as u64)).collect();

        let mut results: Vec<Option<TaskResult>> = Vec::with_capacity(n_tasks);
        results.resize_with(n_tasks, || None);
        let mut worker_clocks: Vec<VirtualClock> = vec![VirtualClock::new(); n_tasks];
        let mut first_err: Option<anyhow::Error> = None;

        let (tx, rx) = mpsc::channel::<ToLearner>();
        let (done_tx, done_rx) = mpsc::channel::<u64>();
        // Version 0: the pre-session state, shared by pointer.
        let cell = SnapshotCell::new(Arc::new(state.model.clone()));
        let cell = &cell;

        let learner_state: Option<LearnerState> = std::thread::scope(|s| {
            let actor = {
                let pred_batch = cfg.rust_pred_batch;
                let train_batch = cfg.rust_train_batch;
                let actor_rec = self.recorder.clone();
                s.spawn(move || -> Result<LearnerState> {
                    // Poison the snapshot cell on EVERY actor exit —
                    // including panics, which would otherwise leave the
                    // workers blocked in `wait_for` forever.  On a
                    // normal exit all workers have already joined, so
                    // the extra poison wakes nobody.
                    struct PoisonOnExit<'a>(&'a SnapshotCell);
                    impl Drop for PoisonOnExit<'_> {
                        fn drop(&mut self) {
                            self.0.poison();
                        }
                    }
                    let _poison_guard = PoisonOnExit(cell);
                    let backend: Arc<dyn Backend> =
                        Arc::new(RustBackend { pred_batch, train_batch });
                    let mut learner = Learner::from_state(lcfg, backend, state);
                    learner.set_scope(actor_rec.scope(Lane::Learner, "learner"));
                    run_learner_actor(learner, rx, cell, done_tx).map(Learner::into_state)
                })
            };
            let mut wave_base: u64 = 0;
            for (w, wave) in tasks.chunks(jobs).enumerate() {
                let ords: Vec<usize> = (0..wave.len()).map(|j| ord_base + w * jobs + j).collect();
                if tx.send(ToLearner::Wave { tasks: ords }).is_err() {
                    set_err(&mut first_err, anyhow::anyhow!("learner actor unavailable"));
                    break;
                }
                let handles: Vec<_> = wave
                    .iter()
                    .enumerate()
                    .map(|(j, task)| {
                        let idx = w * jobs + j;
                        let task = task.clone();
                        let trng = task_rngs[idx].clone();
                        let tx = tx.clone();
                        let sim = self.sim.clone();
                        let cache = self.cache.clone();
                        let scope =
                            self.recorder.scope(Lane::Task(ord_base + idx), &task.name);
                        let cfg = &cfg;
                        s.spawn(move || {
                            run_task_worker(
                                task,
                                ord_base + idx,
                                cfg,
                                sim,
                                cache,
                                tx,
                                cell,
                                wave_base,
                                trng,
                                scope,
                            )
                        })
                    })
                    .collect();
                for (j, h) in handles.into_iter().enumerate() {
                    let idx = w * jobs + j;
                    match h.join() {
                        Ok(Ok((res, clock))) => {
                            results[idx] = Some(res);
                            worker_clocks[idx] = clock;
                        }
                        Ok(Err(e)) => set_err(&mut first_err, e),
                        Err(_) => {
                            set_err(&mut first_err, anyhow::anyhow!("task worker panicked"))
                        }
                    }
                }
                // Wave barrier: the learner reports the post-wave
                // snapshot version once every member's batches (and
                // Finished markers) are consumed — it is idle after.
                match done_rx.recv() {
                    Ok(v) => wave_base = v,
                    Err(_) => {
                        set_err(&mut first_err, anyhow::anyhow!("learner actor exited early"));
                        break;
                    }
                }
                if first_err.is_some() {
                    break;
                }
            }
            let _ = tx.send(ToLearner::Shutdown);
            drop(tx);
            match actor.join() {
                Ok(Ok(st)) => Some(st),
                Ok(Err(e)) => {
                    // The learner's own error is the root cause; the
                    // workers' "no further snapshots" failures are its
                    // side effects — report the cause, not a symptom.
                    first_err = Some(e);
                    None
                }
                Err(_) => {
                    set_err(&mut first_err, anyhow::anyhow!("learner thread panicked"));
                    None
                }
            }
        });

        // Restore the learning plane (continual learning across calls);
        // fall back to the pre-session state if the actor was lost.
        let mut lstate = learner_state.unwrap_or(backup);
        let learn_clocks = std::mem::take(&mut lstate.task_clocks);
        self.learner = Some(Learner::from_state(lcfg, backend_home, lstate));
        if let Some(e) = first_err {
            return Err(e);
        }

        let mut timing = SessionTiming::new();
        for (w, wave) in tasks.chunks(jobs).enumerate() {
            let mut members = Vec::with_capacity(wave.len());
            for j in 0..wave.len() {
                let idx = w * jobs + j;
                let mut c = worker_clocks[idx].clone();
                if let Some(lc) = learn_clocks.get(ord_base + idx) {
                    c.merge(lc);
                }
                members.push(c);
            }
            timing.add_wave(&members);
        }
        let results: Vec<TaskResult> =
            results.into_iter().map(|r| r.expect("worker result present")).collect();
        Ok(self.session(results, timing))
    }
}

fn set_err(slot: &mut Option<anyhow::Error>, e: anyhow::Error) {
    if slot.is_none() {
        *slot = Some(e);
    }
}

/// One `--jobs` worker: drives a single task's pipeline, streaming its
/// batches to the learner actor and pinning every prediction to the
/// snapshot version the deterministic wave schedule dictates.  Pinning
/// builds a [`Predictor`] from the published `Arc<ModelState>` — two
/// pointer clones, independent of the parameter count.
#[allow(clippy::too_many_arguments)]
fn run_task_worker(
    task: Subgraph,
    ord: usize,
    cfg: &TuneConfig,
    sim: DeviceSim,
    cache: Option<Arc<TuneCache>>,
    tx: mpsc::Sender<ToLearner>,
    cell: &SnapshotCell,
    wave_base: u64,
    rng: Rng,
    scope: TraceScope,
) -> Result<(TaskResult, VirtualClock)> {
    // The guard guarantees a `Finished` marker reaches the learner
    // exactly once on every exit path (success, error, even panic) —
    // without it the actor's round barrier would wait forever on a
    // dead worker.
    struct FinishGuard {
        tx: mpsc::Sender<ToLearner>,
        ord: usize,
        sent: u32,
        marked: bool,
    }
    impl FinishGuard {
        fn finish(&mut self) {
            if !self.marked {
                self.marked = true;
                let _ =
                    self.tx.send(ToLearner::Finished { task_ord: self.ord, seq: self.sent });
            }
        }
    }
    impl Drop for FinishGuard {
        fn drop(&mut self) {
            self.finish();
        }
    }
    let mut guard = FinishGuard { tx: tx.clone(), ord, sent: 0, marked: false };
    let mut pipe = TaskPipeline::new(task, ord, cfg, sim, cache, rng, scope);
    match pipe.warm_start()? {
        StageOutput::Complete(r) => return Ok((*r, pipe.clock())),
        StageOutput::Learn(batch) => {
            let shuffle_rng = pipe.fork_shuffle_rng();
            let _ = tx.send(ToLearner::Batch { batch, shuffle_rng });
            guard.sent = 1;
        }
        StageOutput::Exhausted => unreachable!("warm start never exhausts"),
    }
    let backend: Arc<dyn Backend> = Arc::new(RustBackend {
        pred_batch: cfg.rust_pred_batch,
        train_batch: cfg.rust_train_batch,
    });
    loop {
        // Version `wave_base + sent` covers exactly the batches (ours
        // and every wave sibling's) that this round's predictions must
        // observe under the round-major deterministic order.
        let requested = wave_base + guard.sent as u64;
        let pin_timer = pipe.pin_timer();
        let Some(snapshot) = cell.wait_for(requested) else {
            anyhow::bail!("learner failed; no further model snapshots");
        };
        pipe.trace_pin(pin_timer, requested, snapshot.version());
        let view = Predictor::new(backend.clone(), snapshot);
        match pipe.run_round(&view)? {
            StageOutput::Learn(batch) => {
                let shuffle_rng = pipe.fork_shuffle_rng();
                let _ = tx.send(ToLearner::Batch { batch, shuffle_rng });
                guard.sent += 1;
            }
            StageOutput::Exhausted => break,
            StageOutput::Complete(_) => unreachable!("rounds never complete"),
        }
    }
    let requested = wave_base + guard.sent as u64;
    let pin_timer = pipe.pin_timer();
    let Some(snapshot) = cell.wait_for(requested) else {
        anyhow::bail!("learner failed; no further model snapshots");
    };
    pipe.trace_pin(pin_timer, requested, snapshot.version());
    // No more batches will come: release the learner's round barrier
    // NOW so wave siblings don't stall behind this task's finalize
    // (one measurement + cache commits).  The needed snapshot is
    // already in hand.
    guard.finish();
    let view = Predictor::new(backend, snapshot);
    let result = pipe.finalize(&view)?;
    Ok((result, pipe.clock()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::program::SubgraphKind;

    fn small_cfg(strategy: Strategy) -> TuneConfig {
        TuneConfig {
            trials_per_task: 24,
            measure_batch: 4,
            strategy,
            epochs_per_round: 1,
            population: 24,
            generations: 2,
            backend: BackendKind::Rust,
            seed: 42,
            ..TuneConfig::default()
        }
    }

    fn tiny_tasks() -> Vec<Subgraph> {
        vec![
            Subgraph::new(
                "tt.conv",
                SubgraphKind::Conv2d {
                    n: 1, h: 28, w: 28, cin: 64, cout: 64, kh: 3, kw: 3, stride: 1, pad: 1,
                },
            ),
            Subgraph::new("tt.dense", SubgraphKind::Dense { m: 64, n: 512, k: 512 }),
        ]
    }

    #[test]
    fn ansor_random_improves_over_default() {
        let cfg = small_cfg(Strategy::AnsorRandom);
        let mut tuner =
            AutoTuner::builder(presets::rtx_2060()).config(&cfg).build().unwrap();
        let session = tuner.tune(&tiny_tasks()).unwrap();
        assert_eq!(session.tasks.len(), 2);
        assert!(
            session.speedup() > 1.0,
            "tuning should beat the default schedule: {}",
            session.speedup()
        );
        assert!(session.search_time_s() > 0.0);
        assert!(session.total_measurements() > 0);
    }

    #[test]
    fn random_search_also_works() {
        let cfg = small_cfg(Strategy::RandomSearch);
        let mut tuner =
            AutoTuner::builder(presets::jetson_tx2()).config(&cfg).build().unwrap();
        let session = tuner.tune(&tiny_tasks()[..1]).unwrap();
        assert!(session.tasks[0].best_latency_s.is_finite());
        assert!(session.tasks[0].best_latency_s <= session.tasks[0].default_latency_s * 1.01);
    }

    #[test]
    fn moses_uses_fewer_measurements_than_finetune() {
        let mut rng = Rng::new(0);
        let backend: Arc<dyn Backend> = Arc::new(RustBackend::default());
        let pre = layout::init_params(&mut rng);

        let cfg_ft = small_cfg(Strategy::TensetFinetune);
        let model_ft = CostModel::with_params(backend.clone(), pre.clone());
        let mut t_ft = AutoTuner::builder(presets::jetson_tx2())
            .config(&cfg_ft)
            .model(model_ft)
            .build()
            .unwrap();
        let s_ft = t_ft.tune(&tiny_tasks()).unwrap();

        let cfg_mo = small_cfg(Strategy::Moses(transfer::MosesConfig::default()));
        let model_mo = CostModel::with_params(backend, pre);
        let mut t_mo = AutoTuner::builder(presets::jetson_tx2())
            .config(&cfg_mo)
            .model(model_mo)
            .build()
            .unwrap();
        let s_mo = t_mo.tune(&tiny_tasks()).unwrap();

        assert!(
            s_mo.total_measurements() < s_ft.total_measurements(),
            "moses {} vs finetune {}",
            s_mo.total_measurements(),
            s_ft.total_measurements()
        );
        assert!(s_mo.search_time_s() < s_ft.search_time_s());
    }

    #[test]
    fn history_is_monotone_nonincreasing() {
        let cfg = small_cfg(Strategy::AnsorRandom);
        let mut tuner =
            AutoTuner::builder(presets::rtx_2080()).config(&cfg).build().unwrap();
        let session = tuner.tune(&tiny_tasks()[..1]).unwrap();
        let h = &session.tasks[0].history;
        for w in h.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "history not monotone: {h:?}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg(Strategy::AnsorRandom);
        let run = || {
            let mut tuner =
                AutoTuner::builder(presets::rtx_2060()).config(&cfg).build().unwrap();
            tuner.tune(&tiny_tasks()).unwrap().total_best_latency_ms()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn inline_wall_clock_equals_total_cost() {
        let cfg = small_cfg(Strategy::AnsorRandom);
        let mut tuner =
            AutoTuner::builder(presets::rtx_2060()).config(&cfg).build().unwrap();
        let session = tuner.tune(&tiny_tasks()).unwrap();
        assert!((session.wall_time_s() - session.search_time_s()).abs() < 1e-9);
    }

    #[test]
    fn parallel_jobs_produce_valid_deterministic_sessions() {
        let mut cfg = small_cfg(Strategy::AnsorRandom);
        cfg.jobs = 2;
        let run = || {
            let mut tuner =
                AutoTuner::builder(presets::rtx_2060()).config(&cfg).build().unwrap();
            tuner.tune(&tiny_tasks()).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.tasks.len(), 2);
        assert_eq!(a.total_best_latency_ms(), b.total_best_latency_ms());
        assert_eq!(a.total_measurements(), b.total_measurements());
        assert!(a.speedup() >= 1.0);
        // Two concurrent tasks: the critical path is shorter than the
        // summed cost, but never shorter than the slowest member.
        assert!(a.wall_time_s() <= a.search_time_s() + 1e-9);
        assert!(a.wall_time_s() > 0.0);
    }

    #[test]
    fn builder_refuses_jobs_on_the_xla_backend() {
        let err = AutoTuner::builder(presets::rtx_2060())
            .strategy(Strategy::RandomSearch)
            .backend(BackendKind::Xla)
            .jobs(4)
            .build()
            .unwrap_err();
        assert!(err.to_string().contains("rust cost-model backend"), "{err}");
    }
}
