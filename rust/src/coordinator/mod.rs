//! The auto-tuning coordinator: drives search ↔ measurement ↔ online
//! cost-model adaptation per task, with virtual-time accounting — the
//! Ansor tuning loop of paper §2.2 with Moses' §3.6 working flow:
//!
//! 1. initialize the model per the [`crate::transfer::Strategy`]
//!    (random / pre-trained);
//! 2. per task and round, the evolutionary engine proposes predicted
//!    top-k candidates;
//! 3. measured rounds: run them on the (simulated) device, add records
//!    to the replay buffer, update the model (masked updates + variant
//!    weight decay for Moses, full updates for vanilla fine-tuning);
//! 4. the AC module (Moses only) watches prediction stability and cuts
//!    the measurement phase early, after which rounds are
//!    prediction-only;
//! 5. the best configuration is returned with its TRUE latency and the
//!    total virtual search time.
//!
//! Since the staged-pipeline refactor these responsibilities live in
//! four layers: `pipeline` (per-task stages: warm-start → propose →
//! measure → learn-batch emission → finalize), `learner` (the shared
//! learning plane: cost model, replay buffer, Moses adapter, publishing
//! [`ModelSnapshot`]s — a [`crate::costmodel::ModelState`] plus, with
//! the draft tier on, the [`crate::search::DraftState`] distilled from
//! it — per task slot to the work-stealing board in scheduled sessions,
//! or through the [`SnapshotCell`] primitive directly), `sched` (the
//! work-stealing execution plane: tasks as stealable resumable units on
//! per-worker deques, steal-on-idle, park/resume on snapshot
//! availability), and `tuner` (the driver — sequential inline at
//! `--jobs 1`, the always-saturated scheduler pinning read-only
//! [`crate::costmodel::Predictor`] views at `--jobs N`).  Sessions are
//! configured through [`AutoTuner::builder`], which validates knob
//! combinations at build time and serializes to [`TuneConfig`].

mod learner;
mod pipeline;
pub(crate) mod sched;
mod session;
mod tuner;

pub use learner::{ModelSnapshot, SnapshotCell};
pub use session::{Session, TaskResult};
pub use tuner::{AutoTuner, AutoTunerBuilder, BackendKind, TuneConfig};
