//! The auto-tuning coordinator: drives search ↔ measurement ↔ online
//! cost-model adaptation per task, with virtual-time accounting — the
//! Ansor tuning loop of paper §2.2 with Moses' §3.6 working flow:
//!
//! 1. initialize the model per the [`Strategy`] (random / pre-trained);
//! 2. per task and round, the evolutionary engine proposes predicted
//!    top-k candidates;
//! 3. measured rounds: run them on the (simulated) device, add records
//!    to the replay buffer, update the model (masked updates + variant
//!    weight decay for Moses, full updates for vanilla fine-tuning);
//! 4. the AC module (Moses only) watches prediction stability and cuts
//!    the measurement phase early, after which rounds are
//!    prediction-only;
//! 5. the best configuration is returned with its TRUE latency and the
//!    total virtual search time.

mod session;
mod tuner;

pub use session::{Session, TaskResult};
pub use tuner::{AutoTuner, BackendKind, TuneConfig};
