//! Tuning session results: per-task outcomes + aggregate metrics.

use crate::device::VirtualClock;
use crate::metrics::cache::CacheStats;
use crate::program::{Schedule, Subgraph};

/// Outcome of tuning one task.
#[derive(Debug, Clone)]
pub struct TaskResult {
    pub task: Subgraph,
    /// True (noise-free) latency of the chosen schedule, seconds.
    pub best_latency_s: f64,
    pub best_schedule: Schedule,
    /// True latency of the heuristic default schedule ("Raw").
    pub default_latency_s: f64,
    /// On-device measurements consumed.
    pub measured: usize,
    /// Trials served by cost-model prediction alone.
    pub predicted_only: usize,
    /// Best-so-far true latency after each round (convergence curve).
    pub history: Vec<f64>,
    /// Served straight from the tune cache (zero measured trials).
    pub cache_hit: bool,
    /// Same-workload cross-device schedules injected into the search
    /// population.
    pub warm_seeds: usize,
    /// Similar-workload (nearest-neighbor) schedules injected into the
    /// search population.
    pub neighbor_seeds: usize,
}

impl TaskResult {
    /// Speedup of the tuned schedule over the default.
    pub fn speedup(&self) -> f64 {
        self.default_latency_s / self.best_latency_s
    }
}

/// Outcome of tuning a whole model on one device.
#[derive(Debug, Clone)]
pub struct Session {
    pub device: String,
    pub strategy: String,
    pub tasks: Vec<TaskResult>,
    /// Total virtual search time (measurements + model queries/updates),
    /// summed over every task pipeline — the device bill.
    pub clock: VirtualClock,
    /// Critical-path virtual seconds: with `--jobs N`, tasks run
    /// concurrently on the work-stealing scheduler, so the session
    /// *elapses* the schedule makespan while still *spending* the sum.
    /// Equals `clock.seconds()` for sequential (`--jobs 1`) sessions.
    pub wall_s: f64,
    /// Reference wall time under the pre-scheduler wave accounting
    /// (sum of per-wave maxima over the same task clocks); always
    /// `>= wall_s`, and the gap is the work-stealing win.
    pub wave_wall_s: f64,
    /// Tune-cache counter snapshot at session end (None when tuning
    /// without a cache).
    pub cache: Option<CacheStats>,
}

impl Session {
    /// End-to-end tuned latency (weighted by task repeats), ms.
    pub fn total_best_latency_ms(&self) -> f64 {
        self.tasks
            .iter()
            .map(|t| t.best_latency_s * t.task.repeats as f64)
            .sum::<f64>()
            * 1e3
    }

    /// End-to-end default-schedule latency, ms ("Raw" baseline).
    pub fn total_default_latency_ms(&self) -> f64 {
        self.tasks
            .iter()
            .map(|t| t.default_latency_s * t.task.repeats as f64)
            .sum::<f64>()
            * 1e3
    }

    /// End-to-end speedup over the default schedules.
    pub fn speedup(&self) -> f64 {
        self.total_default_latency_ms() / self.total_best_latency_ms()
    }

    /// Total virtual search time in seconds (summed across workers).
    pub fn search_time_s(&self) -> f64 {
        self.clock.seconds()
    }

    /// Critical-path virtual search time: what a wall clock would show
    /// with `--jobs` tasks tuning concurrently.
    pub fn wall_time_s(&self) -> f64 {
        self.wall_s
    }

    /// Wall time the same session would have cost under the old
    /// wave-barrier schedule (every wave waits for its straggler).
    pub fn wave_wall_time_s(&self) -> f64 {
        self.wave_wall_s
    }

    /// Total on-device measurements.
    pub fn total_measurements(&self) -> usize {
        self.tasks.iter().map(|t| t.measured).sum()
    }

    /// Tasks served entirely from the tune cache.
    pub fn cache_hits(&self) -> usize {
        self.tasks.iter().filter(|t| t.cache_hit).count()
    }

    /// Tasks whose search population received cross-device seeds.
    pub fn warm_seeded_tasks(&self) -> usize {
        self.tasks.iter().filter(|t| t.warm_seeds > 0).count()
    }

    /// Tasks whose search population received nearest-neighbor seeds
    /// from similar workloads.
    pub fn neighbor_seeded_tasks(&self) -> usize {
        self.tasks.iter().filter(|t| t.neighbor_seeds > 0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::program::{Schedule, SubgraphKind};

    fn mk_task(lat: f64, default: f64, repeats: usize) -> TaskResult {
        let sub = Subgraph::new("t", SubgraphKind::Dense { m: 8, n: 8, k: 8 })
            .with_repeats(repeats);
        let sched = Schedule::default_for(&sub.geometry());
        TaskResult {
            task: sub,
            best_latency_s: lat,
            best_schedule: sched,
            default_latency_s: default,
            measured: 10,
            predicted_only: 5,
            history: vec![default, lat],
            cache_hit: false,
            warm_seeds: 0,
            neighbor_seeds: 0,
        }
    }

    #[test]
    fn aggregates_weighted_by_repeats() {
        let s = Session {
            device: "d".into(),
            strategy: "moses".into(),
            tasks: vec![mk_task(1e-3, 2e-3, 1), mk_task(2e-3, 6e-3, 2)],
            clock: VirtualClock::new(),
            wall_s: 0.0,
            wave_wall_s: 0.0,
            cache: None,
        };
        assert!((s.total_best_latency_ms() - (1.0 + 4.0)).abs() < 1e-9);
        assert!((s.total_default_latency_ms() - (2.0 + 12.0)).abs() < 1e-9);
        assert!((s.speedup() - 14.0 / 5.0).abs() < 1e-12);
        assert_eq!(s.total_measurements(), 20);
    }

    #[test]
    fn task_speedup() {
        let t = mk_task(1e-3, 3e-3, 1);
        assert!((t.speedup() - 3.0).abs() < 1e-12);
    }
}
