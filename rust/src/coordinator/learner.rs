//! The learning plane of the staged tuning pipeline.
//!
//! A [`Learner`] owns everything that *learns*: the cost model, the
//! replay buffer, the per-task best-throughput normalizers, and the
//! Moses adapter (mask refresh + variant weight decay).  Search workers
//! never touch it directly — they emit [`LearnBatch`]es (replay samples
//! plus an optional training batch) and read back cheap versioned
//! [`crate::costmodel::ModelState`] snapshots:
//!
//! * **inline mode** (`--jobs 1`): the driver calls [`Learner::absorb`]
//!   synchronously between pipeline stages, and stages predict against
//!   the live model — exactly the sequential tuning loop;
//! * **actor mode** (`--jobs N`): [`run_learner_actor`] runs the learner
//!   on its own thread, consuming [`ToLearner`] messages from a channel
//!   while the work-stealing scheduler drives every task pipeline.  In
//!   the default deterministic mode it applies batches in the fixed
//!   total order `(seq, task_ord)` lexicographic — sweep-major,
//!   ascending task ordinal — independent of arrival order
//!   (out-of-order messages wait in a stash), and after each apply it
//!   hands that task's post-apply [`ModelSnapshot`] — the model state
//!   plus, when the speculative draft tier is on, the draft scorer
//!   distilled from it — to the [`SnapshotSink`]: an O(1) pointer
//!   swap, never a parameter copy.
//!   A task's round-`r + 1` proposal pins exactly the snapshot its own
//!   round-`r` batch produced, so results are a pure function of
//!   `(seed, tasks)` no matter which worker runs which step.  With
//!   `--fast-nondeterministic` the actor absorbs batches in arrival
//!   order and publishes only a "latest" snapshot — maximum throughput,
//!   no bit-pinning.
//!
//! Virtual-time charges incurred on the learning plane (gradient steps,
//! ξ saliency refreshes) are attributed to the *originating task's*
//! clock so per-task and session accounting stay exact in both modes.

use std::collections::BTreeMap;
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::costmodel::{layout, CostModel, Mask, ModelState, Predictor};
use crate::device::VirtualClock;
use crate::obs::TraceScope;
use crate::program::N_FEATURES;
use crate::search::draft::{DraftState, MAX_FIT_ROWS, MIN_FIT_ROWS};
use crate::transfer::MosesAdapter;
use crate::util::rng::Rng;

/// Replay-buffer entry: raw measurement for one schedule of one task.
#[derive(Clone)]
pub(crate) struct Sample {
    pub task_ord: usize,
    pub feats: [f32; N_FEATURES],
    pub gflops: f64,
}

/// The labeled rows of one measured round, pre-normalization (the
/// learner normalizes by the task's best-so-far throughput at apply
/// time, exactly like the sequential loop did).
pub(crate) struct TrainBatch {
    pub x: Vec<f32>,
    pub y_raw: Vec<f32>,
}

/// One pipeline stage's contribution to the learning plane.  Every
/// non-cache-hit task emits exactly one batch per stage — `seq` 0 for
/// the warm-start stage, `r + 1` for round `r` — possibly empty, so the
/// actor's round barrier sees every live task every sweep.
pub(crate) struct LearnBatch {
    pub task_ord: usize,
    pub seq: u32,
    pub samples: Vec<Sample>,
    pub train: Option<TrainBatch>,
}

/// Learner-side knobs (lifted from `TuneConfig` so the learner can
/// travel to its own thread without the whole tuning config).
#[derive(Debug, Clone, Copy)]
pub(crate) struct LearnerConfig {
    pub lr: f32,
    pub epochs_per_round: usize,
    pub replay_cap: usize,
    /// Distill and publish a draft scorer with every model snapshot
    /// (the speculative draft-then-verify search tier).
    pub draft: bool,
}

/// The stateful learning plane for one tuner (continual across `tune`
/// calls, shared across that tuner's tasks).
pub(crate) struct Learner {
    cfg: LearnerConfig,
    model: CostModel,
    adapter: Option<MosesAdapter>,
    replay: Vec<Sample>,
    best_gflops_per_task: Vec<f64>,
    /// Learning-plane virtual-time charges, attributed per task.
    task_clocks: Vec<VirtualClock>,
    /// All-ones mask for adapter-less strategies, built once: handing
    /// it to a train round is an `Arc` clone, not an N_PARAMS alloc.
    full_mask: Mask,
    /// The learning plane's trace emitter (not part of
    /// [`LearnerState`]: a scope is bound to one session's recorder).
    scope: TraceScope,
    /// Bumped on every replay push; together with the model version it
    /// keys the draft-distillation memo below.
    replay_stamp: u64,
    /// Memoized draft refresh: `(model version, replay stamp)` → the
    /// draft distilled at that point.  Snapshot publishes between
    /// learning events reuse the `Arc` instead of re-fitting.
    draft_cache: Option<(u64, u64, Arc<DraftState>)>,
}

/// Everything but the backend handle — `Send`, so a learner can be
/// rebuilt on the actor thread (see [`crate::costmodel::ModelState`]).
/// Cloning is cheap: the model state and mask are `Arc`-shared.
#[derive(Clone)]
pub(crate) struct LearnerState {
    pub model: ModelState,
    pub adapter: Option<MosesAdapter>,
    pub replay: Vec<Sample>,
    pub best_gflops_per_task: Vec<f64>,
    pub task_clocks: Vec<VirtualClock>,
}

impl Learner {
    pub fn new(cfg: LearnerConfig, model: CostModel, adapter: Option<MosesAdapter>) -> Learner {
        Learner {
            cfg,
            model,
            adapter,
            replay: Vec::new(),
            best_gflops_per_task: Vec::new(),
            task_clocks: Vec::new(),
            full_mask: Mask::all_ones(layout::N_PARAMS),
            scope: TraceScope::disabled(),
            replay_stamp: 0,
            draft_cache: None,
        }
    }

    pub fn from_state(
        cfg: LearnerConfig,
        backend: Arc<dyn crate::costmodel::Backend>,
        state: LearnerState,
    ) -> Learner {
        Learner {
            cfg,
            model: CostModel::from_state(backend, state.model),
            adapter: state.adapter,
            replay: state.replay,
            best_gflops_per_task: state.best_gflops_per_task,
            task_clocks: state.task_clocks,
            full_mask: Mask::all_ones(layout::N_PARAMS),
            scope: TraceScope::disabled(),
            replay_stamp: 0,
            draft_cache: None,
        }
    }

    /// Attach this learner to a session's trace (actor mode re-attaches
    /// after [`Learner::from_state`] on the actor thread).
    pub fn set_scope(&mut self, scope: TraceScope) {
        self.scope = scope;
    }

    pub fn into_state(self) -> LearnerState {
        LearnerState {
            model: self.model.export_state(),
            adapter: self.adapter,
            replay: self.replay,
            best_gflops_per_task: self.best_gflops_per_task,
            task_clocks: self.task_clocks,
        }
    }

    /// The live cost model (inline-mode predictions, diagnostics).
    pub fn model(&self) -> &CostModel {
        &self.model
    }

    /// Number of task slots allotted so far — the ordinal base for the
    /// next `tune` call (ords must never collide across calls: replay
    /// samples keep referencing their task's normalizer slot).
    pub fn task_count(&self) -> usize {
        self.best_gflops_per_task.len()
    }

    /// Zero the per-task learning-plane clocks (start of a session).
    pub fn reset_task_clocks(&mut self) {
        for c in &mut self.task_clocks {
            *c = VirtualClock::new();
        }
    }

    /// This task's accumulated learning-plane charges.
    pub fn task_clock(&self, ord: usize) -> VirtualClock {
        self.task_clocks.get(ord).cloned().unwrap_or_default()
    }

    /// The current model state as a shareable snapshot handle (O(1)).
    pub fn snapshot_state(&self) -> Arc<ModelState> {
        self.model.shared_state()
    }

    /// The current `(model, draft)` publication pair.  With the draft
    /// tier off this is just the model handle (O(1)); with it on, the
    /// draft is lazily re-distilled — memoized on `(model version,
    /// replay stamp)`, so repeat publishes between learning events are
    /// `Arc` clones.  Refreshing here, at exactly the points the model
    /// snapshot is taken, is what keeps draft refresh on the same
    /// `(seq, ord)`-ordered schedule as model publish and the
    /// `(seed, jobs)` determinism contract intact.
    pub fn snapshot(&mut self) -> ModelSnapshot {
        let draft = if self.cfg.draft { Some(self.draft_state()) } else { None };
        ModelSnapshot { model: self.model.shared_state(), draft }
    }

    /// The current draft scorer (see [`Learner::snapshot`] for the
    /// refresh discipline).  Inline-mode drivers call this directly.
    pub fn draft_state(&mut self) -> Arc<DraftState> {
        let key = (self.model.shared_state().version(), self.replay_stamp);
        if let Some((v, s, d)) = &self.draft_cache {
            if (*v, *s) == key {
                return d.clone();
            }
        }
        let draft = Arc::new(self.distill_draft(key.0));
        self.draft_cache = Some((key.0, key.1, draft.clone()));
        draft
    }

    /// Distill a linear draft from the full model's own scores on the
    /// most recent replay rows (capped at [`MAX_FIT_ROWS`]), shrunk
    /// toward the MLP's first-layer feature projection.  Too little
    /// data or a diverged model yields a passthrough draft — the
    /// search plane then verifies everything, it never mis-prunes.
    fn distill_draft(&self, version: u64) -> DraftState {
        let n = self.replay.len().min(MAX_FIT_ROWS);
        if n < MIN_FIT_ROWS {
            return DraftState::passthrough(version);
        }
        let start = self.replay.len() - n;
        let mut x = Vec::with_capacity(n * N_FEATURES);
        for s in &self.replay[start..] {
            x.extend_from_slice(&s.feats);
        }
        let predictor = self.model.predictor();
        let y = match predictor.predict(&x, n) {
            Ok(y) => y,
            Err(_) => return DraftState::passthrough(version),
        };
        let prior = predictor.feature_projection();
        DraftState::fit(&x, &y, n, Some(&prior), version)
    }

    /// A read-only prediction view pinned to the CURRENT model state
    /// (O(1)).  Inline-mode drivers take a fresh view per stage so
    /// predictions track the live model exactly as the sequential loop
    /// did.
    pub fn predictor(&self) -> Predictor {
        self.model.predictor()
    }

    fn ensure_task(&mut self, ord: usize) {
        while self.best_gflops_per_task.len() <= ord {
            self.best_gflops_per_task.push(0.0);
        }
        while self.task_clocks.len() <= ord {
            self.task_clocks.push(VirtualClock::new());
        }
    }

    fn push_replay(&mut self, sample: Sample) {
        self.replay.push(sample);
        if self.replay.len() > self.cfg.replay_cap {
            let drop = self.replay.len() - self.cfg.replay_cap;
            self.replay.drain(..drop);
        }
        self.replay_stamp += 1;
    }

    /// Rebuild training arrays from the replay buffer with labels
    /// normalized per task by its best-so-far throughput.
    fn training_arrays(&self) -> (Vec<f32>, Vec<f32>) {
        let mut x = Vec::with_capacity(self.replay.len() * N_FEATURES);
        let mut y = Vec::with_capacity(self.replay.len());
        for s in &self.replay {
            x.extend_from_slice(&s.feats);
            let denom = self.best_gflops_per_task[s.task_ord];
            y.push(if denom > 0.0 { (s.gflops / denom) as f32 } else { 0.0 });
        }
        (x, y)
    }

    /// Apply one batch: push its samples into the replay buffer (which
    /// also advances the task's best-throughput normalizer), then — for
    /// measured rounds of an online-training strategy — refresh the
    /// Moses boundary and run the configured epochs over the replay.
    /// `rng` drives the epoch shuffles: the task's own stream inline,
    /// a per-batch forked stream in actor mode.
    pub fn absorb(&mut self, batch: LearnBatch, rng: &mut Rng) -> Result<()> {
        let ord = batch.task_ord;
        self.ensure_task(ord);
        let timer = self.scope.begin(self.task_clocks[ord].seconds());
        let samples = batch.samples.len();
        let trained = self.absorb_inner(batch, rng)?;
        if self.scope.enabled() {
            self.scope.end(
                timer,
                0,
                "learn",
                self.task_clocks[ord].seconds(),
                &[
                    ("replay", self.replay.len() as f64),
                    ("samples", samples as f64),
                    ("task", ord as f64),
                    ("trained", if trained { 1.0 } else { 0.0 }),
                ],
                &[],
            );
        }
        Ok(())
    }

    /// [`Learner::absorb`] minus the tracing; returns whether the batch
    /// carried labels and so trained the model.
    fn absorb_inner(&mut self, batch: LearnBatch, rng: &mut Rng) -> Result<bool> {
        let ord = batch.task_ord;
        for s in batch.samples {
            if s.gflops > self.best_gflops_per_task[ord] {
                self.best_gflops_per_task[ord] = s.gflops;
            }
            self.push_replay(s);
        }
        let Some(train) = batch.train else {
            return Ok(false);
        };
        let denom = self.best_gflops_per_task[ord].max(1e-9) as f32;
        let y_norm: Vec<f32> = train.y_raw.iter().map(|g| g / denom).collect();
        let (mask, wd) = if let Some(ad) = self.adapter.as_mut() {
            if ad.maybe_refresh(&self.model.predictor(), &train.x, &y_norm)? {
                self.task_clocks[ord].charge_xi();
            }
            (ad.mask().clone(), ad.weight_decay())
        } else {
            (self.full_mask.clone(), 0.0)
        };
        let (tx, ty) = self.training_arrays();
        // Bill one clock charge per actual gradient step: the backend's
        // train batch decides how many steps one epoch takes.
        let bt = self.model.train_batch().max(1);
        let steps_per_epoch = ty.len().div_ceil(bt).max(1);
        for _ in 0..self.cfg.epochs_per_round {
            self.model.train_epoch(&tx, &ty, &mask, self.cfg.lr, wd, rng)?;
            for _ in 0..steps_per_epoch {
                self.task_clocks[ord].charge_update();
            }
        }
        Ok(true)
    }

    /// Record a snapshot publication: the version is deterministic, the
    /// stash depth (batches queued out of order) is
    /// scheduling-dependent and lands in `diag`.  Zero virtual
    /// duration, so session-time reconciliation is unaffected.
    pub fn trace_publish(&mut self, version: u64, stash: usize) {
        self.scope.instant(
            0,
            "publish",
            0.0,
            &[("version", version as f64)],
            &[("stash", stash as f64)],
        );
    }
}

// ---------------------------------------------------------------------
// Actor mode: snapshot cell + message protocol + deterministic loop.
// ---------------------------------------------------------------------

/// One paired publication of the learning plane: the full model state
/// plus — when the draft tier is on — the draft scorer distilled from
/// it at the same `(seq, ord)`-ordered publish point.  Cloning is two
/// `Arc` bumps; workers pin the pair atomically so a round never mixes
/// a round-`r` model with a round-`r'` draft.
#[derive(Clone)]
pub struct ModelSnapshot {
    /// The full cost-model state.
    pub model: Arc<ModelState>,
    /// The draft scorer distilled from `model` (`None` when the draft
    /// tier is off).
    pub draft: Option<Arc<DraftState>>,
}

impl ModelSnapshot {
    /// A draft-less snapshot (how pre-draft callers publish).
    pub fn from_model(model: Arc<ModelState>) -> ModelSnapshot {
        ModelSnapshot { model, draft: None }
    }

    /// Version of the pinned model state.
    pub fn version(&self) -> u64 {
        self.model.version()
    }
}

struct SnapState {
    version: u64,
    snap: ModelSnapshot,
    poisoned: bool,
}

/// Versioned read-snapshot of the learner's model state.  The learner
/// publishes a [`ModelSnapshot`] after every round sweep — an O(1)
/// pointer swap regardless of parameter count; workers block until the
/// version covering all batches their next prediction must observe,
/// then pin the snapshot with another pointer clone.  This is the
/// publish/pin primitive of the zero-copy prediction plane (the
/// `snapshot_publish_pin` hotpath bench measures the round trip).
pub struct SnapshotCell {
    state: Mutex<SnapState>,
    cv: Condvar,
}

impl SnapshotCell {
    /// A cell primed with version 0 holding `snap`.
    pub fn new(snap: ModelSnapshot) -> SnapshotCell {
        SnapshotCell {
            state: Mutex::new(SnapState { version: 0, snap, poisoned: false }),
            cv: Condvar::new(),
        }
    }

    /// Publish `snap` as snapshot `version` and wake every waiter.
    pub fn publish(&self, version: u64, snap: ModelSnapshot) {
        let mut st = self.state.lock().expect("snapshot cell poisoned");
        st.version = version;
        st.snap = snap;
        drop(st);
        self.cv.notify_all();
    }

    /// Wake every waiter with failure (the learner died).
    pub fn poison(&self) {
        let mut st = self.state.lock().expect("snapshot cell poisoned");
        st.poisoned = true;
        drop(st);
        self.cv.notify_all();
    }

    /// Block until the published version reaches `v`, then pin that
    /// snapshot (two `Arc` clones).  `None` means the learner failed
    /// and no further snapshot will ever arrive.
    pub fn wait_for(&self, v: u64) -> Option<ModelSnapshot> {
        let mut st = self.state.lock().expect("snapshot cell poisoned");
        while st.version < v && !st.poisoned {
            st = self.cv.wait(st).expect("snapshot cell poisoned");
        }
        if st.poisoned {
            None
        } else {
            Some(st.snap.clone())
        }
    }
}

/// Messages into the learner actor.
pub(crate) enum ToLearner {
    /// One pipeline stage's batch, with a forked stream for the epoch
    /// shuffles (the worker's own stream cannot cross threads).
    Batch { batch: LearnBatch, shuffle_rng: Rng },
    /// The task will emit no batch at `seq` or any later sweep.
    Finished { task_ord: usize, seq: u32 },
}

/// Where the actor publishes post-apply snapshots: the scheduler's
/// snapshot board.  In deterministic mode the board keeps one slot per
/// task (`applied` counts that task's absorbed batches, so a worker
/// waiting on its own batch count pins exactly the post-apply state);
/// in fast mode the board only tracks the newest snapshot.
pub(crate) trait SnapshotSink: Sync {
    /// `task_ord`'s batch number `applied` (1-based count of that
    /// task's absorbed batches) was just applied; `snap` is the
    /// `(model, draft)` pair immediately after.
    fn publish(&self, task_ord: usize, applied: u64, snap: ModelSnapshot);
    /// The learner died: wake every waiter with failure.
    fn poison(&self);
}

type Stashed = Option<(LearnBatch, Rng)>;

/// Keyed `(seq, ord)`: the deterministic total apply order is
/// sweep-major, ascending task ordinal within a sweep.
fn stash(buf: &mut BTreeMap<(u32, usize), Stashed>, msg: ToLearner) {
    match msg {
        ToLearner::Batch { batch, shuffle_rng } => {
            buf.insert((batch.seq, batch.task_ord), Some((batch, shuffle_rng)));
        }
        ToLearner::Finished { task_ord, seq } => {
            buf.insert((seq, task_ord), None);
        }
    }
}

/// The learner actor for one scheduled session over the tasks in
/// `ords` (ascending).
///
/// **Deterministic mode:** absorb every live task's `(seq, ord)` batch
/// in lexicographic order regardless of arrival order (out-of-order
/// messages wait in a stash), publishing each task's post-apply
/// snapshot through the sink right after its batch lands — so a task
/// blocked on its own batch resumes without waiting for the rest of the
/// sweep.  A `Finished` marker retires a task from the sweep.  The loop
/// ends when every task has finished.
///
/// **Fast mode** (`--fast-nondeterministic`): absorb batches in arrival
/// order and publish each as the newest snapshot; nothing is pinned and
/// nothing waits.
pub(crate) fn run_learner_actor(
    mut learner: Learner,
    ords: Vec<usize>,
    rx: Receiver<ToLearner>,
    sink: &dyn SnapshotSink,
    deterministic: bool,
) -> Result<Learner> {
    let mut version: u64 = 0;
    if !deterministic {
        let mut counts: BTreeMap<usize, u64> = BTreeMap::new();
        let mut remaining = ords.len();
        while remaining > 0 {
            match rx.recv() {
                Ok(ToLearner::Batch { batch, mut shuffle_rng }) => {
                    let ord = batch.task_ord;
                    if let Err(e) = learner.absorb(batch, &mut shuffle_rng) {
                        sink.poison();
                        return Err(e);
                    }
                    version += 1;
                    let applied = counts.entry(ord).or_insert(0);
                    *applied += 1;
                    let snap = learner.snapshot();
                    sink.publish(ord, *applied, snap);
                    learner.trace_publish(version, 0);
                }
                Ok(ToLearner::Finished { .. }) => remaining -= 1,
                Err(_) => {
                    sink.poison();
                    anyhow::bail!("learner: workers lost mid-session");
                }
            }
        }
        return Ok(learner);
    }
    let mut live = ords;
    let mut pending: BTreeMap<(u32, usize), Stashed> = BTreeMap::new();
    let mut seq: u32 = 0;
    while !live.is_empty() {
        let mut survivors = Vec::with_capacity(live.len());
        for &ord in &live {
            let entry = loop {
                if let Some(e) = pending.remove(&(seq, ord)) {
                    break e;
                }
                match rx.recv() {
                    Ok(msg) => stash(&mut pending, msg),
                    Err(_) => {
                        sink.poison();
                        anyhow::bail!("learner: workers lost mid-session");
                    }
                }
            };
            if let Some((batch, mut shuffle_rng)) = entry {
                if let Err(e) = learner.absorb(batch, &mut shuffle_rng) {
                    sink.poison();
                    return Err(e);
                }
                version += 1;
                let snap = learner.snapshot();
                sink.publish(ord, seq as u64 + 1, snap);
                learner.trace_publish(version, pending.len());
                survivors.push(ord);
            }
        }
        live = survivors;
        seq += 1;
    }
    Ok(learner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::RustBackend;

    fn learner() -> Learner {
        let backend = Arc::new(RustBackend { pred_batch: 8, train_batch: 8 });
        let model = CostModel::new(backend, &mut Rng::new(1));
        Learner::new(
            LearnerConfig { lr: 1e-3, epochs_per_round: 1, replay_cap: 4, draft: false },
            model,
            None,
        )
    }

    fn sample(ord: usize, gflops: f64) -> Sample {
        Sample { task_ord: ord, feats: [0.1; N_FEATURES], gflops }
    }

    fn varied_sample(ord: usize, i: u64, gflops: f64) -> Sample {
        let mut rng = Rng::new(100 + i);
        let mut feats = [0.0f32; N_FEATURES];
        for f in feats.iter_mut() {
            *f = rng.normal() as f32;
        }
        Sample { task_ord: ord, feats, gflops }
    }

    #[test]
    fn absorb_tracks_best_and_caps_replay() {
        let mut l = learner();
        let mut rng = Rng::new(2);
        let batch = LearnBatch {
            task_ord: 3,
            seq: 0,
            samples: vec![sample(3, 5.0), sample(3, 2.0), sample(3, 9.0)],
            train: None,
        };
        l.absorb(batch, &mut rng).unwrap();
        assert_eq!(l.task_count(), 4);
        assert_eq!(l.best_gflops_per_task[3], 9.0);
        // The cap keeps the most recent rows only.
        let more = LearnBatch {
            task_ord: 3,
            seq: 1,
            samples: vec![sample(3, 1.0), sample(3, 1.0), sample(3, 1.0)],
            train: None,
        };
        l.absorb(more, &mut rng).unwrap();
        assert_eq!(l.replay.len(), 4);
    }

    #[test]
    fn absorb_trains_and_charges_the_task_clock() {
        let mut l = learner();
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..2 * N_FEATURES).map(|_| rng.normal() as f32).collect();
        let batch = LearnBatch {
            task_ord: 0,
            seq: 1,
            samples: vec![sample(0, 4.0), sample(0, 6.0)],
            train: Some(TrainBatch { x, y_raw: vec![4.0, 6.0] }),
        };
        let before = l.model().params().to_vec();
        let v_before = l.snapshot_state().version();
        l.absorb(batch, &mut rng).unwrap();
        assert_ne!(before, l.model().params(), "training must move the parameters");
        assert!(l.snapshot_state().version() > v_before, "updates must bump the version");
        assert!(l.task_clock(0).model_updates() > 0);
        assert_eq!(l.task_clock(1).model_updates(), 0);
        l.reset_task_clocks();
        assert_eq!(l.task_clock(0).model_updates(), 0);
    }

    fn state_of(v: f32) -> ModelSnapshot {
        ModelSnapshot::from_model(Arc::new(ModelState::from_params(vec![v; layout::N_PARAMS])))
    }

    #[test]
    fn snapshot_cell_versions_and_poison() {
        let cell = Arc::new(SnapshotCell::new(state_of(1.0)));
        assert_eq!(cell.wait_for(0).unwrap().model.params()[0], 1.0);
        let c2 = cell.clone();
        let h = std::thread::spawn(move || c2.wait_for(2).map(|p| p.model.params()[0]));
        cell.publish(1, state_of(2.0));
        cell.publish(2, state_of(3.0));
        assert_eq!(h.join().unwrap(), Some(3.0));
        let c3 = cell.clone();
        let h = std::thread::spawn(move || c3.wait_for(99));
        cell.poison();
        assert!(h.join().unwrap().is_none());
    }

    #[test]
    fn snapshot_pin_is_pointer_identical_until_republish() {
        let published = state_of(1.0);
        let cell = SnapshotCell::new(published.clone());
        // Publish/pin never copies the parameters: both pins alias the
        // published storage exactly.
        let a = cell.wait_for(0).unwrap();
        let b = cell.wait_for(0).unwrap();
        assert!(Arc::ptr_eq(&a.model, &published.model));
        assert!(Arc::ptr_eq(&b.model, &published.model));
        cell.publish(1, state_of(2.0));
        let c = cell.wait_for(1).unwrap();
        assert!(!Arc::ptr_eq(&c.model, &published.model));
        // The earlier pin still reads the old parameters.
        assert_eq!(a.model.params()[0], 1.0);
    }

    #[test]
    fn snapshot_has_no_draft_when_the_tier_is_off() {
        let mut l = learner();
        assert!(l.snapshot().draft.is_none());
    }

    #[test]
    fn draft_publishes_with_snapshots_and_memoizes() {
        let backend = Arc::new(RustBackend { pred_batch: 8, train_batch: 8 });
        let model = CostModel::new(backend, &mut Rng::new(1));
        let mut l = Learner::new(
            LearnerConfig { lr: 1e-3, epochs_per_round: 1, replay_cap: 64, draft: true },
            model,
            None,
        );
        // No data yet: the published draft is a passthrough, but it IS
        // published alongside the model.
        let d0 = l.snapshot().draft.unwrap();
        assert!(d0.is_passthrough());
        // Same (version, replay) point → the same Arc, not a refit.
        assert!(Arc::ptr_eq(&l.snapshot().draft.unwrap(), &d0));
        // Enough replay to fit: the refresh produces a live draft
        // stamped with the model version it was distilled from.
        let mut rng = Rng::new(2);
        let samples: Vec<Sample> =
            (0..16).map(|i| varied_sample(0, i, 1.0 + i as f64)).collect();
        l.absorb(LearnBatch { task_ord: 0, seq: 0, samples, train: None }, &mut rng).unwrap();
        let snap = l.snapshot();
        let d1 = snap.draft.unwrap();
        assert!(!Arc::ptr_eq(&d1, &d0), "replay growth must refresh the draft");
        assert!(!d1.is_passthrough());
        assert_eq!(d1.version(), snap.model.version());
    }

    /// Records every publish so tests can assert the apply order.
    struct RecordingSink {
        published: Mutex<Vec<(usize, u64)>>,
        poisoned: Mutex<bool>,
    }

    impl RecordingSink {
        fn new() -> RecordingSink {
            RecordingSink { published: Mutex::new(Vec::new()), poisoned: Mutex::new(false) }
        }
    }

    impl SnapshotSink for RecordingSink {
        fn publish(&self, task_ord: usize, applied: u64, _snap: ModelSnapshot) {
            self.published.lock().unwrap().push((task_ord, applied));
        }
        fn poison(&self) {
            *self.poisoned.lock().unwrap() = true;
        }
    }

    #[test]
    fn actor_applies_in_seq_major_ascending_ord_order() {
        // Feed batches deliberately OUT of the deterministic order; the
        // actor must still apply sweep-major, ascending ord, publishing
        // each task's post-apply snapshot as soon as its batch lands.
        let (tx, rx) = std::sync::mpsc::channel();
        let send_batch = |seq: u32, ord: usize| {
            let batch = LearnBatch { task_ord: ord, seq, samples: vec![sample(ord, 1.0)], train: None };
            tx.send(ToLearner::Batch { batch, shuffle_rng: Rng::new(7) }).unwrap();
        };
        send_batch(1, 1); // task 1 a full sweep ahead of everyone
        send_batch(0, 1); // sweep 0 arrives ord-descending
        send_batch(0, 0);
        send_batch(0, 2);
        send_batch(1, 0);
        tx.send(ToLearner::Finished { task_ord: 2, seq: 1 }).unwrap();
        tx.send(ToLearner::Finished { task_ord: 0, seq: 2 }).unwrap();
        tx.send(ToLearner::Finished { task_ord: 1, seq: 2 }).unwrap();
        drop(tx);
        let sink = RecordingSink::new();
        let l = run_learner_actor(learner(), vec![0, 1, 2], rx, &sink, true).unwrap();
        assert_eq!(
            *sink.published.lock().unwrap(),
            vec![(0, 1), (1, 1), (2, 1), (0, 2), (1, 2)],
            "apply order must be (seq, ord)-lexicographic with 1-based per-task counts"
        );
        assert!(!*sink.poisoned.lock().unwrap());
        assert_eq!(l.task_count(), 3);
    }

    #[test]
    fn actor_fast_mode_absorbs_in_arrival_order() {
        let (tx, rx) = std::sync::mpsc::channel();
        let send_batch = |seq: u32, ord: usize| {
            let batch = LearnBatch { task_ord: ord, seq, samples: vec![sample(ord, 1.0)], train: None };
            tx.send(ToLearner::Batch { batch, shuffle_rng: Rng::new(7) }).unwrap();
        };
        // Arrival order IS the apply order in fast mode — even when it
        // inverts the deterministic (seq, ord) order.
        send_batch(1, 1);
        send_batch(0, 0);
        tx.send(ToLearner::Finished { task_ord: 0, seq: 1 }).unwrap();
        tx.send(ToLearner::Finished { task_ord: 1, seq: 2 }).unwrap();
        drop(tx);
        let sink = RecordingSink::new();
        run_learner_actor(learner(), vec![0, 1], rx, &sink, false).unwrap();
        assert_eq!(*sink.published.lock().unwrap(), vec![(1, 1), (0, 1)]);
    }

    #[test]
    fn actor_poisons_the_sink_when_workers_vanish() {
        let (tx, rx) = std::sync::mpsc::channel::<ToLearner>();
        drop(tx); // no Finished markers will ever arrive
        let sink = RecordingSink::new();
        let err = run_learner_actor(learner(), vec![0], rx, &sink, true).unwrap_err();
        assert!(err.to_string().contains("workers lost"), "{err}");
        assert!(*sink.poisoned.lock().unwrap());
    }

    #[test]
    fn state_roundtrip_preserves_learning() {
        let mut l = learner();
        let mut rng = Rng::new(4);
        let batch = LearnBatch {
            task_ord: 1,
            seq: 0,
            samples: vec![sample(1, 7.0)],
            train: None,
        };
        l.absorb(batch, &mut rng).unwrap();
        let state = l.into_state();
        let backend = Arc::new(RustBackend { pred_batch: 8, train_batch: 8 });
        let l2 = Learner::from_state(
            LearnerConfig { lr: 1e-3, epochs_per_round: 1, replay_cap: 4, draft: false },
            backend,
            state,
        );
        assert_eq!(l2.task_count(), 2);
        assert_eq!(l2.best_gflops_per_task[1], 7.0);
        assert_eq!(l2.replay.len(), 1);
    }
}
