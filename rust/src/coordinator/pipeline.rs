//! The per-task staged tuning pipeline.
//!
//! [`TaskPipeline`] carries everything one task's tuning needs — its
//! forked RNG, virtual clock, search engines, adaptive controller,
//! best-so-far state and convergence history — through explicit named
//! stages:
//!
//! ```text
//! warm-start ──► (propose ► measure ► learn)* ──► finalize
//! ```
//!
//! * **warm-start** consults the tune cache: an exact hit completes the
//!   task outright (zero measured trials, a truthful single-point
//!   history); otherwise local/cross-device/neighbor seeds ground the
//!   search and the probe measurements become the stage's
//!   [`LearnBatch`].
//! * **propose + measure** ([`TaskPipeline::run_round`]) asks the search
//!   engine for candidates scored against a read-only [`Predictor`]
//!   view pinned to a model snapshot — optionally pre-pruned by the
//!   cheap draft scorer pinned alongside it (see
//!   [`crate::search::draft`]) —
//!   measures them (or, on AC-terminated rounds, only the predicted
//!   top), and emits the round's `LearnBatch`.
//! * **learn** happens on the learning plane ([`super::learner`]) — the
//!   pipeline never mutates the cost model.
//! * **finalize** re-ranks the surviving prediction-only candidates with
//!   the *current* model, verifies the winner on device, applies the
//!   default-schedule fallback, and commits outcomes to the cache.
//!
//! The split is what lets sessions overlap cheap cost-model work with
//! expensive measurement across tasks: stages only communicate through
//! `LearnBatch`es and `Arc`-shared model snapshots (pinning one is a
//! pointer clone — see [`crate::costmodel::ModelState`]), so N
//! pipelines drive one shared learner from N threads (`--jobs N`).

use std::sync::Arc;

use anyhow::Result;

use super::learner::{LearnBatch, Sample, TrainBatch};
use super::session::TaskResult;
use super::tuner::TuneConfig;
use crate::costmodel::Predictor;
use crate::device::{DeviceSim, VirtualClock};
use crate::metrics::search::DraftCounters;
use crate::obs::{SpanTimer, TraceScope};
use crate::program::{featurize, Geometry, Schedule, Subgraph, TensorProgram, N_FEATURES};
use crate::search::{DraftGate, DraftState, EvolutionarySearch, RandomSearch, SearchPolicy};
use crate::transfer::{AdaptiveController, Strategy};
use crate::tunecache::{warmstart, TuneCache, TuneRecord, WorkloadKey};
use crate::util::rng::Rng;

/// Cap on warm-start schedules (cross-device plus nearest-neighbor)
/// injected into one task's search population (the evolutionary engine
/// holds up to 32 seeds).
const MAX_WARM_SEEDS: usize = 8;

/// What a pipeline stage hands back to its driver.
pub(crate) enum StageOutput {
    /// Task fully served (exact cache hit) — no rounds will run.
    Complete(Box<TaskResult>),
    /// A batch for the learning plane; more stages may follow.
    Learn(LearnBatch),
    /// No candidates remain (or the round budget is spent): finalize.
    Exhausted,
}

fn program_fingerprint(task: &Subgraph, s: &Schedule) -> u64 {
    TensorProgram::new(task.clone(), *s).fingerprint()
}

/// Index of the best finite prediction (first entry if all are
/// non-finite — a diverged model must neither panic nor win).
fn top_prediction(preds: &[f32]) -> usize {
    preds
        .iter()
        .enumerate()
        .filter(|(_, p)| p.is_finite())
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Per-task state of the staged tuning pipeline.
pub(crate) struct TaskPipeline {
    task: Subgraph,
    /// Stable task ordinal across the learner's lifetime (replay
    /// normalizer slot).
    ord: usize,
    cfg: TuneConfig,
    sim: DeviceSim,
    cache: Option<Arc<TuneCache>>,
    rng: Rng,
    clock: VirtualClock,
    geometry: Geometry,
    default_sched: Schedule,
    default_latency: f64,
    evo: EvolutionarySearch,
    random: RandomSearch,
    ac: Option<AdaptiveController>,
    rounds: usize,
    round: usize,
    measured_round_budget: usize,
    seen_fps: Vec<u64>,
    best_latency: f64,
    best_sched: Schedule,
    measured: usize,
    predicted_only: usize,
    history: Vec<f64>,
    /// Prediction-only candidates surviving for the finalize re-rank.
    pending: Vec<Schedule>,
    /// Measured-OK (schedule, true latency) pairs for cache commit.
    cache_outcomes: Vec<(Schedule, f64)>,
    warm_seeds_n: usize,
    neighbor_seeds_n: usize,
    /// Last measured batch awaiting the AC's post-update stability
    /// observation (consumed by the next stage that sees the model).
    pending_observe: Option<(Vec<f32>, usize)>,
    /// Scheduled (`--jobs N`) sessions defer cache commits: finalize
    /// stashes records here and the driver lands them in task order
    /// after every pipeline is done, so what a sibling task's warm
    /// start sees never depends on thread timing.
    defer_commits: bool,
    deferred_commits: Vec<TuneRecord>,
    /// This task's trace emitter (disabled scopes reduce every span to
    /// one branch).
    scope: TraceScope,
    /// Session-wide draft kept/pruned counters (shared across pipelines
    /// when the draft tier is on).
    draft_counters: Option<DraftCounters>,
}

impl TaskPipeline {
    pub fn new(
        task: Subgraph,
        ord: usize,
        cfg: &TuneConfig,
        sim: DeviceSim,
        cache: Option<Arc<TuneCache>>,
        rng: Rng,
        scope: TraceScope,
    ) -> TaskPipeline {
        let geometry = task.geometry();
        let default_sched = Schedule::default_for(&geometry);
        let default_latency = sim.true_latency(&TensorProgram::new(task.clone(), default_sched));
        let rounds = (cfg.trials_per_task / cfg.measure_batch).max(1);
        let evo = EvolutionarySearch::with_params(task.clone(), cfg.population, cfg.generations);
        let random = RandomSearch::new(evo.generator.clone());
        let ac = match &cfg.strategy {
            Strategy::Moses(c) => {
                Some(AdaptiveController::new(c.ac_cv_threshold, c.ac_min_batches))
            }
            _ => None,
        };
        let measured_round_budget = match &cfg.strategy {
            Strategy::Moses(c) => ((rounds as f64) * c.train_fraction).ceil() as usize,
            _ => rounds,
        };
        TaskPipeline {
            task,
            ord,
            cfg: cfg.clone(),
            sim,
            cache,
            rng,
            clock: VirtualClock::new(),
            geometry,
            default_sched,
            default_latency,
            evo,
            random,
            ac,
            rounds,
            round: 0,
            measured_round_budget,
            seen_fps: Vec::new(),
            best_latency: f64::INFINITY,
            best_sched: default_sched,
            measured: 0,
            predicted_only: 0,
            history: Vec::with_capacity(rounds),
            pending: Vec::new(),
            cache_outcomes: Vec::new(),
            warm_seeds_n: 0,
            neighbor_seeds_n: 0,
            pending_observe: None,
            defer_commits: false,
            deferred_commits: Vec::new(),
            scope,
            draft_counters: None,
        }
    }

    /// Stash finalize's cache records instead of committing them (the
    /// scheduler lands them in task order once the session is done).
    pub fn defer_cache_commits(&mut self) {
        self.defer_commits = true;
    }

    /// Attach the session's shared draft kept/pruned counters (present
    /// only when the draft tier is on).
    pub fn set_draft_counters(&mut self, counters: DraftCounters) {
        self.draft_counters = Some(counters);
    }

    /// The records finalize stashed under
    /// [`TaskPipeline::defer_cache_commits`].
    pub fn take_deferred_commits(&mut self) -> Vec<TuneRecord> {
        std::mem::take(&mut self.deferred_commits)
    }

    /// Serve the pending post-update AC observation, if one is due: the
    /// last measured batch is re-scored under `model` (which by now
    /// includes the learner's update for it) and handed to the AC.
    fn flush_pending_observe(&mut self, model: &Predictor) -> Result<()> {
        if let Some((bx, n)) = self.pending_observe.take() {
            if let Some(a) = self.ac.as_mut() {
                a.observe_scored(model, &bx, n)?;
                self.clock.charge_query();
            }
        }
        Ok(())
    }

    /// The task's own deterministic stream (inline-mode learning draws
    /// from it so the staged path reproduces the sequential one).
    pub fn rng_mut(&mut self) -> &mut Rng {
        &mut self.rng
    }

    /// Fork an independent stream off the task's (actor-mode epoch
    /// shuffles — the task stream itself cannot cross threads).
    pub fn fork_shuffle_rng(&mut self) -> Rng {
        self.rng.fork(0xB47C)
    }

    /// Search/measurement-plane charges accumulated so far.
    pub fn clock(&self) -> VirtualClock {
        self.clock.clone()
    }

    /// Open a span at the task's current virtual time (snapshot-pin
    /// waits bracket the wait with this and [`TaskPipeline::trace_pin`]).
    pub fn pin_timer(&self) -> SpanTimer {
        self.scope.begin(self.clock.seconds())
    }

    /// Record a completed snapshot pin: the wave's requested version in
    /// `args` (deterministic), the actually-pinned model version and the
    /// wall-clock wait in `diag` (the learner may have published past
    /// the requested version, which is scheduling-dependent).
    pub fn trace_pin(&mut self, timer: SpanTimer, requested: u64, model_version: u64) {
        self.scope.end(
            timer,
            1,
            "pin",
            self.clock.seconds(),
            &[("version", requested as f64)],
            &[("model_version", model_version as f64)],
        );
    }

    /// Stage 1: consult the tune cache.  An exact-device hit at a
    /// sufficient trial budget completes the task with zero measured
    /// trials; otherwise local records ground the best, the most
    /// promising cross-device/neighbor seeds are probed on device, and
    /// every seed joins the evolutionary population.
    pub fn warm_start(&mut self) -> Result<StageOutput> {
        let timer = self.scope.begin(self.clock.seconds());
        let out = self.warm_start_inner();
        if self.scope.enabled() {
            let (hit, probes) = match &out {
                Ok(StageOutput::Complete(_)) => (1.0, 0.0),
                Ok(StageOutput::Learn(b)) => (0.0, b.samples.len() as f64),
                _ => (0.0, 0.0),
            };
            self.scope.end(
                timer,
                0,
                "warm_start",
                self.clock.seconds(),
                &[
                    ("hit", hit),
                    ("neighbor_seeds", self.neighbor_seeds_n as f64),
                    ("probes", probes),
                    ("warm_seeds", self.warm_seeds_n as f64),
                ],
                &[],
            );
        }
        out
    }

    fn warm_start_inner(&mut self) -> Result<StageOutput> {
        let mut warm_seeds: Vec<Schedule> = Vec::new();
        let mut neighbor_seeds: Vec<Schedule> = Vec::new();
        let mut local_seeds: Vec<Schedule> = Vec::new();
        if let Some(cache) = self.cache.clone() {
            let plan = warmstart::plan(
                &cache,
                &self.task,
                &self.sim.arch,
                &warmstart::WarmStartOptions {
                    max_seeds: MAX_WARM_SEEDS,
                    requested_trials: self.cfg.trials_per_task,
                    nn_k: self.cfg.nn_k,
                    nn_radius: self.cfg.nn_radius,
                },
            );
            if let Some(rec) = plan.exact {
                let cached = rec.schedule();
                if cached.is_valid(&self.geometry) {
                    let cached_latency = self
                        .sim
                        .true_latency(&TensorProgram::new(self.task.clone(), cached));
                    // The default fallback applies to cached choices too.
                    let (best_latency, best_sched) =
                        if cached_latency.is_finite() && cached_latency <= self.default_latency {
                            (cached_latency, cached)
                        } else {
                            (self.default_latency, self.default_sched)
                        };
                    // A truthful convergence history: the hit ran zero
                    // search rounds, so it contributes one point — not
                    // `rounds` fabricated copies.
                    return Ok(StageOutput::Complete(Box::new(TaskResult {
                        task: self.task.clone(),
                        best_latency_s: best_latency,
                        best_schedule: best_sched,
                        default_latency_s: self.default_latency,
                        measured: 0,
                        predicted_only: 0,
                        history: vec![best_latency],
                        cache_hit: true,
                        warm_seeds: 0,
                        neighbor_seeds: 0,
                    })));
                }
            }
            warm_seeds = plan.seeds.iter().map(|s| s.schedule).collect();
            neighbor_seeds = plan.neighbor_seeds.iter().map(|s| s.schedule).collect();
            local_seeds = plan.local_seeds;
        }
        self.warm_seeds_n = warm_seeds.len();
        self.neighbor_seeds_n = neighbor_seeds.len();

        // Re-seed from this device's own cached records (present when a
        // bigger budget than any previous session was requested): their
        // latencies are deterministic ground truth, so ground the best
        // and mark them seen at zero measurement cost.
        for s in &local_seeds {
            let prog = TensorProgram::new(self.task.clone(), *s);
            let true_lat = self.sim.true_latency(&prog);
            if true_lat < self.best_latency {
                self.best_latency = true_lat;
                self.best_sched = *s;
            }
            self.seen_fps.push(prog.fingerprint());
            self.evo.add_seed(*s);
        }

        // Verify the most promising seeds on device first (grounds the
        // session's best immediately), then hand ALL seeds to the
        // evolutionary engine's population.  Same-workload cross-device
        // seeds rank ahead of similar-workload neighbor seeds in the
        // probe order — they carry no shape mismatch — and the neighbor
        // tier arrives distance-weighted from `warmstart::plan` (closest
        // neighbor's best record first).
        let mut samples = Vec::new();
        let probe_order: Vec<Schedule> =
            warm_seeds.iter().chain(neighbor_seeds.iter()).copied().collect();
        for (i, s) in probe_order.iter().enumerate() {
            if i < self.cfg.seed_probe {
                let prog = TensorProgram::new(self.task.clone(), *s);
                let m = self.sim.measure(&prog, &mut self.rng);
                self.clock.charge_measurement(m.cost_s);
                self.measured += 1;
                self.seen_fps.push(prog.fingerprint());
                let feats = featurize(&self.task, s);
                let gflops = if m.ok { m.gflops } else { 0.0 };
                if m.ok {
                    let true_lat = self.sim.true_latency(&prog);
                    self.cache_outcomes.push((*s, true_lat));
                    if true_lat < self.best_latency {
                        self.best_latency = true_lat;
                        self.best_sched = *s;
                    }
                }
                samples.push(Sample { task_ord: self.ord, feats, gflops });
            }
            self.evo.add_seed(*s);
        }
        Ok(StageOutput::Learn(LearnBatch { task_ord: self.ord, seq: 0, samples, train: None }))
    }

    /// Stages 2+3: propose a candidate batch against `model` and measure
    /// it (measured rounds) or trust the ranking and verify only the
    /// predicted top (AC-terminated rounds).  Returns the round's
    /// `LearnBatch`, or `Exhausted` once the budget is spent or the
    /// schedule space ran dry.
    ///
    /// When `draft` is `Some`, the evolutionary engine scores each
    /// generation with the cheap linear draft first and asks the full
    /// `model` to verify only the top `draft_keep` fraction
    /// (speculative draft-then-verify); `None` reproduces the
    /// full-verification path bit for bit.
    ///
    /// Every call — including the terminal `Exhausted` one — records a
    /// "round" span: the exhausted path still charges the virtual clock
    /// (a trailing AC observation), and stage spans must cover every
    /// charge for the trace's virtual time to reconcile with the
    /// session total.
    pub fn run_round(
        &mut self,
        model: &Predictor,
        draft: Option<&DraftState>,
    ) -> Result<StageOutput> {
        let timer = self.scope.begin(self.clock.seconds());
        let round = self.round;
        let measured_before = self.measured;
        let out = self.run_round_inner(model, draft);
        if self.scope.enabled() {
            let exhausted = matches!(out, Ok(StageOutput::Exhausted));
            self.scope.end(
                timer,
                0,
                "round",
                self.clock.seconds(),
                &[
                    ("exhausted", if exhausted { 1.0 } else { 0.0 }),
                    ("measured", (self.measured - measured_before) as f64),
                    ("round", round as f64),
                ],
                &[],
            );
        }
        out
    }

    fn run_round_inner(
        &mut self,
        model: &Predictor,
        draft: Option<&DraftState>,
    ) -> Result<StageOutput> {
        // The AC watches post-update prediction stability on the last
        // measured batch; the learner's update for it is visible in
        // `model` by the time this stage runs.
        self.flush_pending_observe(model)?;
        if self.round >= self.rounds {
            return Ok(StageOutput::Exhausted);
        }
        let round = self.round;
        let gate = draft.map(|state| DraftGate { state, keep: self.cfg.draft_keep });
        let propose_vt = self.clock.seconds();
        let propose_timer = self.scope.begin(propose_vt);
        let verify_timer = self.scope.begin(propose_vt);
        let candidates = {
            let task = &self.task;
            let seen_fps = &self.seen_fps;
            let seen = |s: &Schedule| seen_fps.contains(&program_fingerprint(task, s));
            let clock = &mut self.clock;
            let mut charge = || clock.charge_query();
            match &self.cfg.strategy {
                Strategy::RandomSearch => self.random.propose(
                    self.cfg.measure_batch,
                    model,
                    &seen,
                    &mut self.rng,
                    gate.as_ref(),
                    &mut charge,
                ),
                _ => self.evo.propose(
                    self.cfg.measure_batch,
                    model,
                    &seen,
                    &mut self.rng,
                    gate.as_ref(),
                    &mut charge,
                ),
            }
        };
        // The draft/verify split nests (depth 2) inside "propose": a
        // zero-duration "draft" instant with the generation-summed
        // kept/pruned counts, then a "verify" span covering the full
        // predictor's share of the propose interval.  Draft-off traces
        // stay byte-identical — neither event is emitted.
        if gate.is_some() && !matches!(self.cfg.strategy, Strategy::RandomSearch) {
            let stats = self.evo.last_draft_stats();
            if let Some(c) = &self.draft_counters {
                c.record_generation(stats.kept, stats.pruned);
            }
            if self.scope.enabled() {
                self.scope.instant(
                    2,
                    "draft",
                    propose_vt,
                    &[
                        ("kept", stats.kept as f64),
                        ("pruned", stats.pruned as f64),
                        ("round", round as f64),
                        ("scored", stats.draft_scored as f64),
                    ],
                    &[],
                );
                self.scope.end(
                    verify_timer,
                    2,
                    "verify",
                    self.clock.seconds(),
                    &[("round", round as f64), ("rows", stats.full_rows as f64)],
                    &[],
                );
            }
        }
        self.scope.end(
            propose_timer,
            1,
            "propose",
            self.clock.seconds(),
            &[("candidates", candidates.len() as f64), ("round", round as f64)],
            &[],
        );
        if candidates.is_empty() {
            return Ok(StageOutput::Exhausted);
        }

        let do_measure = match &self.cfg.strategy {
            Strategy::TensetPretrain => round == 0 || round == self.rounds - 1,
            Strategy::Moses(_) => {
                round < self.measured_round_budget
                    && self.ac.as_ref().map(|a| a.keep_measuring()).unwrap_or(true)
            }
            _ => true,
        };

        let batch = if do_measure {
            // For pretrain: only verify the single top prediction.
            let to_measure: &[Schedule] = match &self.cfg.strategy {
                Strategy::TensetPretrain => &candidates[..1],
                _ => &candidates[..],
            };
            let measure_timer = self.scope.begin(self.clock.seconds());
            let mut batch_x = Vec::with_capacity(to_measure.len() * N_FEATURES);
            let mut batch_y = Vec::with_capacity(to_measure.len());
            let mut samples = Vec::with_capacity(to_measure.len());
            for s in to_measure {
                let prog = TensorProgram::new(self.task.clone(), *s);
                let m = self.sim.measure(&prog, &mut self.rng);
                self.clock.charge_measurement(m.cost_s);
                self.measured += 1;
                self.seen_fps.push(prog.fingerprint());
                let feats = featurize(&self.task, s);
                let gflops = if m.ok { m.gflops } else { 0.0 };
                if m.ok {
                    let true_lat = self.sim.true_latency(&prog);
                    self.cache_outcomes.push((*s, true_lat));
                    if true_lat < self.best_latency {
                        self.best_latency = true_lat;
                        self.best_sched = *s;
                    }
                    self.evo.add_seed(*s);
                }
                batch_x.extend_from_slice(&feats);
                batch_y.push(gflops as f32);
                samples.push(Sample { task_ord: self.ord, feats, gflops });
            }
            self.scope.end(
                measure_timer,
                1,
                "measure",
                self.clock.seconds(),
                &[("measured", to_measure.len() as f64), ("round", round as f64)],
                &[],
            );
            let train = if self.cfg.strategy.trains_online() {
                Some(TrainBatch { x: batch_x.clone(), y_raw: batch_y })
            } else {
                None
            };
            if self.ac.is_some() {
                self.pending_observe = Some((batch_x, to_measure.len()));
            }
            LearnBatch { task_ord: self.ord, seq: round as u32 + 1, samples, train }
        } else {
            // Prediction-only round: trust the model's ranking for the
            // batch, but VERIFY the top prediction with one cheap
            // measurement (1 vs measure_batch) so the final choice is
            // grounded — the AC saves the other 7/8ths.
            self.predicted_only += candidates.len().saturating_sub(1);
            let mut cx = Vec::with_capacity(candidates.len() * N_FEATURES);
            for s in &candidates {
                cx.extend_from_slice(&featurize(&self.task, s));
            }
            for s in &candidates {
                let fp = program_fingerprint(&self.task, s);
                self.seen_fps.push(fp);
            }
            let preds = model.predict(&cx, candidates.len())?;
            self.clock.charge_query();
            let top = top_prediction(&preds);
            let prog = TensorProgram::new(self.task.clone(), candidates[top]);
            let measure_timer = self.scope.begin(self.clock.seconds());
            let meas = self.sim.measure(&prog, &mut self.rng);
            self.clock.charge_measurement(meas.cost_s);
            self.measured += 1;
            if meas.ok {
                let true_lat = self.sim.true_latency(&prog);
                self.cache_outcomes.push((candidates[top], true_lat));
                if true_lat < self.best_latency {
                    self.best_latency = true_lat;
                    self.best_sched = candidates[top];
                }
                self.evo.add_seed(candidates[top]);
            }
            self.scope.end(
                measure_timer,
                1,
                "measure",
                self.clock.seconds(),
                &[("measured", 1.0), ("round", round as f64)],
                &[],
            );
            // The rest survive for the finalize re-rank under the final
            // model — not a running argmax under stale scores.
            for (i, s) in candidates.iter().enumerate() {
                if i != top {
                    self.pending.push(*s);
                }
            }
            LearnBatch {
                task_ord: self.ord,
                seq: round as u32 + 1,
                samples: Vec::new(),
                train: None,
            }
        };
        self.history.push(if self.best_latency.is_finite() {
            self.best_latency
        } else {
            self.default_latency
        });
        self.round += 1;
        Ok(StageOutput::Learn(batch))
    }

    /// Final stage: re-rank the surviving prediction-only candidates
    /// with the *current* model and verify the winner with one final
    /// measurement (TVM always builds/measures the final choice), apply
    /// the default-schedule fallback, and commit measured outcomes plus
    /// the final choice to the tune cache.
    pub fn finalize(&mut self, model: &Predictor) -> Result<TaskResult> {
        let timer = self.scope.begin(self.clock.seconds());
        let out = self.finalize_inner(model);
        if self.scope.enabled() {
            let (measured, predicted_only) = match &out {
                Ok(r) => (r.measured as f64, r.predicted_only as f64),
                Err(_) => (0.0, 0.0),
            };
            self.scope.end(
                timer,
                0,
                "finalize",
                self.clock.seconds(),
                &[
                    ("commits", self.cache_outcomes.len() as f64),
                    ("measured", measured),
                    ("predicted_only", predicted_only),
                ],
                &[],
            );
        }
        out
    }

    fn finalize_inner(&mut self, model: &Predictor) -> Result<TaskResult> {
        // A trailing AC observation (from the last measured round) keeps
        // the query accounting aligned with the sequential loop.
        self.flush_pending_observe(model)?;
        if !self.pending.is_empty() {
            let mut cx = Vec::with_capacity(self.pending.len() * N_FEATURES);
            for s in &self.pending {
                cx.extend_from_slice(&featurize(&self.task, s));
            }
            let preds = model.predict(&cx, self.pending.len())?;
            self.clock.charge_query();
            let sched = self.pending[top_prediction(&preds)];
            let prog = TensorProgram::new(self.task.clone(), sched);
            let m = self.sim.measure(&prog, &mut self.rng);
            self.clock.charge_measurement(m.cost_s);
            self.measured += 1;
            if m.ok {
                let true_lat = self.sim.true_latency(&prog);
                self.cache_outcomes.push((sched, true_lat));
                if true_lat < self.best_latency {
                    self.best_latency = true_lat;
                    self.best_sched = sched;
                }
            }
        }

        // The default schedule is always available at deploy time: if
        // the search never beat it (tiny budgets, unlucky measurements),
        // ship the default — as TVM's fallback configuration does.
        if !self.best_latency.is_finite() || self.best_latency > self.default_latency {
            self.best_latency = self.default_latency;
            self.best_sched = self.default_sched;
        }

        // Commit measured outcomes plus the final choice, so later
        // sessions — on this device or others — can warm start.
        if let Some(cache) = &self.cache {
            let key = WorkloadKey::new(&self.task, &self.sim.arch);
            let desc = self.task.descriptor();
            self.cache_outcomes.push((self.best_sched, self.best_latency));
            for (sched, lat) in &self.cache_outcomes {
                let gflops = self.task.flops() / lat.max(1e-12) / 1e9;
                let rec = TuneRecord::new(
                    key,
                    desc,
                    &self.sim.arch.name,
                    sched,
                    *lat,
                    gflops,
                    self.cfg.trials_per_task,
                )
                .with_task(&self.task);
                if self.defer_commits {
                    self.deferred_commits.push(rec);
                } else {
                    cache.commit(rec);
                }
            }
        }

        Ok(TaskResult {
            task: self.task.clone(),
            best_latency_s: self.best_latency,
            best_schedule: self.best_sched,
            default_latency_s: self.default_latency,
            measured: self.measured,
            predicted_only: self.predicted_only,
            history: self.history.clone(),
            cache_hit: false,
            warm_seeds: self.warm_seeds_n,
            neighbor_seeds: self.neighbor_seeds_n,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::{CostModel, RustBackend};
    use crate::device::presets;
    use crate::obs::{Lane, Recorder, TraceEvent};
    use crate::program::SubgraphKind;

    fn cfg() -> TuneConfig {
        TuneConfig {
            trials_per_task: 16,
            measure_batch: 4,
            strategy: Strategy::AnsorRandom,
            population: 16,
            generations: 2,
            seed: 3,
            ..TuneConfig::default()
        }
    }

    fn model() -> Predictor {
        CostModel::new(
            Arc::new(RustBackend { pred_batch: 64, train_batch: 64 }),
            &mut Rng::new(9),
        )
        .predictor()
    }

    #[test]
    fn stages_run_to_a_valid_result_without_a_learner() {
        // Even with a frozen model view the staged walk must terminate
        // and produce a sane result (the learner is optional plumbing).
        let task = Subgraph::new("pp.dense", SubgraphKind::Dense { m: 64, n: 256, k: 256 });
        let c = cfg();
        let mut pipe = TaskPipeline::new(
            task,
            0,
            &c,
            DeviceSim::new(presets::rtx_2060()),
            None,
            Rng::new(5),
            TraceScope::disabled(),
        );
        let m = model();
        match pipe.warm_start().unwrap() {
            StageOutput::Learn(b) => {
                assert_eq!(b.seq, 0);
                assert!(b.train.is_none());
            }
            _ => panic!("cache-less warm start must yield a batch"),
        }
        let mut batches = 0;
        loop {
            match pipe.run_round(&m, None).unwrap() {
                StageOutput::Learn(b) => {
                    assert_eq!(b.seq as usize, batches + 1);
                    batches += 1;
                }
                StageOutput::Exhausted => break,
                StageOutput::Complete(_) => panic!("rounds never complete a task"),
            }
        }
        assert!((1..=4).contains(&batches));
        let r = pipe.finalize(&m).unwrap();
        assert!(r.best_latency_s.is_finite());
        assert!(r.best_latency_s <= r.default_latency_s * 1.0001);
        assert_eq!(r.history.len(), batches);
        for w in r.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
        assert!(pipe.clock().seconds() > 0.0);
    }

    #[test]
    fn traced_stages_cover_the_whole_virtual_clock() {
        let task = Subgraph::new("pp.dense2", SubgraphKind::Dense { m: 64, n: 128, k: 256 });
        let c = cfg();
        let rec = Recorder::enabled();
        let mut pipe = TaskPipeline::new(
            task,
            0,
            &c,
            DeviceSim::new(presets::rtx_2060()),
            None,
            Rng::new(5),
            rec.scope(Lane::Task(0), "pp.dense2"),
        );
        let m = model();
        pipe.warm_start().unwrap();
        while !matches!(pipe.run_round(&m, None).unwrap(), StageOutput::Exhausted) {}
        pipe.finalize(&m).unwrap();

        let evs = rec.drain();
        let stage_names: Vec<&str> =
            evs.iter().filter(|e| e.depth == 0).map(|e| e.name.as_str()).collect();
        assert_eq!(stage_names.first(), Some(&"warm_start"));
        assert_eq!(stage_names.last(), Some(&"finalize"));
        assert!(stage_names[1..stage_names.len() - 1].iter().all(|n| *n == "round"));
        // Per-lane seqs are contiguous from 0 in drain order.
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
        }
        // Draft-off sessions emit no depth-2 draft/verify detail at all.
        assert!(evs.iter().all(|e| e.depth < 2));
        // Every virtual-clock charge happened inside a stage span.
        let vt_sum: f64 = evs.iter().filter(|e| e.depth == 0).map(|e| e.vt_dur_s).sum();
        assert!((vt_sum - pipe.clock().seconds()).abs() < 1e-9);
    }

    #[test]
    fn traced_draft_rounds_nest_draft_and_verify_inside_propose() {
        let task = Subgraph::new("pp.dense3", SubgraphKind::Dense { m: 64, n: 128, k: 256 });
        let c = cfg();
        let rec = Recorder::enabled();
        let mut pipe = TaskPipeline::new(
            task,
            0,
            &c,
            DeviceSim::new(presets::rtx_2060()),
            None,
            Rng::new(5),
            rec.scope(Lane::Task(0), "pp.dense3"),
        );
        let counters = DraftCounters::default();
        pipe.set_draft_counters(counters.clone());
        let m = model();
        // A passthrough draft exercises the span plumbing without
        // needing a fitted scorer: everything still verifies.
        let d = DraftState::passthrough(0);
        pipe.warm_start().unwrap();
        while !matches!(pipe.run_round(&m, Some(&d)).unwrap(), StageOutput::Exhausted) {}
        pipe.finalize(&m).unwrap();

        let evs = rec.drain();
        let proposes: Vec<&TraceEvent> =
            evs.iter().filter(|e| e.depth == 1 && e.name == "propose").collect();
        let drafts: Vec<&TraceEvent> =
            evs.iter().filter(|e| e.depth == 2 && e.name == "draft").collect();
        let verifies: Vec<&TraceEvent> =
            evs.iter().filter(|e| e.depth == 2 && e.name == "verify").collect();
        assert!(!proposes.is_empty());
        assert_eq!(drafts.len(), proposes.len());
        assert_eq!(verifies.len(), proposes.len());
        for ((d, v), p) in drafts.iter().zip(&verifies).zip(&proposes) {
            // The instant sits at propose start; verify covers the
            // propose interval; lane order is draft < verify < propose.
            assert_eq!(d.vt_dur_s, 0.0);
            assert_eq!(d.vt_start_s, p.vt_start_s);
            assert_eq!(v.vt_start_s, p.vt_start_s);
            assert!((v.vt_dur_s - p.vt_dur_s).abs() < 1e-12);
            assert!(d.seq < v.seq && v.seq < p.seq);
        }
        // Depth-0 stage spans still cover the whole virtual clock —
        // nested detail never double-bills it.
        let vt_sum: f64 = evs.iter().filter(|e| e.depth == 0).map(|e| e.vt_dur_s).sum();
        assert!((vt_sum - pipe.clock().seconds()).abs() < 1e-9);
        // Passthrough drafts verify everything, so nothing was pruned.
        assert_eq!(counters.kept(), 0);
        assert_eq!(counters.pruned(), 0);
    }

    #[test]
    fn top_prediction_ignores_non_finite() {
        assert_eq!(top_prediction(&[0.1, f32::NAN, 0.9, f32::INFINITY]), 2);
        assert_eq!(top_prediction(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(top_prediction(&[0.3]), 0);
    }
}
