//! The work-stealing execution plane of `--jobs N` sessions.
//!
//! Tasks are stealable units: each [`TaskUnit`] wraps one
//! [`TaskPipeline`] as a resumable step-state machine (warm-start, one
//! round per step, finalize) and lives on the [`Board`] — per-worker
//! deques (own pops are LIFO, steals FIFO), a global injector for
//! resumed units, and a parking lot for units waiting on a model
//! snapshot.  A worker that drains its own deque takes resumed work
//! from the injector, then steals the oldest unit from a sibling; it
//! only sleeps when every task is either running on some worker or
//! parked.  That keeps all `--jobs` workers saturated instead of
//! idling behind a wave barrier's straggler.
//!
//! **Determinism contract.**  In the default mode the schedule is free
//! but the *results* are not: the learner actor applies batches in the
//! fixed `(seq, task_ord)` order and publishes each task's post-apply
//! snapshot into that task's board slot ([`Board`] is the learner's
//! [`SnapshotSink`]).  A unit blocked on its round-`r + 1` pin parks
//! until its *own* round-`r` batch has been applied, and the slot
//! cannot advance past that point until the task itself sends another
//! batch — so the pinned state is independent of which worker resumes
//! the unit or how long it slept.  Sessions are therefore
//! bit-reproducible per `(seed, tasks)` for any worker count, while
//! every scheduling decision (steal/park/resume, recorded on the
//! [`Lane::Sched`](crate::obs::Lane) lanes) stays timing-dependent.
//! In `--fast-nondeterministic` mode units never park: a blocked unit
//! immediately pins the newest published snapshot and requeues.
//!
//! Cache commits are deferred through the unit
//! ([`TaskPipeline::defer_cache_commits`]) and landed by the driver in
//! task order after the scheduler finishes, so a sibling's warm start
//! never races a finalize commit.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use super::learner::{ModelSnapshot, SnapshotSink, ToLearner};
use super::pipeline::{StageOutput, TaskPipeline};
use super::session::TaskResult;
use crate::costmodel::{Backend, Predictor};
use crate::device::VirtualClock;
use crate::obs::{SpanTimer, TraceScope};
use crate::tunecache::TuneRecord;

/// What one finished task hands back to the driver.
pub(crate) struct UnitOutput {
    pub idx: usize,
    pub result: TaskResult,
    pub clock: VirtualClock,
    /// Deferred cache records, landed by the driver in task order.
    pub commits: Vec<TuneRecord>,
}

/// One step's outcome: the unit either sent a batch and needs its
/// next snapshot, or ran to completion.
enum StepResult {
    /// Park until the task's applied-batch count reaches `want`.
    Blocked { want: u64 },
    Done(Box<UnitOutput>),
}

/// A task pipeline as a stealable, resumable unit of work.
///
/// Steps: the first `step` runs warm-start (a cache hit completes the
/// unit outright); every later step pins the snapshot the scheduler
/// supplied, runs one search round, and either emits the next batch
/// (blocking on its apply) or — once the budget is exhausted —
/// finalizes under the same snapshot.  Dropping a unit on any path
/// sends the learner's `Finished` marker exactly once, so the actor's
/// sweep never waits on a dead task.
pub(crate) struct TaskUnit {
    /// Local index on the board (`ord - ord_base`).
    idx: usize,
    /// Global task ordinal (the learner's slot key).
    ord: usize,
    pipe: TaskPipeline,
    tx: Sender<ToLearner>,
    /// Batches sent so far; the next pin waits for this many applies.
    sent: u32,
    finished_sent: bool,
    started: bool,
    /// Snapshot supplied by the scheduler before a resumed step (the
    /// `(model, draft)` pair is pinned atomically).
    pinned: Option<ModelSnapshot>,
    /// Open pin span covering the park wait (wall time lands in diag).
    pin_timer: Option<SpanTimer>,
    was_parked: bool,
}

impl TaskUnit {
    pub fn new(idx: usize, ord: usize, pipe: TaskPipeline, tx: Sender<ToLearner>) -> TaskUnit {
        TaskUnit {
            idx,
            ord,
            pipe,
            tx,
            sent: 0,
            finished_sent: false,
            started: false,
            pinned: None,
            pin_timer: None,
            was_parked: false,
        }
    }

    /// Tell the learner this task will emit no batch at `sent` or any
    /// later sweep (idempotent; also fired by `Drop` on error paths).
    fn send_finished(&mut self) {
        if !self.finished_sent {
            self.finished_sent = true;
            let _ = self.tx.send(ToLearner::Finished { task_ord: self.ord, seq: self.sent });
        }
    }

    fn send_batch(&mut self, batch: super::learner::LearnBatch) {
        let shuffle_rng = self.pipe.fork_shuffle_rng();
        let _ = self.tx.send(ToLearner::Batch { batch, shuffle_rng });
        self.sent += 1;
    }

    fn done(&mut self, result: TaskResult) -> StepResult {
        StepResult::Done(Box::new(UnitOutput {
            idx: self.idx,
            result,
            clock: self.pipe.clock(),
            commits: self.pipe.take_deferred_commits(),
        }))
    }

    /// Run the unit until it blocks on a snapshot or completes.
    fn step(&mut self, backend: &Arc<dyn Backend>) -> Result<StepResult> {
        if !self.started {
            self.started = true;
            match self.pipe.warm_start()? {
                StageOutput::Complete(r) => {
                    self.send_finished();
                    return Ok(self.done(*r));
                }
                StageOutput::Learn(batch) => {
                    self.send_batch(batch);
                    self.pin_timer = Some(self.pipe.pin_timer());
                    return Ok(StepResult::Blocked { want: 1 });
                }
                StageOutput::Exhausted => unreachable!("warm start never exhausts"),
            }
        }
        // Resumed step: the scheduler must have pinned a snapshot; the
        // only way it could not is a poisoned board (the learner died).
        let Some(snapshot) = self.pinned.take() else {
            anyhow::bail!("learner failed; no further model snapshots");
        };
        let model_version = snapshot.version();
        if let Some(timer) = self.pin_timer.take() {
            self.pipe.trace_pin(timer, self.sent as u64, model_version);
        }
        let view = Predictor::new(backend.clone(), snapshot.model);
        let draft = snapshot.draft;
        match self.pipe.run_round(&view, draft.as_deref())? {
            StageOutput::Learn(batch) => {
                self.send_batch(batch);
                self.pin_timer = Some(self.pipe.pin_timer());
                Ok(StepResult::Blocked { want: self.sent as u64 })
            }
            StageOutput::Exhausted => {
                // Finalize under the SAME snapshot: this task sent no
                // further batch, so its slot cannot have advanced — the
                // zero-wait pin span keeps the trace's stage shape.
                let timer = self.pipe.pin_timer();
                self.pipe.trace_pin(timer, self.sent as u64, model_version);
                // Release the learner's sweep before the final
                // verification measurement: no more batches will come.
                self.send_finished();
                let result = self.pipe.finalize(&view)?;
                Ok(self.done(result))
            }
            StageOutput::Complete(_) => unreachable!("rounds never complete"),
        }
    }
}

impl Drop for TaskUnit {
    fn drop(&mut self) {
        // Error/panic paths drop the unit without finalizing; the
        // learner still needs its Finished marker to retire the task.
        self.send_finished();
    }
}

/// How a worker came by a unit (drives the sched-lane trace events).
enum Picked {
    /// Popped from the worker's own deque.
    Own,
    /// Taken from the injector (resumed after a park or poison).
    Resumed,
    /// Stolen from worker `.0`'s deque.
    Stolen(usize),
}

struct BoardState {
    /// Per-worker deques: own pops are LIFO, steals FIFO.
    queues: Vec<VecDeque<TaskUnit>>,
    /// Units resumed by a snapshot publish; any worker may take them.
    injector: VecDeque<TaskUnit>,
    /// Parked units by local task index, with the applied-batch count
    /// each is waiting for.
    parked: Vec<Option<(u64, TaskUnit)>>,
    /// Per-task `(applied batches, post-apply snapshot)` slots.
    slots: Vec<(u64, ModelSnapshot)>,
    /// Fast mode: the newest published snapshot, whatever task it came
    /// from.
    latest: ModelSnapshot,
    results: Vec<Option<UnitOutput>>,
    first_err: Option<anyhow::Error>,
    /// Units neither completed nor failed yet.
    active: usize,
    poisoned: bool,
}

/// The scheduler's shared state: work queues, the parking lot, and the
/// per-task snapshot slots the learner publishes into (one mutex — the
/// board is only touched between steps, never during one).
pub(crate) struct Board {
    ord_base: usize,
    jobs: usize,
    deterministic: bool,
    st: Mutex<BoardState>,
    cv: Condvar,
}

impl Board {
    pub fn new(
        ord_base: usize,
        jobs: usize,
        deterministic: bool,
        init: ModelSnapshot,
        units: Vec<TaskUnit>,
    ) -> Board {
        let n = units.len();
        let mut queues: Vec<VecDeque<TaskUnit>> = (0..jobs).map(|_| VecDeque::new()).collect();
        // Deal tasks round-robin; reversed so each worker's first LIFO
        // pop is its lowest-ordinal task.
        for unit in units.into_iter().rev() {
            let w = unit.idx % jobs;
            queues[w].push_back(unit);
        }
        Board {
            ord_base,
            jobs,
            deterministic,
            st: Mutex::new(BoardState {
                queues,
                injector: VecDeque::new(),
                parked: (0..n).map(|_| None).collect(),
                slots: (0..n).map(|_| (0, init.clone())).collect(),
                latest: init,
                results: (0..n).map(|_| None).collect(),
                first_err: None,
                active: n,
                poisoned: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Next unit for worker `w`: own deque, then the injector, then a
    /// steal; sleep only when everything is running or parked.  `None`
    /// once every unit has completed or failed.
    fn next_unit(&self, w: usize) -> Option<(TaskUnit, Picked)> {
        let mut st = self.st.lock().expect("scheduler board poisoned");
        loop {
            if let Some(u) = st.queues[w].pop_back() {
                return Some((u, Picked::Own));
            }
            if let Some(u) = st.injector.pop_front() {
                return Some((u, Picked::Resumed));
            }
            for i in 1..self.jobs {
                let v = (w + i) % self.jobs;
                if let Some(u) = st.queues[v].pop_front() {
                    return Some((u, Picked::Stolen(v)));
                }
            }
            if st.active == 0 {
                return None;
            }
            st = self.cv.wait(st).expect("scheduler board poisoned");
        }
    }

    /// Handle a blocked unit: requeue it immediately when its snapshot
    /// is already available (or will never come), park it otherwise.
    /// Returns true when the unit parked.
    fn block(&self, w: usize, mut unit: TaskUnit, want: u64) -> bool {
        let mut st = self.st.lock().expect("scheduler board poisoned");
        if !self.deterministic {
            // Fast mode: pin whatever is newest and keep going.
            unit.pinned = Some(st.latest.clone());
            st.queues[w].push_back(unit);
            return false;
        }
        let idx = unit.idx;
        if st.slots[idx].0 >= want {
            unit.pinned = Some(st.slots[idx].1.clone());
            st.queues[w].push_back(unit);
            false
        } else if st.poisoned {
            // No snapshot will ever arrive: resume pin-less so the next
            // step reports the learner failure.
            st.queues[w].push_back(unit);
            false
        } else {
            unit.was_parked = true;
            st.parked[idx] = Some((want, unit));
            true
        }
    }

    fn complete(&self, out: UnitOutput) {
        let mut st = self.st.lock().expect("scheduler board poisoned");
        let idx = out.idx;
        st.results[idx] = Some(out);
        st.active -= 1;
        if st.active == 0 {
            drop(st);
            self.cv.notify_all();
        }
    }

    fn fail(&self, e: anyhow::Error) {
        let mut st = self.st.lock().expect("scheduler board poisoned");
        if st.first_err.is_none() {
            st.first_err = Some(e);
        }
        st.active -= 1;
        if st.active == 0 {
            drop(st);
            self.cv.notify_all();
        }
    }

    /// Tear the board down and hand the driver its outputs.
    pub fn into_results(self) -> (Vec<Option<UnitOutput>>, Option<anyhow::Error>) {
        let st = self.st.into_inner().expect("scheduler board poisoned");
        (st.results, st.first_err)
    }

    /// The learner died: mark the board so blocked units fail fast, and
    /// resume every parked unit pin-less so its next step reports the
    /// failure instead of waiting forever.
    pub fn poison(&self) {
        let mut st = self.st.lock().expect("scheduler board poisoned");
        st.poisoned = true;
        let resumed: Vec<TaskUnit> =
            st.parked.iter_mut().filter_map(|slot| slot.take().map(|(_, u)| u)).collect();
        st.injector.extend(resumed);
        drop(st);
        self.cv.notify_all();
    }

    /// Drop every unit the workers left behind (queued, resumed, or
    /// parked).  A clean run leaves nothing to abandon; after a
    /// catastrophic worker exit this releases the learner actor — each
    /// dropped unit sends its `Finished` marker, so the actor's sweep
    /// can retire it and exit instead of blocking on the channel.
    pub fn abandon(&self) {
        let mut st = self.st.lock().expect("scheduler board poisoned");
        let mut orphans: Vec<TaskUnit> = Vec::new();
        for q in &mut st.queues {
            orphans.extend(q.drain(..));
        }
        let resumed: Vec<TaskUnit> = st.injector.drain(..).collect();
        orphans.extend(resumed);
        let parked: Vec<TaskUnit> =
            st.parked.iter_mut().filter_map(|slot| slot.take().map(|(_, u)| u)).collect();
        orphans.extend(parked);
        st.active = st.active.saturating_sub(orphans.len());
        drop(st);
        // Dropping outside the lock: each unit's Drop sends Finished.
        drop(orphans);
    }
}

impl SnapshotSink for Board {
    fn publish(&self, task_ord: usize, applied: u64, snap: ModelSnapshot) {
        let mut st = self.st.lock().expect("scheduler board poisoned");
        if !self.deterministic {
            st.latest = snap;
            return;
        }
        let idx = task_ord - self.ord_base;
        st.slots[idx] = (applied, snap);
        let ready = matches!(&st.parked[idx], Some((want, _)) if *want <= applied);
        if ready {
            let (_, mut unit) = st.parked[idx].take().expect("parked unit present");
            unit.pinned = Some(st.slots[idx].1.clone());
            st.injector.push_back(unit);
            drop(st);
            self.cv.notify_one();
        }
    }

    fn poison(&self) {
        Board::poison(self);
    }
}

/// One scheduler worker: pull a unit (own → injector → steal), run one
/// step, and route the outcome back to the board.  Steal/park/resume
/// decisions are recorded as zero-virtual-time instants on this
/// worker's sched lane — timing-dependent by nature, and exempt from
/// the trace determinism contract (see [`crate::obs`]).
pub(crate) fn run_worker(
    w: usize,
    board: &Board,
    backend: Arc<dyn Backend>,
    mut scope: TraceScope,
) {
    while let Some((mut unit, how)) = board.next_unit(w) {
        match how {
            Picked::Own => {}
            Picked::Resumed => {
                if unit.was_parked {
                    unit.was_parked = false;
                    scope.instant(0, "resume", 0.0, &[("task", unit.idx as f64)], &[]);
                }
            }
            Picked::Stolen(victim) => {
                scope.instant(
                    0,
                    "steal",
                    0.0,
                    &[("from", victim as f64), ("task", unit.idx as f64)],
                    &[],
                );
            }
        }
        let idx = unit.idx as f64;
        // A panicking step must not strand the session: convert it to a
        // task failure and let the unit's Drop send the Finished marker.
        let stepped = catch_unwind(AssertUnwindSafe(|| unit.step(&backend)));
        match stepped {
            Ok(Ok(StepResult::Done(out))) => {
                drop(unit);
                board.complete(*out);
            }
            Ok(Ok(StepResult::Blocked { want })) => {
                if board.block(w, unit, want) {
                    scope.instant(0, "park", 0.0, &[("task", idx), ("want", want as f64)], &[]);
                }
            }
            Ok(Err(e)) => {
                drop(unit);
                board.fail(e);
            }
            Err(_) => {
                drop(unit);
                board.fail(anyhow::anyhow!("task worker panicked"));
            }
        }
    }
}

/// Self-scheduling execution of `n` independent jobs on up to `jobs`
/// workers: an idle worker always takes the next unstarted job, the
/// degenerate work-stealing schedule for coarse independent work
/// (`moses tables` grid cells).  Results land by job index, so output
/// order is deterministic whenever each job's output is.
pub(crate) fn run_independent<T, F>(n: usize, jobs: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let jobs = jobs.max(1).min(n.max(1));
    if jobs <= 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                *results[i].lock().expect("grid result slot poisoned") = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().expect("grid result slot poisoned").expect("job ran"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_independent_preserves_index_order() {
        for jobs in [1, 2, 5, 16] {
            let out = run_independent(9, jobs, |i| i * i);
            assert_eq!(out, (0..9).map(|i| i * i).collect::<Vec<_>>(), "jobs={jobs}");
        }
    }

    #[test]
    fn run_independent_actually_runs_concurrently_when_asked() {
        // With 4 workers over 4 jobs that each wait on a shared
        // barrier, completion is only possible if all run at once.
        let barrier = std::sync::Barrier::new(4);
        let out = run_independent(4, 4, |i| {
            barrier.wait();
            i + 1
        });
        assert_eq!(out, vec![1, 2, 3, 4]);
    }

    #[test]
    fn run_independent_handles_empty_and_oversubscribed() {
        let out: Vec<usize> = run_independent(0, 8, |i| i);
        assert!(out.is_empty());
        let out = run_independent(3, 64, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }
}
