//! # Moses — cross-device transferable cost-model adaptation for tensor
//! # program optimization (reproduction)
//!
//! This crate reproduces the system described in *"Moses: Efficient
//! Exploitation of Cross-device Transferable Features for Tensor Program
//! Optimization"* (2022): an Ansor-style tensor-program auto-tuner whose
//! learned cost model is transferred from a **source device** (where a
//! large offline measurement corpus exists, à la Tenset) to a **target
//! device** by *lottery-ticket* domain adaptation — only the
//! domain-invariant ("transferable") parameters are fine-tuned online
//! while the domain-variant rest decays to zero.
//!
//! ## Architecture (three layers, Python never on the tuning path)
//!
//! * **L1 (Pallas)** — the cost-model MLP forward and the masked-Adam
//!   update are Pallas kernels (`python/compile/kernels/`).
//! * **L2 (JAX)** — predict / train-step / ξ-saliency / loss graphs are
//!   AOT-lowered once to HLO text (`make artifacts`).
//! * **L3 (this crate)** — everything else: the tensor-program IR and
//!   schedule-knob space ([`program`]), the simulated measurement
//!   substrate ([`device`]), the DNN model zoo ([`models`]), dataset
//!   generation ([`dataset`]), evolutionary search ([`search`]), the
//!   Moses transfer strategies and adaptive controller ([`transfer`]),
//!   the auto-tuning coordinator ([`coordinator`]), the XLA/PJRT runtime
//!   that executes the AOT artifacts ([`runtime`]) and the paper's
//!   metrics ([`metrics`]).
//!
//! ## The model API: a zero-copy prediction plane ([`costmodel`])
//!
//! The cost model is split into two planes.  **Mutation** lives in
//! [`costmodel::CostModel`], the single owner of an immutable,
//! versioned [`costmodel::ModelState`] (parameters + Adam moments
//! behind `Arc<[f32]>` shared storage); every update is copy-on-write —
//! detach fresh vectors, wrap, republish.  **Prediction** happens
//! through [`costmodel::Predictor`], a read-only view pinned to a state
//! snapshot: search policies, the task pipeline's re-ranking, the
//! adaptive controller and the Moses mask refresh all consume
//! `&Predictor` and can never mutate (or even observe mutation of) the
//! model.  Publishing a snapshot to N parallel workers and pinning it
//! there are O(1) pointer swaps — the hot prediction path that ranks
//! thousands of candidate schedules per round never copies the
//! ~350k-float parameter vector.
//!
//! ## The staged tuning engine ([`coordinator`])
//!
//! Tuning is a staged per-task pipeline (warm-start → propose →
//! measure → learn → finalize) over a split between the
//! search/measurement plane and the *learning plane*: a learner owning
//! the cost model, replay buffer and Moses adapter consumes measurement
//! batches while search workers predict against pinned
//! `Arc<ModelState>` snapshots published through a versioned
//! [`coordinator::SnapshotCell`].  `moses tune --jobs N` runs N task
//! pipelines concurrently in deterministic waves — sessions are
//! bit-reproducible for a fixed `(seed, jobs)`, wall-clock search time
//! is the per-wave maximum while device cost stays the sum (see
//! ROADMAP.md §ARCHITECTURE).
//!
//! Sessions are configured through the builder:
//! [`coordinator::AutoTuner::builder`] validates knob combinations at
//! build time (worker threads require the `Send` rust backend, pretrain
//! strategies require a checkpoint, budgets must be non-empty) and
//! produces the flat serialized [`coordinator::TuneConfig`] the CLI and
//! experiment grids round-trip.
//!
//! ## The tuning-record store ([`tunecache`])
//!
//! Sitting beside the coordinator is a sharded, persistent store of
//! measured `(workload, device) → top-k (schedule, latency)` records.
//! Sessions check it before searching (an exact hit costs zero measured
//! trials), commit after measuring, and — on a miss for the target
//! device — seed the evolutionary search with the same workload's
//! records from *other* devices: schedule-level transfer complementing
//! Moses' parameter-level transfer.  A feature-space workload index
//! ([`tunecache::index`]) extends the fallback to *similar* workloads:
//! a never-seen shape retrieves its nearest cached neighbors by
//! descriptor distance and starts from their schedules, remapped onto
//! the new geometry.  Records carry a featurizer/simulator version
//! stamp so a latency-model change invalidates them on load.  Records
//! persist as a JSONL append log with compaction, so tuning knowledge
//! accumulates across sessions and hosts; hit/miss/seed counters live
//! in [`metrics::cache`].
//!
//! ## The observability plane ([`obs`])
//!
//! A span/event recorder threads through all three planes of the
//! engine — pipeline stages, the learner actor, the tunecache — and
//! records each stage against *both* clocks: the deterministic virtual
//! device clock and the harness wall clock.  `moses tune --trace`
//! writes a versioned JSONL trace; `moses trace report` breaks the
//! session down per task and per stage; `moses trace chrome` exports a
//! flame view.  Tracing is deterministic in event content (the
//! `(seed, jobs)` reproducibility guarantee extends to traces modulo
//! wall-clock fields) and free when disabled — see the [`obs`] module
//! docs for the two-clock duality and the determinism contract.
//!
//! ## The determinism contract, statically enforced
//!
//! `cargo run -p detlint --` (rust/tools/detlint, also run by CI and by
//! its own self-check test) lints this tree against the contract:
//! wall-clock reads, unordered collections and ambient nondeterminism
//! are banned from the deterministic planes, and the per-module
//! `unwrap()/expect()` count is ratcheted against
//! `detlint-baseline.toml`.  See `detlint.toml` for the rule scopes
//! and ROADMAP §ARCHITECTURE for the rule-by-rule rationale.

// The simulator/engine is pure Rust end to end; nothing here needs
// unsafe, and the determinism contract is easier to audit if that
// stays true.
#![deny(unsafe_code)]

pub mod coordinator;
pub mod costmodel;
pub mod dataset;
pub mod device;
pub mod metrics;
pub mod models;
pub mod obs;
pub mod program;
pub mod runtime;
pub mod search;
pub mod transfer;
pub mod tunecache;
pub mod util;
