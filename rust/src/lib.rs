//! # Moses — cross-device transferable cost-model adaptation for tensor
//! # program optimization (reproduction)
//!
//! This crate reproduces the system described in *"Moses: Efficient
//! Exploitation of Cross-device Transferable Features for Tensor Program
//! Optimization"* (2022): an Ansor-style tensor-program auto-tuner whose
//! learned cost model is transferred from a **source device** (where a
//! large offline measurement corpus exists, à la Tenset) to a **target
//! device** by *lottery-ticket* domain adaptation — only the
//! domain-invariant ("transferable") parameters are fine-tuned online
//! while the domain-variant rest decays to zero.
//!
//! ## Architecture (three layers, Python never on the tuning path)
//!
//! * **L1 (Pallas)** — the cost-model MLP forward and the masked-Adam
//!   update are Pallas kernels (`python/compile/kernels/`).
//! * **L2 (JAX)** — predict / train-step / ξ-saliency / loss graphs are
//!   AOT-lowered once to HLO text (`make artifacts`).
//! * **L3 (this crate)** — everything else: the tensor-program IR and
//!   schedule-knob space ([`program`]), the simulated measurement
//!   substrate ([`device`]), the DNN model zoo ([`models`]), dataset
//!   generation ([`dataset`]), evolutionary search ([`search`]), the
//!   Moses transfer strategies and adaptive controller ([`transfer`]),
//!   the auto-tuning coordinator ([`coordinator`]), the XLA/PJRT runtime
//!   that executes the AOT artifacts ([`runtime`]) and the paper's
//!   metrics ([`metrics`]).
//!
//! ## The staged tuning engine ([`coordinator`])
//!
//! Tuning is a staged per-task pipeline (warm-start → propose →
//! measure → learn → finalize) over a split between the
//! search/measurement plane and the *learning plane*: a learner owning
//! the cost model, replay buffer and Moses adapter consumes measurement
//! batches while search workers predict against cheap versioned
//! parameter snapshots.  `moses tune --jobs N` runs N task pipelines
//! concurrently in deterministic waves — sessions are bit-reproducible
//! for a fixed `(seed, jobs)`, wall-clock search time is the per-wave
//! maximum while device cost stays the sum (see ROADMAP.md
//! §ARCHITECTURE).
//!
//! ## The tuning-record store ([`tunecache`])
//!
//! Sitting beside the coordinator is a sharded, persistent store of
//! measured `(workload, device) → top-k (schedule, latency)` records.
//! Sessions check it before searching (an exact hit costs zero measured
//! trials), commit after measuring, and — on a miss for the target
//! device — seed the evolutionary search with the same workload's
//! records from *other* devices: schedule-level transfer complementing
//! Moses' parameter-level transfer.  A feature-space workload index
//! ([`tunecache::index`]) extends the fallback to *similar* workloads:
//! a never-seen shape retrieves its nearest cached neighbors by
//! descriptor distance and starts from their schedules, remapped onto
//! the new geometry.  Records carry a featurizer/simulator version
//! stamp so a latency-model change invalidates them on load.  Records
//! persist as a JSONL append log with compaction, so tuning knowledge
//! accumulates across sessions and hosts; hit/miss/seed counters live
//! in [`metrics::cache`].

pub mod coordinator;
pub mod costmodel;
pub mod dataset;
pub mod device;
pub mod metrics;
pub mod models;
pub mod program;
pub mod runtime;
pub mod search;
pub mod transfer;
pub mod tunecache;
pub mod util;
