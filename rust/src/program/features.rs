//! The 164-dimensional program feature vector (paper §2.2: "we adopt the
//! 164-d features in Ansor to depict the program").
//!
//! The paper's key structural assumption (§3.3, Eq. 3) is that this
//! feature space is **hardware-independent**: every dimension is a pure
//! function of the subgraph geometry and the schedule knobs — nothing
//! about SM counts, cache sizes or clock speeds enters.  The *labels*
//! (measured throughput) are hardware-dependent; the cost model's job is
//! to map the invariant features to a device-specific response, and
//! Moses' job is to preserve the parameters encoding the invariant part.
//!
//! Layout (indices inclusive, 164 total — checked by tests):
//!
//! | group | dims | contents |
//! |-------|------|----------|
//! | A 0-11    | 12 | problem geometry: log extents, flops, bytes, AI |
//! | B 12-49   | 38 | raw tiling knobs: logs + one-hots |
//! | C 50-61   | 12 | vectorize/unroll/layout/shared one-hots |
//! | D 62-77   | 16 | derived execution shape: grid, tpb, regs, waste |
//! | E 78-119  | 42 | per-buffer access stats (3 buffers × 14) |
//! | F 120-131 | 12 | loop-nest extents and position weights |
//! | G 132-147 | 16 | per-level touch statistics (4 levels × 4) |
//! | H 148-163 | 16 | tails, alignment flags, interactions, bias |
//!
//! All continuous values are squashed with `log2(1+v)/32` into ≈[0,1];
//! flags are 0/1.  Determinism is load-bearing: the same program must
//! featurize identically on every call (dataset records store features).

use super::schedule::{
    Layout, Schedule, INNER_CHOICES, RT_CHOICES, TX_CHOICES, TY_CHOICES, UNROLL_CHOICES,
    VEC_CHOICES,
};
use super::subgraph::Subgraph;

/// Feature dimensionality (matches `python/compile/kernels/ref.py`).
pub const N_FEATURES: usize = 164;

/// `log2(1+v)` squashed to ≈[0,1] for v up to ~2^32.
fn lg(v: f64) -> f32 {
    ((1.0 + v.max(0.0)).log2() / 32.0) as f32
}

fn flag(b: bool) -> f32 {
    if b {
        1.0
    } else {
        0.0
    }
}

fn one_hot<const N: usize>(out: &mut Vec<f32>, choices: &[usize; N], v: usize) {
    for &c in choices {
        out.push(flag(c == v));
    }
}

/// Compute the 164-d feature vector for (subgraph, schedule).
pub fn featurize(sub: &Subgraph, s: &Schedule) -> [f32; N_FEATURES] {
    let g = sub.geometry();
    let flops = sub.kind.flops();
    let (ba, bb, bo) = sub.kind.buffer_bytes();
    let total_bytes = ba + bb + bo;
    let mut f: Vec<f32> = Vec::with_capacity(N_FEATURES);

    // ---- A: problem geometry (12) ------------------------------------
    f.push(lg(g.x as f64));
    f.push(lg(g.y as f64));
    f.push(lg(g.r as f64));
    f.push(lg(flops));
    f.push(lg(total_bytes));
    f.push(lg(sub.kind.arithmetic_intensity()));
    f.push(flag(g.mac));
    f.push(lg((g.x * g.y) as f64));
    f.push(lg(ba));
    f.push(lg(bb));
    f.push(lg(bo));
    f.push(lg(sub.repeats as f64));

    // ---- B: raw tiling knobs (38 = 5 logs + 9+5+7+5+7 one-hots) ------
    f.push(lg(s.tx as f64));
    f.push(lg(s.ix as f64));
    f.push(lg(s.ty as f64));
    f.push(lg(s.iy as f64));
    f.push(lg(s.rt as f64));
    one_hot(&mut f, &TX_CHOICES, s.tx);
    one_hot(&mut f, &INNER_CHOICES, s.ix);
    one_hot(&mut f, &TY_CHOICES, s.ty);
    one_hot(&mut f, &INNER_CHOICES, s.iy);
    one_hot(&mut f, &RT_CHOICES, s.rt);

    // ---- C: vector/unroll/layout/shared (12 = 4+4+3+1) ---------------
    one_hot(&mut f, &VEC_CHOICES, s.vectorize);
    one_hot(&mut f, &UNROLL_CHOICES, s.unroll);
    for l in Layout::ALL {
        f.push(flag(s.layout == l));
    }
    f.push(flag(s.use_shared));

    // ---- D: derived execution shape (16) ------------------------------
    let (gx, gy) = s.grid(&g);
    let tpb = s.threads_per_block();
    let blocks = s.num_blocks(&g);
    f.push(lg(tpb as f64));
    f.push(lg(tpb as f64 / 32.0)); // warps per block
    f.push(lg(blocks as f64));
    f.push(lg(gx as f64));
    f.push(lg(gy as f64));
    f.push((s.padding_factor(&g) - 1.0).min(1.0) as f32); // waste fraction
    f.push(lg(s.work_per_thread() as f64));
    f.push(lg(s.regs_per_thread() as f64));
    f.push(lg(s.shared_bytes() as f64));
    f.push((s.vectorize as f64 / s.iy.max(1) as f64).min(1.0) as f32);
    f.push(lg(blocks as f64 * tpb as f64)); // total parallelism
    f.push(((blocks * tpb) as f64 / (g.x * g.y).max(1) as f64).min(1.0) as f32);
    f.push(lg(g.r.div_ceil(s.rt) as f64)); // outer reduction steps
    f.push(lg((s.ix * s.iy * s.rt) as f64)); // innermost serial length
    f.push(flag(g.x % s.block_tile_x() != 0));
    f.push(flag(g.y % s.block_tile_y() != 0));

    // ---- E: per-buffer access stats (3 × 14 = 42) ----------------------
    // Buffer tiles touched per block per reduction step.
    let tile_x = s.block_tile_x() as f64;
    let tile_y = s.block_tile_y() as f64;
    let rt = s.rt as f64;
    // (bytes, tile_bytes_per_block, innermost_extent, is_written, reduced)
    let buffers: [(f64, f64, f64, bool, bool); 3] = [
        (ba, 4.0 * tile_x * rt, g.r as f64, false, true),  // input
        (bb, 4.0 * tile_y * rt, g.r as f64, false, true),  // weight/operand
        (bo, 4.0 * tile_x * tile_y, g.y as f64, true, false), // output
    ];
    for (bytes, tile_bytes, inner_extent, written, reduced) in buffers {
        let stride_quality: f32 = match s.layout {
            Layout::RowMajor => {
                if written {
                    1.0
                } else {
                    0.6
                }
            }
            Layout::ChannelsLast => 0.85,
            Layout::Packed => {
                if s.vectorize >= 4 {
                    1.0
                } else {
                    0.7
                }
            }
        };
        let touched_per_thread = tile_bytes / tpb.max(1) as f64;
        let reuse = if bytes > 0.0 {
            (blocks as f64 * tile_bytes * (g.r as f64 / rt)) / bytes
        } else {
            0.0
        };
        f.push(lg(bytes));
        f.push(lg(tile_bytes));
        f.push(lg(touched_per_thread));
        f.push(lg(reuse));
        f.push(stride_quality);
        f.push(flag(s.vectorize > 1 && inner_extent % s.vectorize as f64 == 0.0));
        f.push(flag(tile_bytes <= 32.0 * 1024.0)); // fits L1/shared tile
        f.push(flag(tile_bytes <= 256.0 * 1024.0)); // fits L2 slice
        f.push(lg(tile_bytes / 128.0)); // cache lines per block
        f.push(flag(written));
        f.push(flag(reduced));
        f.push(lg(bytes / flops.max(1.0) * 1e6)); // bytes per Mflop
        f.push(flag(s.use_shared && !written));
        f.push((tile_bytes / (48.0 * 1024.0)).min(2.0) as f32 / 2.0); // shared pressure
    }

    // ---- F: loop-nest extents & positions (12) -------------------------
    let nest: [f64; 6] = [
        gy as f64,
        gx as f64,
        s.ty as f64,
        s.tx as f64,
        (s.ix * s.iy) as f64,
        rt,
    ];
    for e in nest {
        f.push(lg(e));
    }
    let total: f64 = nest.iter().map(|e| e.max(1.0).log2()).sum::<f64>().max(1e-9);
    for e in nest {
        f.push((e.max(1.0).log2() / total) as f32);
    }

    // ---- G: per-level touch statistics (4 × 4 = 16) --------------------
    // Levels: block, thread, inner(serial), reduction-step.
    let level_elems: [f64; 4] = [
        tile_x * tile_y,
        (s.ix * s.iy) as f64,
        s.vectorize as f64,
        rt,
    ];
    let level_bytes: [f64; 4] = [
        4.0 * (tile_x + tile_y) * rt,
        4.0 * (s.ix + s.iy) as f64 * rt,
        4.0 * s.vectorize as f64,
        4.0 * (tile_x + tile_y),
    ];
    for lvl in 0..4 {
        let flops_here = if g.mac { 2.0 * level_elems[lvl] * rt } else { level_elems[lvl] };
        f.push(lg(level_elems[lvl]));
        f.push(lg(level_bytes[lvl]));
        f.push(lg(flops_here));
        f.push(lg(flops_here / level_bytes[lvl].max(1.0)));
    }

    // ---- H: tails, alignment, interactions, bias (16) -------------------
    let (px, py) = {
        let bx = s.block_tile_x();
        let by = s.block_tile_y();
        (
            (bx - (g.x % bx).min(bx)) % bx,
            (by - (g.y % by).min(by)) % by,
        )
    };
    f.push((px as f64 / s.block_tile_x() as f64) as f32); // x tail fraction
    f.push((py as f64 / s.block_tile_y() as f64) as f32); // y tail fraction
    f.push(flag(g.r % s.rt != 0));
    f.push(flag(tpb % 32 == 0)); // warp-aligned
    f.push((tpb as f64 / 1024.0) as f32);
    f.push(flag(s.ix == 1));
    f.push(flag(s.iy == 1));
    f.push(flag(s.rt == 1));
    f.push(flag(s.layout == Layout::Packed && s.vectorize >= 4));
    f.push(lg((s.vectorize * s.unroll.max(1)) as f64));
    f.push(lg(s.shared_bytes() as f64 / tpb.max(1) as f64));
    f.push(flag(s.unroll >= 64 && s.ix * s.iy >= 8)); // unroll pressure
    f.push(flag(blocks < 16)); // under-parallelized
    f.push(flag(blocks > 65_535)); // grid overflow risk
    f.push(flag(s.use_shared && s.rt >= 8)); // staging amortized
    f.push(1.0); // bias

    debug_assert_eq!(f.len(), N_FEATURES, "feature layout drifted");
    let mut out = [0.0f32; N_FEATURES];
    out.copy_from_slice(&f);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::program::generator::SpaceGenerator;
    use crate::program::subgraph::SubgraphKind;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn sub() -> Subgraph {
        Subgraph::new(
            "t.conv",
            SubgraphKind::Conv2d {
                n: 1,
                h: 56,
                w: 56,
                cin: 64,
                cout: 128,
                kh: 3,
                kw: 3,
                stride: 1,
                pad: 1,
            },
        )
    }

    #[test]
    fn exactly_164_dims() {
        let s = sub();
        let sched = Schedule::default_for(&s.geometry());
        let f = featurize(&s, &sched);
        assert_eq!(f.len(), N_FEATURES);
    }

    #[test]
    fn deterministic() {
        let s = sub();
        let sched = Schedule::default_for(&s.geometry());
        assert_eq!(featurize(&s, &sched), featurize(&s, &sched));
    }

    #[test]
    fn all_finite_and_bounded() {
        let s = sub();
        let gen = SpaceGenerator::new(s.geometry());
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let sched = gen.sample(&mut rng);
            for (i, v) in featurize(&s, &sched).iter().enumerate() {
                assert!(v.is_finite(), "dim {i} not finite");
                assert!((-0.1..=2.0).contains(v), "dim {i} out of range: {v}");
            }
        }
    }

    #[test]
    fn different_schedules_differ() {
        let s = sub();
        let g = s.geometry();
        let a = Schedule::default_for(&g);
        let b = Schedule { tx: 128, vectorize: 4, ix: 8, ..a };
        assert!(b.is_valid(&g));
        assert_ne!(featurize(&s, &a), featurize(&s, &b));
    }

    #[test]
    fn different_subgraphs_differ() {
        let a = sub();
        let b = Subgraph::new("t.dense", SubgraphKind::Dense { m: 128, n: 768, k: 768 });
        let sched = Schedule::default_for(&a.geometry());
        assert_ne!(featurize(&a, &sched)[..12], featurize(&b, &sched)[..12]);
    }

    #[test]
    fn hardware_independence_by_construction() {
        // The same (subgraph, schedule) featurizes identically regardless
        // of any device context — there is no device parameter at all.
        // This test documents the API-level guarantee.
        let s = sub();
        let sched = Schedule::default_for(&s.geometry());
        let f1 = featurize(&s, &sched);
        let f2 = featurize(&s, &sched);
        assert_eq!(f1, f2);
    }

    #[test]
    fn prop_fuzz_geometries_and_schedules() {
        prop::check(|rng| {
            let kind = match rng.below(4) {
                0 => SubgraphKind::Conv2d {
                    n: rng.below(4) + 1,
                    h: rng.below(200) + 8,
                    w: rng.below(200) + 8,
                    cin: rng.below(512) + 1,
                    cout: rng.below(512) + 1,
                    kh: [1, 3, 5, 7][rng.below(4)],
                    kw: [1, 3, 5, 7][rng.below(4)],
                    stride: rng.below(2) + 1,
                    pad: rng.below(3),
                },
                1 => SubgraphKind::Dense {
                    m: rng.below(2048) + 1,
                    n: rng.below(4096) + 1,
                    k: rng.below(4096) + 1,
                },
                2 => SubgraphKind::BatchMatmul {
                    b: rng.below(16) + 1,
                    m: rng.below(512) + 1,
                    n: rng.below(512) + 1,
                    k: rng.below(512) + 1,
                },
                _ => SubgraphKind::Elementwise { len: rng.below(1_000_000) + 1, ops: rng.below(8) + 1 },
            };
            let sub = Subgraph::new("fuzz", kind);
            let gen = SpaceGenerator::new(sub.geometry());
            let sched = gen.sample(rng);
            let f = featurize(&sub, &sched);
            assert_eq!(f.len(), N_FEATURES);
            for (i, v) in f.iter().enumerate() {
                assert!(v.is_finite() && (-0.1..=2.0).contains(v), "dim {i}: {v}");
            }
        });
    }
}
