//! Schedule knobs: one point in the per-subgraph search space.
//!
//! Models the Ansor/AutoTVM GPU schedule template (paper Fig. 1 &
//! §2.2): multi-level tiling of the two spatial axes onto (grid ×
//! threads × serial-inner), a reduction split, vectorization, an
//! auto-unroll cap, shared-memory staging, and a data-layout choice.
//! Grids use ceil-division (real GPU codegen pads), so any knob
//! combination is *representable*; [`Schedule::is_valid`] additionally
//! enforces hardware-meaningful constraints (thread counts, vector
//! width ≤ inner tile) that define the searchable space.

use super::subgraph::Geometry;

/// Thread-count choices per axis (powers of two, as in TVM templates).
pub const TX_CHOICES: [usize; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];
pub const TY_CHOICES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];
/// Serial inner-tile choices per axis.
pub const INNER_CHOICES: [usize; 5] = [1, 2, 4, 8, 16];
/// Reduction inner-split choices.
pub const RT_CHOICES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];
/// Vectorization widths (f32 lanes).
pub const VEC_CHOICES: [usize; 4] = [1, 2, 4, 8];
/// `auto_unroll_max_step` choices (Fig. 1 shows 512).
pub const UNROLL_CHOICES: [usize; 4] = [0, 16, 64, 512];

/// Data-layout variants (e.g. NCHW / NHWC / NCHWc-packed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layout {
    RowMajor = 0,
    ChannelsLast = 1,
    Packed = 2, // NCHWc-style vector-packed innermost dim
}

impl Layout {
    pub const ALL: [Layout; 3] = [Layout::RowMajor, Layout::ChannelsLast, Layout::Packed];

    pub fn from_index(i: usize) -> Layout {
        Layout::ALL[i % 3]
    }
}

/// One schedule point (the knob vector ψ of paper Eq. 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Schedule {
    /// Threads bound along X per block.
    pub tx: usize,
    /// Serial inner tile along X (per-thread work items).
    pub ix: usize,
    /// Threads bound along Y per block.
    pub ty: usize,
    /// Serial inner tile along Y.
    pub iy: usize,
    /// Reduction inner split (accumulate `rt` elements per loop step).
    pub rt: usize,
    /// Vector width on the innermost dimension.
    pub vectorize: usize,
    /// Auto-unroll max step (0 = off).
    pub unroll: usize,
    /// Stage operand tiles through shared memory?
    pub use_shared: bool,
    /// Buffer layout choice.
    pub layout: Layout,
}

impl Schedule {
    /// The heuristic default schedule — stands in for the untuned
    /// vendor-library configuration ("Raw" baseline, paper §4.4).
    pub fn default_for(g: &Geometry) -> Schedule {
        Schedule {
            tx: 32,
            ix: 2,
            ty: if g.y >= 8 { 8 } else { 1 },
            iy: if g.y >= 32 { 4 } else { 1 },
            rt: if g.r >= 8 { 8 } else { 1 },
            vectorize: 1,
            unroll: 0,
            use_shared: false,
            layout: Layout::RowMajor,
        }
    }

    // ----------------------------------------------------- derived ----

    /// Threads per block (CUDA blockDim product).
    pub fn threads_per_block(&self) -> usize {
        self.tx * self.ty
    }

    /// Elements of X covered by one block.
    pub fn block_tile_x(&self) -> usize {
        self.tx * self.ix
    }

    /// Elements of Y covered by one block.
    pub fn block_tile_y(&self) -> usize {
        self.ty * self.iy
    }

    /// Grid dims (ceil division — codegen pads the boundary).
    pub fn grid(&self, g: &Geometry) -> (usize, usize) {
        (g.x.div_ceil(self.block_tile_x()), g.y.div_ceil(self.block_tile_y()))
    }

    /// Total blocks.
    pub fn num_blocks(&self, g: &Geometry) -> usize {
        let (gx, gy) = self.grid(g);
        gx * gy
    }

    /// Fraction of launched work that is padding waste (≥ 1.0 == none).
    pub fn padding_factor(&self, g: &Geometry) -> f64 {
        let (gx, gy) = self.grid(g);
        let launched = (gx * self.block_tile_x()) as f64 * (gy * self.block_tile_y()) as f64;
        launched / (g.x as f64 * g.y as f64)
    }

    /// Estimated shared-memory bytes per block (operand staging tiles
    /// for one reduction step of `rt`).
    pub fn shared_bytes(&self) -> usize {
        if !self.use_shared {
            return 0;
        }
        4 * self.rt * (self.block_tile_x() + self.block_tile_y())
    }

    /// Crude register-per-thread estimate: accumulators (ix·iy) plus
    /// operand/vector registers; unrolling multiplies live values.
    pub fn regs_per_thread(&self) -> usize {
        let acc = self.ix * self.iy;
        let operands = self.ix + self.iy + self.vectorize;
        let unroll_mult = match self.unroll {
            0 => 1.0,
            16 => 1.25,
            64 => 1.5,
            _ => 2.0,
        };
        (((acc + operands) as f64) * unroll_mult).ceil() as usize + 12
    }

    /// Work items per thread (serial loop length excluding reduction).
    pub fn work_per_thread(&self) -> usize {
        self.ix * self.iy
    }

    // ---------------------------------------------------- validity ----

    /// Hardware-meaningful constraints defining the search space.
    pub fn is_valid(&self, g: &Geometry) -> bool {
        let tpb = self.threads_per_block();
        if !(1..=1024).contains(&tpb) {
            return false;
        }
        // Vector width cannot exceed the serial inner tile it vectorizes.
        if self.vectorize > self.ix.max(self.iy) {
            return false;
        }
        // Packed layout requires vectorization.
        if self.layout == Layout::Packed && self.vectorize == 1 {
            return false;
        }
        // Don't split the reduction further than it is long.
        if self.rt > g.r.next_power_of_two() {
            return false;
        }
        // A block shouldn't cover more than the whole problem in either
        // axis beyond one tile of padding.
        if self.block_tile_x() > 2 * g.x.next_power_of_two()
            || self.block_tile_y() > 2 * g.y.next_power_of_two()
        {
            return false;
        }
        // Shared staging above 96 KiB is unschedulable anywhere.
        if self.shared_bytes() > 96 * 1024 {
            return false;
        }
        true
    }

    /// Remap a schedule tuned for one geometry onto another: shrink
    /// whichever knobs overshoot the new extents (reduction split,
    /// block tiles, vector width) while keeping the overall tiling
    /// *structure* — the part that transfers between similar workloads.
    /// All knob choices are powers of two, so halving stays within the
    /// choice sets.  The result still needs [`Schedule::is_valid`]: a
    /// schedule that was invalid to begin with stays invalid.
    pub fn remap_for(&self, g: &Geometry) -> Schedule {
        let mut s = *self;
        while s.rt > 1 && s.rt > g.r.next_power_of_two() {
            s.rt /= 2;
        }
        while s.block_tile_x() > 2 * g.x.next_power_of_two() && s.block_tile_x() > 1 {
            if s.ix > 1 {
                s.ix /= 2;
            } else {
                s.tx /= 2;
            }
        }
        while s.block_tile_y() > 2 * g.y.next_power_of_two() && s.block_tile_y() > 1 {
            if s.iy > 1 {
                s.iy /= 2;
            } else {
                s.ty /= 2;
            }
        }
        while s.vectorize > 1 && s.vectorize > s.ix.max(s.iy) {
            s.vectorize /= 2;
        }
        if s.layout == Layout::Packed && s.vectorize == 1 {
            s.layout = Layout::RowMajor;
        }
        s
    }

    // ------------------------------------------------ serialization ----

    /// Fixed-width knob encoding (for fingerprints & dataset records).
    pub fn encode(&self) -> [u32; 9] {
        [
            self.tx as u32,
            self.ix as u32,
            self.ty as u32,
            self.iy as u32,
            self.rt as u32,
            self.vectorize as u32,
            self.unroll as u32,
            self.use_shared as u32,
            self.layout as u32,
        ]
    }

    /// Inverse of [`Schedule::encode`].
    pub fn decode(v: &[u32; 9]) -> Schedule {
        Schedule {
            tx: v[0] as usize,
            ix: v[1] as usize,
            ty: v[2] as usize,
            iy: v[3] as usize,
            rt: v[4] as usize,
            vectorize: v[5] as usize,
            unroll: v[6] as usize,
            use_shared: v[7] != 0,
            layout: Layout::from_index(v[8] as usize),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom() -> Geometry {
        Geometry { x: 3136, y: 128, r: 576, mac: true }
    }

    #[test]
    fn default_schedule_is_valid() {
        let g = geom();
        assert!(Schedule::default_for(&g).is_valid(&g));
    }

    #[test]
    fn grid_ceil_division_and_padding() {
        let g = Geometry { x: 100, y: 10, r: 4, mac: true };
        let s = Schedule { tx: 32, ix: 1, ty: 4, iy: 1, ..Schedule::default_for(&g) };
        let (gx, gy) = s.grid(&g);
        assert_eq!(gx, 4); // ceil(100/32)
        assert_eq!(gy, 3); // ceil(10/4)
        assert!(s.padding_factor(&g) > 1.0);
        // Exact fit → factor 1.
        let s2 = Schedule { tx: 25, ix: 4, ty: 10, iy: 1, ..s };
        assert!((s2.padding_factor(&g) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn validity_rejects_bad_configs() {
        let g = geom();
        let base = Schedule::default_for(&g);
        // Too many threads.
        assert!(!Schedule { tx: 256, ty: 64, ..base }.is_valid(&g));
        // Vector wider than inner tiles.
        assert!(!Schedule { vectorize: 8, ix: 2, iy: 2, ..base }.is_valid(&g));
        // Packed layout without vectorization.
        assert!(!Schedule { layout: Layout::Packed, vectorize: 1, ..base }.is_valid(&g));
        // Reduction split longer than reduction axis.
        let small_r = Geometry { r: 2, ..g };
        assert!(!Schedule { rt: 64, ..base }.is_valid(&small_r));
    }

    #[test]
    fn shared_bytes_zero_when_disabled() {
        let g = geom();
        let s = Schedule::default_for(&g);
        assert_eq!(s.shared_bytes(), 0);
        let s2 = Schedule { use_shared: true, ..s };
        assert!(s2.shared_bytes() > 0);
    }

    #[test]
    fn regs_grow_with_tiles_and_unroll() {
        let g = geom();
        let small = Schedule::default_for(&g);
        let big = Schedule { ix: 16, iy: 16, unroll: 512, ..small };
        assert!(big.regs_per_thread() > small.regs_per_thread());
    }

    #[test]
    fn remap_shrinks_onto_smaller_geometry() {
        let big = geom();
        let s = Schedule {
            tx: 64,
            ix: 4,
            ty: 8,
            iy: 4,
            rt: 64,
            vectorize: 4,
            unroll: 512,
            use_shared: true,
            layout: Layout::Packed,
        };
        assert!(s.is_valid(&big));
        // A much smaller problem: the raw schedule overshoots it.
        let small = Geometry { x: 64, y: 8, r: 4, mac: true };
        assert!(!s.is_valid(&small));
        let r = s.remap_for(&small);
        assert!(r.is_valid(&small), "remapped schedule invalid: {r:?}");
        // Structure knobs that already fit are untouched.
        assert_eq!(r.unroll, s.unroll);
        assert_eq!(r.use_shared, s.use_shared);
        // Remapping onto the original geometry is the identity.
        assert_eq!(s.remap_for(&big), s);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let g = geom();
        let s = Schedule {
            tx: 64,
            ix: 4,
            ty: 8,
            iy: 2,
            rt: 16,
            vectorize: 4,
            unroll: 512,
            use_shared: true,
            layout: Layout::Packed,
        };
        assert!(s.is_valid(&g));
        assert_eq!(Schedule::decode(&s.encode()), s);
    }
}
