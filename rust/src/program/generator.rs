//! Schedule-space generation: random sampling, mutation and crossover —
//! the raw material for the evolutionary search (paper §2.2: spaces are
//! "millions for CPUs and billions for GPUs").

use super::schedule::{
    Layout, Schedule, INNER_CHOICES, RT_CHOICES, TX_CHOICES, TY_CHOICES, UNROLL_CHOICES,
    VEC_CHOICES,
};
use super::subgraph::Geometry;
use crate::util::rng::Rng;

/// Generates valid schedules for one subgraph geometry.
#[derive(Debug, Clone)]
pub struct SpaceGenerator {
    pub geometry: Geometry,
}

impl SpaceGenerator {
    pub fn new(geometry: Geometry) -> SpaceGenerator {
        SpaceGenerator { geometry }
    }

    /// Upper bound on the knob-combination count (before validity
    /// filtering) — matches the order of magnitude the paper quotes.
    pub fn space_size(&self) -> f64 {
        (TX_CHOICES.len()
            * INNER_CHOICES.len()
            * TY_CHOICES.len()
            * INNER_CHOICES.len()
            * RT_CHOICES.len()
            * VEC_CHOICES.len()
            * UNROLL_CHOICES.len()
            * 2 // use_shared
            * Layout::ALL.len()) as f64
    }

    fn raw_sample(&self, rng: &mut Rng) -> Schedule {
        Schedule {
            tx: *rng.choice(&TX_CHOICES),
            ix: *rng.choice(&INNER_CHOICES),
            ty: *rng.choice(&TY_CHOICES),
            iy: *rng.choice(&INNER_CHOICES),
            rt: *rng.choice(&RT_CHOICES),
            vectorize: *rng.choice(&VEC_CHOICES),
            unroll: *rng.choice(&UNROLL_CHOICES),
            use_shared: rng.chance(0.5),
            layout: Layout::from_index(rng.below(3)),
        }
    }

    /// Rejection-sample a valid schedule.  The validity rate of the raw
    /// space is high enough (>20%) that this terminates fast; falls back
    /// to the default schedule after 256 attempts (cannot happen for any
    /// geometry the zoo produces — defensive only).
    pub fn sample(&self, rng: &mut Rng) -> Schedule {
        for _ in 0..256 {
            let s = self.raw_sample(rng);
            if s.is_valid(&self.geometry) {
                return s;
            }
        }
        Schedule::default_for(&self.geometry)
    }

    /// Sample `n` distinct valid schedules (deduplicated by knob value).
    pub fn sample_distinct(&self, rng: &mut Rng, n: usize) -> Vec<Schedule> {
        let mut out: Vec<Schedule> = Vec::with_capacity(n);
        let mut attempts = 0;
        while out.len() < n && attempts < 64 * n.max(8) {
            let s = self.sample(rng);
            if !out.contains(&s) {
                out.push(s);
            }
            attempts += 1;
        }
        out
    }

    /// Mutate exactly one knob into a different valid value — the
    /// evolutionary search's mutation operator.
    pub fn mutate(&self, s: &Schedule, rng: &mut Rng) -> Schedule {
        for _ in 0..64 {
            let mut t = *s;
            match rng.below(9) {
                0 => t.tx = *rng.choice(&TX_CHOICES),
                1 => t.ix = *rng.choice(&INNER_CHOICES),
                2 => t.ty = *rng.choice(&TY_CHOICES),
                3 => t.iy = *rng.choice(&INNER_CHOICES),
                4 => t.rt = *rng.choice(&RT_CHOICES),
                5 => t.vectorize = *rng.choice(&VEC_CHOICES),
                6 => t.unroll = *rng.choice(&UNROLL_CHOICES),
                7 => t.use_shared = !t.use_shared,
                _ => t.layout = Layout::from_index(rng.below(3)),
            }
            if t != *s && t.is_valid(&self.geometry) {
                return t;
            }
        }
        *s
    }

    /// Uniform knob-wise crossover of two parents (retried until valid).
    pub fn crossover(&self, a: &Schedule, b: &Schedule, rng: &mut Rng) -> Schedule {
        for _ in 0..64 {
            let pick = |rng: &mut Rng, x: usize, y: usize| if rng.chance(0.5) { x } else { y };
            let t = Schedule {
                tx: pick(rng, a.tx, b.tx),
                ix: pick(rng, a.ix, b.ix),
                ty: pick(rng, a.ty, b.ty),
                iy: pick(rng, a.iy, b.iy),
                rt: pick(rng, a.rt, b.rt),
                vectorize: pick(rng, a.vectorize, b.vectorize),
                unroll: pick(rng, a.unroll, b.unroll),
                use_shared: if rng.chance(0.5) { a.use_shared } else { b.use_shared },
                layout: if rng.chance(0.5) { a.layout } else { b.layout },
            };
            if t.is_valid(&self.geometry) {
                return t;
            }
        }
        if rng.chance(0.5) {
            *a
        } else {
            *b
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn geom() -> Geometry {
        Geometry { x: 12544, y: 256, r: 1152, mac: true }
    }

    #[test]
    fn samples_are_valid() {
        let gen = SpaceGenerator::new(geom());
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let s = gen.sample(&mut rng);
            assert!(s.is_valid(&gen.geometry), "{s:?}");
        }
    }

    #[test]
    fn sample_distinct_dedups() {
        let gen = SpaceGenerator::new(geom());
        let mut rng = Rng::new(2);
        let pop = gen.sample_distinct(&mut rng, 64);
        assert_eq!(pop.len(), 64);
        for i in 0..pop.len() {
            for j in (i + 1)..pop.len() {
                assert_ne!(pop[i], pop[j]);
            }
        }
    }

    #[test]
    fn mutation_changes_one_thing_and_stays_valid() {
        let gen = SpaceGenerator::new(geom());
        let mut rng = Rng::new(3);
        let s = gen.sample(&mut rng);
        let mut changed = 0;
        for _ in 0..50 {
            let t = gen.mutate(&s, &mut rng);
            assert!(t.is_valid(&gen.geometry));
            if t != s {
                changed += 1;
                // Count differing knobs.
                let diff = s
                    .encode()
                    .iter()
                    .zip(t.encode().iter())
                    .filter(|(a, b)| a != b)
                    .count();
                assert_eq!(diff, 1, "mutation touched {diff} knobs: {s:?} -> {t:?}");
            }
        }
        assert!(changed > 40);
    }

    #[test]
    fn space_size_is_large() {
        let gen = SpaceGenerator::new(geom());
        assert!(gen.space_size() > 100_000.0);
    }

    #[test]
    fn prop_crossover_valid_and_from_parents() {
        prop::check(|rng| {
            let gen = SpaceGenerator::new(geom());
            let a = gen.sample(rng);
            let b = gen.sample(rng);
            let c = gen.crossover(&a, &b, rng);
            assert!(c.is_valid(&gen.geometry));
            // Every knob comes from one of the parents.
            let (ea, eb, ec) = (a.encode(), b.encode(), c.encode());
            for k in 0..9 {
                assert!(ec[k] == ea[k] || ec[k] == eb[k], "knob {k} invented");
            }
        });
    }

    #[test]
    fn prop_samples_valid_for_odd_geometries() {
        prop::check(|rng| {
            let g = Geometry {
                x: rng.below(100_000) + 1,
                y: rng.below(4096) + 1,
                r: rng.below(8192) + 1,
                mac: rng.chance(0.8),
            };
            let gen = SpaceGenerator::new(g);
            let s = gen.sample(rng);
            assert!(s.is_valid(&g), "geom {g:?} sched {s:?}");
        });
    }
}
