//! The tensor-program substrate: what the auto-tuner tunes.
//!
//! TVM/Ansor partitions a DNN into *subgraphs* (fused operator groups —
//! paper §2.2: "a subgraph is a unit with the finest granularity during
//! compilation") and searches, per subgraph, a combinatorial space of
//! *schedules* (tilings, unrolling, vectorization, thread binding, ...).
//!
//! * [`subgraph`] — operator kinds with real DNN shapes and their
//!   canonical compute geometry (spatial × spatial × reduction).
//! * [`schedule`] — the knob vector defining one tensor program.
//! * [`generator`] — schedule-space sampling and mutation.
//! * [`features`]  — the 164-d hardware-independent feature vector
//!   (Ansor's representation, paper §2.2) consumed by the cost model.

pub mod features;
pub mod generator;
pub mod schedule;
pub mod subgraph;

pub use features::{featurize, N_FEATURES};
pub use generator::SpaceGenerator;
pub use schedule::Schedule;
pub use subgraph::{Geometry, Subgraph, SubgraphKind, DESC_DIM};

/// A concrete tensor program = a subgraph plus one schedule point.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorProgram {
    pub subgraph: Subgraph,
    pub schedule: Schedule,
}

impl TensorProgram {
    pub fn new(subgraph: Subgraph, schedule: Schedule) -> TensorProgram {
        TensorProgram { subgraph, schedule }
    }

    /// The 164-d feature vector for the cost model.
    pub fn features(&self) -> [f32; N_FEATURES] {
        featurize(&self.subgraph, &self.schedule)
    }

    /// Stable 64-bit identity of this program (used to key deterministic
    /// simulator noise and to deduplicate search populations).
    pub fn fingerprint(&self) -> u64 {
        let mut bytes = Vec::with_capacity(128);
        bytes.extend_from_slice(self.subgraph.name.as_bytes());
        for v in self.schedule.encode() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        crate::util::rng::hash_bytes(&bytes)
    }
}
