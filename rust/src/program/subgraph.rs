//! Subgraph (task) definitions: the fused operator groups the graph-level
//! optimizer hands to the tensor-level tuner, with real DNN shapes.
//!
//! Every kind is reduced to a canonical **compute geometry** — two
//! spatial iteration axes and one reduction axis — which is what the
//! schedule knobs act on:
//!
//! | kind          | X (spatial)     | Y (spatial) | R (reduction) |
//! |---------------|-----------------|-------------|---------------|
//! | Conv2d        | N·OH·OW         | Cout        | Cin·KH·KW     |
//! | Depthwise     | N·OH·OW         | C           | KH·KW         |
//! | Dense         | M               | N           | K             |
//! | BatchMatmul   | B·M             | N           | K             |
//! | Pool2d        | N·OH·OW         | C           | K·K           |
//! | Elementwise   | len             | 1           | 1             |

/// Dimensionality of the workload descriptor ([`Subgraph::descriptor`]).
pub const DESC_DIM: usize = 9;

/// Operator kind with full shape parameters.
#[derive(Debug, Clone, PartialEq)]
pub enum SubgraphKind {
    /// Standard 2-D convolution (NCHW logical shapes).
    Conv2d {
        n: usize,
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
    },
    /// Depthwise-separable convolution's depthwise half.
    DepthwiseConv2d {
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
    },
    /// Fully-connected / projection: `[m,k] @ [k,n]`.
    Dense { m: usize, n: usize, k: usize },
    /// Batched matmul (attention scores / context): `b × [m,k] @ [k,n]`.
    BatchMatmul { b: usize, m: usize, n: usize, k: usize },
    /// 2-D pooling window `k×k`.
    Pool2d { n: usize, h: usize, w: usize, c: usize, k: usize, stride: usize },
    /// Fused elementwise chain (bias+activation+residual, LayerNorm...).
    Elementwise { len: usize, ops: usize },
}

/// Canonical geometry the scheduler tunes over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Geometry {
    /// First spatial extent (output pixels / rows).
    pub x: usize,
    /// Second spatial extent (output channels / cols).
    pub y: usize,
    /// Reduction extent.
    pub r: usize,
    /// Is the reduction a multiply-accumulate (MAC) reduction?
    /// (pooling reduces without MACs).
    pub mac: bool,
}

/// A named tuning task: one subgraph of a DNN.
#[derive(Debug, Clone, PartialEq)]
pub struct Subgraph {
    /// Unique name within a model, e.g. `resnet18.conv2_1`.
    pub name: String,
    pub kind: SubgraphKind,
    /// How many times the model invokes this subgraph per inference
    /// (weight-shared repeats, e.g. identical residual blocks).
    pub repeats: usize,
}

impl SubgraphKind {
    /// Output spatial dims for conv-like kinds.
    fn out_hw(h: usize, w: usize, k: usize, stride: usize, pad: usize) -> (usize, usize) {
        let oh = (h + 2 * pad - k) / stride + 1;
        let ow = (w + 2 * pad - k) / stride + 1;
        (oh.max(1), ow.max(1))
    }

    /// Canonical compute geometry.
    pub fn geometry(&self) -> Geometry {
        match *self {
            SubgraphKind::Conv2d { n, h, w, cin, cout, kh, kw, stride, pad } => {
                let (oh, ow) = Self::out_hw(h, w, kh.max(kw), stride, pad);
                Geometry { x: n * oh * ow, y: cout, r: cin * kh * kw, mac: true }
            }
            SubgraphKind::DepthwiseConv2d { n, h, w, c, kh, kw, stride, pad } => {
                let (oh, ow) = Self::out_hw(h, w, kh.max(kw), stride, pad);
                Geometry { x: n * oh * ow, y: c, r: kh * kw, mac: true }
            }
            SubgraphKind::Dense { m, n, k } => Geometry { x: m, y: n, r: k, mac: true },
            SubgraphKind::BatchMatmul { b, m, n, k } => {
                Geometry { x: b * m, y: n, r: k, mac: true }
            }
            SubgraphKind::Pool2d { n, h, w, c, k, stride } => {
                let (oh, ow) = Self::out_hw(h, w, k, stride, 0);
                Geometry { x: n * oh * ow, y: c, r: k * k, mac: false }
            }
            SubgraphKind::Elementwise { len, .. } => Geometry { x: len, y: 1, r: 1, mac: false },
        }
    }

    /// Total floating-point operations for one invocation.
    pub fn flops(&self) -> f64 {
        let g = self.geometry();
        match *self {
            SubgraphKind::Elementwise { len, ops } => (len * ops) as f64,
            SubgraphKind::Pool2d { .. } => (g.x * g.y * g.r) as f64, // compares/adds
            _ => 2.0 * (g.x as f64) * (g.y as f64) * (g.r as f64),   // MACs
        }
    }

    /// Bytes of each logical buffer (input, weight/second-operand,
    /// output), assuming f32 and no reuse (cold traffic upper bound).
    pub fn buffer_bytes(&self) -> (f64, f64, f64) {
        const F: f64 = 4.0;
        match *self {
            SubgraphKind::Conv2d { n, h, w, cin, cout, kh, kw, .. } => {
                let g = self.geometry();
                (
                    (n * cin * h * w) as f64 * F,
                    (cout * cin * kh * kw) as f64 * F,
                    (g.x * g.y) as f64 * F,
                )
            }
            SubgraphKind::DepthwiseConv2d { n, h, w, c, kh, kw, .. } => {
                let g = self.geometry();
                ((n * c * h * w) as f64 * F, (c * kh * kw) as f64 * F, (g.x * g.y) as f64 * F)
            }
            SubgraphKind::Dense { m, n, k } => {
                ((m * k) as f64 * F, (k * n) as f64 * F, (m * n) as f64 * F)
            }
            SubgraphKind::BatchMatmul { b, m, n, k } => (
                (b * m * k) as f64 * F,
                (b * k * n) as f64 * F,
                (b * m * n) as f64 * F,
            ),
            SubgraphKind::Pool2d { n, h, w, c, .. } => {
                let g = self.geometry();
                ((n * c * h * w) as f64 * F, 0.0, (g.x * g.y) as f64 * F)
            }
            SubgraphKind::Elementwise { len, .. } => {
                (len as f64 * F, 0.0, len as f64 * F)
            }
        }
    }

    /// Total cold memory traffic in bytes.
    pub fn total_bytes(&self) -> f64 {
        let (a, b, c) = self.buffer_bytes();
        a + b + c
    }

    /// Arithmetic intensity (flops per cold byte).
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops() / self.total_bytes().max(1.0)
    }

    /// Compact feature-space descriptor of the workload: log-scaled
    /// geometry extents, MAC flag, log-scaled flops, per-buffer bytes,
    /// and arithmetic intensity.  Log scaling (`log2(1 + v)`) makes the
    /// L2 distance between two descriptors measure *ratios* between
    /// shapes, so a conv with twice the channels sits one octave away
    /// regardless of absolute size — the similarity metric the
    /// nearest-neighbor warm start retrieves along.
    pub fn descriptor(&self) -> [f64; DESC_DIM] {
        let l2 = |v: f64| (1.0 + v.max(0.0)).log2();
        let g = self.geometry();
        let (in_b, w_b, out_b) = self.buffer_bytes();
        [
            l2(g.x as f64),
            l2(g.y as f64),
            l2(g.r as f64),
            if g.mac { 1.0 } else { 0.0 },
            l2(self.flops()),
            l2(in_b),
            l2(w_b),
            l2(out_b),
            l2(self.arithmetic_intensity()),
        ]
    }

    /// Tagged canonical encoding (kind tag + shape parameters in a fixed
    /// order) — the single source of truth for dataset serialization and
    /// workload hashing.
    pub fn encode_tagged(&self) -> (u8, Vec<u32>) {
        match *self {
            SubgraphKind::Conv2d { n, h, w, cin, cout, kh, kw, stride, pad } => (
                0,
                vec![
                    n as u32, h as u32, w as u32, cin as u32, cout as u32, kh as u32,
                    kw as u32, stride as u32, pad as u32,
                ],
            ),
            SubgraphKind::DepthwiseConv2d { n, h, w, c, kh, kw, stride, pad } => (
                1,
                vec![
                    n as u32, h as u32, w as u32, c as u32, kh as u32, kw as u32,
                    stride as u32, pad as u32,
                ],
            ),
            SubgraphKind::Dense { m, n, k } => (2, vec![m as u32, n as u32, k as u32]),
            SubgraphKind::BatchMatmul { b, m, n, k } => {
                (3, vec![b as u32, m as u32, n as u32, k as u32])
            }
            SubgraphKind::Pool2d { n, h, w, c, k, stride } => (
                4,
                vec![n as u32, h as u32, w as u32, c as u32, k as u32, stride as u32],
            ),
            SubgraphKind::Elementwise { len, ops } => (5, vec![len as u32, ops as u32]),
        }
    }

    /// Inverse of [`SubgraphKind::encode_tagged`].  Returns `None` for an
    /// unknown tag or a too-short parameter list (corrupt input).
    pub fn decode_tagged(tag: u8, p: &[u32]) -> Option<SubgraphKind> {
        let need = match tag {
            0 => 9,
            1 => 8,
            2 => 3,
            3 => 4,
            4 => 6,
            5 => 2,
            _ => return None,
        };
        if p.len() < need {
            return None;
        }
        let u = |i: usize| p[i] as usize;
        Some(match tag {
            0 => SubgraphKind::Conv2d {
                n: u(0),
                h: u(1),
                w: u(2),
                cin: u(3),
                cout: u(4),
                kh: u(5),
                kw: u(6),
                stride: u(7),
                pad: u(8),
            },
            1 => SubgraphKind::DepthwiseConv2d {
                n: u(0),
                h: u(1),
                w: u(2),
                c: u(3),
                kh: u(4),
                kw: u(5),
                stride: u(6),
                pad: u(7),
            },
            2 => SubgraphKind::Dense { m: u(0), n: u(1), k: u(2) },
            3 => SubgraphKind::BatchMatmul { b: u(0), m: u(1), n: u(2), k: u(3) },
            4 => SubgraphKind::Pool2d { n: u(0), h: u(1), w: u(2), c: u(3), k: u(4), stride: u(5) },
            _ => SubgraphKind::Elementwise { len: u(0), ops: u(1) },
        })
    }

    /// Short kind tag for logs/dataset records.
    pub fn tag(&self) -> &'static str {
        match self {
            SubgraphKind::Conv2d { .. } => "conv2d",
            SubgraphKind::DepthwiseConv2d { .. } => "dwconv",
            SubgraphKind::Dense { .. } => "dense",
            SubgraphKind::BatchMatmul { .. } => "bmm",
            SubgraphKind::Pool2d { .. } => "pool",
            SubgraphKind::Elementwise { .. } => "eltwise",
        }
    }
}

impl Subgraph {
    pub fn new(name: &str, kind: SubgraphKind) -> Subgraph {
        Subgraph { name: name.to_string(), kind, repeats: 1 }
    }

    pub fn with_repeats(mut self, repeats: usize) -> Subgraph {
        self.repeats = repeats;
        self
    }

    pub fn geometry(&self) -> Geometry {
        self.kind.geometry()
    }

    pub fn flops(&self) -> f64 {
        self.kind.flops()
    }

    /// Feature-space descriptor of the normalized workload
    /// ([`SubgraphKind::descriptor`]) — like the fingerprint, invariant
    /// to task naming and repeat counts.
    pub fn descriptor(&self) -> [f64; DESC_DIM] {
        self.kind.descriptor()
    }

    /// Stable, collision-resistant fingerprint of the *normalized*
    /// workload: kind + shape parameters only.  Invariant to task naming
    /// and weight-shared repeat counts, so `resnet18.conv2_1` and a
    /// same-shaped layer of another model share one tuning-cache line.
    pub fn workload_fingerprint(&self) -> u64 {
        let (tag, params) = self.kind.encode_tagged();
        let mut bytes = Vec::with_capacity(1 + 4 * params.len());
        bytes.push(tag);
        for p in &params {
            bytes.extend_from_slice(&p.to_le_bytes());
        }
        crate::util::rng::hash_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv() -> SubgraphKind {
        // Paper Fig. 1: Conv2d(3, 64, kernel 3, stride 1, pad 0) at 224².
        SubgraphKind::Conv2d {
            n: 1,
            h: 224,
            w: 224,
            cin: 3,
            cout: 64,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 0,
        }
    }

    #[test]
    fn conv_geometry_and_flops() {
        let g = conv().geometry();
        assert_eq!(g.x, 222 * 222);
        assert_eq!(g.y, 64);
        assert_eq!(g.r, 27);
        assert!(g.mac);
        // 2 * X * Y * R MACs
        assert_eq!(conv().flops(), 2.0 * (222.0 * 222.0) * 64.0 * 27.0);
    }

    #[test]
    fn strided_conv_shrinks_output() {
        let s2 = SubgraphKind::Conv2d {
            n: 1,
            h: 56,
            w: 56,
            cin: 64,
            cout: 128,
            kh: 3,
            kw: 3,
            stride: 2,
            pad: 1,
        };
        let g = s2.geometry();
        assert_eq!(g.x, 28 * 28);
    }

    #[test]
    fn dense_geometry() {
        let d = SubgraphKind::Dense { m: 128, n: 768, k: 3072 };
        let g = d.geometry();
        assert_eq!((g.x, g.y, g.r), (128, 768, 3072));
        assert_eq!(d.flops(), 2.0 * 128.0 * 768.0 * 3072.0);
    }

    #[test]
    fn pool_is_not_mac() {
        let p = SubgraphKind::Pool2d { n: 1, h: 112, w: 112, c: 64, k: 3, stride: 2 };
        assert!(!p.geometry().mac);
        assert!(p.flops() > 0.0);
    }

    #[test]
    fn arithmetic_intensity_orders_sensibly() {
        // Big dense matmul should have far higher intensity than eltwise.
        let d = SubgraphKind::Dense { m: 512, n: 512, k: 512 };
        let e = SubgraphKind::Elementwise { len: 512 * 512, ops: 2 };
        assert!(d.arithmetic_intensity() > 50.0 * e.arithmetic_intensity());
    }

    #[test]
    fn buffer_bytes_positive_and_consistent() {
        for kind in [
            conv(),
            SubgraphKind::DepthwiseConv2d {
                n: 1, h: 56, w: 56, c: 128, kh: 3, kw: 3, stride: 1, pad: 1,
            },
            SubgraphKind::BatchMatmul { b: 12, m: 128, n: 128, k: 64 },
        ] {
            let (a, b, c) = kind.buffer_bytes();
            assert!(a > 0.0 && c > 0.0, "{kind:?}");
            assert_eq!(kind.total_bytes(), a + b + c);
        }
    }

    #[test]
    fn repeats_default_one() {
        let s = Subgraph::new("t", conv());
        assert_eq!(s.repeats, 1);
        assert_eq!(s.with_repeats(3).repeats, 3);
    }

    #[test]
    fn descriptor_is_finite_and_shape_sensitive() {
        let a = conv().descriptor();
        assert!(a.iter().all(|v| v.is_finite()));
        // Same shape -> identical descriptor regardless of naming.
        let named = Subgraph::new("x.y", conv()).with_repeats(5);
        assert_eq!(named.descriptor(), a);
        // Doubling cout moves the y/flops dims by about one octave.
        let wider = SubgraphKind::Conv2d {
            n: 1, h: 224, w: 224, cin: 3, cout: 128, kh: 3, kw: 3, stride: 1, pad: 0,
        };
        let b = wider.descriptor();
        assert!((b[1] - a[1] - 1.0).abs() < 0.05, "y dim should shift ~1 octave");
        assert!((b[4] - a[4] - 1.0).abs() < 0.05, "flops dim should shift ~1 octave");
        // A very different kind is far in every compute dimension.
        let e = SubgraphKind::Elementwise { len: 1024, ops: 1 }.descriptor();
        assert!((e[2] - a[2]).abs() > 2.0, "reduction extents should differ");
    }

    #[test]
    fn tagged_encoding_roundtrips_every_kind() {
        for kind in [
            conv(),
            SubgraphKind::DepthwiseConv2d {
                n: 1, h: 56, w: 56, c: 128, kh: 3, kw: 3, stride: 1, pad: 1,
            },
            SubgraphKind::Dense { m: 128, n: 768, k: 3072 },
            SubgraphKind::BatchMatmul { b: 12, m: 128, n: 128, k: 64 },
            SubgraphKind::Pool2d { n: 1, h: 112, w: 112, c: 64, k: 3, stride: 2 },
            SubgraphKind::Elementwise { len: 4096, ops: 3 },
        ] {
            let (tag, params) = kind.encode_tagged();
            assert_eq!(SubgraphKind::decode_tagged(tag, &params), Some(kind));
        }
        // Corrupt inputs decode to None, never panic.
        assert_eq!(SubgraphKind::decode_tagged(99, &[1, 2, 3]), None);
        assert_eq!(SubgraphKind::decode_tagged(0, &[1, 2]), None);
    }

    #[test]
    fn workload_fingerprint_ignores_name_and_repeats() {
        let a = Subgraph::new("resnet18.conv2_1", conv());
        let b = Subgraph::new("other.model.layer9", conv()).with_repeats(4);
        assert_eq!(a.workload_fingerprint(), b.workload_fingerprint());
        // Any shape change must move the fingerprint.
        let c = Subgraph::new(
            "t",
            SubgraphKind::Conv2d {
                n: 1, h: 224, w: 224, cin: 3, cout: 64, kh: 3, kw: 3, stride: 2, pad: 0,
            },
        );
        assert_ne!(a.workload_fingerprint(), c.workload_fingerprint());
        // Different kinds with similar numbers differ too.
        let d = Subgraph::new("t", SubgraphKind::Dense { m: 224, n: 224, k: 3 });
        assert_ne!(a.workload_fingerprint(), d.workload_fingerprint());
    }
}
