//! Draft-then-verify speculative scoring (Pruner-style, PAPERS.md):
//! a tiny linear scorer over the 164-d feature vector ranks the whole
//! evolutionary population cheaply, and only a shortlist survives to be
//! verified by the full MLP [`Predictor`](crate::costmodel::Predictor).
//!
//! The draft is *distilled from the live model*, never a static
//! heuristic (TLP's argument, PAPERS.md): the learner fits it by ridge
//! least squares against the full model's own scores on the replay
//! buffer, shrunk toward the MLP's first-layer feature projection
//! ([`Predictor::feature_projection`](crate::costmodel::Predictor::feature_projection)),
//! and republishes it alongside every model snapshot.  Draft scoring
//! charges **zero virtual time** — only full-model verify batches hit
//! the virtual clock — so a draft-off session stays bit-identical to
//! the pre-draft engine.

use crate::program::N_FEATURES;

/// Minimum replay rows required before a least-squares fit is
/// attempted; below this the learner publishes a passthrough draft
/// (no pruning) rather than trusting a fit on noise.
pub const MIN_FIT_ROWS: usize = 8;

/// Cap on replay rows used per distillation (the most recent rows win);
/// keeps a refresh O(rows · 164²) even with a large replay buffer.
pub const MAX_FIT_ROWS: usize = 512;

/// An immutable, versioned draft scorer: `score = w · x + b` over the
/// 164-d feature vector.
///
/// Shares the publish discipline of
/// [`ModelState`](crate::costmodel::ModelState): a `DraftState` is
/// never mutated, only replaced, and carries the version of the model
/// it was distilled from so workers can pin `(model, draft)` pairs.
#[derive(Debug, Clone)]
pub struct DraftState {
    /// Per-feature weights (`N_FEATURES` long; empty in passthrough mode).
    weights: Vec<f32>,
    bias: f32,
    version: u64,
    passthrough: bool,
}

impl DraftState {
    /// A draft that prunes nothing (used before enough distillation
    /// data exists, or when a fit diverges).  Callers detect it with
    /// [`DraftState::is_passthrough`] and verify the full population.
    pub fn passthrough(version: u64) -> DraftState {
        DraftState { weights: Vec::new(), bias: 0.0, version, passthrough: true }
    }

    /// Distill a linear scorer from `rows` feature rows `x` (row-major,
    /// `rows * N_FEATURES`) labeled with the full model's scores `y`.
    ///
    /// Solves the ridge normal equations `(XᵀX + λI) w = Xᵀy + λ w₀` in
    /// f64 with an augmented bias column, where the prior `w₀` (when
    /// given) is the full MLP's first-layer feature projection — with
    /// little data the draft shrinks toward the live model's own
    /// linearization instead of toward zero.  Any non-finite input,
    /// too-few rows, or a non-positive-definite system yields a
    /// [`DraftState::passthrough`] — a diverging fit can never poison
    /// the ranking (it just stops pruning).
    pub fn fit(
        x: &[f32],
        y: &[f32],
        rows: usize,
        prior: Option<&[f32]>,
        version: u64,
    ) -> DraftState {
        const D: usize = N_FEATURES;
        const A: usize = D + 1;
        if rows < MIN_FIT_ROWS || x.len() != rows * D || y.len() != rows {
            return DraftState::passthrough(version);
        }
        if x.iter().any(|v| !v.is_finite()) || y.iter().any(|v| !v.is_finite()) {
            return DraftState::passthrough(version);
        }
        if let Some(p) = prior {
            if p.len() != D || p.iter().any(|v| !v.is_finite()) {
                return DraftState::passthrough(version);
            }
        }
        // Accumulate G = XᵀX (upper triangle) and b = Xᵀy in f64, with
        // an augmented all-ones column for the bias term.
        let mut g = vec![0.0f64; A * A];
        let mut b = vec![0.0f64; A];
        for r in 0..rows {
            let row = &x[r * D..(r + 1) * D];
            let yr = y[r] as f64;
            for i in 0..D {
                let xi = row[i] as f64;
                if xi == 0.0 {
                    continue; // feature rows are sparse in practice
                }
                b[i] += xi * yr;
                let gi = &mut g[i * A..(i + 1) * A];
                for (j, &xj) in row.iter().enumerate().skip(i) {
                    gi[j] += xi * xj as f64;
                }
                gi[D] += xi;
            }
            b[D] += yr;
            g[D * A + D] += 1.0;
        }
        // Ridge term: keeps G positive definite under rank-deficient
        // features and pulls the solution toward the prior.
        let lambda = 1e-3 * rows as f64;
        for (i, bi) in b.iter_mut().enumerate().take(D) {
            g[i * A + i] += lambda;
            if let Some(p) = prior {
                *bi += lambda * p[i] as f64;
            }
        }
        g[D * A + D] += lambda;
        // Mirror the upper triangle.
        for i in 1..A {
            for j in 0..i {
                g[i * A + j] = g[j * A + i];
            }
        }
        let Some(w) = cholesky_solve(&mut g, &mut b, A) else {
            return DraftState::passthrough(version);
        };
        if w.iter().any(|v| !v.is_finite()) {
            return DraftState::passthrough(version);
        }
        DraftState {
            weights: w[..D].iter().map(|&v| v as f32).collect(),
            bias: w[D] as f32,
            version,
            passthrough: false,
        }
    }

    /// Version of the model this draft was distilled from.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Whether this draft prunes nothing (see [`DraftState::passthrough`]).
    pub fn is_passthrough(&self) -> bool {
        self.passthrough
    }

    /// Score `rows` feature rows (row-major, `rows * N_FEATURES` f32).
    ///
    /// One fused multiply-add sweep per row — ~1600× less arithmetic
    /// than the full MLP forward — and deterministic (fixed f32
    /// accumulation order).  A passthrough draft scores everything 0.
    pub fn score(&self, x: &[f32], rows: usize) -> Vec<f32> {
        assert_eq!(x.len(), rows * N_FEATURES);
        if self.passthrough {
            return vec![0.0; rows];
        }
        let mut out = Vec::with_capacity(rows);
        for r in 0..rows {
            let row = &x[r * N_FEATURES..(r + 1) * N_FEATURES];
            let mut acc = self.bias;
            for (w, v) in self.weights.iter().zip(row) {
                acc += w * v;
            }
            out.push(acc);
        }
        out
    }
}

/// A borrowed view of the draft tier for one propose call: the pinned
/// scorer plus the shortlist fraction.
pub struct DraftGate<'a> {
    /// The distilled draft scorer to rank candidates with.
    pub state: &'a DraftState,
    /// Fraction of each fresh scoring batch the full model verifies
    /// (`0 < keep ≤ 1`; `1.0` disables pruning bitwise-exactly).
    pub keep: f64,
}

/// Per-propose accounting of the two scoring tiers (reset on every
/// [`propose`](super::SearchPolicy::propose) call).
#[derive(Debug, Clone, Copy, Default)]
pub struct DraftStats {
    /// Rows scored by the draft tier.
    pub draft_scored: u64,
    /// Rows the draft shortlisted for full verification.
    pub kept: u64,
    /// Rows the draft pruned (assigned the sentinel-worst score).
    pub pruned: u64,
    /// Rows the full `Predictor` actually scored (counted with the
    /// draft tier on *or* off — the speculative-search bench gate
    /// compares exactly this number across the two modes).
    pub full_rows: u64,
}

/// In-place Cholesky factorization + solve of `a x = b` for a
/// symmetric positive-definite row-major `n × n` system.  Returns
/// `None` on a non-positive pivot (system not PD) so the caller can
/// fall back to a passthrough draft.
fn cholesky_solve(a: &mut [f64], b: &mut [f64], n: usize) -> Option<Vec<f64>> {
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= a[i * n + k] * a[j * n + k];
            }
            if i == j {
                if !(sum > 0.0 && sum.is_finite()) {
                    return None;
                }
                a[i * n + i] = sum.sqrt();
            } else {
                a[i * n + j] = sum / a[j * n + j];
            }
        }
    }
    // L z = b (forward), then Lᵀ x = z (backward), in place in b.
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= a[i * n + k] * b[k];
        }
        b[i] = sum / a[i * n + i];
    }
    for i in (0..n).rev() {
        let mut sum = b[i];
        for k in i + 1..n {
            sum -= a[k * n + i] * b[k];
        }
        b[i] = sum / a[i * n + i];
    }
    Some(b.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn synthetic(rows: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        // A planted linear target the fit should recover.
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..N_FEATURES).map(|_| rng.normal() as f32 * 0.1).collect();
        let mut x = Vec::with_capacity(rows * N_FEATURES);
        let mut y = Vec::with_capacity(rows);
        for _ in 0..rows {
            let row: Vec<f32> = (0..N_FEATURES).map(|_| rng.normal() as f32).collect();
            let target: f32 = row.iter().zip(&w).map(|(a, b)| a * b).sum::<f32>() + 0.5;
            x.extend_from_slice(&row);
            y.push(target);
        }
        (x, y, w)
    }

    #[test]
    fn fit_recovers_a_planted_linear_target() {
        let (x, y, _) = synthetic(256, 1);
        let draft = DraftState::fit(&x, &y, 256, None, 7);
        assert!(!draft.is_passthrough());
        assert_eq!(draft.version(), 7);
        let pred = draft.score(&x, 256);
        for (p, t) in pred.iter().zip(&y) {
            assert!((p - t).abs() < 0.05, "pred {p} vs target {t}");
        }
    }

    #[test]
    fn fit_ranks_like_the_labels() {
        // The draft is used for ranking, so check order, not values.
        let (x, y, _) = synthetic(128, 2);
        let draft = DraftState::fit(&x, &y, 128, None, 0);
        let pred = draft.score(&x, 128);
        let argmax_y = y
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        let argmax_p = pred
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(argmax_y, argmax_p);
    }

    #[test]
    fn prior_breaks_ties_when_data_is_scarce() {
        // With exactly MIN_FIT_ROWS rows of an all-zero design matrix,
        // the data says nothing; the ridge prior must carry the fit.
        let rows = MIN_FIT_ROWS;
        let x = vec![0.0f32; rows * N_FEATURES];
        let y = vec![0.0f32; rows];
        let mut prior = vec![0.0f32; N_FEATURES];
        prior[3] = 2.0;
        let draft = DraftState::fit(&x, &y, rows, Some(&prior), 1);
        assert!(!draft.is_passthrough());
        let mut probe = vec![0.0f32; N_FEATURES];
        probe[3] = 1.0;
        let zero = vec![0.0f32; N_FEATURES];
        let hot = draft.score(&probe, 1)[0];
        let cold = draft.score(&zero, 1)[0];
        assert!(hot > cold, "prior-weighted feature should score higher: {hot} vs {cold}");
    }

    #[test]
    fn non_finite_labels_yield_passthrough() {
        // A diverged full model emits NaN labels; the distillation must
        // degrade to no-pruning, never to a garbage shortlist.
        let (x, mut y, _) = synthetic(64, 3);
        y[10] = f32::NAN;
        let draft = DraftState::fit(&x, &y, 64, None, 4);
        assert!(draft.is_passthrough());
        assert_eq!(draft.version(), 4);
        assert_eq!(draft.score(&x[..N_FEATURES], 1), vec![0.0]);
    }

    #[test]
    fn too_few_rows_yield_passthrough() {
        let (x, y, _) = synthetic(MIN_FIT_ROWS - 1, 5);
        let draft = DraftState::fit(&x, &y, MIN_FIT_ROWS - 1, None, 0);
        assert!(draft.is_passthrough());
    }

    #[test]
    fn degenerate_design_matrix_does_not_panic() {
        // Identical rows make XᵀX rank-1; the ridge term must keep the
        // solve alive (or fall back to passthrough) without panicking.
        let row: Vec<f32> = (0..N_FEATURES).map(|i| (i % 3) as f32).collect();
        let rows = 16;
        let mut x = Vec::new();
        for _ in 0..rows {
            x.extend_from_slice(&row);
        }
        let y = vec![1.0f32; rows];
        let draft = DraftState::fit(&x, &y, rows, None, 0);
        let s = draft.score(&x, rows);
        assert!(s.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn fit_is_deterministic() {
        let (x, y, _) = synthetic(100, 6);
        let a = DraftState::fit(&x, &y, 100, None, 0);
        let b = DraftState::fit(&x, &y, 100, None, 0);
        assert_eq!(a.score(&x, 100), b.score(&x, 100));
    }
}
