//! Evolutionary search guided by the cost model (Ansor's engine,
//! paper §2.2): maintain a population, score it with `C()`, evolve by
//! tournament selection + crossover + mutation, return the predicted
//! top-k for on-device measurement.
//!
//! Scoring is speculative when a [`DraftGate`] is armed: the distilled
//! draft ranks every fresh candidate for free and only the top
//! `keep` fraction is verified by the full [`Predictor`] (Pruner's
//! draft-then-verify, PAPERS.md).  Elite rows carry their feature rows
//! *and* their verified scores across generations, so each generation
//! only featurizes and scores its fresh offspring.

use super::{DraftGate, DraftStats, SearchPolicy};
use crate::costmodel::Predictor;
use crate::program::{featurize, Schedule, SpaceGenerator, Subgraph, N_FEATURES};
use crate::util::rng::Rng;

/// Evolutionary search engine for one task.
pub struct EvolutionarySearch {
    pub subgraph: Subgraph,
    pub generator: SpaceGenerator,
    /// Population per generation.
    pub population: usize,
    /// Number of generations per proposal round.
    pub generations: usize,
    /// Probability a child is mutated after crossover.
    pub mutation_prob: f64,
    /// Fraction of the population carried over unchanged (elitism).
    pub elite_frac: f64,
    /// Measured good schedules seeding the next population.
    seeds: Vec<Schedule>,
    /// Scratch: feature matrix of the CURRENT population, row-aligned
    /// with it (reused across generations and rounds — never
    /// re-allocated, and elite rows are never re-featurized).
    feat_buf: Vec<f32>,
    /// Scratch: next generation's feature matrix, swapped with
    /// `feat_buf` once the generation is assembled.
    carry_buf: Vec<f32>,
    /// Scratch: gathered shortlist features for the verify batch.
    gather_buf: Vec<f32>,
    /// Two-tier scoring accounting for the most recent propose call.
    last_stats: DraftStats,
}

impl EvolutionarySearch {
    pub fn new(subgraph: Subgraph) -> EvolutionarySearch {
        let generator = SpaceGenerator::new(subgraph.geometry());
        EvolutionarySearch {
            subgraph,
            generator,
            population: 64,
            generations: 3,
            mutation_prob: 0.85,
            elite_frac: 0.125,
            seeds: Vec::new(),
            feat_buf: Vec::new(),
            carry_buf: Vec::new(),
            gather_buf: Vec::new(),
            last_stats: DraftStats::default(),
        }
    }

    /// Engine with explicit population/generation parameters (how the
    /// staged task pipeline constructs its search plane).
    pub fn with_params(
        subgraph: Subgraph,
        population: usize,
        generations: usize,
    ) -> EvolutionarySearch {
        let mut es = EvolutionarySearch::new(subgraph);
        es.population = population;
        es.generations = generations;
        es
    }

    /// Feed back measured results so future rounds start from winners.
    pub fn add_seed(&mut self, s: Schedule) {
        if !self.seeds.contains(&s) {
            self.seeds.push(s);
            if self.seeds.len() > 32 {
                self.seeds.remove(0);
            }
        }
    }

    /// Draft/verify accounting for the most recent
    /// [`propose`](SearchPolicy::propose) call.  `full_rows` is counted
    /// with the draft tier on or off, so the speculative-search bench
    /// gate can compare full-Predictor work across the two modes.
    pub fn last_draft_stats(&self) -> DraftStats {
        self.last_stats
    }

    /// Score rows `carried..n` of the current population — whose
    /// feature matrix sits row-aligned in `self.feat_buf` — appending
    /// onto `scores` (which already holds the `carried` carried-over
    /// elite scores).
    ///
    /// With a draft gate armed, the draft tier ranks the fresh rows
    /// first (zero virtual-time cost) and only the top `keep` fraction
    /// is verified by the full model; pruned rows get the
    /// sentinel-worst score.  Draft scores pass through the same
    /// non-finite → sentinel mapping as full predictions, so a
    /// diverging draft fit can neither panic the ranking nor promote
    /// garbage into the shortlist.  Exactly one `charge_query` is
    /// issued per call with fresh rows, draft or not — which is what
    /// keeps `keep = 1.0` (and draft-off) bitwise identical to the
    /// pre-draft engine.
    fn score_fresh(
        &mut self,
        n: usize,
        carried: usize,
        scores: &mut Vec<f32>,
        model: &Predictor,
        draft: Option<&DraftGate<'_>>,
        charge_query: &mut dyn FnMut(),
    ) {
        let fresh = n - carried;
        if fresh == 0 {
            return;
        }
        let tail = &self.feat_buf[carried * N_FEATURES..n * N_FEATURES];
        let shortlist: Option<Vec<usize>> = match draft {
            Some(gate) if !gate.state.is_passthrough() => {
                let mut ds = gate.state.score(tail, fresh);
                for v in &mut ds {
                    if !v.is_finite() {
                        *v = f32::NEG_INFINITY;
                    }
                }
                let keep = ((gate.keep * fresh as f64).ceil() as usize).clamp(1, fresh);
                let mut order: Vec<usize> = (0..fresh).collect();
                order.sort_by(|&a, &b| ds[b].total_cmp(&ds[a]));
                let mut short = order[..keep].to_vec();
                // Restore featurize order: the verify batch must be
                // row-order stable so that keep = 1.0 reproduces the
                // draft-off batch bitwise.
                short.sort_unstable();
                self.last_stats.draft_scored += fresh as u64;
                self.last_stats.kept += short.len() as u64;
                self.last_stats.pruned += (fresh - short.len()) as u64;
                Some(short)
            }
            _ => None,
        };
        match shortlist {
            Some(short) if short.len() < fresh => {
                self.gather_buf.clear();
                for &i in &short {
                    self.gather_buf
                        .extend_from_slice(&tail[i * N_FEATURES..(i + 1) * N_FEATURES]);
                }
                charge_query();
                self.last_stats.full_rows += short.len() as u64;
                let full = model
                    .predict(&self.gather_buf, short.len())
                    .unwrap_or_else(|_| vec![0.0; short.len()]);
                let mut tail_scores = vec![f32::NEG_INFINITY; fresh];
                for (j, &i) in short.iter().enumerate() {
                    if full[j].is_finite() {
                        tail_scores[i] = full[j];
                    }
                }
                scores.extend_from_slice(&tail_scores);
            }
            _ => {
                charge_query();
                self.last_stats.full_rows += fresh as u64;
                let mut full =
                    model.predict(tail, fresh).unwrap_or_else(|_| vec![0.0; fresh]);
                for v in &mut full {
                    if !v.is_finite() {
                        *v = f32::NEG_INFINITY;
                    }
                }
                scores.extend_from_slice(&full);
            }
        }
    }

    /// Tournament pick: the better of two random members.
    fn tournament<'a>(pop: &'a [Schedule], scores: &[f32], rng: &mut Rng) -> &'a Schedule {
        let a = rng.below(pop.len());
        let b = rng.below(pop.len());
        if scores[a] >= scores[b] {
            &pop[a]
        } else {
            &pop[b]
        }
    }
}

impl SearchPolicy for EvolutionarySearch {
    fn propose(
        &mut self,
        k: usize,
        model: &Predictor,
        seen: &dyn Fn(&Schedule) -> bool,
        rng: &mut Rng,
        draft: Option<&DraftGate<'_>>,
        charge_query: &mut dyn FnMut(),
    ) -> Vec<Schedule> {
        self.last_stats = DraftStats::default();
        // Initial population: seeds + mutated seeds + random fill.
        let mut pop: Vec<Schedule> = Vec::with_capacity(self.population);
        for s in &self.seeds {
            if pop.len() < self.population / 2 {
                pop.push(*s);
            }
        }
        let seeds_snapshot = self.seeds.clone();
        for s in &seeds_snapshot {
            if pop.len() >= self.population * 3 / 4 {
                break;
            }
            let m = self.generator.mutate(s, rng);
            if !pop.contains(&m) {
                pop.push(m);
            }
        }
        // Random fill, attempt-bounded: a tiny geometry's distinct
        // schedule space can be smaller than the population, in which
        // case duplicates are accepted past the bound rather than
        // spinning forever.
        let mut attempts = 0usize;
        let max_attempts = 32 * self.population.max(4);
        while pop.len() < self.population {
            let s = self.generator.sample(rng);
            if attempts >= max_attempts || !pop.contains(&s) {
                pop.push(s);
            }
            attempts += 1;
        }

        self.feat_buf.clear();
        self.feat_buf.reserve(pop.len() * N_FEATURES);
        for s in &pop {
            self.feat_buf.extend_from_slice(&featurize(&self.subgraph, s));
        }
        let mut scores: Vec<f32> = Vec::with_capacity(pop.len());
        self.score_fresh(pop.len(), 0, &mut scores, model, draft, charge_query);

        for _gen in 0..self.generations {
            // Elite carry-over: the schedules, their feature rows, and
            // their verified scores all move forward verbatim.  Per-row
            // prediction independence makes the carried score bitwise
            // identical to a re-score, so only fresh offspring are
            // featurized and ranked below.
            let mut order: Vec<usize> = (0..pop.len()).collect();
            order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
            let n_elite = ((self.population as f64 * self.elite_frac) as usize).max(1);
            let mut next: Vec<Schedule> = Vec::with_capacity(self.population);
            let mut next_scores: Vec<f32> = Vec::with_capacity(self.population);
            self.carry_buf.clear();
            for &i in &order[..n_elite] {
                next.push(pop[i]);
                next_scores.push(scores[i]);
                self.carry_buf
                    .extend_from_slice(&self.feat_buf[i * N_FEATURES..(i + 1) * N_FEATURES]);
            }
            // Offspring, attempt-bounded like the random fill above.
            let mut attempts = 0usize;
            while next.len() < self.population {
                let pa = *Self::tournament(&pop, &scores, rng);
                let pb = *Self::tournament(&pop, &scores, rng);
                let mut child = self.generator.crossover(&pa, &pb, rng);
                if rng.chance(self.mutation_prob) {
                    child = self.generator.mutate(&child, rng);
                }
                if attempts >= max_attempts || !next.contains(&child) {
                    next.push(child);
                }
                attempts += 1;
            }
            for s in &next[n_elite..] {
                self.carry_buf.extend_from_slice(&featurize(&self.subgraph, s));
            }
            std::mem::swap(&mut self.feat_buf, &mut self.carry_buf);
            pop = next;
            scores = next_scores;
            self.score_fresh(pop.len(), n_elite, &mut scores, model, draft, charge_query);
        }

        // Final: predicted top-k, unseen only.
        let mut order: Vec<usize> = (0..pop.len()).collect();
        order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
        let mut out = Vec::with_capacity(k);
        for &i in &order {
            if out.len() >= k {
                break;
            }
            if !seen(&pop[i]) && !out.contains(&pop[i]) {
                out.push(pop[i]);
            }
        }
        // Top off with random unseen if the population was exhausted.
        let mut attempts = 0;
        while out.len() < k && attempts < 64 * k.max(4) {
            let s = self.generator.sample(rng);
            if !seen(&s) && !out.contains(&s) {
                out.push(s);
            }
            attempts += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::{layout, CostModel, Mask, RustBackend};
    use crate::program::SubgraphKind;
    use crate::search::DraftState;
    use std::sync::Arc;

    fn task() -> Subgraph {
        Subgraph::new(
            "evo.conv",
            SubgraphKind::Conv2d {
                n: 1, h: 28, w: 28, cin: 128, cout: 128, kh: 3, kw: 3, stride: 1, pad: 1,
            },
        )
    }

    fn model(seed: u64) -> CostModel {
        CostModel::new(
            Arc::new(RustBackend { pred_batch: 64, train_batch: 64 }),
            &mut Rng::new(seed),
        )
    }

    /// A non-passthrough draft distilled from `m`'s own scores on a
    /// random schedule sample (the same construction the learner uses).
    fn distilled_draft(m: &CostModel, rng: &mut Rng) -> DraftState {
        let gen = SpaceGenerator::new(task().geometry());
        let scheds = gen.sample_distinct(rng, 64);
        let mut x = Vec::new();
        for s in &scheds {
            x.extend_from_slice(&featurize(&task(), s));
        }
        let y = m.predict(&x, scheds.len()).unwrap();
        let draft = DraftState::fit(&x, &y, scheds.len(), None, 0);
        assert!(!draft.is_passthrough());
        draft
    }

    #[test]
    fn proposes_k_valid_unseen() {
        let mut es = EvolutionarySearch::new(task());
        es.population = 32;
        es.generations = 2;
        let m = model(1);
        let mut rng = Rng::new(2);
        let mut queries = 0;
        let out = es.propose(8, &m.predictor(), &|_| false, &mut rng, None, &mut || queries += 1);
        assert_eq!(out.len(), 8);
        assert!(queries >= 3, "expected >=3 scoring passes, got {queries}");
        let g = es.subgraph.geometry();
        for s in &out {
            assert!(s.is_valid(&g));
        }
    }

    #[test]
    fn search_finds_higher_scoring_configs_than_random() {
        // Train a model toward a synthetic preference (high tx), then
        // check evolution maximizes it better than random sampling.
        let mut es = EvolutionarySearch::new(task());
        es.population = 48;
        es.generations = 4;
        let mut m = model(3);
        let mut rng = Rng::new(4);
        // Synthetic labels: prefer larger block tiles.
        let gen = SpaceGenerator::new(task().geometry());
        let scheds = gen.sample_distinct(&mut rng, 64);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for s in &scheds {
            x.extend_from_slice(&featurize(&task(), s));
            y.push((s.threads_per_block() as f32 / 1024.0).min(1.0));
        }
        let mask = Mask::all_ones(layout::N_PARAMS);
        for _ in 0..30 {
            m.train_epoch(&x, &y, &mask, 1e-2, 0.0, &mut rng).unwrap();
        }
        let proposed = es.propose(8, &m.predictor(), &|_| false, &mut rng, None, &mut || {});
        let mean_prop: f64 = proposed.iter().map(|s| s.threads_per_block() as f64).sum::<f64>()
            / proposed.len() as f64;
        let random: Vec<Schedule> = gen.sample_distinct(&mut rng, 64);
        let mean_rand: f64 = random.iter().map(|s| s.threads_per_block() as f64).sum::<f64>()
            / random.len() as f64;
        assert!(
            mean_prop > mean_rand,
            "evolution {mean_prop} should beat random {mean_rand}"
        );
    }

    #[test]
    fn nan_predictions_do_not_panic_or_win() {
        // A diverged model (all-NaN params) emits NaN for every
        // schedule; propose must neither panic in the ranking sorts nor
        // hang, and still returns k candidates.
        let mut es = EvolutionarySearch::new(task());
        es.population = 16;
        es.generations = 2;
        let nan_model = CostModel::with_params(
            Arc::new(RustBackend { pred_batch: 64, train_batch: 64 }),
            vec![f32::NAN; layout::N_PARAMS],
        );
        let mut rng = Rng::new(6);
        let out = es.propose(4, &nan_model.predictor(), &|_| false, &mut rng, None, &mut || {});
        assert_eq!(out.len(), 4);
        let g = es.subgraph.geometry();
        assert!(out.iter().all(|s| s.is_valid(&g)));
    }

    #[test]
    fn nan_predictions_do_not_panic_or_win_with_draft_tier() {
        // Same guarantee through the speculative path: a healthy draft
        // shortlists against a diverged (all-NaN) full model, and the
        // verify batch's NaNs must map to the sentinel-worst score
        // without panicking the ranking sorts.
        let mut es = EvolutionarySearch::new(task());
        es.population = 16;
        es.generations = 2;
        let healthy = model(9);
        let mut rng = Rng::new(6);
        let draft = distilled_draft(&healthy, &mut rng);
        let gate = DraftGate { state: &draft, keep: 0.25 };
        let nan_model = CostModel::with_params(
            Arc::new(RustBackend { pred_batch: 64, train_batch: 64 }),
            vec![f32::NAN; layout::N_PARAMS],
        );
        let out = es.propose(
            4,
            &nan_model.predictor(),
            &|_| false,
            &mut rng,
            Some(&gate),
            &mut || {},
        );
        assert_eq!(out.len(), 4);
        let g = es.subgraph.geometry();
        assert!(out.iter().all(|s| s.is_valid(&g)));
        let stats = es.last_draft_stats();
        assert!(stats.pruned > 0, "draft should have pruned: {stats:?}");
    }

    #[test]
    fn draft_tier_cuts_full_model_rows() {
        // The tentpole property at the unit level: with keep = 0.25 the
        // full Predictor sees at most ~a quarter of the rows (elite
        // score carry cuts a further slice), at the same query count.
        let m = model(1);
        let mut rng = Rng::new(2);
        let draft = distilled_draft(&m, &mut rng);

        let mut off = EvolutionarySearch::new(task());
        off.population = 32;
        off.generations = 2;
        let mut off_q = 0;
        off.propose(8, &m.predictor(), &|_| false, &mut Rng::new(3), None, &mut || off_q += 1);
        let off_stats = off.last_draft_stats();

        let mut on = EvolutionarySearch::new(task());
        on.population = 32;
        on.generations = 2;
        let gate = DraftGate { state: &draft, keep: 0.25 };
        let mut on_q = 0;
        on.propose(8, &m.predictor(), &|_| false, &mut Rng::new(3), Some(&gate), &mut || {
            on_q += 1
        });
        let on_stats = on.last_draft_stats();

        assert_eq!(off_q, on_q, "virtual-clock query count must not change");
        assert!(
            on_stats.full_rows * 3 <= off_stats.full_rows,
            "draft should cut full-model rows >=3x: on={} off={}",
            on_stats.full_rows,
            off_stats.full_rows
        );
        assert_eq!(on_stats.kept + on_stats.pruned, on_stats.draft_scored);
    }

    #[test]
    fn keep_all_is_bitwise_identical_to_draft_off() {
        // keep = 1.0 shortlists every fresh row in featurize order, so
        // the verify batches — and therefore the rng stream and the
        // proposals — are exactly the draft-off ones.
        let m = model(1);
        let mut rng = Rng::new(2);
        let draft = distilled_draft(&m, &mut rng);
        let gate = DraftGate { state: &draft, keep: 1.0 };

        let mut a = EvolutionarySearch::new(task());
        a.population = 32;
        a.generations = 2;
        let out_a = a.propose(8, &m.predictor(), &|_| false, &mut Rng::new(5), None, &mut || {});

        let mut b = EvolutionarySearch::new(task());
        b.population = 32;
        b.generations = 2;
        let out_b =
            b.propose(8, &m.predictor(), &|_| false, &mut Rng::new(5), Some(&gate), &mut || {});

        assert_eq!(out_a, out_b);
        assert_eq!(a.last_draft_stats().full_rows, b.last_draft_stats().full_rows);
        assert_eq!(b.last_draft_stats().pruned, 0);
    }

    #[test]
    fn passthrough_draft_verifies_everything() {
        let m = model(1);
        let passthrough = DraftState::passthrough(0);
        let gate = DraftGate { state: &passthrough, keep: 0.2 };
        let mut es = EvolutionarySearch::new(task());
        es.population = 16;
        es.generations = 1;
        let out =
            es.propose(4, &m.predictor(), &|_| false, &mut Rng::new(5), Some(&gate), &mut || {});
        assert_eq!(out.len(), 4);
        let stats = es.last_draft_stats();
        assert_eq!(stats.pruned, 0);
        assert_eq!(stats.draft_scored, 0);
        assert!(stats.full_rows > 0);
    }

    #[test]
    fn tiny_schedule_space_terminates_with_duplicates() {
        // A 1x1x1 elementwise geometry has only a handful of distinct
        // valid schedules — far fewer than this population.  The fill
        // loops must accept duplicates past the attempt bound instead
        // of spinning forever.
        let tiny = Subgraph::new("tiny.elt", SubgraphKind::Elementwise { len: 1, ops: 1 });
        let mut es = EvolutionarySearch::new(tiny);
        es.population = 512;
        es.generations = 1;
        let m = model(7);
        let mut rng = Rng::new(8);
        let out = es.propose(4, &m.predictor(), &|_| false, &mut rng, None, &mut || {});
        assert!(!out.is_empty());
        let g = es.subgraph.geometry();
        assert!(out.iter().all(|s| s.is_valid(&g)));
    }

    #[test]
    fn seeds_survive_into_proposals() {
        let mut es = EvolutionarySearch::new(task());
        es.population = 16;
        es.generations = 1;
        let mut rng = Rng::new(5);
        let seed = es.generator.sample(&mut rng);
        es.add_seed(seed);
        assert_eq!(es.seeds.len(), 1);
        es.add_seed(seed); // dedup
        assert_eq!(es.seeds.len(), 1);
    }
}
