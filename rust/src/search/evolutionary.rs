//! Evolutionary search guided by the cost model (Ansor's engine,
//! paper §2.2): maintain a population, score it with `C()`, evolve by
//! tournament selection + crossover + mutation, return the predicted
//! top-k for on-device measurement.

use super::SearchPolicy;
use crate::costmodel::Predictor;
use crate::program::{featurize, Schedule, SpaceGenerator, Subgraph, N_FEATURES};
use crate::util::rng::Rng;

/// Evolutionary search engine for one task.
pub struct EvolutionarySearch {
    pub subgraph: Subgraph,
    pub generator: SpaceGenerator,
    /// Population per generation.
    pub population: usize,
    /// Number of generations per proposal round.
    pub generations: usize,
    /// Probability a child is mutated after crossover.
    pub mutation_prob: f64,
    /// Fraction of the population carried over unchanged (elitism).
    pub elite_frac: f64,
    /// Measured good schedules seeding the next population.
    seeds: Vec<Schedule>,
    /// Scratch: feature matrix buffer reused across rounds (perf:
    /// avoids re-allocating ~population × 164 floats every generation).
    feat_buf: Vec<f32>,
}

impl EvolutionarySearch {
    pub fn new(subgraph: Subgraph) -> EvolutionarySearch {
        let generator = SpaceGenerator::new(subgraph.geometry());
        EvolutionarySearch {
            subgraph,
            generator,
            population: 64,
            generations: 3,
            mutation_prob: 0.85,
            elite_frac: 0.125,
            seeds: Vec::new(),
            feat_buf: Vec::new(),
        }
    }

    /// Engine with explicit population/generation parameters (how the
    /// staged task pipeline constructs its search plane).
    pub fn with_params(
        subgraph: Subgraph,
        population: usize,
        generations: usize,
    ) -> EvolutionarySearch {
        let mut es = EvolutionarySearch::new(subgraph);
        es.population = population;
        es.generations = generations;
        es
    }

    /// Feed back measured results so future rounds start from winners.
    pub fn add_seed(&mut self, s: Schedule) {
        if !self.seeds.contains(&s) {
            self.seeds.push(s);
            if self.seeds.len() > 32 {
                self.seeds.remove(0);
            }
        }
    }

    /// Score a set of schedules with the cost model.  Non-finite
    /// predictions (a diverging model can emit NaN/inf) are mapped to a
    /// sentinel-worst score so ranking stays total and panic-free.
    fn score(
        &mut self,
        pop: &[Schedule],
        model: &Predictor,
        charge_query: &mut dyn FnMut(),
    ) -> Vec<f32> {
        self.feat_buf.clear();
        self.feat_buf.reserve(pop.len() * N_FEATURES);
        for s in pop {
            self.feat_buf.extend_from_slice(&featurize(&self.subgraph, s));
        }
        charge_query();
        let mut scores =
            model.predict(&self.feat_buf, pop.len()).unwrap_or_else(|_| vec![0.0; pop.len()]);
        for v in &mut scores {
            if !v.is_finite() {
                *v = f32::NEG_INFINITY;
            }
        }
        scores
    }

    /// Tournament pick: the better of two random members.
    fn tournament<'a>(pop: &'a [Schedule], scores: &[f32], rng: &mut Rng) -> &'a Schedule {
        let a = rng.below(pop.len());
        let b = rng.below(pop.len());
        if scores[a] >= scores[b] {
            &pop[a]
        } else {
            &pop[b]
        }
    }
}

impl SearchPolicy for EvolutionarySearch {
    fn propose(
        &mut self,
        k: usize,
        model: &Predictor,
        seen: &dyn Fn(&Schedule) -> bool,
        rng: &mut Rng,
        charge_query: &mut dyn FnMut(),
    ) -> Vec<Schedule> {
        // Initial population: seeds + mutated seeds + random fill.
        let mut pop: Vec<Schedule> = Vec::with_capacity(self.population);
        for s in &self.seeds {
            if pop.len() < self.population / 2 {
                pop.push(*s);
            }
        }
        let seeds_snapshot = self.seeds.clone();
        for s in &seeds_snapshot {
            if pop.len() >= self.population * 3 / 4 {
                break;
            }
            let m = self.generator.mutate(s, rng);
            if !pop.contains(&m) {
                pop.push(m);
            }
        }
        // Random fill, attempt-bounded: a tiny geometry's distinct
        // schedule space can be smaller than the population, in which
        // case duplicates are accepted past the bound rather than
        // spinning forever.
        let mut attempts = 0usize;
        let max_attempts = 32 * self.population.max(4);
        while pop.len() < self.population {
            let s = self.generator.sample(rng);
            if attempts >= max_attempts || !pop.contains(&s) {
                pop.push(s);
            }
            attempts += 1;
        }

        let mut scores = self.score(&pop, model, charge_query);

        for _gen in 0..self.generations {
            // Elite carry-over.
            let mut order: Vec<usize> = (0..pop.len()).collect();
            order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
            let n_elite = ((self.population as f64 * self.elite_frac) as usize).max(1);
            let mut next: Vec<Schedule> =
                order[..n_elite].iter().map(|&i| pop[i]).collect();
            // Offspring, attempt-bounded like the random fill above.
            let mut attempts = 0usize;
            while next.len() < self.population {
                let pa = *Self::tournament(&pop, &scores, rng);
                let pb = *Self::tournament(&pop, &scores, rng);
                let mut child = self.generator.crossover(&pa, &pb, rng);
                if rng.chance(self.mutation_prob) {
                    child = self.generator.mutate(&child, rng);
                }
                if attempts >= max_attempts || !next.contains(&child) {
                    next.push(child);
                }
                attempts += 1;
            }
            pop = next;
            scores = self.score(&pop, model, charge_query);
        }

        // Final: predicted top-k, unseen only.
        let mut order: Vec<usize> = (0..pop.len()).collect();
        order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
        let mut out = Vec::with_capacity(k);
        for &i in &order {
            if out.len() >= k {
                break;
            }
            if !seen(&pop[i]) && !out.contains(&pop[i]) {
                out.push(pop[i]);
            }
        }
        // Top off with random unseen if the population was exhausted.
        let mut attempts = 0;
        while out.len() < k && attempts < 64 * k.max(4) {
            let s = self.generator.sample(rng);
            if !seen(&s) && !out.contains(&s) {
                out.push(s);
            }
            attempts += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::{layout, CostModel, Mask, RustBackend};
    use crate::program::SubgraphKind;
    use std::sync::Arc;

    fn task() -> Subgraph {
        Subgraph::new(
            "evo.conv",
            SubgraphKind::Conv2d {
                n: 1, h: 28, w: 28, cin: 128, cout: 128, kh: 3, kw: 3, stride: 1, pad: 1,
            },
        )
    }

    fn model(seed: u64) -> CostModel {
        CostModel::new(
            Arc::new(RustBackend { pred_batch: 64, train_batch: 64 }),
            &mut Rng::new(seed),
        )
    }

    #[test]
    fn proposes_k_valid_unseen() {
        let mut es = EvolutionarySearch::new(task());
        es.population = 32;
        es.generations = 2;
        let m = model(1);
        let mut rng = Rng::new(2);
        let mut queries = 0;
        let out = es.propose(8, &m.predictor(), &|_| false, &mut rng, &mut || queries += 1);
        assert_eq!(out.len(), 8);
        assert!(queries >= 3, "expected >=3 scoring passes, got {queries}");
        let g = es.subgraph.geometry();
        for s in &out {
            assert!(s.is_valid(&g));
        }
    }

    #[test]
    fn search_finds_higher_scoring_configs_than_random() {
        // Train a model toward a synthetic preference (high tx), then
        // check evolution maximizes it better than random sampling.
        let mut es = EvolutionarySearch::new(task());
        es.population = 48;
        es.generations = 4;
        let mut m = model(3);
        let mut rng = Rng::new(4);
        // Synthetic labels: prefer larger block tiles.
        let gen = SpaceGenerator::new(task().geometry());
        let scheds = gen.sample_distinct(&mut rng, 64);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for s in &scheds {
            x.extend_from_slice(&featurize(&task(), s));
            y.push((s.threads_per_block() as f32 / 1024.0).min(1.0));
        }
        let mask = Mask::all_ones(layout::N_PARAMS);
        for _ in 0..30 {
            m.train_epoch(&x, &y, &mask, 1e-2, 0.0, &mut rng).unwrap();
        }
        let proposed = es.propose(8, &m.predictor(), &|_| false, &mut rng, &mut || {});
        let mean_prop: f64 = proposed.iter().map(|s| s.threads_per_block() as f64).sum::<f64>()
            / proposed.len() as f64;
        let random: Vec<Schedule> = gen.sample_distinct(&mut rng, 64);
        let mean_rand: f64 = random.iter().map(|s| s.threads_per_block() as f64).sum::<f64>()
            / random.len() as f64;
        assert!(
            mean_prop > mean_rand,
            "evolution {mean_prop} should beat random {mean_rand}"
        );
    }

    #[test]
    fn nan_predictions_do_not_panic_or_win() {
        // A diverged model (all-NaN params) emits NaN for every
        // schedule; propose must neither panic in the ranking sorts nor
        // hang, and still returns k candidates.
        let mut es = EvolutionarySearch::new(task());
        es.population = 16;
        es.generations = 2;
        let nan_model = CostModel::with_params(
            Arc::new(RustBackend { pred_batch: 64, train_batch: 64 }),
            vec![f32::NAN; layout::N_PARAMS],
        );
        let mut rng = Rng::new(6);
        let out = es.propose(4, &nan_model.predictor(), &|_| false, &mut rng, &mut || {});
        assert_eq!(out.len(), 4);
        let g = es.subgraph.geometry();
        assert!(out.iter().all(|s| s.is_valid(&g)));
    }

    #[test]
    fn tiny_schedule_space_terminates_with_duplicates() {
        // A 1x1x1 elementwise geometry has only a handful of distinct
        // valid schedules — far fewer than this population.  The fill
        // loops must accept duplicates past the attempt bound instead
        // of spinning forever.
        let tiny = Subgraph::new("tiny.elt", SubgraphKind::Elementwise { len: 1, ops: 1 });
        let mut es = EvolutionarySearch::new(tiny);
        es.population = 512;
        es.generations = 1;
        let m = model(7);
        let mut rng = Rng::new(8);
        let out = es.propose(4, &m.predictor(), &|_| false, &mut rng, &mut || {});
        assert!(!out.is_empty());
        let g = es.subgraph.geometry();
        assert!(out.iter().all(|s| s.is_valid(&g)));
    }

    #[test]
    fn seeds_survive_into_proposals() {
        let mut es = EvolutionarySearch::new(task());
        es.population = 16;
        es.generations = 1;
        let mut rng = Rng::new(5);
        let seed = es.generator.sample(&mut rng);
        es.add_seed(seed);
        assert_eq!(es.seeds.len(), 1);
        es.add_seed(seed); // dedup
        assert_eq!(es.seeds.len(), 1);
    }
}
