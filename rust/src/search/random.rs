//! Uniform-random search: the no-cost-model baseline proposal engine
//! (also used to seed the evolutionary population).

use super::{DraftGate, SearchPolicy};
use crate::costmodel::Predictor;
use crate::program::{Schedule, SpaceGenerator};
use crate::util::rng::Rng;

/// Proposes uniformly random unseen schedules.
pub struct RandomSearch {
    pub generator: SpaceGenerator,
}

impl RandomSearch {
    pub fn new(generator: SpaceGenerator) -> RandomSearch {
        RandomSearch { generator }
    }
}

impl SearchPolicy for RandomSearch {
    fn propose(
        &mut self,
        k: usize,
        _model: &Predictor,
        seen: &dyn Fn(&Schedule) -> bool,
        rng: &mut Rng,
        _draft: Option<&DraftGate<'_>>,
        _charge_query: &mut dyn FnMut(),
    ) -> Vec<Schedule> {
        let mut out: Vec<Schedule> = Vec::with_capacity(k);
        let mut attempts = 0;
        while out.len() < k && attempts < 128 * k.max(4) {
            let s = self.generator.sample(rng);
            if !seen(&s) && !out.contains(&s) {
                out.push(s);
            }
            attempts += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::{CostModel, Predictor, RustBackend};
    use crate::program::subgraph::Geometry;
    use std::sync::Arc;

    fn model() -> Predictor {
        CostModel::new(Arc::new(RustBackend { pred_batch: 8, train_batch: 8 }), &mut Rng::new(0))
            .predictor()
    }

    #[test]
    fn proposes_k_unseen() {
        let g = Geometry { x: 4096, y: 128, r: 256, mac: true };
        let mut rs = RandomSearch::new(SpaceGenerator::new(g));
        let mut rng = Rng::new(1);
        let mut charges = 0;
        let out = rs.propose(16, &model(), &|_| false, &mut rng, None, &mut || charges += 1);
        assert_eq!(out.len(), 16);
        assert_eq!(charges, 0); // random search never queries the model
    }

    #[test]
    fn respects_seen_filter() {
        let g = Geometry { x: 4096, y: 128, r: 256, mac: true };
        let gen = SpaceGenerator::new(g);
        let mut rng = Rng::new(2);
        let banned: Vec<Schedule> = gen.sample_distinct(&mut rng, 32);
        let mut rs = RandomSearch::new(gen);
        let out = rs.propose(
            8,
            &model(),
            &|s| banned.contains(s),
            &mut rng,
            None,
            &mut || {},
        );
        for s in &out {
            assert!(!banned.contains(s));
        }
    }
}
