//! Search policies: how candidate schedules are proposed each tuning
//! round (paper §2.2: "a batch of candidate programs are sampled by an
//! evolutionary search engine" guided by the cost model).

pub mod evolutionary;
pub mod random;

pub use evolutionary::EvolutionarySearch;
pub use random::RandomSearch;

use crate::costmodel::Predictor;
use crate::program::Schedule;
use crate::util::rng::Rng;

/// A search policy proposes the next batch of candidates for one task.
///
/// Policies are pure consumers of the prediction plane: they score
/// candidates against a read-only [`Predictor`] view (a pinned model
/// snapshot) and never observe — let alone cause — model mutation.
pub trait SearchPolicy {
    /// Propose up to `k` candidates, guided by `model` scores, avoiding
    /// fingerprints in `seen`.  `charge_query` is invoked once per
    /// cost-model batch query so the virtual clock sees search costs.
    fn propose(
        &mut self,
        k: usize,
        model: &Predictor,
        seen: &dyn Fn(&Schedule) -> bool,
        rng: &mut Rng,
        charge_query: &mut dyn FnMut(),
    ) -> Vec<Schedule>;
}
