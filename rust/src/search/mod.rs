//! Search policies: how candidate schedules are proposed each tuning
//! round (paper §2.2: "a batch of candidate programs are sampled by an
//! evolutionary search engine" guided by the cost model).
//!
//! Since the speculative-search PR, scoring is optionally two-tier
//! ([`draft`]): a cheap distilled [`DraftState`] ranks the whole
//! population and only a `draft_keep` shortlist is verified by the full
//! [`Predictor`].

pub mod draft;
pub mod evolutionary;
pub mod random;

pub use draft::{DraftGate, DraftState, DraftStats};
pub use evolutionary::EvolutionarySearch;
pub use random::RandomSearch;

use crate::costmodel::Predictor;
use crate::program::Schedule;
use crate::util::rng::Rng;

/// A search policy proposes the next batch of candidates for one task.
///
/// Policies are pure consumers of the prediction plane: they score
/// candidates against a read-only [`Predictor`] view (a pinned model
/// snapshot) and never observe — let alone cause — model mutation.
pub trait SearchPolicy {
    /// Propose up to `k` candidates, guided by `model` scores, avoiding
    /// fingerprints in `seen`.  When `draft` is armed, a policy may
    /// pre-rank candidates with the draft tier and only verify the
    /// shortlist against `model` (policies that never query the model
    /// ignore it).  `charge_query` is invoked once per *full-model*
    /// batch query so the virtual clock sees search costs; draft
    /// scoring is never charged.
    fn propose(
        &mut self,
        k: usize,
        model: &Predictor,
        seen: &dyn Fn(&Schedule) -> bool,
        rng: &mut Rng,
        draft: Option<&DraftGate<'_>>,
        charge_query: &mut dyn FnMut(),
    ) -> Vec<Schedule>;
}
