//! Tenset-style program-performance datasets (paper §2.3, §4.1).
//!
//! A dataset is a set of `(task, schedule, measured throughput)` records
//! collected offline on one device.  The paper pre-trains the source
//! cost model on Tenset (K80 slice) and contributes a generated dataset
//! for two embedded devices (TX2, Xavier); `moses dataset` reproduces
//! that generation against the simulator (scaled — DESIGN.md §2).

pub mod export;
pub mod gen;
pub mod io;

use crate::program::{featurize, Schedule, Subgraph, TensorProgram, N_FEATURES};

/// One measurement record.
#[derive(Debug, Clone)]
pub struct Record {
    /// Task (subgraph) this record belongs to, by index into
    /// [`Dataset::tasks`].
    pub task_idx: usize,
    /// Schedule knobs.
    pub knobs: [u32; 9],
    /// Measured throughput (GFLOP/s; 0 for failed configs).
    pub gflops: f64,
    /// Measured latency in seconds (INFINITY for failed configs).
    pub latency_s: f64,
}

/// A program-performance dataset for one device.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    /// Device name the labels were measured on.
    pub device: String,
    /// Task table.
    pub tasks: Vec<Subgraph>,
    /// Measurement records.
    pub records: Vec<Record>,
}

impl Dataset {
    pub fn new(device: &str) -> Dataset {
        Dataset { device: device.to_string(), tasks: Vec::new(), records: Vec::new() }
    }

    /// Add a task, returning its index (deduplicates by name).
    pub fn add_task(&mut self, task: Subgraph) -> usize {
        if let Some(i) = self.tasks.iter().position(|t| t.name == task.name) {
            return i;
        }
        self.tasks.push(task);
        self.tasks.len() - 1
    }

    pub fn push(&mut self, task_idx: usize, sched: &Schedule, gflops: f64, latency_s: f64) {
        debug_assert!(task_idx < self.tasks.len());
        self.records.push(Record { task_idx, knobs: sched.encode(), gflops, latency_s });
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Rebuild the TensorProgram of a record (features are recomputed,
    /// not stored — featurization is deterministic).
    pub fn program(&self, r: &Record) -> TensorProgram {
        TensorProgram::new(self.tasks[r.task_idx].clone(), Schedule::decode(&r.knobs))
    }

    /// Build training arrays over the whole dataset: features (row-major)
    /// and labels normalized **per task** to `[0, 1]` by the task's best
    /// throughput (Tenset/Ansor convention — the cost model learns
    /// relative ranking within a task, transferable across tasks).
    pub fn training_arrays(&self) -> (Vec<f32>, Vec<f32>) {
        let mut best_per_task = vec![0.0f64; self.tasks.len()];
        for r in &self.records {
            if r.gflops > best_per_task[r.task_idx] {
                best_per_task[r.task_idx] = r.gflops;
            }
        }
        let mut x = Vec::with_capacity(self.records.len() * N_FEATURES);
        let mut y = Vec::with_capacity(self.records.len());
        for r in &self.records {
            let feats = featurize(&self.tasks[r.task_idx], &Schedule::decode(&r.knobs));
            x.extend_from_slice(&feats);
            let denom = best_per_task[r.task_idx];
            y.push(if denom > 0.0 { (r.gflops / denom) as f32 } else { 0.0 });
        }
        (x, y)
    }

    /// Deterministic train/holdout split by record index hash.
    pub fn split(&self, holdout_fraction: f64) -> (Dataset, Dataset) {
        let mut train = Dataset { device: self.device.clone(), tasks: self.tasks.clone(), records: Vec::new() };
        let mut hold = train.clone();
        for (i, r) in self.records.iter().enumerate() {
            if crate::util::rng::hash_unit(i as u64 ^ 0xDA7A) < holdout_fraction {
                hold.records.push(r.clone());
            } else {
                train.records.push(r.clone());
            }
        }
        (train, hold)
    }

    /// Per-task record counts.
    pub fn counts_per_task(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.tasks.len()];
        for r in &self.records {
            counts[r.task_idx] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::program::{SpaceGenerator, SubgraphKind};
    use crate::util::rng::Rng;

    fn small_ds() -> Dataset {
        let mut ds = Dataset::new("testdev");
        let t = ds.add_task(Subgraph::new(
            "t0",
            SubgraphKind::Dense { m: 64, n: 64, k: 64 },
        ));
        let gen = SpaceGenerator::new(ds.tasks[t].geometry());
        let mut rng = Rng::new(1);
        for i in 0..20 {
            let s = gen.sample(&mut rng);
            ds.push(t, &s, 10.0 + i as f64, 1.0 / (10.0 + i as f64));
        }
        ds
    }

    #[test]
    fn add_task_dedups_by_name() {
        let mut ds = Dataset::new("d");
        let a = ds.add_task(Subgraph::new("x", SubgraphKind::Dense { m: 1, n: 1, k: 1 }));
        let b = ds.add_task(Subgraph::new("x", SubgraphKind::Dense { m: 2, n: 2, k: 2 }));
        assert_eq!(a, b);
        assert_eq!(ds.tasks.len(), 1);
    }

    #[test]
    fn training_arrays_normalized_per_task() {
        let ds = small_ds();
        let (x, y) = ds.training_arrays();
        assert_eq!(x.len(), ds.len() * N_FEATURES);
        assert_eq!(y.len(), ds.len());
        let max = y.iter().cloned().fold(0.0f32, f32::max);
        assert!((max - 1.0).abs() < 1e-6);
        assert!(y.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn split_partitions_all_records() {
        let ds = small_ds();
        let (train, hold) = ds.split(0.3);
        assert_eq!(train.len() + hold.len(), ds.len());
        assert!(!train.is_empty());
        // Deterministic.
        let (t2, h2) = ds.split(0.3);
        assert_eq!(train.len(), t2.len());
        assert_eq!(hold.len(), h2.len());
    }

    #[test]
    fn program_roundtrip() {
        let ds = small_ds();
        let p = ds.program(&ds.records[3]);
        assert_eq!(p.schedule.encode(), ds.records[3].knobs);
        assert_eq!(p.subgraph.name, "t0");
    }
}
