//! Binary (de)serialization of datasets (`.moses-ds` files).
//!
//! Little-endian, versioned-magic format; features are NOT stored (they
//! are a deterministic function of task + knobs and are recomputed on
//! load), which keeps a 60k-record dataset ≈ 3 MB instead of ≈ 45 MB.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Dataset, Record};
use crate::program::{Subgraph, SubgraphKind};

const MAGIC: &[u8; 8] = b"MOSESDS1";

struct Writer<W: Write> {
    w: W,
}

impl<W: Write> Writer<W> {
    fn u32(&mut self, v: u32) -> Result<()> {
        self.w.write_all(&v.to_le_bytes())?;
        Ok(())
    }

    fn u64(&mut self, v: u64) -> Result<()> {
        self.w.write_all(&v.to_le_bytes())?;
        Ok(())
    }

    fn f64(&mut self, v: f64) -> Result<()> {
        self.w.write_all(&v.to_le_bytes())?;
        Ok(())
    }

    fn str(&mut self, s: &str) -> Result<()> {
        self.u32(s.len() as u32)?;
        self.w.write_all(s.as_bytes())?;
        Ok(())
    }
}

struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    fn f64(&mut self) -> Result<f64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }

    fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        if len > 1 << 20 {
            bail!("string too long ({len})");
        }
        let mut b = vec![0u8; len];
        self.r.read_exact(&mut b)?;
        String::from_utf8(b).context("invalid utf-8 in dataset string")
    }
}

/// Save a dataset.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating {path:?}"))?;
    let mut w = Writer { w: std::io::BufWriter::new(file) };
    w.w.write_all(MAGIC)?;
    w.str(&ds.device)?;
    w.u32(ds.tasks.len() as u32)?;
    for t in &ds.tasks {
        w.str(&t.name)?;
        let (tag, params) = t.kind.encode_tagged();
        w.u32(tag as u32)?;
        w.u32(params.len() as u32)?;
        for p in params {
            w.u32(p)?;
        }
        w.u32(t.repeats as u32)?;
    }
    w.u64(ds.records.len() as u64)?;
    for r in &ds.records {
        w.u32(r.task_idx as u32)?;
        for k in r.knobs {
            w.u32(k)?;
        }
        w.f64(r.gflops)?;
        w.f64(r.latency_s)?;
    }
    Ok(())
}

/// Load a dataset.
pub fn load(path: &Path) -> Result<Dataset> {
    let file = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut r = Reader { r: std::io::BufReader::new(file) };
    let mut magic = [0u8; 8];
    r.r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?} is not a moses dataset (bad magic)");
    }
    let device = r.str()?;
    let n_tasks = r.u32()? as usize;
    let mut ds = Dataset::new(&device);
    for _ in 0..n_tasks {
        let name = r.str()?;
        let tag = r.u32()? as u8;
        let n_params = r.u32()? as usize;
        if n_params > 64 {
            bail!("implausible param count {n_params}");
        }
        let mut params = Vec::with_capacity(n_params);
        for _ in 0..n_params {
            params.push(r.u32()?);
        }
        let repeats = r.u32()? as usize;
        let kind = SubgraphKind::decode_tagged(tag, &params)
            .ok_or_else(|| anyhow::anyhow!("bad subgraph record (tag {tag})"))?;
        let mut sub = Subgraph::new(&name, kind);
        sub.repeats = repeats;
        ds.tasks.push(sub);
    }
    let n_records = r.u64()? as usize;
    ds.records.reserve(n_records);
    for _ in 0..n_records {
        let task_idx = r.u32()? as usize;
        if task_idx >= ds.tasks.len() {
            bail!("record references task {task_idx} >= {}", ds.tasks.len());
        }
        let mut knobs = [0u32; 9];
        for k in knobs.iter_mut() {
            *k = r.u32()?;
        }
        let gflops = r.f64()?;
        let latency_s = r.f64()?;
        ds.records.push(Record { task_idx, knobs, gflops, latency_s });
    }
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::gen::{generate, GenConfig, TaskSource};
    use crate::device::presets;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("moses_ds_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let cfg = GenConfig { records_per_task: 12, seed: 5 };
        let ds = generate(&presets::jetson_xavier(), TaskSource::Random { count: 6 }, &cfg);
        let path = tmp("roundtrip.moses-ds");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.device, ds.device);
        assert_eq!(back.tasks.len(), ds.tasks.len());
        for (a, b) in back.tasks.iter().zip(&ds.tasks) {
            assert_eq!(a, b);
        }
        assert_eq!(back.len(), ds.len());
        for (a, b) in back.records.iter().zip(&ds.records) {
            assert_eq!(a.task_idx, b.task_idx);
            assert_eq!(a.knobs, b.knobs);
            assert_eq!(a.gflops, b.gflops);
            assert!(
                a.latency_s == b.latency_s
                    || (a.latency_s.is_infinite() && b.latency_s.is_infinite())
            );
        }
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.moses-ds");
        std::fs::write(&path, b"not a dataset at all").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn training_arrays_survive_roundtrip() {
        let cfg = GenConfig { records_per_task: 8, seed: 2 };
        let ds = generate(&presets::tesla_k80(), TaskSource::Random { count: 3 }, &cfg);
        let path = tmp("arrays.moses-ds");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(ds.training_arrays().0, back.training_arrays().0);
        assert_eq!(ds.training_arrays().1, back.training_arrays().1);
    }
}
