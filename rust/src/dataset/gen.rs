//! Dataset generation against the device simulator — reproduces both the
//! Tenset source corpus (K80) and the paper's §4.1 embedded-device
//! dataset (TX2 + Xavier, "tasks from over 50 DNN models").

use super::Dataset;
use crate::device::{DeviceArch, DeviceSim};
use crate::models::zoo;
use crate::program::{SpaceGenerator, Subgraph, SubgraphKind, TensorProgram};
use crate::util::rng::Rng;

/// Task source for dataset generation.
pub enum TaskSource {
    /// The evaluation zoo (resnet18, mobilenet, squeezenet, bert,
    /// mobilevit).
    Zoo,
    /// Randomly sampled realistic shapes ("over 50 DNN models" stand-in).
    Random { count: usize },
    /// Explicit task list.
    Tasks(Vec<Subgraph>),
}

/// Sample a realistic random subgraph (shape ranges cover common CNN /
/// transformer layers).
pub fn random_task(rng: &mut Rng, idx: usize) -> Subgraph {
    let pow2 = |rng: &mut Rng, lo: u32, hi: u32| 1usize << (lo + rng.below((hi - lo + 1) as usize) as u32);
    let kind = match rng.below(6) {
        0 | 1 => {
            let h = [7, 14, 28, 56, 112, 224][rng.below(6)];
            SubgraphKind::Conv2d {
                n: 1,
                h,
                w: h,
                cin: pow2(rng, 3, 9),
                cout: pow2(rng, 4, 9),
                kh: [1, 3, 5][rng.below(3)],
                kw: [1, 3, 5][rng.below(3)],
                stride: rng.below(2) + 1,
                pad: rng.below(3),
            }
        }
        2 => {
            let h = [7, 14, 28, 56, 112][rng.below(5)];
            SubgraphKind::DepthwiseConv2d {
                n: 1,
                h,
                w: h,
                c: pow2(rng, 4, 10),
                kh: 3,
                kw: 3,
                stride: rng.below(2) + 1,
                pad: 1,
            }
        }
        3 => SubgraphKind::Dense {
            m: pow2(rng, 0, 9),
            n: pow2(rng, 5, 12),
            k: pow2(rng, 5, 12),
        },
        4 => SubgraphKind::BatchMatmul {
            b: pow2(rng, 0, 5),
            m: pow2(rng, 4, 9),
            n: pow2(rng, 4, 9),
            k: pow2(rng, 4, 8),
        },
        _ => {
            let h = [14, 28, 56, 112][rng.below(4)];
            SubgraphKind::Pool2d { n: 1, h, w: h, c: pow2(rng, 4, 9), k: 3, stride: 2 }
        }
    };
    Subgraph::new(&format!("rand{idx}.{}", kind.tag()), kind)
}

/// Generation configuration.
pub struct GenConfig {
    pub records_per_task: usize,
    pub seed: u64,
}

impl Default for GenConfig {
    fn default() -> Self {
        GenConfig { records_per_task: 128, seed: 0 }
    }
}

/// Generate a dataset for `device` from `source` tasks: sample schedules
/// uniformly, "measure" each on the simulator (noisy), record
/// throughput.  Failed configs are kept with gflops 0 — the cost model
/// must learn to rank them last, like real Tenset records with errors.
pub fn generate(device: &DeviceArch, source: TaskSource, cfg: &GenConfig) -> Dataset {
    let mut rng = Rng::new(cfg.seed ^ crate::util::rng::hash_bytes(device.name.as_bytes()));
    let sim = DeviceSim::new(device.clone());
    let tasks: Vec<Subgraph> = match source {
        TaskSource::Zoo => zoo::all().into_iter().flat_map(|m| m.tasks()).collect(),
        TaskSource::Random { count } => {
            (0..count).map(|i| random_task(&mut rng, i)).collect()
        }
        TaskSource::Tasks(ts) => ts,
    };
    let mut ds = Dataset::new(&device.name);
    for task in tasks {
        let idx = ds.add_task(task.clone());
        let gen = SpaceGenerator::new(task.geometry());
        let mut task_rng = rng.fork(idx as u64);
        let schedules = gen.sample_distinct(&mut task_rng, cfg.records_per_task);
        for s in schedules {
            let prog = TensorProgram::new(task.clone(), s);
            let m = sim.measure(&prog, &mut task_rng);
            let (gflops, lat) =
                if m.ok { (m.gflops, m.latency_s) } else { (0.0, f64::INFINITY) };
            ds.push(idx, &s, gflops, lat);
        }
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;

    #[test]
    fn generates_requested_volume() {
        let cfg = GenConfig { records_per_task: 16, seed: 1 };
        let ds = generate(&presets::tesla_k80(), TaskSource::Random { count: 5 }, &cfg);
        assert_eq!(ds.tasks.len(), 5);
        assert_eq!(ds.len(), 5 * 16);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = GenConfig { records_per_task: 8, seed: 7 };
        let a = generate(&presets::jetson_tx2(), TaskSource::Random { count: 3 }, &cfg);
        let b = generate(&presets::jetson_tx2(), TaskSource::Random { count: 3 }, &cfg);
        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.knobs, rb.knobs);
            assert_eq!(ra.gflops, rb.gflops);
        }
    }

    #[test]
    fn different_devices_have_different_labels() {
        let cfg = GenConfig { records_per_task: 16, seed: 3 };
        let tasks: Vec<Subgraph> = (0..3).map(|i| random_task(&mut Rng::new(9), i)).collect();
        let a = generate(&presets::tesla_k80(), TaskSource::Tasks(tasks.clone()), &cfg);
        let b = generate(&presets::rtx_2060(), TaskSource::Tasks(tasks), &cfg);
        // Same schedules (same seed derivation differs by device hash) —
        // compare label distributions instead: means should differ.
        let mean = |ds: &Dataset| {
            ds.records.iter().map(|r| r.gflops).sum::<f64>() / ds.len() as f64
        };
        assert!((mean(&a) - mean(&b)).abs() > 1e-3);
    }

    #[test]
    fn zoo_source_covers_all_models() {
        let cfg = GenConfig { records_per_task: 2, seed: 0 };
        let ds = generate(&presets::rtx_2060(), TaskSource::Zoo, &cfg);
        let names: Vec<&str> = ds.tasks.iter().map(|t| t.name.as_str()).collect();
        for prefix in ["resnet18.", "mobilenet.", "squeezenet.", "bert.", "mobilevit."] {
            assert!(names.iter().any(|n| n.starts_with(prefix)), "{prefix}");
        }
    }

    #[test]
    fn some_failures_recorded_as_zero() {
        let cfg = GenConfig { records_per_task: 256, seed: 11 };
        let ds = generate(&presets::jetson_tx2(), TaskSource::Random { count: 4 }, &cfg);
        // Uniform sampling over the space should hit at least one
        // unrunnable config (shared-mem oversubscription etc.).
        let failures = ds.records.iter().filter(|r| r.gflops == 0.0).count();
        let successes = ds.len() - failures;
        assert!(successes > ds.len() / 2, "too many failures: {failures}/{}", ds.len());
    }
}
