//! Export tuning history as pretraining corpora.
//!
//! `tunecache` records are exactly the `(task, schedule, latency)`
//! triples the cost model pretrains on — except measured on *real*
//! tuning trajectories instead of uniform random sampling, so the
//! label distribution concentrates where search actually goes.  This
//! module groups a record dump by measuring device and rebuilds one
//! [`Dataset`] per device, ready for [`super::io`] and the standard
//! pretraining path (`moses pretrain` / `experiments::pretrain_on`).
//!
//! Only records that carry their concrete task payload can be exported
//! (the workload hash is one-way); records from a different
//! featurizer/simulator version, or whose schedule no longer validates
//! against the task geometry, are skipped and counted.

// Outside the deterministic planes (detlint [rules.unordered-collections]):
// the HashMap is a per-device dedup index; corpus order comes from the
// BTreeMap walk and record order, never from hash iteration.
#![allow(clippy::disallowed_types)]

use std::collections::{BTreeMap, HashMap};

use crate::program::Schedule;
use crate::tunecache::{TuneRecord, RECORD_VERSION};

use super::Dataset;

/// Outcome of an export: one dataset per device plus skip accounting.
#[derive(Debug, Default)]
pub struct ExportReport {
    /// One dataset per measuring device, sorted by device name.
    pub datasets: Vec<Dataset>,
    /// Records exported as dataset rows.
    pub exported: usize,
    /// Records stamped by a different featurizer/simulator version.
    pub skipped_stale: usize,
    /// Records without a task payload (pre-v3 log lines).
    pub skipped_no_task: usize,
    /// Records whose schedule/latency no longer validates.
    pub skipped_invalid: usize,
}

/// Convert tuning records into per-device datasets.
pub fn from_records(records: &[TuneRecord]) -> ExportReport {
    // Tasks must be keyed by WORKLOAD, not name: `Dataset::add_task`
    // dedups by name alone, and two models may reuse a task name for
    // different shapes — their records must not be featurized against
    // the first shape's geometry.  Same-named distinct workloads get a
    // hash-suffixed unique name instead.
    let mut by_device: BTreeMap<String, (Dataset, HashMap<u64, usize>)> = BTreeMap::new();
    let mut report = ExportReport::default();
    for r in records {
        if r.version != RECORD_VERSION {
            report.skipped_stale += 1;
            continue;
        }
        let Some(task) = &r.task else {
            report.skipped_no_task += 1;
            continue;
        };
        let sched = Schedule::decode(&r.knobs);
        if !sched.is_valid(&task.geometry()) || !r.latency_s.is_finite() || r.latency_s <= 0.0 {
            report.skipped_invalid += 1;
            continue;
        }
        let (ds, task_idx_by_workload) = by_device
            .entry(r.device_name.clone())
            .or_insert_with(|| (Dataset::new(&r.device_name), HashMap::new()));
        let idx = match task_idx_by_workload.get(&r.workload) {
            Some(&idx) => idx,
            None => {
                let mut unique = task.clone();
                if ds.tasks.iter().any(|t| t.name == unique.name) {
                    unique.name = format!("{}#{:016x}", task.name, r.workload);
                }
                let idx = ds.add_task(unique);
                task_idx_by_workload.insert(r.workload, idx);
                idx
            }
        };
        ds.push(idx, &sched, r.gflops, r.latency_s);
        report.exported += 1;
    }
    report.datasets = by_device.into_values().map(|(ds, _)| ds).collect();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::presets;
    use crate::program::{SpaceGenerator, Subgraph, SubgraphKind};
    use crate::tunecache::WorkloadKey;
    use crate::util::rng::Rng;

    fn task(name: &str, cout: usize) -> Subgraph {
        Subgraph::new(
            name,
            SubgraphKind::Conv2d {
                n: 1, h: 28, w: 28, cin: 64, cout, kh: 3, kw: 3, stride: 1, pad: 1,
            },
        )
    }

    fn rec(t: &Subgraph, device: &str, lat: f64, with_task: bool) -> TuneRecord {
        let arch = presets::by_name(device).unwrap();
        let key = WorkloadKey::new(t, &arch);
        let mut rng = Rng::new(7);
        let sched = SpaceGenerator::new(t.geometry()).sample(&mut rng);
        let r = TuneRecord::new(key, t.descriptor(), &arch.name, &sched, lat, 10.0, 64);
        if with_task {
            r.with_task(t)
        } else {
            r
        }
    }

    #[test]
    fn groups_by_device_and_counts_skips() {
        let a = task("ex.a", 64);
        let b = task("ex.b", 96);
        let mut records = vec![
            rec(&a, "tx2", 1e-3, true),
            rec(&b, "tx2", 2e-3, true),
            rec(&a, "rtx2060", 3e-4, true),
            rec(&a, "tx2", 1e-3, false), // pre-v3: no task payload
        ];
        let mut stale = rec(&b, "tx2", 2e-3, true);
        stale.version = 0;
        records.push(stale);

        let report = from_records(&records);
        assert_eq!(report.exported, 3);
        assert_eq!(report.skipped_no_task, 1);
        assert_eq!(report.skipped_stale, 1);
        assert_eq!(report.skipped_invalid, 0);
        assert_eq!(report.datasets.len(), 2);
        let tx2 = report.datasets.iter().find(|d| d.device == "tx2").unwrap();
        assert_eq!(tx2.tasks.len(), 2);
        assert_eq!(tx2.len(), 2);
        let r2060 = report.datasets.iter().find(|d| d.device == "rtx2060").unwrap();
        assert_eq!(r2060.len(), 1);
        // The rebuilt datasets are directly trainable.
        let (x, y) = tx2.training_arrays();
        assert_eq!(y.len(), 2);
        assert_eq!(x.len(), 2 * crate::program::N_FEATURES);
    }

    #[test]
    fn same_named_distinct_workloads_keep_their_own_geometry() {
        // Two models reusing the task name "conv" for different shapes:
        // the narrow one's records must not be featurized against the
        // wide one's geometry.
        let wide = task("conv", 96);
        let narrow = task("conv", 32);
        let report = from_records(&[
            rec(&wide, "tx2", 1e-3, true),
            rec(&narrow, "tx2", 2e-3, true),
        ]);
        assert_eq!(report.exported, 2);
        let ds = &report.datasets[0];
        assert_eq!(ds.tasks.len(), 2, "distinct workloads need distinct task slots");
        assert_ne!(ds.tasks[0].kind, ds.tasks[1].kind);
        for r in &ds.records {
            let t = &ds.tasks[r.task_idx];
            assert!(
                Schedule::decode(&r.knobs).is_valid(&t.geometry()),
                "record attributed to the wrong geometry"
            );
        }
    }

    #[test]
    fn invalid_schedules_and_latencies_are_skipped() {
        let t = task("ex.c", 64);
        let mut bad_lat = rec(&t, "tx2", f64::INFINITY, true);
        bad_lat.latency_s = f64::INFINITY;
        let mut bad_knobs = rec(&t, "tx2", 1e-3, true);
        bad_knobs.knobs = [0; 9]; // zero tiles never validate
        let report = from_records(&[bad_lat, bad_knobs]);
        assert_eq!(report.exported, 0);
        assert_eq!(report.skipped_invalid, 2);
        assert!(report.datasets.is_empty());
    }
}
