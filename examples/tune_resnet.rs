//! End-to-end driver (DESIGN.md §4): the full Moses pipeline on a real
//! small workload — ResNet-18, K80 → TX2 — reporting the paper's
//! headline metrics and the convergence log.
//!
//! Pipeline exercised: dataset generation (simulated K80 corpus) →
//! offline pre-training via the AOT Pallas/JAX artifacts on PJRT →
//! cross-device transfer → per-task evolutionary search with
//! lottery-ticket masked adaptation + AC early termination → end-to-end
//! latency & search-efficiency report vs. the Tenset-Finetune baseline.
//!
//! ```bash
//! make artifacts && cargo run --release --example tune_resnet
//! ```

use moses::metrics::{self, experiments::{self, ExpConfig}};
use moses::device::presets;
use moses::transfer::{MosesConfig, Strategy};
use moses::util::table::Table;

fn main() -> anyhow::Result<()> {
    let cfg = ExpConfig::default();
    let target = presets::jetson_tx2();
    let trials = 48;

    println!("== Moses end-to-end: ResNet-18, K80 -> TX2 ==\n");
    println!("[1/3] source cost model (simulated K80 Tenset corpus, AOT/PJRT training)");
    #[allow(clippy::disallowed_methods)] // example-driver timing only
    let t0 = std::time::Instant::now();
    let pretrained = experiments::pretrained_source_checkpoint(&cfg)?;
    println!("      ready in {:.1}s (cached across runs)\n", t0.elapsed().as_secs_f64());

    println!("[2/3] tuning with Tenset-Finetune (baseline) ...");
    let tf = experiments::run_session(
        &cfg, &pretrained, "resnet18", &target, Strategy::TensetFinetune, trials,
    )?;
    println!("[3/3] tuning with Moses ...");
    let mo = experiments::run_session(
        &cfg,
        &pretrained,
        "resnet18",
        &target,
        Strategy::Moses(MosesConfig::default()),
        trials,
    )?;

    let mut t = Table::new(
        "ResNet-18 on TX2 (paper headline metrics)",
        &["metric", "tenset-finetune", "moses", "moses gain"],
    );
    t.row(vec![
        "end-to-end latency (ms)".into(),
        format!("{:.3}", tf.total_best_latency_ms()),
        format!("{:.3}", mo.total_best_latency_ms()),
        format!(
            "{:.2}x",
            metrics::latency_reduction(tf.total_best_latency_ms(), mo.total_best_latency_ms())
        ),
    ]);
    t.row(vec![
        "virtual search time (s)".into(),
        format!("{:.0}", tf.search_time_s()),
        format!("{:.0}", mo.search_time_s()),
        format!("{:.2}x", metrics::search_gain(tf.search_time_s(), mo.search_time_s())),
    ]);
    t.row(vec![
        "on-device measurements".into(),
        tf.total_measurements().to_string(),
        mo.total_measurements().to_string(),
        String::new(),
    ]);
    let cmat = metrics::cmat(
        metrics::search_gain(tf.search_time_s(), mo.search_time_s()),
        metrics::latency_reduction(tf.total_best_latency_ms(), mo.total_best_latency_ms()),
    );
    t.row(vec!["CMAT (%)".into(), String::new(), format!("{cmat:.1}"), String::new()]);
    t.print();

    // Convergence curves (best-so-far per round) for the 3 biggest tasks.
    println!("convergence (best-so-far latency per round, ms):");
    let mut tasks: Vec<_> = mo.tasks.iter().collect();
    tasks.sort_by(|a, b| b.task.flops().partial_cmp(&a.task.flops()).unwrap());
    for r in tasks.iter().take(3) {
        let curve: Vec<String> =
            r.history.iter().map(|l| format!("{:.3}", l * 1e3)).collect();
        println!("  {:<28} {}", r.task.name, curve.join(" -> "));
    }
    println!("\nspeedup over untuned default schedules: {:.2}x", mo.speedup());
    Ok(())
}
