//! Cross-device comparison: SqueezeNet, K80 → RTX 2060, all four
//! strategies side by side (the Fig. 4 / Fig. 5 view for one cell).
//!
//! ```bash
//! make artifacts && cargo run --release --example cross_device
//! ```

use moses::device::presets;
use moses::metrics::experiments::{self, ExpConfig};
use moses::util::table::{pct_gain, Table};

fn main() -> anyhow::Result<()> {
    let cfg = ExpConfig::default();
    let target = presets::rtx_2060();
    let trials = 48;

    println!("== SqueezeNet, K80 -> RTX 2060, all strategies ==\n");
    let pretrained = experiments::pretrained_source_checkpoint(&cfg)?;

    let mut rows = Vec::new();
    for strategy in experiments::eval_strategies() {
        println!("tuning with {} ...", strategy.name());
        let s = experiments::run_session(
            &cfg, &pretrained, "squeezenet", &target, strategy.clone(), trials,
        )?;
        rows.push((strategy.name().to_string(), s));
    }

    let raw_ms = rows[0].1.total_default_latency_ms();
    let mut t = Table::new(
        "SqueezeNet on RTX 2060",
        &["strategy", "latency ms", "vs raw", "search s", "measurements"],
    );
    t.row(vec!["raw (no tuning)".into(), format!("{raw_ms:.3}"), "-".into(), "0".into(), "0".into()]);
    for (name, s) in &rows {
        t.row(vec![
            name.clone(),
            format!("{:.3}", s.total_best_latency_ms()),
            pct_gain(raw_ms / s.total_best_latency_ms()),
            format!("{:.0}", s.search_time_s()),
            s.total_measurements().to_string(),
        ]);
    }
    t.print();

    let finetune = rows.iter().find(|(n, _)| n == "tenset-finetune").unwrap();
    let moses_row = rows.iter().find(|(n, _)| n == "moses").unwrap();
    println!(
        "Moses vs Tenset-Finetune: {} latency, {} search efficiency",
        pct_gain(finetune.1.total_best_latency_ms() / moses_row.1.total_best_latency_ms()),
        pct_gain(finetune.1.search_time_s() / moses_row.1.search_time_s()),
    );
    Ok(())
}
