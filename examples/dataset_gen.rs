//! Reproduce the paper's §4.1 contribution: a program-performance
//! dataset for two embedded devices (Jetson TX2 + AGX Xavier), scaled to
//! run in seconds (DESIGN.md §2 records the scaling).
//!
//! ```bash
//! cargo run --release --example dataset_gen
//! ```

use moses::dataset::gen::{generate, GenConfig, TaskSource};
use moses::dataset::io;
use moses::device::presets;
use moses::util::stats::Summary;
use moses::util::table::Table;

fn main() -> anyhow::Result<()> {
    let out_dir = std::path::PathBuf::from("artifacts");
    std::fs::create_dir_all(&out_dir)?;
    let cfg = GenConfig { records_per_task: 160, seed: 0 };

    let mut t = Table::new(
        "Embedded-device dataset (paper §4.1, scaled)",
        &["device", "tasks", "records", "failed %", "median GFLOP/s", "file"],
    );
    for device in [presets::jetson_tx2(), presets::jetson_xavier()] {
        // "tasks from over 50 DNN models": zoo + 50 random realistic tasks.
        let mut ds = generate(&device, TaskSource::Random { count: 50 }, &cfg);
        let zoo_ds = generate(&device, TaskSource::Zoo, &cfg);
        for r in &zoo_ds.records {
            let idx = ds.add_task(zoo_ds.tasks[r.task_idx].clone());
            let sched = moses::program::Schedule::decode(&r.knobs);
            ds.push(idx, &sched, r.gflops, r.latency_s);
        }
        let path = out_dir.join(format!("{}.moses-ds", device.name));
        io::save(&ds, &path)?;

        let ok: Vec<f64> =
            ds.records.iter().filter(|r| r.gflops > 0.0).map(|r| r.gflops).collect();
        let failed = ds.len() - ok.len();
        let mut sorted = ok.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if sorted.is_empty() { 0.0 } else { sorted[sorted.len() / 2] };
        t.row(vec![
            device.name.clone(),
            ds.tasks.len().to_string(),
            ds.len().to_string(),
            format!("{:.1}", failed as f64 / ds.len() as f64 * 100.0),
            format!("{median:.1}"),
            path.display().to_string(),
        ]);
        // Round-trip check.
        let back = io::load(&path)?;
        assert_eq!(back.len(), ds.len());
        let s = Summary::of(&ok);
        println!(
            "{}: throughput mean {:.1} GFLOP/s (min {:.2}, max {:.1})",
            device.name, s.mean, s.min, s.max
        );
    }
    t.print();
    Ok(())
}
