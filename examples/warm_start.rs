//! Warm start from the tune cache: repeating a workload on the same
//! device costs ZERO measured trials, and a new device's search starts
//! from the schedules other devices already found — schedule-level
//! transfer beside the paper's parameter-level transfer.
//!
//! ```bash
//! cargo run --release --example warm_start
//! ```

use std::sync::Arc;

use moses::coordinator::{AutoTuner, BackendKind, Session, TuneConfig};
use moses::device::{presets, DeviceArch};
use moses::models::zoo;
use moses::transfer::Strategy;
use moses::tunecache::TuneCache;
use moses::util::table::Table;

fn cfg(seed: u64) -> TuneConfig {
    TuneConfig {
        trials_per_task: 24,
        measure_batch: 4,
        strategy: Strategy::AnsorRandom,
        population: 32,
        generations: 2,
        backend: BackendKind::Rust,
        seed,
        ..TuneConfig::default()
    }
}

/// Total bytes across a cache directory's log files.
fn dir_bytes(dir: &std::path::Path) -> u64 {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.flatten()
                .filter_map(|e| e.metadata().ok())
                .map(|m| m.len())
                .sum()
        })
        .unwrap_or(0)
}

fn main() -> anyhow::Result<()> {
    let tasks = zoo::squeezenet().tasks()[..4].to_vec();
    // A cache *directory*: multiple concurrent tuner processes could
    // share it, each appending to its own segment.
    let path = std::env::temp_dir().join("moses_warm_start_cache");
    let _ = std::fs::remove_dir_all(&path);
    let cache = Arc::new(TuneCache::open(&path, 8)?);

    let mut table = Table::new(
        "Warm start on 4 SqueezeNet tasks",
        &[
            "run", "device", "measured", "cache hits", "seeded tasks", "nn-seeded",
            "latency ms", "search s",
        ],
    );
    let mut run = |label: &str, device: DeviceArch, seed: u64| -> anyhow::Result<Session> {
        let mut tuner =
            AutoTuner::builder(device).config(&cfg(seed)).cache(cache.clone()).build()?;
        let s = tuner.tune(&tasks)?;
        table.row(vec![
            label.to_string(),
            s.device.clone(),
            s.total_measurements().to_string(),
            s.cache_hits().to_string(),
            s.warm_seeded_tasks().to_string(),
            s.neighbor_seeded_tasks().to_string(),
            format!("{:.3}", s.total_best_latency_ms()),
            format!("{:.0}", s.search_time_s()),
        ]);
        Ok(s)
    };

    let _cold = run("cold", presets::rtx_2060(), 1)?;
    let repeat = run("repeat (same device)", presets::rtx_2060(), 2)?;
    let cross = run("cross-device", presets::jetson_tx2(), 3)?;
    drop(run);
    table.print();

    assert_eq!(repeat.total_measurements(), 0, "repeat run must be measurement-free");
    assert!(cross.warm_seeded_tasks() > 0, "cross-device run must be seeded");

    // The same trial budget WITHOUT the cache, for comparison.  (The
    // seeded run additionally spends up to `seed_probe` measurements
    // per task verifying cross-device seeds — the measurement counts
    // below make that visible.)
    let mut unseeded = AutoTuner::builder(presets::jetson_tx2()).config(&cfg(3)).build()?;
    let cold_tx2 = unseeded.tune(&tasks)?;
    println!(
        "\ntx2 seeded  : {:.3} ms after {:.0} virtual s ({} measurements, incl. seed probes)\n\
         tx2 unseeded: {:.3} ms after {:.0} virtual s ({} measurements)",
        cross.total_best_latency_ms(),
        cross.search_time_s(),
        cross.total_measurements(),
        cold_tx2.total_best_latency_ms(),
        cold_tx2.search_time_s(),
        cold_tx2.total_measurements(),
    );

    let s = cache.stats();
    let size = dir_bytes(&path);
    println!(
        "\ncache: {} hits / {} misses, {} cross-device seeds, {} neighbor seeds, \
         {} commits; {} live records, {size} bytes on disk",
        s.hits, s.misses, s.cross_device_seeds, s.neighbor_seeds, s.commits,
        cache.total_records(),
    );
    cache.compact()?;
    println!("after compaction: {} bytes", dir_bytes(&path));
    Ok(())
}
