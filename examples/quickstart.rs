//! Quickstart: tune one convolution task on a simulated Jetson TX2 with
//! Moses and print what happened.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use moses::coordinator::{AutoTuner, BackendKind, TuneConfig};
use moses::device::presets;
use moses::metrics::experiments::{pretrained_source_checkpoint, ExpConfig};
use moses::program::{Subgraph, SubgraphKind};
use moses::transfer::{MosesConfig, Strategy};

fn main() -> anyhow::Result<()> {
    // The paper's Fig. 1 running example: Conv2d(3→64, k3, s1).
    let task = Subgraph::new(
        "quickstart.conv",
        SubgraphKind::Conv2d {
            n: 1,
            h: 224,
            w: 224,
            cin: 3,
            cout: 64,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 0,
        },
    );

    // 1. Pre-train (or load the cached) source cost model on the
    //    simulated K80 — paper §3.6 Step 1.
    let exp = ExpConfig::default();
    println!("loading/pre-training the K80 source cost model ...");
    let pretrained = pretrained_source_checkpoint(&exp)?;

    // 2. Transfer to the target (TX2) and tune with Moses — Steps 2-4.
    let cfg = TuneConfig {
        trials_per_task: 64,
        strategy: Strategy::Moses(MosesConfig::default()),
        backend: BackendKind::auto(),
        ..TuneConfig::default()
    };
    let model = moses::costmodel::CostModel::with_params(exp.backend_arc()?, pretrained);
    let mut tuner = AutoTuner::builder(presets::jetson_tx2()).config(&cfg).model(model).build()?;
    let session = tuner.tune(&[task])?;

    let r = &session.tasks[0];
    println!("\ntask           : {}", r.task.name);
    println!("default latency: {:.3} ms", r.default_latency_s * 1e3);
    println!("tuned latency  : {:.3} ms  ({:.2}x speedup)", r.best_latency_s * 1e3, r.speedup());
    println!("best schedule  : {:?}", r.best_schedule);
    println!(
        "measurements   : {} on-device, {} prediction-only trials",
        r.measured, r.predicted_only
    );
    println!("virtual search : {:.0} s", session.search_time_s());
    Ok(())
}
